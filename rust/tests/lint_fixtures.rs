//! Golden tests for the lint rules over the seeded corpus in
//! `tests/lint_fixtures/` — every rule must catch its seeded violation at
//! the exact file:line, well-formed suppressions must silence theirs, and
//! malformed suppressions must themselves be findings (and suppress
//! nothing). The corpus replicates the source layout (`serve/`, `fleet/`,
//! `sim/`, `telemetry/`, `util/`) so path scoping is exercised too; the engine's
//! directory walker skips `lint_fixtures/` during normal descent, which is
//! why `cargo test lint_clean` and this file can coexist.

use medea::analysis::{findings_to_json, lint_paths, lint_source};
use std::path::PathBuf;

/// Findings over the corpus, reduced to (path-inside-corpus, line, rule).
fn fixture_findings() -> Vec<(String, usize, &'static str)> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures");
    lint_paths(&[dir])
        .expect("walking tests/lint_fixtures")
        .into_iter()
        .map(|f| {
            let pos = f.file.rfind("lint_fixtures/").expect("fixture display path");
            let rel = f.file[pos + "lint_fixtures/".len()..].to_string();
            (rel, f.line, f.rule)
        })
        .collect()
}

#[test]
fn every_rule_catches_its_seeded_fixture_at_the_exact_line() {
    let got = fixture_findings();
    let want: Vec<(String, usize, &'static str)> = [
        ("fleet/pool.rs", 20, "lock-discipline"),
        ("serve/pool.rs", 5, "no-unwrap"),
        ("serve/pool.rs", 6, "sleep-under-lock"),
        ("serve/pool.rs", 7, "lock-discipline"),
        ("serve/pool.rs", 7, "no-unwrap"),
        ("sim/engine.rs", 4, "no-wall-clock"),
        ("sim/engine.rs", 5, "no-wall-clock"),
        ("telemetry/hist.rs", 5, "ordering-comment"),
        ("telemetry/hist.rs", 8, "ordering-comment"),
        ("telemetry/hist.rs", 13, "ordering-comment"),
        ("telemetry/hist.rs", 16, "bad-suppression"),
        ("telemetry/hist.rs", 19, "bad-suppression"),
        ("telemetry/hist.rs", 21, "ordering-comment"),
        ("util/misc.rs", 5, "no-partial-cmp"),
    ]
    .into_iter()
    .map(|(f, l, r)| (f.to_string(), l, r))
    .collect();
    assert_eq!(got, want);
}

#[test]
fn well_formed_suppressions_silence_their_rule() {
    // The corpus seeds suppressed twins next to each flagged site; none of
    // those lines may appear among the findings.
    let got = fixture_findings();
    let suppressed = [
        ("serve/pool.rs", 14),     // lock().expect under allow(no-unwrap)
        ("serve/pool.rs", 17),     // nested lock + unwrap, both allowed
        ("sim/engine.rs", 10),     // Instant::now under allow(no-wall-clock)
        ("telemetry/hist.rs", 26), // SeqCst under allow(ordering-comment)
        // fleet/pool.rs:8-14 is the *compliant* gate-split sequence (drop
        // the admission guard, then take the gate to ring): no suppression
        // needed, and no finding may fire on it.
        ("fleet/pool.rs", 11),
    ];
    for (file, line) in suppressed {
        assert!(
            !got.iter().any(|(f, l, _)| f == file && *l == line),
            "{file}:{line} should be suppressed, got {got:?}"
        );
    }
}

#[test]
fn suppression_without_reason_is_a_finding_and_suppresses_nothing() {
    let got = fixture_findings();
    // The bare `// lint: allow(ordering-comment)` at hist.rs:19 ...
    assert!(got.contains(&("telemetry/hist.rs".to_string(), 19, "bad-suppression")));
    // ... and the SeqCst load it sits above still fires.
    assert!(got.contains(&("telemetry/hist.rs".to_string(), 21, "ordering-comment")));
}

#[test]
fn test_regions_and_out_of_scope_paths_stay_quiet() {
    let got = fixture_findings();
    // serve/pool.rs lines 22-28 are a #[cfg(test)] module full of unwraps
    // and sleeps; util/misc.rs unwraps and reads the clock outside the
    // scoped directories. Only the partial_cmp in util/ may fire.
    assert!(got.iter().all(|(f, l, _)| !(f == "serve/pool.rs" && *l >= 22)));
    assert_eq!(got.iter().filter(|(f, _, _)| f == "util/misc.rs").count(), 1);
}

#[test]
fn json_exposition_is_byte_stable() {
    // Machine-independent: lint an in-memory source under a fixed display
    // path instead of a filesystem walk.
    let src = "fn f(x: Option<u32>, c: &AtomicU64) {\n\
               let v = x.unwrap();\n\
               c.load(Ordering::SeqCst);\n\
               }\n";
    let findings = lint_source("serve/pool.rs", src);
    let golden = "{\n\
                  \x20 \"schema\": \"medea.lint.v1\",\n\
                  \x20 \"count\": 2,\n\
                  \x20 \"findings\": [\n\
                  \x20   {\n\
                  \x20     \"file\": \"serve/pool.rs\",\n\
                  \x20     \"line\": 2,\n\
                  \x20     \"rule\": \"no-unwrap\",\n\
                  \x20     \"message\": \"`.unwrap()` on the serving path can take a worker down; bubble the error instead\"\n\
                  \x20   },\n\
                  \x20   {\n\
                  \x20     \"file\": \"serve/pool.rs\",\n\
                  \x20     \"line\": 3,\n\
                  \x20     \"rule\": \"ordering-comment\",\n\
                  \x20     \"message\": \"atomic ordering choice without an adjacent `// ordering:` justification\"\n\
                  \x20   }\n\
                  \x20 ]\n\
                  }\n";
    assert_eq!(findings_to_json(&findings), golden);
}

#[test]
fn empty_findings_render_an_empty_document() {
    let doc = findings_to_json(&[]);
    assert_eq!(
        doc,
        "{\n  \"schema\": \"medea.lint.v1\",\n  \"count\": 0,\n  \"findings\": []\n}\n"
    );
}
