//! Cross-layer integration tests: schedules ↔ JSON ↔ simulator ↔ PJRT.

use medea::baselines::{
    coarse_grain_app_dvfs, cpu_max_vf, static_accel_app_dvfs, static_accel_max_vf,
};
use medea::exp::ExpContext;
use medea::ir::tsd::{tsd_full, TsdParams};
use medea::manager::schedule::Schedule;
use medea::runtime::artifacts::ArtifactManifest;
use medea::runtime::client::Runtime;
use medea::sim::replay::simulate;
use medea::util::units::{Energy, Time};

#[test]
fn schedule_json_round_trip_preserves_sim_outcome() {
    let ctx = ExpContext::paper();
    let schedule = ctx
        .medea()
        .schedule(&ctx.workload, Time::from_ms(200.0))
        .unwrap();
    let dir = std::env::temp_dir().join("medea_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("schedule.json");
    schedule.save(&path).unwrap();
    let loaded = Schedule::load(&path).unwrap();
    loaded.validate(&ctx.workload, &ctx.platform).unwrap();

    let r1 = simulate(&ctx.workload, &ctx.platform, &ctx.model, &schedule);
    let r2 = simulate(&ctx.workload, &ctx.platform, &ctx.model, &loaded);
    assert!((r1.active_time.raw() - r2.active_time.raw()).abs() < 1e-9);
    assert!((r1.active_energy.raw() - r2.active_energy.raw()).abs() < 1e-12);
    assert_eq!(r1.events, r2.events);
}

#[test]
fn all_schedulers_produce_valid_simulable_schedules() {
    let ctx = ExpContext::paper();
    let d = Time::from_ms(200.0);
    let (w, p, pr, m) = (&ctx.workload, &ctx.platform, &ctx.profiles, &ctx.model);
    let schedules = vec![
        cpu_max_vf(w, p, pr, m, d).unwrap(),
        static_accel_max_vf(w, p, pr, m, d).unwrap(),
        static_accel_app_dvfs(w, p, pr, m, d).unwrap(),
        coarse_grain_app_dvfs(w, p, pr, m, d).unwrap(),
        ctx.medea().schedule(w, d).unwrap(),
    ];
    for s in schedules {
        s.validate(w, p).unwrap_or_else(|e| panic!("{}: {e}", s.scheduler));
        let r = simulate(w, p, m, &s);
        assert!(r.active_time.raw() > 0.0);
        assert!(r.active_energy.raw() > 0.0);
        // The sim's independent accounting stays within 10 % of the
        // scheduler's own estimates for every scheduler.
        let dt = (r.active_time.raw() - s.active_time().raw()).abs() / s.active_time().raw();
        assert!(dt < 0.10, "{}: sim/est time gap {dt:.3}", s.scheduler);
    }
}

#[test]
fn full_tsd_workload_with_frontend_is_schedulable() {
    // The tsd_full variant adds the CPU-only FFT frontend kernel; MEDEA
    // must handle it (it pins to the CPU) and the extra cost must push the
    // makespan up, not break feasibility at moderate deadlines.
    let ctx = ExpContext::paper();
    let full = tsd_full(&TsdParams::default());
    let s_core = ctx
        .medea()
        .schedule(&ctx.workload, Time::from_ms(400.0))
        .unwrap();
    let s_full = ctx.medea().schedule(&full, Time::from_ms(400.0)).unwrap();
    s_full.validate(&full, &ctx.platform).unwrap();
    assert!(s_full.active_time().raw() > s_core.active_time().raw());
    // The FFT kernel landed on the CPU.
    let fft_decision = s_full
        .decisions
        .iter()
        .find(|dec| full.kernels()[dec.kernel].name == "frontend.fft_mag")
        .unwrap();
    assert_eq!(fft_decision.pe, ctx.platform.cpu().id);
}

#[test]
fn energy_budget_and_deadline_objectives_are_consistent() {
    // Scheduling for deadline T yields energy E*; scheduling for energy
    // budget E* must then achieve a time ≤ T (duality sanity).
    let ctx = ExpContext::paper();
    let d = Time::from_ms(300.0);
    let by_deadline = ctx.medea().schedule(&ctx.workload, d).unwrap();
    let e = by_deadline.active_energy();
    let by_budget = ctx
        .medea()
        .schedule_energy_budget(&ctx.workload, Energy(e.raw() * 1.0001), 30)
        .unwrap();
    assert!(
        by_budget.active_time().raw() <= d.raw() * 1.01,
        "budget-dual time {:.1} ms exceeds {:.1} ms",
        by_budget.active_time().as_ms(),
        d.as_ms()
    );
    assert!(by_budget.active_energy().raw() <= e.raw() * 1.0002);
}

#[test]
fn pjrt_kernel_chain_matches_reference_statistics() {
    // Kernel-level dispatch through PJRT: norm -> gelu chained on the rust
    // side, validated against the mathematical definitions.
    if !Runtime::available() {
        eprintln!("skipping: PJRT backend not built (stub; build with --cfg medea_pjrt)");
        return;
    }
    let dir = ArtifactManifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rt = Runtime::new(&dir).unwrap();
    let x: Vec<f32> = (0..97 * 128)
        .map(|i| ((i * 37 % 101) as f32 - 50.0) * 0.2)
        .collect();
    let normed = rt.run_f32("k_norm", &[&x]).unwrap().remove(0);
    // Row statistics of layernorm output.
    for r in 0..97 {
        let row = &normed[r * 128..(r + 1) * 128];
        let mean: f32 = row.iter().sum::<f32>() / 128.0;
        let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 128.0;
        assert!(mean.abs() < 1e-4, "row {r} mean {mean}");
        assert!((var - 1.0).abs() < 1e-2, "row {r} var {var}");
    }
    // Chain into an add with itself: PJRT output feeds PJRT input.
    let doubled = rt.run_f32("k_add", &[&normed, &normed]).unwrap().remove(0);
    for (d, n) in doubled.iter().zip(&normed) {
        assert!((d - 2.0 * n).abs() < 1e-5);
    }
}

#[test]
fn deadline_feasibility_boundary_is_sharp() {
    // Just above the minimum makespan must be feasible; well below must
    // error as infeasible — no silent deadline violations.
    let ctx = ExpContext::paper();
    // Probe for the edge.
    let mut lo = 1.0f64;
    let mut hi = 200.0f64;
    for _ in 0..20 {
        let mid = 0.5 * (lo + hi);
        if ctx.medea().schedule(&ctx.workload, Time::from_ms(mid)).is_ok() {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    let ok = ctx
        .medea()
        .schedule(&ctx.workload, Time::from_ms(hi * 1.01))
        .unwrap();
    assert!(ok.meets_deadline());
    assert!(ctx
        .medea()
        .schedule(&ctx.workload, Time::from_ms(lo * 0.9))
        .is_err());
}
