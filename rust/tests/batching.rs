//! Batched-admission integration tests.
//!
//! The safety property everything here pins: **batch admission is
//! deadline-monotone** — coalescing requests into one dispatch never
//! violates a member deadline the solo path would have met. Two layers:
//!
//! * a randomized property over [`EdfQueue::pop_compatible`] driven by the
//!   *production* admission predicate (the sim-anchored batch makespan
//!   against the earliest member deadline), checked against every member of
//!   every group it forms;
//! * an end-to-end pool property: bursts of randomized feasible deadlines
//!   through a batching [`ServePool`] must complete with zero deadline
//!   misses and per-member energy charges no worse than solo.

use medea::eeg::synth::{EegGenerator, SynthConfig};
use medea::exp::ExpContext;
use medea::serve::{
    AtlasConfig, BatchConfig, EdfQueue, PoolConfig, ScheduleAtlas, ServePool, Ticket,
};
use medea::sim::replay::simulate;
use medea::util::rng::Rng;
use medea::util::units::Time;
use std::path::PathBuf;
use std::sync::OnceLock;

/// One coarse atlas per test binary (correctness is knot-density-free).
fn shared_atlas() -> &'static ScheduleAtlas {
    static ATLAS: OnceLock<ScheduleAtlas> = OnceLock::new();
    ATLAS.get_or_init(|| {
        let ctx = ExpContext::paper();
        ScheduleAtlas::build(
            &ctx.medea(),
            &ctx.workload,
            &AtlasConfig {
                relax_factor: 8.0,
                growth: 1.5,
                refine_rel_energy: 0.05,
                max_knots: 32,
                ..AtlasConfig::default()
            },
        )
        .unwrap()
    })
}

#[test]
fn pop_compatible_with_production_predicate_is_deadline_monotone() {
    let atlas = shared_atlas();
    let floor = atlas.floor().raw();
    let hi = atlas.knots().last().unwrap().deadline.raw() * 4.0;
    let amort = BatchConfig::default().amortization;

    let mut rng = Rng::new(0xBA7C4);
    for case in 0..50 {
        let mut q: EdfQueue<usize> = EdfQueue::new(256);
        let n_jobs = rng.usize_below(48) + 2;
        let mut deadlines = Vec::with_capacity(n_jobs);
        for i in 0..n_jobs {
            // Feasible by construction (≥ floor), spread across the whole
            // range so several land on the same knot while others scatter.
            let d = Time(rng.range_f64(floor, hi));
            deadlines.push(d);
            q.push(d, i);
        }
        let max_batch = rng.usize_below(8) + 1;
        while !q.is_empty() {
            let group = q.pop_compatible(
                max_batch,
                // The production key: the resolved knot's coordinate (the
                // pools stamp this on the job at submit; same value).
                |&i| {
                    atlas
                        .lookup(deadlines[i])
                        .map(|k| k.deadline.raw().to_bits())
                        .unwrap_or(u64::MAX)
                },
                // The production grow check: sim-anchored makespan against
                // the earliest member deadline.
                |group, _d, _cand| match atlas.lookup(group[0].0) {
                    Ok(knot) => {
                        knot.batch_makespan(group.len() + 1, amort).raw() <= group[0].0.raw()
                    }
                    Err(_) => false,
                },
            );
            assert!(!group.is_empty());
            assert!(group.len() <= max_batch);
            let knot = atlas.lookup(group[0].0).unwrap();
            let makespan = knot.batch_makespan(group.len(), amort);
            for &(deadline, job) in &group {
                // Every member shares the head's knot…
                let member_knot = atlas.lookup(deadline).unwrap();
                assert_eq!(
                    member_knot.deadline.raw().to_bits(),
                    knot.deadline.raw().to_bits(),
                    "case {case}: job {job} batched across knots"
                );
                // …and the batch completes within *its* deadline, not just
                // the head's (deadline monotonicity).
                assert!(
                    makespan.raw() <= deadline.raw() + 1e-12,
                    "case {case}: batch of {} finishing at {:.3} ms violates \
                     member deadline {:.3} ms (solo path met it: knot {:.3} ms)",
                    group.len(),
                    makespan.as_ms(),
                    deadline.as_ms(),
                    member_knot.deadline.as_ms()
                );
                // The solo path would also have met it — so batching
                // strictly preserved feasibility rather than trading it.
                assert!(member_knot.sim_time.raw() <= deadline.raw() + 1e-12);
            }
        }
    }
}

#[test]
fn batched_pool_meets_every_admitted_deadline() {
    let pool = ServePool::start_with_atlas(
        PoolConfig {
            workers: 2,
            queue_capacity: 256,
            artifact_dir: PathBuf::from("/nonexistent-artifacts"),
            batch: BatchConfig {
                max_batch: 8,
                ..BatchConfig::default()
            },
            ..PoolConfig::default()
        },
        shared_atlas().clone(),
    )
    .unwrap();
    let floor = pool.floor();
    let ctx = ExpContext::paper();
    let mut rng = Rng::new(0xD15BA7C4);
    let mut gen = EegGenerator::new(SynthConfig::default(), 17);

    // Three bursts of randomized feasible deadlines (bursts are what make
    // batches form); every admitted request must meet the deadline it asked
    // for, and batch members must never be charged more energy than solo.
    for _burst in 0..3 {
        let tickets: Vec<(Time, Ticket)> = (0..96)
            .map(|_| {
                let d = floor * (1.0 + rng.f64() * 63.0);
                (d, pool.submit(gen.next_window(), d).unwrap())
            })
            .collect();
        for (deadline, t) in tickets {
            let out = t.wait().unwrap();
            assert!(
                out.sim.deadline_met,
                "deadline {:.2} ms missed by a batch of {}",
                deadline.as_ms(),
                out.batch_size
            );
            assert!(out.sim.active_time.raw() <= deadline.raw() + 1e-12);
            assert!(out.knot_deadline.raw() <= deadline.raw() + 1e-12);
            if out.batch_size > 1 {
                // Amortization: a batch member's active-energy share must
                // never exceed the solo simulated charge for the same knot
                // (scale(n)/n < 1 for n ≥ 2).
                let knot = shared_atlas().lookup(deadline).unwrap();
                let solo_sim = simulate(&ctx.workload, &ctx.platform, &ctx.model, &knot.schedule);
                assert!(
                    out.sim.active_energy.raw() <= solo_sim.active_energy.raw() * (1.0 + 1e-9),
                    "batch member charged {:.2} uJ vs solo sim {:.2} uJ",
                    out.sim.active_energy.as_uj(),
                    solo_sim.active_energy.as_uj()
                );
            }
        }
    }
    let m = pool.shutdown();
    assert_eq!(m.aggregate.requests, 3 * 96);
    assert_eq!(m.aggregate.deadline_misses, 0, "{}", m.summary());
    assert_eq!(m.total_shed(), 0);
    assert_eq!(m.batched_requests() + m.solo_requests(), 3 * 96);
}
