//! Serving-subsystem integration tests: atlas correctness against the
//! event-level simulator, cross-solver agreement on knot energies, and the
//! pool's typed shedding behavior.

use medea::exp::ExpContext;
use medea::manager::medea::SolverKind;
use medea::serve::{AtlasConfig, PoolConfig, Rejection, ScheduleAtlas, ServePool};
use medea::sim::replay::simulate;
use medea::util::rng::Rng;
use medea::util::units::Time;

fn default_atlas(ctx: &ExpContext) -> ScheduleAtlas {
    ScheduleAtlas::build(&ctx.medea(), &ctx.workload, &AtlasConfig::default()).unwrap()
}

#[test]
fn atlas_meets_100_random_deadlines_in_simulation() {
    // The acceptance property: for any requested deadline at or above the
    // floor, the atlas-resolved schedule's *simulated* makespan (which does
    // not grant the estimator's optimistic LM-residency chaining) meets it.
    let ctx = ExpContext::paper();
    let atlas = default_atlas(&ctx);
    let lo = atlas.floor().raw();
    let hi = lo * 30.0; // deliberately past the sweep bound: laxer deadlines
                        // fall back to the energy-minimal knot
    let mut rng = Rng::new(0xA71A5);
    for case in 0..100 {
        let deadline = Time(rng.range_f64(lo, hi));
        let schedule = atlas.resolve(deadline).unwrap();
        assert!(
            (schedule.deadline.raw() - deadline.raw()).abs() < 1e-15,
            "case {case}: resolve must stamp the requested deadline"
        );
        let report = simulate(&ctx.workload, &ctx.platform, &ctx.model, &schedule);
        assert!(
            report.deadline_met,
            "case {case}: deadline {:.2} ms missed (sim makespan {:.2} ms)",
            deadline.as_ms(),
            report.active_time.as_ms()
        );
    }
}

#[test]
fn atlas_energy_is_monotone_in_deadline() {
    // Snapping down to knots must preserve the design-time Pareto property:
    // more slack never costs more active energy.
    let ctx = ExpContext::paper();
    let atlas = default_atlas(&ctx);
    let mut last = f64::INFINITY;
    let lo = atlas.floor().as_ms();
    for i in 0..40 {
        let d = Time::from_ms(lo * (1.0 + 0.6 * i as f64));
        let e = atlas.resolve(d).unwrap().active_energy().as_uj();
        assert!(e <= last * 1.001, "deadline {:.1} ms: {e} > {last}", d.as_ms());
        last = e;
    }
}

#[test]
fn dp_and_bb_agree_on_knot_energies() {
    // The atlas is built with the DP solver; the independent exact
    // branch-and-bound must certify (within DP quantization tolerance) the
    // same optimal energy at every sampled knot deadline.
    let ctx = ExpContext::paper();
    let atlas = default_atlas(&ctx);
    let step = (atlas.len() / 8).max(1);
    for knot in atlas.knots().iter().step_by(step) {
        let dp_energy = knot.schedule.active_energy().as_uj();
        // Re-derive the exact optimization problem the atlas solved (the
        // knot records its effective solve deadline).
        let bb = ctx
            .medea()
            .with_solver(SolverKind::Bb)
            .schedule(&ctx.workload, knot.solve_deadline)
            .unwrap();
        let bb_energy = bb.active_energy().as_uj();
        let rel = (dp_energy - bb_energy).abs() / dp_energy.max(bb_energy);
        assert!(
            rel < 5e-3,
            "knot {:.2} ms: dp {dp_energy:.2} uJ vs bb {bb_energy:.2} uJ (rel {rel:.4})",
            knot.deadline.as_ms()
        );
    }
}

#[test]
fn atlas_round_trips_through_disk() {
    let ctx = ExpContext::paper();
    let atlas = default_atlas(&ctx);
    let dir = std::env::temp_dir().join("medea_serve_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("atlas.json");
    atlas.save(&path).unwrap();
    let loaded = ScheduleAtlas::load(&path).unwrap();
    assert_eq!(loaded.len(), atlas.len());
    assert_eq!(loaded.workload, atlas.workload);
    assert!((loaded.floor().raw() - atlas.floor().raw()).abs() < 1e-12);
    // A loaded atlas drives a pool end-to-end.
    let pool = ServePool::start_with_atlas(
        PoolConfig {
            workers: 2,
            artifact_dir: std::path::PathBuf::from("/nonexistent-artifacts"),
            ..PoolConfig::default()
        },
        loaded,
    )
    .unwrap();
    let mut gen = medea::eeg::synth::EegGenerator::new(Default::default(), 11);
    let out = pool.infer(gen.next_window(), Time::from_ms(250.0)).unwrap();
    assert!(out.sim.deadline_met);
    assert_eq!(out.scheduler, "medea");
    pool.shutdown();
}

#[test]
fn infeasible_deadlines_shed_with_typed_rejection_not_solver_error() {
    // Acceptance criterion: the EDF queue sheds infeasible deadlines with a
    // typed rejection rather than an `Err` bubbling out of the solver.
    let ctx = ExpContext::paper();
    let atlas = default_atlas(&ctx);
    let pool = ServePool::start_with_atlas(
        PoolConfig {
            workers: 1,
            artifact_dir: std::path::PathBuf::from("/nonexistent-artifacts"),
            ..PoolConfig::default()
        },
        atlas,
    )
    .unwrap();
    let floor = pool.floor();
    let mut gen = medea::eeg::synth::EegGenerator::new(Default::default(), 12);
    match pool.submit(gen.next_window(), floor * 0.25) {
        Err(Rejection::BelowFloor { requested, floor: f }) => {
            assert!(requested.raw() < f.raw());
        }
        other => panic!("expected typed BelowFloor rejection, got {other:?}"),
    }
    let metrics = pool.shutdown();
    assert_eq!(metrics.shed_below_floor, 1);
    assert_eq!(metrics.aggregate.requests, 0);
}
