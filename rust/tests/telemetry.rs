//! Telemetry integration tests.
//!
//! Four properties pinned here:
//!
//! * **Liveness** — the Prometheus endpoint answers while a burst is still
//!   draining (no quiesce, no lock on the serving path), and once every
//!   ticket has its reply a scrape accounts for the whole burst.
//! * **Fidelity** — `live_metrics()` mid-flight and the `shutdown()` report
//!   read the same registry: after the burst drains they are byte-identical,
//!   percentiles included (no more "live approximation vs exact shutdown").
//! * **Traceability** — the dispatch-event ring renders a chrome://tracing
//!   document that parses with the crate's own JSON codec and retires every
//!   admitted request exactly once; the fleet pool publishes under its own
//!   `platform="fleet"` labels with typed shed reasons.
//! * **Attribution** — every dispatch lands in the energy ledger (the new
//!   `medea_pe_*`/`medea_knot_*` families), the exposition round-trips into
//!   the `medea energy-report` snapshot, and the trace ring carries exactly
//!   one kernel span per scheduled decision per dispatch.

use medea::eeg::synth::{EegGenerator, SynthConfig};
use medea::exp::ExpContext;
use medea::fleet::{
    Demand, EnergyAtlasConfig, FleetConfig, FleetEntry, FleetPool, FleetPoolConfig, FleetRegistry,
};
use medea::serve::{AtlasConfig, PoolConfig, Rejection, ScheduleAtlas, ServePool};
use medea::telemetry::{render_prometheus, scrape, MetricsServer, TelemetryConfig};
use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

/// One coarse atlas per test binary (correctness is knot-density-free).
fn shared_atlas() -> &'static ScheduleAtlas {
    static ATLAS: OnceLock<ScheduleAtlas> = OnceLock::new();
    ATLAS.get_or_init(|| {
        let ctx = ExpContext::paper();
        ScheduleAtlas::build(
            &ctx.medea(),
            &ctx.workload,
            &AtlasConfig {
                relax_factor: 8.0,
                growth: 1.5,
                refine_rel_energy: 0.05,
                max_knots: 32,
                ..AtlasConfig::default()
            },
        )
        .unwrap()
    })
}

fn observed_pool(workers: usize) -> ServePool {
    ServePool::start_with_atlas(
        PoolConfig {
            workers,
            queue_capacity: 256,
            artifact_dir: PathBuf::from("/nonexistent-artifacts"),
            telemetry: TelemetryConfig { trace_events: 4096 },
            ..PoolConfig::default()
        },
        shared_atlas().clone(),
    )
    .unwrap()
}

/// Sum one counter family's samples across its per-worker series.
fn family_sum(body: &str, family: &str) -> f64 {
    let prefix = format!("{family}{{");
    body.lines()
        .filter(|l| l.starts_with(&prefix))
        .map(|l| l.rsplit(' ').next().unwrap().parse::<f64>().unwrap())
        .sum()
}

#[test]
fn live_scrape_answers_under_load_and_matches_shutdown() {
    const N: usize = 64;
    let pool = observed_pool(2);
    let server = MetricsServer::start("127.0.0.1:0", Arc::clone(pool.telemetry())).unwrap();
    let addr = server.addr().to_string();

    let floor = shared_atlas().floor();
    let mut gen = EegGenerator::new(SynthConfig::default(), 9);
    let tickets: Vec<_> = (0..N)
        .map(|i| {
            // Feasible by construction (≥ floor), spread so some dispatches
            // batch and others stay solo.
            let d = floor * (1.05 + (i % 5) as f64);
            pool.submit(gen.next_window(), d).unwrap()
        })
        .collect();

    // Scrape immediately, while the burst is still draining: the endpoint
    // must answer without waiting for the pool to go idle.
    let mid = scrape(&addr).unwrap();
    assert!(
        mid.contains("# TYPE medea_requests_total counter"),
        "mid-flight scrape is not a well-formed exposition:\n{mid}"
    );
    assert!(mid.contains("platform=\"heeptimize\""));

    for t in tickets {
        t.wait().unwrap();
    }

    // Every reply delivered ⇒ every per-request counter is recorded; a
    // second scrape must account for the whole burst.
    let done = scrape(&addr).unwrap();
    assert_eq!(family_sum(&done, "medea_requests_total"), N as f64);
    assert!(done.contains("workload=\"tsd-core\""));
    assert!(done.contains("medea_host_latency_seconds_bucket"));
    drop(server);

    let live = pool.live_metrics();
    let ring = Arc::clone(pool.trace().expect("trace ring was enabled"));
    let shut = pool.shutdown();
    assert_eq!(live.aggregate.requests, N as u64);
    assert_eq!(
        live.to_json().to_compact(),
        shut.to_json().to_compact(),
        "live metrics must equal the shutdown report once the burst drained"
    );

    // The trace dump parses with the crate's own codec and retires every
    // admitted request exactly once.
    let doc = medea::util::json::parse(&ring.to_chrome_json()).unwrap();
    let events = doc.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
    let count = |name: &str| {
        events
            .iter()
            .filter(|e| e.get("name").and_then(|n| n.as_str()) == Some(name))
            .count()
    };
    assert_eq!(count("enqueue"), N);
    assert_eq!(count("retire"), N);
    assert!(count("dispatch") >= 1, "no dispatch events recorded");
}

#[test]
fn ledger_families_and_kernel_spans_cover_every_dispatch() {
    use medea::telemetry::{ledger_from_prometheus, TraceEventKind};
    const N: usize = 8;
    let pool = observed_pool(1);
    let floor = shared_atlas().floor();
    let deadline = floor * 1.05;
    let kernels = pool.atlas().lookup(deadline).unwrap().schedule.decisions.len();
    let mut gen = EegGenerator::new(SynthConfig::default(), 11);
    for _ in 0..N {
        // Sequential submit/wait keeps every dispatch solo, so the span
        // arithmetic below is exact.
        pool.submit(gen.next_window(), deadline).unwrap().wait().unwrap();
    }

    let body = render_prometheus(&pool.telemetry().snapshot());
    for family in [
        "medea_queue_depth{",
        "medea_pe_energy_joules_total{",
        "medea_pe_busy_seconds_total{",
        "medea_knot_dispatches_total{",
        "medea_atlas_drift_ratio{",
        "medea_unattributed_dispatches_total{",
    ] {
        assert!(body.contains(family), "{family} missing from exposition:\n{body}");
    }
    assert_eq!(family_sum(&body, "medea_knot_dispatches_total"), N as f64);
    assert_eq!(family_sum(&body, "medea_unattributed_dispatches_total"), 0.0);
    assert!(family_sum(&body, "medea_pe_busy_seconds_total") > 0.0);
    assert!(family_sum(&body, "medea_pe_energy_joules_total") > 0.0);

    // The exposition round-trips into the `medea energy-report` snapshot.
    let snap = ledger_from_prometheus(&body).unwrap();
    assert_eq!(snap.entries.len(), 1);
    assert_eq!(snap.entries[0].knot_dispatches.iter().sum::<u64>(), N as u64);

    // Every dispatch left one kernel span per scheduled decision, and the
    // chrome dump carries them as complete ("X") slices on the PE tracks.
    let ring = Arc::clone(pool.trace().expect("trace ring was enabled"));
    let typed = ring
        .events()
        .iter()
        .filter(|e| e.kind == TraceEventKind::KernelSpan)
        .count();
    assert_eq!(typed, N * kernels);
    let doc = medea::util::json::parse(&ring.to_chrome_json()).unwrap();
    let slices = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .unwrap()
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
        .count();
    assert_eq!(slices, N * kernels);
    pool.shutdown();
}

/// Coarse sweeps keep the entry build affordable; label correctness does
/// not depend on knot density.
fn fleet_fast_cfg() -> FleetConfig {
    FleetConfig {
        atlas: AtlasConfig {
            relax_factor: 6.0,
            growth: 1.7,
            refine_rel_energy: 0.0,
            max_knots: 12,
            ..AtlasConfig::default()
        },
        energy: EnergyAtlasConfig {
            growth: 1.7,
            max_knots: 6,
            bisect_iters: 10,
            ..EnergyAtlasConfig::default()
        },
    }
}

#[test]
fn fleet_pool_publishes_fleet_labelled_telemetry() {
    let registry = FleetRegistry::new();
    registry.publish(FleetEntry::build("heeptimize", "tsd-small", &fleet_fast_cfg()).unwrap());
    let registry = Arc::new(registry);
    let floor = registry
        .resolve_named("heeptimize", "tsd-small")
        .unwrap()
        .entry
        .atlas
        .floor();

    let pool = FleetPool::start(
        registry,
        FleetPoolConfig {
            workers: 1,
            queue_capacity: 16,
            artifact_dir: PathBuf::from("/nonexistent-artifacts"),
            telemetry: TelemetryConfig { trace_events: 256 },
            ..FleetPoolConfig::default()
        },
    )
    .unwrap();

    let mut gen = EegGenerator::new(SynthConfig::default(), 3);
    let tickets: Vec<_> = (0..3)
        .map(|_| {
            pool.submit(
                "heeptimize",
                "tsd-small",
                gen.next_window(),
                Demand::Deadline(floor * 4.0),
            )
            .unwrap()
        })
        .collect();
    for t in tickets {
        assert!(t.wait().unwrap().sim.deadline_met);
    }

    // An unrouteable tag sheds with a typed rejection and must surface in
    // the exposition under its own reason label.
    let err = pool
        .submit(
            "no-such-soc",
            "tsd-small",
            gen.next_window(),
            Demand::Deadline(floor),
        )
        .unwrap_err();
    assert!(matches!(err, Rejection::UnknownEntry { .. }), "got {err:?}");

    let body = render_prometheus(&pool.telemetry().snapshot());
    assert!(body.contains("platform=\"fleet\""), "fleet label missing:\n{body}");
    assert!(body.contains("workload=\"multi\""));
    assert!(body.contains("shed_reason=\"unknown_entry\""));

    let live = pool.live_metrics();
    let shut = pool.shutdown();
    assert_eq!(live.to_json().to_compact(), shut.to_json().to_compact());
    assert_eq!(shut.aggregate.requests, 3);
    assert_eq!(shut.shed_unknown_entry, 1);
}
