//! Seeded fixture: the gate-split lock protocol in the dispatch pools.
//! The admission (`state`) guard must be dropped before the dispatch-half
//! `gate` mutex is taken to ring a sibling — nesting the two would
//! deadlock against a parked worker acquiring them in the same order.
//! Never compiled.

fn ring_after_drop(&self) {
    let mut st = self.shards[0].state.lock();
    st.queue.push(job);
    drop(st);
    let mut token = self.shards[1].gate.lock();
    *token = true;
    drop(token);
    self.shards[1].cv.notify_one();
}

fn dirty_rings_under_the_admission_lock(&self) {
    let mut st = self.shards[0].state.lock();
    st.queue.push(job);
    let token = self.shards[1].gate.lock();
    drop(token);
    drop(st);
}
