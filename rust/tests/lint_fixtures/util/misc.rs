//! Seeded fixture: out-of-scope directory — no-unwrap and no-wall-clock
//! do not apply under util/, but no-partial-cmp fires everywhere.

fn anywhere(a: f64, b: f64) {
    let _ = a.partial_cmp(&b);
    let x = opt.unwrap();
    let t = Instant::now();
}
