//! Seeded fixture: wall-clock reads in design-time code. Never compiled.

fn tick() {
    let t = Instant::now();
    let w = SystemTime::now();
}

fn justified() {
    // lint: allow(no-wall-clock): fixture measures host overhead only
    let t = Instant::now();
}
