//! Seeded fixture: ordering-comment adjacency plus malformed suppression
//! directives. Never compiled.

fn orderings(c: &AtomicU64) {
    c.load(Ordering::Relaxed);
    c.store(1, Ordering::Release); // ordering: publishes the payload
    let gap = 1;
    c.load(Ordering::Acquire);
    // ordering: the block comment covers the contiguous run below
    c.fetch_add(1, Ordering::Relaxed);
    c.fetch_add(2, Ordering::Relaxed);

    c.store(3, Ordering::SeqCst);
}

// lint: allow(not-a-rule): unknown rules must be findings
fn bad_unknown_rule() {}

// lint: allow(ordering-comment)
fn bad_missing_reason(c: &AtomicU64) {
    c.load(Ordering::SeqCst);
}

fn suppressed(c: &AtomicU64) {
    // lint: allow(ordering-comment): fixture suppression with a reason
    c.load(Ordering::SeqCst);
}
