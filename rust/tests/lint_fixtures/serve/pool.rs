//! Seeded fixture: serving-path rules (no-unwrap, lock-discipline,
//! sleep-under-lock) plus suppression behavior. Never compiled.

fn dirty(&self) {
    let mut st = self.shards[0].state.lock().unwrap();
    std::thread::sleep(poll);
    let sib = self.shards[1].state.lock().unwrap();
    drop(st);
    drop(sib);
}

fn suppressed(&self) {
    // lint: allow(no-unwrap): fixture invariant holds by construction
    let st = self.state.lock().expect("poisoned");
    // lint: allow(lock-discipline): fixture nests on purpose
    // lint: allow(no-unwrap): fixture invariant holds by construction
    let nested = self.other.lock().unwrap();
    drop(nested);
    drop(st);
}

#[cfg(test)]
mod tests {
    fn free_for_all() {
        let st = lock().unwrap();
        std::thread::sleep(d);
    }
}
