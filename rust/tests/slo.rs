//! SLO engine + flight recorder integration tests.
//!
//! Three scenarios pinned here:
//!
//! * **Synthetic deadline storm** — a deterministic snapshot timeline flips
//!   the deadline objective to `Critical`, emits exactly one rate-limited
//!   post-mortem bundle (registry snapshot + trace events + the firing
//!   evaluation), and `/slo` + the Prometheus gauges report the same state.
//! * **Real overload** — a burst into a tiny admission queue sheds far past
//!   the ceiling on a live `ServePool`; the engine sees it through real
//!   registry snapshots, the recorder captures it, and the pool's readiness
//!   probe still answers once the burst drains.
//! * **Synthetic atlas drift** — a pool whose dispatches are stretched past
//!   the knots' modeled times (`synth_slowdown`) pushes the drift EWMA over
//!   the configured bound; the `atlas_drift` objective flips `Critical` and
//!   the one rate-limited bundle carries the energy ledger snapshot.

use medea::eeg::synth::{EegGenerator, SynthConfig};
use medea::exp::ExpContext;
use medea::serve::{AtlasConfig, PoolConfig, ScheduleAtlas, ServePool};
use medea::telemetry::{
    http_get, scrape, FlightConfig, FlightRecorder, MetricsServer, RegistrySnapshot, SloEngine,
    SloSpec, SloState, TelemetryConfig, TelemetryRegistry, TraceEventKind, TraceRing,
    WorkerSnapshot,
};
use std::path::PathBuf;
use std::sync::{Arc, OnceLock};
use std::time::Duration;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("medea-slo-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn bundle_paths(dir: &std::path::Path) -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(dir)
        .expect("postmortem dir readable")
        .flatten()
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("postmortem-") && n.ends_with(".json"))
        })
        .collect();
    out.sort();
    out
}

/// A deterministic cumulative-counter timeline, snapshotted at chosen
/// uptimes — the evaluator sees exactly the windows the test intends.
struct SyntheticTimeline {
    totals: WorkerSnapshot,
}

impl SyntheticTimeline {
    fn new() -> SyntheticTimeline {
        SyntheticTimeline { totals: WorkerSnapshot::default() }
    }

    fn advance(&mut self, add_requests: u64, add_misses: u64) {
        self.totals.requests += add_requests;
        self.totals.deadline_misses += add_misses;
        for _ in 0..add_requests.min(64) {
            self.totals.dispatch.record(1_000_000); // 1 ms, comfortably in bound
        }
    }

    fn at(&self, uptime_s: u64) -> RegistrySnapshot {
        RegistrySnapshot {
            platform: "heeptimize".into(),
            workload: "tsd-core".into(),
            uptime: Duration::from_secs(uptime_s),
            workers: vec![self.totals.clone()],
            ..RegistrySnapshot::default()
        }
    }
}

#[test]
fn deadline_storm_flips_critical_and_leaves_one_bundle() {
    let dir = temp_dir("deadline-storm");
    let flight = Arc::new(
        FlightRecorder::new(FlightConfig {
            dir: dir.clone(),
            min_interval: Duration::from_secs(3600),
            ..FlightConfig::default()
        })
        .expect("recorder"),
    );
    let ring = Arc::new(TraceRing::new(64));
    ring.record(TraceEventKind::Enqueue, 0, 1, 200_000);
    ring.record(TraceEventKind::Dispatch, 0, 1, 0);
    let live = Arc::new(TelemetryRegistry::new("heeptimize", "tsd-core", 1));
    let engine =
        SloEngine::new(SloSpec::default(), live, Some(ring.clone()), Some(flight.clone()));

    // Five healthy seconds, then one second where 400 of 500 new requests
    // miss their deadline: burn explodes in both windows.
    let mut tl = SyntheticTimeline::new();
    for t in 1..=5u64 {
        tl.advance(200, 0);
        let status = engine.observe(&tl.at(t));
        assert_eq!(status.worst(), SloState::Ok, "healthy at t={t}: {status:?}");
    }
    tl.advance(500, 400);
    let status = engine.observe(&tl.at(6));
    assert_eq!(status.worst(), SloState::Critical, "{status:?}");
    assert!(status.transitions.contains(&"deadline"), "{status:?}");
    assert_eq!(flight.bundles_written(), 1, "the Critical transition must write a bundle");

    // Still burning at t=7: no new transition, and the rate limiter holds
    // the recorder to the one bundle it already wrote.
    tl.advance(100, 80);
    let again = engine.observe(&tl.at(7));
    assert_eq!(again.worst(), SloState::Critical);
    assert_eq!(flight.bundles_written(), 1, "rate limiter must suppress the repeat trigger");
    assert!(flight.suppressed() >= 1);
    let bundles = bundle_paths(&dir);
    assert_eq!(bundles.len(), 1, "exactly one bundle on disk: {bundles:?}");

    // The bundle carries all three parts: the firing evaluation, the
    // registry snapshot, and the trace tail.
    let doc = medea::util::json::parse(&std::fs::read_to_string(&bundles[0]).expect("read"))
        .expect("bundle json");
    assert_eq!(doc.get("schema").and_then(|v| v.as_str()), Some("medea.postmortem.v1"));
    assert!(
        doc.get("trigger").and_then(|v| v.as_str()).expect("trigger").contains("deadline"),
        "{doc:?}"
    );
    let slo = doc.get("slo").expect("firing evaluation embedded");
    assert_eq!(slo.get("state").and_then(|v| v.as_str()), Some("critical"));
    let registry = doc.get("registry").expect("registry snapshot embedded");
    assert_eq!(registry.get("requests").and_then(|v| v.as_u64()), Some(1500));
    let trace = doc.get("trace").and_then(|v| v.as_arr()).expect("trace events embedded");
    assert_eq!(trace.len(), 2);

    // `/slo` and the Prometheus gauges report the same Critical state.
    let server_reg = Arc::new(TelemetryRegistry::new("heeptimize", "tsd-core", 1));
    let server = MetricsServer::start_with("127.0.0.1:0", server_reg, Some(engine.clone()), None)
        .expect("bind");
    let addr = server.addr().to_string();
    let (code, body) = http_get(&addr, "/slo", Duration::from_secs(2)).expect("GET /slo");
    assert_eq!(code, 200);
    let json = medea::util::json::parse(&body).expect("/slo json");
    assert_eq!(json.get("state").and_then(|v| v.as_str()), Some("critical"));
    let deadline = json
        .get("objectives")
        .and_then(|v| v.as_arr())
        .and_then(|objs| {
            objs.iter().find(|o| o.get("objective").and_then(|v| v.as_str()) == Some("deadline"))
        })
        .expect("deadline objective in /slo");
    assert_eq!(deadline.get("state").and_then(|v| v.as_str()), Some("critical"));
    let metrics = scrape(&addr).expect("scrape");
    assert!(
        metrics.contains(
            "medea_slo_state{platform=\"heeptimize\",workload=\"tsd-core\",objective=\"deadline\"} 2"
        ),
        "gauges disagree with /slo:\n{metrics}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// One coarse atlas per test binary (correctness is knot-density-free).
fn shared_atlas() -> &'static ScheduleAtlas {
    static ATLAS: OnceLock<ScheduleAtlas> = OnceLock::new();
    ATLAS.get_or_init(|| {
        let ctx = ExpContext::paper();
        ScheduleAtlas::build(
            &ctx.medea(),
            &ctx.workload,
            &AtlasConfig {
                relax_factor: 8.0,
                growth: 1.5,
                refine_rel_energy: 0.05,
                max_knots: 32,
                ..AtlasConfig::default()
            },
        )
        .unwrap()
    })
}

#[test]
fn real_overload_sheds_past_the_ceiling_and_records() {
    let dir = temp_dir("overload");
    let pool = ServePool::start_with_atlas(
        PoolConfig {
            workers: 1,
            queue_capacity: 4,
            artifact_dir: PathBuf::from("/nonexistent-artifacts"),
            telemetry: TelemetryConfig { trace_events: 4096 },
            ..PoolConfig::default()
        },
        shared_atlas().clone(),
    )
    .unwrap();
    let flight = Arc::new(
        FlightRecorder::new(FlightConfig { dir: dir.clone(), ..FlightConfig::default() })
            .expect("recorder"),
    );
    let engine = SloEngine::new(
        SloSpec::default(),
        Arc::clone(pool.telemetry()),
        pool.trace().map(Arc::clone),
        Some(flight.clone()),
    );
    let probe = pool.readiness_probe();
    assert!(probe().ready, "fresh pool must be ready");

    // Baseline evaluation, then a burst far past the 4-deep queue: most
    // submissions shed, blowing through the 5% ceiling.
    assert_eq!(engine.evaluate_now().worst(), SloState::Ok);
    let floor = shared_atlas().floor();
    let mut gen = EegGenerator::new(SynthConfig::default(), 17);
    let mut shed = 0u64;
    let mut tickets = Vec::new();
    for _ in 0..200 {
        match pool.submit(gen.next_window(), floor * 1.5) {
            Ok(t) => tickets.push(t),
            Err(_) => shed += 1,
        }
    }
    assert!(shed > 10, "burst did not overload the queue (shed {shed})");
    for t in tickets {
        let _ = t.wait();
    }

    let status = engine.evaluate_now();
    assert_eq!(status.worst(), SloState::Critical, "{status:?}");
    let shed_obj = status
        .objectives
        .iter()
        .find(|o| o.objective == "shed")
        .expect("shed objective evaluated");
    assert_eq!(shed_obj.state, SloState::Critical, "{status:?}");
    assert_eq!(flight.bundles_written(), 1);
    assert_eq!(bundle_paths(&dir).len(), 1);

    // The health surface agrees: /slo critical, shed gauge at 2, and the
    // drained pool reports ready again.
    let server = MetricsServer::start_with(
        "127.0.0.1:0",
        Arc::clone(pool.telemetry()),
        Some(engine.clone()),
        Some(pool.readiness_probe()),
    )
    .expect("bind");
    let addr = server.addr().to_string();
    let (code, body) = http_get(&addr, "/slo", Duration::from_secs(2)).expect("GET /slo");
    assert_eq!(code, 200);
    let json = medea::util::json::parse(&body).expect("/slo json");
    assert_eq!(json.get("state").and_then(|v| v.as_str()), Some("critical"));
    let metrics = scrape(&addr).expect("scrape");
    assert!(
        metrics.contains("objective=\"shed\"} 2"),
        "shed gauge must be critical:\n{metrics}"
    );
    let (code, body) = http_get(&addr, "/readyz", Duration::from_secs(2)).expect("GET /readyz");
    assert_eq!(code, 200, "drained pool must be ready again: {body}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn synthetic_atlas_drift_flips_critical_and_bundles_the_ledger() {
    let dir = temp_dir("drift");
    // Every dispatch is stretched to 3x its knot's modeled time, so each
    // realized/modeled sample — and hence the per-knot EWMA — is >= 3.0.
    let pool = ServePool::start_with_atlas(
        PoolConfig {
            workers: 1,
            queue_capacity: 16,
            artifact_dir: PathBuf::from("/nonexistent-artifacts"),
            telemetry: TelemetryConfig { trace_events: 256 },
            synth_slowdown: 3.0,
            ..PoolConfig::default()
        },
        shared_atlas().clone(),
    )
    .unwrap();
    let flight = Arc::new(
        FlightRecorder::new(FlightConfig { dir: dir.clone(), ..FlightConfig::default() })
            .expect("recorder"),
    );
    // Bound 1.2 puts the burn at >= 3.0 / 1.2 = 2.5 in both windows — past
    // the default critical burn of 2.
    let engine = SloEngine::new(
        SloSpec { drift_ratio_bound: 1.2, ..SloSpec::default() },
        Arc::clone(pool.telemetry()),
        pool.trace().map(Arc::clone),
        Some(flight.clone()),
    );
    assert_eq!(engine.evaluate_now().worst(), SloState::Ok, "fresh pool has no drift");

    let floor = shared_atlas().floor();
    let mut gen = EegGenerator::new(SynthConfig::default(), 23);
    for _ in 0..3 {
        pool.submit(gen.next_window(), floor * 1.05).unwrap().wait().unwrap();
    }

    let status = engine.evaluate_now();
    let drift_obj = status
        .objectives
        .iter()
        .find(|o| o.objective == "atlas_drift")
        .expect("atlas_drift objective evaluated");
    assert_eq!(drift_obj.state, SloState::Critical, "{status:?}");
    assert!(status.transitions.contains(&"atlas_drift"), "{status:?}");
    assert_eq!(flight.bundles_written(), 1, "the drift transition must write a bundle");

    // Still drifting on the next evaluation: the rate limiter holds the
    // recorder to the one bundle it already wrote.
    pool.submit(gen.next_window(), floor * 1.05).unwrap().wait().unwrap();
    assert_eq!(engine.evaluate_now().worst(), SloState::Critical);
    let bundles = bundle_paths(&dir);
    assert_eq!(bundles.len(), 1, "exactly one bundle on disk: {bundles:?}");

    // The bundle's registry snapshot carries the energy ledger, so the
    // postmortem is self-contained: per-PE attribution plus the drifting
    // knots, without a second scrape of the (possibly gone) process.
    let doc = medea::util::json::parse(&std::fs::read_to_string(&bundles[0]).expect("read"))
        .expect("bundle json");
    assert!(
        doc.get("trigger").and_then(|v| v.as_str()).expect("trigger").contains("atlas_drift"),
        "{doc:?}"
    );
    let ledger = doc
        .get("registry")
        .and_then(|r| r.get("ledger"))
        .expect("ledger snapshot embedded in the bundle");
    let snap = medea::telemetry::LedgerSnapshot::from_json(ledger).expect("ledger parses");
    assert!(snap.max_drift() >= 2.4, "drift {} must clear the critical line", snap.max_drift());
    assert!(snap.entries[0].knot_dispatches.iter().sum::<u64>() >= 3);
    assert_eq!(snap.unattributed, 0);
    pool.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
