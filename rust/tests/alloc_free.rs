//! Steady-state dispatch is allocation-free, proven by a counting global
//! allocator.
//!
//! The dispatch hot path is the pair exercised here: [`EdfQueue::push`]
//! into a pre-sized queue, then [`EdfQueue::pop_compatible_into`] into a
//! caller-owned group buffer that the worker loop reuses across
//! dispatches. After a warm-up cycle (the queue's heap and the buffer are
//! sized at construction, so even that should not grow anything), repeated
//! push/pop cycles must perform **zero** heap allocations.
//!
//! This lives in its own integration binary because `#[global_allocator]`
//! is per-binary: sharing a binary with unrelated tests would let their
//! allocations race the counter.

use medea::serve::{Admission, EdfQueue};
use medea::util::units::Time;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// System allocator wrapper that counts every allocation and reallocation.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        // ordering: a test-only monotone event counter read after the
        // measured section on the same thread; no cross-thread protocol.
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // ordering: same test-only counter as `alloc`.
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocations() -> u64 {
    // ordering: see the counter increments above.
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn steady_state_group_formation_allocates_nothing() {
    const CYCLES: usize = 100;
    const BURST: usize = 16;

    // All construction-time allocation happens here, before measurement:
    // the queue's heap is sized to capacity and the group buffer to the
    // largest group a cycle can form.
    let mut q: EdfQueue<u64> = EdfQueue::new(256);
    let mut group: Vec<(Time, u64)> = Vec::with_capacity(BURST);

    // Warm-up cycle: exercises the exact code path once so any lazy
    // first-use allocation (there should be none) lands outside the
    // measured window.
    for i in 0..BURST {
        match q.push(Time(1.0 + i as f64), i as u64) {
            Admission::Accepted => {}
            _ => panic!("warm-up push rejected"),
        }
    }
    while q.pop_compatible_into(BURST, |_| 0u8, |_, _, _| true, &mut group) > 0 {
        group.clear();
    }

    let before = allocations();
    for cycle in 0..CYCLES {
        for i in 0..BURST {
            // Distinct deadlines keep the heap doing real sift work.
            let d = Time(1.0 + ((cycle * BURST + i) % 97) as f64);
            match q.push(d, i as u64) {
                Admission::Accepted => {}
                _ => panic!("steady-state push rejected"),
            }
        }
        while q.pop_compatible_into(BURST, |_| 0u8, |_, _, _| true, &mut group) > 0 {
            group.clear();
        }
        assert!(q.is_empty());
    }
    let delta = allocations() - before;

    assert_eq!(
        delta, 0,
        "steady-state push/pop_compatible_into cycles allocated {delta} times; \
         the dispatch hot path must reuse its pre-sized buffers"
    );
}
