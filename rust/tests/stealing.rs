//! Cross-shard work-stealing integration tests.
//!
//! Two properties pinned here:
//!
//! * **Outcome preservation** — stealing only changes *where* a queued job
//!   executes, never what it is judged against: every request that met its
//!   deadline in the no-steal run still meets it with stealing enabled,
//!   under the identical pinned skewed burst (all jobs on shard 0, sibling
//!   workers idle — the scenario that maximizes steal traffic).
//! * **Drain safety** — shutdown under concurrent steals answers every
//!   ticket exactly once: nothing lost, nothing double-dispatched (a
//!   double dispatch would inflate the request counter past the submitted
//!   total).

use medea::eeg::synth::{EegGenerator, SynthConfig};
use medea::exp::ExpContext;
use medea::serve::{
    AtlasConfig, PoolConfig, ScheduleAtlas, ServeMetrics, ServePool, StealConfig, Ticket,
};
use medea::util::rng::Rng;
use medea::util::units::Time;
use std::path::PathBuf;
use std::sync::OnceLock;
use std::time::Duration;

/// One coarse atlas per test binary (correctness is knot-density-free).
fn shared_atlas() -> &'static ScheduleAtlas {
    static ATLAS: OnceLock<ScheduleAtlas> = OnceLock::new();
    ATLAS.get_or_init(|| {
        let ctx = ExpContext::paper();
        ScheduleAtlas::build(
            &ctx.medea(),
            &ctx.workload,
            &AtlasConfig {
                relax_factor: 8.0,
                growth: 1.5,
                refine_rel_energy: 0.05,
                max_knots: 32,
                ..AtlasConfig::default()
            },
        )
        .unwrap()
    })
}

fn pool_with(steal: StealConfig, workers: usize) -> ServePool {
    ServePool::start_with_atlas(
        PoolConfig {
            workers,
            queue_capacity: 512,
            artifact_dir: PathBuf::from("/nonexistent-artifacts"),
            steal,
            ..PoolConfig::default()
        },
        shared_atlas().clone(),
    )
    .unwrap()
}

/// Drive an identical randomized burst — every job pinned to shard 0 while
/// the sibling workers idle — and record each request's deadline outcome
/// in submission order.
fn run_pinned_burst(steal: StealConfig, seed: u64, n: usize) -> (Vec<bool>, ServeMetrics) {
    let pool = pool_with(steal, 3);
    let atlas = shared_atlas();
    let floor = atlas.floor().raw();
    let hi = atlas.knots().last().unwrap().deadline.raw();
    let mut rng = Rng::new(seed);
    let mut gen = EegGenerator::new(SynthConfig::default(), seed);
    let tickets: Vec<Ticket> = (0..n)
        .map(|_| {
            // Feasible by construction (≥ floor), spread across the sweep
            // so some dispatches batch and others stay solo.
            let deadline = Time(rng.range_f64(floor, hi * 2.0));
            pool.submit_pinned(0, gen.next_window(), deadline).unwrap()
        })
        .collect();
    let met: Vec<bool> = tickets
        .into_iter()
        .map(|t| t.wait().unwrap().sim.deadline_met)
        .collect();
    (met, pool.shutdown())
}

#[test]
fn stealing_preserves_per_request_deadline_outcomes() {
    const N: usize = 96;
    let (base, base_m) = run_pinned_burst(StealConfig::disabled(), 0x5EED, N);
    let (stolen, steal_m) = run_pinned_burst(StealConfig::default(), 0x5EED, N);
    assert_eq!(base.len(), stolen.len());
    for (i, (b, s)) in base.iter().zip(&stolen).enumerate() {
        assert!(
            !b || *s,
            "request {i} met its deadline without stealing but missed with stealing enabled"
        );
    }
    assert_eq!(base_m.steals(), 0);
    assert_eq!(base_m.aggregate.requests as usize, N);
    assert_eq!(steal_m.aggregate.requests as usize, N);
    // A 96-job backlog pinned to one shard of three drains over many
    // multi-dispatch rounds; two idle pollers must have lifted work.
    assert!(
        steal_m.steals() > 0,
        "pinned burst never triggered a steal: {}",
        steal_m.summary()
    );
    assert!(steal_m.stolen_requests() >= steal_m.steals());
}

#[test]
fn shutdown_drains_every_ticket_exactly_once_under_concurrent_steals() {
    const N: usize = 200;
    let pool = pool_with(
        StealConfig {
            poll: Duration::from_micros(50),
            ..StealConfig::default()
        },
        4,
    );
    let floor = shared_atlas().floor();
    let mut gen = EegGenerator::new(SynthConfig::default(), 7);
    let tickets: Vec<Ticket> = (0..N)
        .map(|i| {
            let deadline = floor * (1.5 + (i % 13) as f64 * 0.45);
            pool.submit_pinned(0, gen.next_window(), deadline).unwrap()
        })
        .collect();
    // Shut down immediately: the drain races three thieves lifting groups
    // off shard 0. Every queued job must still be answered exactly once —
    // a double dispatch would push the request counter past N, a lost job
    // would surface as a dropped reply channel below.
    let m = pool.shutdown();
    assert_eq!(m.aggregate.requests as usize, N);
    assert_eq!(m.per_worker_requests.iter().sum::<u64>() as usize, N);
    for t in tickets {
        assert!(t.wait().is_ok(), "a queued job was dropped during drain");
    }
}
