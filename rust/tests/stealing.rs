//! Cross-shard work-stealing integration tests.
//!
//! Four properties pinned here:
//!
//! * **Outcome preservation** — stealing only changes *where* a queued job
//!   executes, never what it is judged against: every request that met its
//!   deadline in the no-steal run still meets it with stealing enabled,
//!   under the identical pinned skewed burst (all jobs on shard 0, sibling
//!   workers idle — the scenario that maximizes steal traffic).
//! * **Drain safety** — shutdown under concurrent steals answers every
//!   ticket exactly once: nothing lost, nothing double-dispatched (a
//!   double dispatch would inflate the request counter past the submitted
//!   total).
//! * **Event-driven wakeups** — with the fallback poll heartbeat cranked
//!   far past the test's runtime, steals still happen and happen fast:
//!   backlog crossing the wake threshold rings the longest-idle sibling
//!   directly instead of waiting for a poll tick.
//! * **Spurious-wakeup bound** — the notifier protocol wakes workers with
//!   purpose: an idle-then-loaded run with the heartbeat off records at
//!   most a handful of spurious wakeups (OS-level condvar noise), not a
//!   poll-driven stream of them.

use medea::eeg::synth::{EegGenerator, SynthConfig};
use medea::exp::ExpContext;
use medea::serve::{
    AtlasConfig, PoolConfig, ScheduleAtlas, ServeMetrics, ServePool, StealConfig, Ticket,
};
use medea::util::rng::Rng;
use medea::util::units::Time;
use std::path::PathBuf;
use std::sync::OnceLock;
use std::time::Duration;

/// One coarse atlas per test binary (correctness is knot-density-free).
fn shared_atlas() -> &'static ScheduleAtlas {
    static ATLAS: OnceLock<ScheduleAtlas> = OnceLock::new();
    ATLAS.get_or_init(|| {
        let ctx = ExpContext::paper();
        ScheduleAtlas::build(
            &ctx.medea(),
            &ctx.workload,
            &AtlasConfig {
                relax_factor: 8.0,
                growth: 1.5,
                refine_rel_energy: 0.05,
                max_knots: 32,
                ..AtlasConfig::default()
            },
        )
        .unwrap()
    })
}

fn pool_with(steal: StealConfig, workers: usize) -> ServePool {
    ServePool::start_with_atlas(
        PoolConfig {
            workers,
            queue_capacity: 512,
            artifact_dir: PathBuf::from("/nonexistent-artifacts"),
            steal,
            ..PoolConfig::default()
        },
        shared_atlas().clone(),
    )
    .unwrap()
}

/// Drive an identical randomized burst — every job pinned to shard 0 while
/// the sibling workers idle — and record each request's deadline outcome
/// in submission order.
fn run_pinned_burst(steal: StealConfig, seed: u64, n: usize) -> (Vec<bool>, ServeMetrics) {
    let pool = pool_with(steal, 3);
    let atlas = shared_atlas();
    let floor = atlas.floor().raw();
    let hi = atlas.knots().last().unwrap().deadline.raw();
    let mut rng = Rng::new(seed);
    let mut gen = EegGenerator::new(SynthConfig::default(), seed);
    let tickets: Vec<Ticket> = (0..n)
        .map(|_| {
            // Feasible by construction (≥ floor), spread across the sweep
            // so some dispatches batch and others stay solo.
            let deadline = Time(rng.range_f64(floor, hi * 2.0));
            pool.submit_pinned(0, gen.next_window(), deadline).unwrap()
        })
        .collect();
    let met: Vec<bool> = tickets
        .into_iter()
        .map(|t| t.wait().unwrap().sim.deadline_met)
        .collect();
    (met, pool.shutdown())
}

#[test]
fn stealing_preserves_per_request_deadline_outcomes() {
    const N: usize = 96;
    let (base, base_m) = run_pinned_burst(StealConfig::disabled(), 0x5EED, N);
    let (stolen, steal_m) = run_pinned_burst(StealConfig::default(), 0x5EED, N);
    assert_eq!(base.len(), stolen.len());
    for (i, (b, s)) in base.iter().zip(&stolen).enumerate() {
        assert!(
            !b || *s,
            "request {i} met its deadline without stealing but missed with stealing enabled"
        );
    }
    assert_eq!(base_m.steals(), 0);
    assert_eq!(base_m.aggregate.requests as usize, N);
    assert_eq!(steal_m.aggregate.requests as usize, N);
    // A 96-job backlog pinned to one shard of three drains over many
    // multi-dispatch rounds; two idle pollers must have lifted work.
    assert!(
        steal_m.steals() > 0,
        "pinned burst never triggered a steal: {}",
        steal_m.summary()
    );
    assert!(steal_m.stolen_requests() >= steal_m.steals());
}

#[test]
fn steal_wakeups_arrive_without_the_poll_heartbeat() {
    const N: usize = 64;
    // Heartbeat cranked far past this test's runtime: if a steal happens at
    // all, an event wake delivered it. The retired design rediscovered
    // backlog only by polling every 200 us — here polling would mean a
    // multi-second stall that the elapsed bound below turns into a failure.
    let heartbeat = Duration::from_secs(30);
    let pool = pool_with(
        StealConfig {
            poll: heartbeat,
            ..StealConfig::default()
        },
        3,
    );
    let floor = shared_atlas().floor();
    let mut gen = EegGenerator::new(SynthConfig::default(), 11);
    let started = std::time::Instant::now();
    let tickets: Vec<Ticket> = (0..N)
        .map(|i| {
            let deadline = floor * (1.5 + (i % 13) as f64 * 0.45);
            pool.submit_pinned(0, gen.next_window(), deadline).unwrap()
        })
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let elapsed = started.elapsed();
    let totals = pool.telemetry().snapshot().totals();
    let m = pool.shutdown();
    assert!(
        m.steals() > 0,
        "64 jobs pinned to one shard of three never triggered a steal: {}",
        m.summary()
    );
    assert!(
        elapsed < heartbeat,
        "burst drained only after the fallback heartbeat fired ({elapsed:?}) — \
         the event wakeup path is dead"
    );
    assert!(
        totals.wake.count() >= 1,
        "steals happened but no event wakeup was ever consumed"
    );
    // The wake itself is a mutex/condvar handoff (~microseconds); 50 ms is
    // pure CI headroom for a preempted thief thread, while still orders of
    // magnitude under the heartbeat that polling would have needed.
    let p99 = Duration::from_nanos(totals.wake.percentile(99.0));
    assert!(
        p99 < Duration::from_millis(50),
        "steal wakeup p99 {p99:?} is not event-driven-fast"
    );
}

#[test]
fn spurious_wakeups_stay_bounded_with_the_heartbeat_off() {
    const N: usize = 48;
    let workers = 3;
    let pool = pool_with(
        StealConfig {
            poll: Duration::from_secs(30),
            ..StealConfig::default()
        },
        workers,
    );
    // Idle phase: nothing should wake anyone.
    std::thread::sleep(Duration::from_millis(100));
    // Loaded phase: every wake now has a purpose (own-shard ring or steal
    // wake), so none of them count as spurious either.
    let floor = shared_atlas().floor();
    let mut gen = EegGenerator::new(SynthConfig::default(), 23);
    let tickets: Vec<Ticket> = (0..N)
        .map(|i| {
            let deadline = floor * (1.5 + (i % 11) as f64 * 0.5);
            pool.submit_pinned(0, gen.next_window(), deadline).unwrap()
        })
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let totals = pool.telemetry().snapshot().totals();
    pool.shutdown();
    // With the heartbeat effectively off, the only legal spurious wakeups
    // are OS-level condvar ones — rare, not a stream. The bound is generous
    // (a few per worker) so scheduler noise cannot flake CI, while a
    // regression back to poll-driven waking (hundreds over the idle phase)
    // fails decisively.
    let bound = workers as u64 * 3;
    assert!(
        totals.spurious_wakeups <= bound,
        "{} spurious wakeups recorded (bound {bound}) — workers are waking \
         without being notified",
        totals.spurious_wakeups
    );
}

#[test]
fn shutdown_drains_every_ticket_exactly_once_under_concurrent_steals() {
    const N: usize = 200;
    let pool = pool_with(
        StealConfig {
            poll: Duration::from_micros(50),
            ..StealConfig::default()
        },
        4,
    );
    let floor = shared_atlas().floor();
    let mut gen = EegGenerator::new(SynthConfig::default(), 7);
    let tickets: Vec<Ticket> = (0..N)
        .map(|i| {
            let deadline = floor * (1.5 + (i % 13) as f64 * 0.45);
            pool.submit_pinned(0, gen.next_window(), deadline).unwrap()
        })
        .collect();
    // Shut down immediately: the drain races three thieves lifting groups
    // off shard 0. Every queued job must still be answered exactly once —
    // a double dispatch would push the request counter past N, a lost job
    // would surface as a dropped reply channel below.
    let m = pool.shutdown();
    assert_eq!(m.aggregate.requests as usize, N);
    assert_eq!(m.per_worker_requests.iter().sum::<u64>() as usize, N);
    for t in tickets {
        assert!(t.wait().is_ok(), "a queued job was dropped during drain");
    }
}
