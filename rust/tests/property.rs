//! Property-based tests over randomized inputs (deterministic seeds via the
//! in-house `check_cases` driver — replays exactly on failure).

use medea::config::estimator::{Estimator, TilingPolicy};
use medea::ir::builder::{encoder_block, small_cnn, TransformerDims};
use medea::ir::{DataWidth, Kernel, KernelType, Shape, Workload};
use medea::manager::medea::Medea;
use medea::platform::heeptimize::{heeptimize, CARUS, CGRA};
use medea::platform::loader::{platform_from_json, platform_to_json};
use medea::profile::characterize;
use medea::solver::{random_instance, BranchBound, DpSolver, GreedySolver, LagrangeSolver, McKpSolver};
use medea::tiling::modes::TilingMode;
use medea::tiling::plan::plan_kernel;
use medea::timing::cycle_model::CycleModel;
use medea::util::json::parse;
use medea::util::rng::{check_cases, Rng};
use medea::util::units::{Bytes, Time};

// ---- MCKP solver invariants -------------------------------------------

#[test]
fn solver_sandwich_property() {
    // For every random instance: lagrange lower bound ≤ bb ≈ dp ≤ greedy,
    // and every returned solution is feasible.
    check_cases(0xC0FFEE, 25, |rng, case| {
        let groups = rng.usize_below(20) + 3;
        let items = rng.usize_below(8) + 2;
        let inst = random_instance(rng, groups, items);
        let dp = DpSolver::with_resolution(30_000).solve(&inst);
        let bb = BranchBound::default().solve(&inst);
        let gr = GreedySolver.solve(&inst);
        let lb = LagrangeSolver::default().lower_bound(&inst);
        match (dp, bb, gr, lb) {
            (Some(d), Some(b), Some(g), Some(l)) => {
                for s in [&d, &b, &g] {
                    assert!(s.total_time <= inst.deadline + 1e-9, "case {case}: infeasible");
                }
                assert!(
                    l <= d.total_energy + d.total_energy.abs() * 1e-6,
                    "case {case}: bound {l} above dp {}",
                    d.total_energy
                );
                let rel = (b.total_energy - d.total_energy).abs() / d.total_energy;
                assert!(rel < 5e-3, "case {case}: bb vs dp {rel}");
                assert!(
                    g.total_energy >= d.total_energy * 0.995,
                    "case {case}: greedy {} below exact {}",
                    g.total_energy,
                    d.total_energy
                );
            }
            (None, None, None, None) => {}
            other => panic!("case {case}: solvers disagree on feasibility: {other:?}"),
        }
    });
}

// ---- tiling invariants --------------------------------------------------

fn random_kernel(rng: &mut Rng) -> Kernel {
    let dw = *rng.choose(&[DataWidth::Int8, DataWidth::Int16, DataWidth::Int32]);
    let d = |rng: &mut Rng| rng.range_u64(1, 300);
    match rng.below(5) {
        0 => Kernel::new(
            "mm",
            KernelType::MatMul,
            Shape::MatMul { m: d(rng), k: d(rng), n: d(rng) },
            dw,
        ),
        1 => Kernel::new(
            "add",
            KernelType::Add,
            Shape::Elementwise { n: rng.range_u64(1, 100_000), arity: 2 },
            dw,
        ),
        2 => Kernel::new(
            "norm",
            KernelType::Norm,
            Shape::Rowwise { rows: d(rng), cols: rng.range_u64(1, 400) },
            dw,
        ),
        3 => Kernel::new(
            "t",
            KernelType::Transpose,
            Shape::Transpose { rows: d(rng), cols: rng.range_u64(1, 400) },
            dw,
        ),
        _ => Kernel::new(
            "conv",
            KernelType::Conv2d,
            Shape::Conv2d {
                h: rng.range_u64(1, 32),
                w: rng.range_u64(1, 32),
                c_in: rng.range_u64(1, 32),
                c_out: rng.range_u64(1, 32),
                kh: 3,
                kw: 3,
            },
            dw,
        ),
    }
}

#[test]
fn tiling_plan_invariants() {
    check_cases(0x7114E, 300, |rng, case| {
        let kernel = random_kernel(rng);
        let budget = Bytes(rng.range_u64(512, 128 * 1024));
        let max_dim = if rng.bool() { Some(rng.range_u64(8, 1024)) } else { None };
        let Some(plan) = plan_kernel(&kernel, budget, max_dim) else {
            return; // legitimately untileable for this budget
        };
        // Traffic covers at least the raw operand bytes (reloads only add).
        assert!(
            plan.traffic_in.raw() + 1 >= kernel.shape.input_bytes(kernel.dw).raw(),
            "case {case}: in-traffic below operand size for {kernel:?}"
        );
        assert!(
            plan.traffic_out == kernel.shape.output_bytes(kernel.dw),
            "case {case}: out-traffic mismatch"
        );
        // Chaining discount never exceeds the activation bytes or traffic.
        assert!(plan.chainable_in.raw() <= kernel.shape.activation_bytes(kernel.dw).raw());
        assert!(plan.chainable_in.raw() <= plan.traffic_in.raw());
        // For streaming shapes (no reload amplification), halving the
        // budget never reduces tiles and never changes traffic. Matmul/conv
        // legitimately trade strip width for panel width, so only the
        // operand-minimum bound applies there.
        let streaming = !matches!(
            kernel.shape,
            Shape::MatMul { .. } | Shape::Conv2d { .. }
        );
        if let Some(half) = plan_kernel(&kernel, Bytes(budget.raw() / 2), max_dim) {
            if streaming {
                assert!(half.n_tiles >= plan.n_tiles, "case {case}: tiles shrank");
                assert_eq!(
                    half.traffic_in, plan.traffic_in,
                    "case {case}: streaming traffic changed with budget"
                );
            } else {
                assert!(
                    half.traffic_in.raw() + 1 >= kernel.shape.input_bytes(kernel.dw).raw(),
                    "case {case}: half-budget traffic below operand size"
                );
            }
        }
    });
}

#[test]
fn mode_cycles_relationships() {
    // For every kernel × accelerator: adaptive ≤ forced-db; both ≥ pure
    // compute cycles (DMA and overheads only ever add).
    let platform = heeptimize();
    let model = CycleModel::heeptimize();
    let profiles = characterize(&platform, &model);
    check_cases(0xAB1E, 200, |rng, case| {
        let kernel = random_kernel(rng);
        let est = Estimator::new(&platform, &profiles, &model);
        let est_db =
            Estimator::new(&platform, &profiles, &model).with_policy(TilingPolicy::ForceDouble);
        for pe in [CGRA, CARUS] {
            let (Some((_, ad)), Some((_, db))) = (est.best_mode(pe, &kernel), est_db.best_mode(pe, &kernel))
            else {
                continue;
            };
            assert!(ad <= db, "case {case}: adaptive worse than forced db on {pe}");
            if let Some(compute) = est.processing_cycles(pe, &kernel) {
                assert!(ad >= compute, "case {case}: total below compute");
            }
        }
    });
}

// ---- scheduler invariants over random workloads --------------------------

fn random_workload(rng: &mut Rng) -> Workload {
    match rng.below(2) {
        0 => {
            let mut w = Workload::new("rand-transformer");
            let dims = TransformerDims {
                seq: rng.range_u64(8, 128),
                d_model: 16 * rng.range_u64(1, 8),
                heads: *rng.choose(&[1, 2, 4]),
                d_ff: 16 * rng.range_u64(1, 16),
                dw: DataWidth::Int8,
                dw_row: DataWidth::Int16,
            };
            for b in 0..rng.range_u64(1, 3) {
                encoder_block(&mut w, &format!("e{b}"), dims);
            }
            w
        }
        _ => small_cnn(
            "rand-cnn",
            rng.range_u64(4, 24),
            rng.range_u64(4, 24),
            &[
                rng.range_u64(1, 8),
                rng.range_u64(4, 32),
                rng.range_u64(4, 32),
            ],
            rng.range_u64(2, 12),
            DataWidth::Int8,
        ),
    }
}

#[test]
fn medea_schedules_random_workloads() {
    let platform = heeptimize();
    let model = CycleModel::heeptimize();
    let profiles = characterize(&platform, &model);
    check_cases(0x5EED, 20, |rng, case| {
        let w = random_workload(rng);
        let medea = Medea::new(&platform, &profiles, &model);
        // A generous deadline must always be feasible and optimal.
        let relaxed = medea
            .schedule(&w, Time::from_ms(10_000.0))
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        relaxed.validate(&w, &platform).unwrap();
        assert!(relaxed.meets_deadline());
        // Tightening to the relaxed makespan stays feasible; the energy is
        // monotone non-increasing as the deadline relaxes.
        let tight = medea.schedule(&w, relaxed.active_time() * 1.2);
        if let Ok(t) = tight {
            t.validate(&w, &platform).unwrap();
            assert!(
                t.active_energy().raw() >= relaxed.active_energy().raw() * 0.999,
                "case {case}: tighter deadline yielded less energy"
            );
        }
    });
}

// ---- platform JSON fuzz ---------------------------------------------------

#[test]
fn platform_json_round_trip_preserves_values() {
    // Unit conversion (W <-> uW) may move floats by an ulp per trip, so
    // exact string fixpoints are not guaranteed; values must stay within
    // a few ulps across repeated round trips, and structure must be exact.
    let mut p = heeptimize();
    let reference = heeptimize();
    for _ in 0..4 {
        p = platform_from_json(&parse(&platform_to_json(&p).to_pretty()).unwrap()).unwrap();
    }
    assert_eq!(p.pes.len(), reference.pes.len());
    assert_eq!(p.vf.points().len(), reference.vf.points().len());
    assert_eq!(
        p.constraints.iter().count(),
        reference.constraints.iter().count()
    );
    let close = |a: f64, b: f64| (a - b).abs() <= a.abs().max(b.abs()) * 1e-9;
    assert!(close(p.sleep_power.raw(), reference.sleep_power.raw()));
    for (a, b) in p.pes.iter().zip(&reference.pes) {
        assert!(close(a.power.p_stat_ref.raw(), b.power.p_stat_ref.raw()));
        assert!(close(a.power.c_eff, b.power.c_eff));
        assert!(close(a.power.e_fixed, b.power.e_fixed));
        assert_eq!(a.lm, b.lm);
        assert_eq!(a.dma, b.dma);
    }
}

#[test]
fn json_codec_fuzz_round_trip() {
    use medea::util::json::{Json, JsonObj};
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool()),
            2 => Json::Num((rng.range_f64(-1e9, 1e9) * 1e3).round() / 1e3),
            3 => {
                let len = rng.usize_below(12);
                Json::Str(
                    (0..len)
                        .map(|_| *rng.choose(&['a', 'é', '"', '\\', '\n', '😀', ' ', 'z']))
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.usize_below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut o = JsonObj::new();
                for i in 0..rng.usize_below(5) {
                    o.insert(format!("k{i}"), random_json(rng, depth - 1));
                }
                Json::Obj(o)
            }
        }
    }
    check_cases(0x15AC, 200, |rng, case| {
        let v = random_json(rng, 3);
        for text in [v.to_pretty(), v.to_compact()] {
            let back = parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
            assert_eq!(back, v, "case {case}");
        }
    });
}
