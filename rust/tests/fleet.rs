//! Fleet-subsystem integration tests: one registry serving multiple
//! platform/workload entries, live hot-swap under traffic, energy-budget
//! resolution, the on-disk library round trip, and the reload watcher
//! that bridges on-disk swaps into a running registry.

use medea::eeg::synth::{EegGenerator, SynthConfig};
use medea::fleet::{
    load_library, save_library, swap_entry, Demand, EnergyAtlasConfig, FleetConfig, FleetEntry,
    FleetPool, FleetPoolConfig, FleetRegistry,
};
use medea::serve::{AtlasConfig, Rejection};
use medea::sim::replay::simulate;
use medea::util::rng::Rng;
use medea::util::units::Energy;
use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

const PLATFORMS: [&str; 2] = ["heeptimize", "heeptimize-hp"];
const WORKLOADS: [&str; 2] = ["tsd-core", "tsd-small"];

/// Coarse sweeps keep the 2×2 build affordable; correctness properties do
/// not depend on knot density.
fn fast_cfg() -> FleetConfig {
    FleetConfig {
        atlas: AtlasConfig {
            relax_factor: 6.0,
            growth: 1.7,
            refine_rel_energy: 0.0,
            max_knots: 12,
            ..AtlasConfig::default()
        },
        energy: EnergyAtlasConfig {
            growth: 1.7,
            max_knots: 6,
            bisect_iters: 10,
            ..EnergyAtlasConfig::default()
        },
    }
}

/// The full 2 platforms × 2 workloads library, built once per test binary.
fn shared_registry() -> Arc<FleetRegistry> {
    static REG: OnceLock<Arc<FleetRegistry>> = OnceLock::new();
    REG.get_or_init(|| {
        let registry = FleetRegistry::new();
        for p in PLATFORMS {
            for w in WORKLOADS {
                registry.publish(FleetEntry::build(p, w, &fast_cfg()).unwrap());
            }
        }
        Arc::new(registry)
    })
    .clone()
}

fn pool_config(workers: usize) -> FleetPoolConfig {
    FleetPoolConfig {
        workers,
        queue_capacity: 64,
        // Nonexistent on purpose: exercises the schedule-only path.
        artifact_dir: PathBuf::from("/nonexistent-artifacts"),
        ..FleetPoolConfig::default()
    }
}

#[test]
fn one_registry_serves_two_platforms_and_two_workloads() {
    let registry = shared_registry();
    assert_eq!(registry.len(), 4);
    let pool = FleetPool::start(registry.clone(), pool_config(2)).unwrap();
    let mut gen = EegGenerator::new(SynthConfig::default(), 7);

    let mut tickets = Vec::new();
    for p in PLATFORMS {
        for w in WORKLOADS {
            let floor = registry.resolve_named(p, w).unwrap().entry.atlas.floor();
            for _ in 0..2 {
                let ticket = pool
                    .submit(p, w, gen.next_window(), Demand::Deadline(floor * 4.0))
                    .unwrap();
                tickets.push((p, w, ticket));
            }
        }
    }
    for (p, w, ticket) in tickets {
        let out = ticket.wait().unwrap();
        assert_eq!(out.platform, p);
        assert_eq!(out.workload, w);
        assert!(out.sim.deadline_met, "{p}/{w} missed its deadline");
        assert_eq!(out.scheduler, "medea");
    }

    // Unrouteable tags shed with a typed rejection, never a panic or solve.
    let err = pool
        .submit(
            "no-such-soc",
            "tsd-core",
            gen.next_window(),
            Demand::Deadline(medea::util::units::Time::from_ms(100.0)),
        )
        .unwrap_err();
    assert!(matches!(err, Rejection::UnknownEntry { .. }), "got {err:?}");

    let m = pool.shutdown();
    assert_eq!(m.workers, 2);
    assert_eq!(m.aggregate.requests, 8);
    assert_eq!(m.aggregate.deadline_misses, 0);
    assert_eq!(m.shed_unknown_entry, 1);
    assert_eq!(m.total_shed(), 1);
}

#[test]
fn hot_swap_mid_stream_changes_lookups_without_rejecting_inflight() {
    // A private registry so the swap does not disturb the shared one.
    let registry = Arc::new(FleetRegistry::new());
    let e1 = FleetEntry::build("heeptimize", "tsd-small", &fast_cfg()).unwrap();
    let key = e1.key;
    let n1 = e1.atlas.len();
    let floor = e1.atlas.floor();
    let epoch1 = registry.publish(e1);

    let pool = FleetPool::start(registry.clone(), pool_config(1)).unwrap();
    let mut gen = EegGenerator::new(SynthConfig::default(), 8);
    let submit = |gen: &mut EegGenerator| {
        pool.submit(
            "heeptimize",
            "tsd-small",
            gen.next_window(),
            Demand::Deadline(floor * 4.0),
        )
        .unwrap()
    };

    // First wave admitted under epoch 1, then swap in a finer rebuild while
    // those jobs are still queued/executing, then a second wave.
    let first: Vec<_> = (0..6).map(|_| submit(&mut gen)).collect();
    let mut finer = fast_cfg();
    finer.atlas.growth = 1.25;
    let e2 = FleetEntry::build("heeptimize", "tsd-small", &finer).unwrap();
    assert_eq!(e2.key, key, "same content must key identically");
    let n2 = e2.atlas.len();
    assert!(n2 >= n1, "finer sweep lost knots ({n2} vs {n1})");
    let epoch2 = registry.publish(e2);
    assert!(epoch2 > epoch1);
    let second: Vec<_> = (0..6).map(|_| submit(&mut gen)).collect();

    // Every in-flight request of the first wave completes under the entry
    // it was admitted with; the second wave sees the swapped entry.
    for ticket in first {
        let out = ticket.wait().unwrap();
        assert_eq!(out.epoch, epoch1);
        assert!(out.sim.deadline_met);
    }
    for ticket in second {
        let out = ticket.wait().unwrap();
        assert_eq!(out.epoch, epoch2);
        assert!(out.sim.deadline_met);
    }
    assert_eq!(registry.resolve(&key).unwrap().entry.atlas.len(), n2);

    let m = pool.shutdown();
    assert_eq!(m.aggregate.requests, 12);
    assert_eq!(m.total_shed(), 0);
}

#[test]
fn energy_budget_requests_resolve_through_the_library() {
    let registry = shared_registry();
    let resolved = registry.resolve_named("heeptimize", "tsd-small").unwrap();
    let entry = &resolved.entry;
    let floor = entry.energy.floor();

    // Sim-validated knots: any cap at or above the floor resolves to a
    // schedule whose *simulated* active energy fits the cap.
    let mut rng = Rng::new(0xF1EE7);
    for case in 0..40 {
        let budget = Energy(rng.range_f64(floor.raw(), floor.raw() * 8.0));
        let schedule = entry.energy.resolve(budget).unwrap();
        let sim = simulate(&entry.workload, &entry.platform, &entry.model, &schedule);
        assert!(
            sim.active_energy.raw() <= budget.raw() * (1.0 + 1e-9),
            "case {case}: cap {:.1} uJ, sim {:.1} uJ",
            budget.as_uj(),
            sim.active_energy.as_uj()
        );
    }

    // The same path through the pool: typed shed below the energy floor,
    // served within the cap above it.
    let pool = FleetPool::start(registry.clone(), pool_config(2)).unwrap();
    let mut gen = EegGenerator::new(SynthConfig::default(), 9);
    match pool.submit(
        "heeptimize",
        "tsd-small",
        gen.next_window(),
        Demand::EnergyBudget(floor * 0.4),
    ) {
        Err(Rejection::BelowEnergyFloor { requested, floor: f }) => {
            assert!(requested.raw() < f.raw());
        }
        other => panic!("expected BelowEnergyFloor, got {other:?}"),
    }
    let cap = floor * 2.0;
    let out = pool
        .infer(
            "heeptimize",
            "tsd-small",
            gen.next_window(),
            Demand::EnergyBudget(cap),
        )
        .unwrap();
    assert_eq!(out.demand, Demand::EnergyBudget(cap));
    let knot_budget = out.knot_budget.expect("energy demand records its knot");
    assert!(knot_budget.raw() <= cap.raw() * (1.0 + 1e-9));
    assert!(out.sim.active_energy.raw() <= cap.raw() * (1.0 + 1e-9));

    let m = pool.shutdown();
    assert_eq!(m.shed_below_floor, 1);
    assert_eq!(m.aggregate.requests, 1);
}

#[test]
fn library_round_trips_swaps_and_skips_stale_entries() {
    let dir = std::env::temp_dir().join("medea_fleet_test_lib");
    let _ = std::fs::remove_dir_all(&dir);

    let registry = FleetRegistry::new();
    for p in PLATFORMS {
        registry.publish(FleetEntry::build(p, "tsd-small", &fast_cfg()).unwrap());
    }
    save_library(&dir, &registry).unwrap();

    let loaded = load_library(&dir).unwrap();
    assert_eq!(loaded.len(), 2);
    assert_eq!(loaded.epoch(), registry.epoch());
    for r in registry.entries() {
        let l = loaded.resolve(&r.entry.key).unwrap();
        assert_eq!(l.entry.atlas.len(), r.entry.atlas.len());
        assert_eq!(l.entry.energy.len(), r.entry.energy.len());
        assert!(
            (l.entry.atlas.floor().raw() - r.entry.atlas.floor().raw()).abs() < 1e-12,
            "floor drifted across the disk round trip"
        );
    }

    // An atomic on-disk swap bumps the index epoch and keeps entry count.
    let mut coarser = fast_cfg();
    coarser.atlas.relax_factor = 5.0;
    let e2 = FleetEntry::build("heeptimize", "tsd-small", &coarser).unwrap();
    let epoch = swap_entry(&dir, &e2).unwrap();
    assert_eq!(epoch, registry.epoch() + 1);
    let reloaded = load_library(&dir).unwrap();
    assert_eq!(reloaded.len(), 2);
    assert_eq!(reloaded.epoch(), epoch);

    // Corrupting an entry's content key makes it stale: loading skips it
    // (with a warning) instead of serving schedules for the wrong hardware.
    let path = dir.join("entries").join(format!("{}.json", e2.key));
    let text = std::fs::read_to_string(&path).unwrap();
    let bad = text.replace(
        &e2.key.to_string(),
        "0000000000000000-0000000000000000",
    );
    std::fs::write(&path, bad).unwrap();
    let partial = load_library(&dir).unwrap();
    assert_eq!(partial.len(), 1);
    assert!(partial.resolve(&e2.key).is_none());
}

#[test]
fn reload_watcher_republishes_on_disk_swaps_into_a_running_registry() {
    use medea::fleet::{index_epoch, reload_library_into, watch_library};
    use std::time::{Duration, Instant};

    let dir = std::env::temp_dir().join("medea_fleet_watch_lib");
    let _ = std::fs::remove_dir_all(&dir);

    let seeded = FleetRegistry::new();
    seeded.publish(FleetEntry::build("heeptimize", "tsd-small", &fast_cfg()).unwrap());
    save_library(&dir, &seeded).unwrap();

    let registry = Arc::new(load_library(&dir).unwrap());
    assert_eq!(registry.len(), 1);

    // A second entry lands on disk behind the running registry's back.
    let e2 = FleetEntry::build("heeptimize-hp", "tsd-small", &fast_cfg()).unwrap();
    let key2 = e2.key;
    let disk_epoch = swap_entry(&dir, &e2).unwrap();
    assert!(registry.resolve(&key2).is_none(), "nothing reloaded yet");
    assert_eq!(index_epoch(&dir).unwrap(), disk_epoch);

    // One manual bridge pass publishes exactly the new entry and catches
    // the registry's epoch up to the on-disk index; a second pass finds
    // nothing new.
    assert_eq!(reload_library_into(&dir, &registry).unwrap(), 1);
    assert!(registry.resolve(&key2).is_some());
    assert!(registry.epoch() >= disk_epoch);
    assert_eq!(reload_library_into(&dir, &registry).unwrap(), 0);

    // The background watcher notices a third swap on its own.
    let watcher = watch_library(&dir, registry.clone(), Duration::from_millis(25));
    let e3 = FleetEntry::build("heeptimize", "tsd-core", &fast_cfg()).unwrap();
    let key3 = e3.key;
    swap_entry(&dir, &e3).unwrap();
    let give_up = Instant::now() + Duration::from_secs(10);
    while registry.resolve(&key3).is_none() && Instant::now() < give_up {
        std::thread::sleep(Duration::from_millis(10));
    }
    watcher.stop();
    assert!(
        registry.resolve(&key3).is_some(),
        "watcher never republished the on-disk swap"
    );
    assert_eq!(registry.len(), 3);
}

#[test]
fn fleet_batches_coalesce_per_entry_and_respect_demands() {
    use medea::serve::BatchConfig;
    let registry = shared_registry();
    let pool = FleetPool::start(
        registry.clone(),
        FleetPoolConfig {
            batch: BatchConfig {
                max_batch: 8,
                ..BatchConfig::default()
            },
            ..pool_config(1)
        },
    )
    .unwrap();
    let mut gen = EegGenerator::new(SynthConfig::default(), 31);

    // A single-worker burst of lax same-entry deadline demands: batches
    // must form, and every member must still meet the deadline it asked
    // for (deadline monotonicity through the fleet path).
    let floor = registry
        .resolve_named("heeptimize", "tsd-small")
        .unwrap()
        .entry
        .atlas
        .floor();
    let tickets: Vec<_> = (0..48)
        .map(|_| {
            pool.submit(
                "heeptimize",
                "tsd-small",
                gen.next_window(),
                Demand::Deadline(floor * 48.0),
            )
            .unwrap()
        })
        .collect();
    let mut max_batch_seen = 0;
    for t in tickets {
        let out = t.wait().unwrap();
        assert!(out.sim.deadline_met, "batched member missed its deadline");
        assert!(out.batch_size >= 1 && out.batch_size <= 8);
        max_batch_seen = max_batch_seen.max(out.batch_size);
    }

    // Energy-budget demands batch under the dual check: the amortized
    // per-member share must fit every member's requested cap.
    let e_floor = registry
        .resolve_named("heeptimize", "tsd-small")
        .unwrap()
        .entry
        .energy
        .floor();
    let caps = [e_floor * 1.5, e_floor * 2.0, e_floor * 3.0];
    let tickets: Vec<_> = (0..24)
        .map(|i| {
            let cap = caps[i % caps.len()];
            pool.submit(
                "heeptimize",
                "tsd-small",
                gen.next_window(),
                Demand::EnergyBudget(cap),
            )
            .map(|t| (cap, t))
            .unwrap()
        })
        .collect();
    for (cap, t) in tickets {
        let out = t.wait().unwrap();
        assert!(
            out.sim.active_energy.raw() <= cap.raw() + 1e-12,
            "amortized share {:.2} uJ exceeds the requested cap {:.2} uJ",
            out.sim.active_energy.as_uj(),
            cap.as_uj()
        );
        assert!(out.sim.deadline_met, "energy member marked as missing its demand");
    }

    let m = pool.shutdown();
    assert_eq!(m.aggregate.requests, 48 + 24);
    assert_eq!(m.aggregate.deadline_misses, 0);
    assert_eq!(m.batched_requests() + m.solo_requests(), 48 + 24);
    assert!(
        max_batch_seen >= 2,
        "single-worker burst formed no batches at all"
    );
}
