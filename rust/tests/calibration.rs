//! Calibration integration test: the reproduced system must land in the
//! paper's quantitative envelope (shapes and rough magnitudes, not exact
//! numbers — see DESIGN.md "Calibration anchors").

use medea::baselines::{coarse_grain_app_dvfs, cpu_max_vf, static_accel_app_dvfs, static_accel_max_vf};
use medea::ir::tsd::{tsd_core, TsdParams};
use medea::manager::medea::{Medea, MedeaFeatures};
use medea::platform::heeptimize::heeptimize;
use medea::profile::characterize;
use medea::timing::cycle_model::CycleModel;
use medea::util::units::Time;

#[test]
fn paper_envelope() {
    let platform = heeptimize();
    let model = CycleModel::heeptimize();
    let profiles = characterize(&platform, &model);
    let w = tsd_core(&TsdParams::default());

    // ---- Table 5 shape: MEDEA across the three deadlines ---------------
    let medea = Medea::new(&platform, &profiles, &model);
    let s50 = medea.schedule(&w, Time::from_ms(50.0)).unwrap();
    let s200 = medea.schedule(&w, Time::from_ms(200.0)).unwrap();
    let s1000 = medea.schedule(&w, Time::from_ms(1000.0)).unwrap();

    let report = |tag: &str, s: &medea::manager::Schedule| {
        println!(
            "{tag}: active {:.1} ms, active energy {:.0} uJ, total {:.0} uJ, switches {}",
            s.active_time().as_ms(),
            s.active_energy().as_uj(),
            s.total_energy(&platform).as_uj(),
            s.vf_switch_count(),
        );
    };
    report("MEDEA@50ms ", &s50);
    report("MEDEA@200ms", &s200);
    report("MEDEA@1000ms", &s1000);

    // Paper: active time 50 / 200 / 223 ms. The relaxed schedule must be
    // deadline-insensitive (lowest V-F everywhere) and land near 200 ms so
    // that the 200 ms deadline bites and 1000 ms does not.
    let t1000 = s1000.active_time().as_ms();
    assert!(
        (150.0..300.0).contains(&t1000),
        "min-V active time {t1000:.1} ms outside the 223 ms envelope"
    );
    assert!(t1000 > 200.0, "the 200 ms deadline must be binding (paper: 223 ms)");

    // Paper: 946 / 395 / 368 µJ active. Check ratios, loosely.
    let e50 = s50.active_energy().as_uj();
    let e200 = s200.active_energy().as_uj();
    let e1000 = s1000.active_energy().as_uj();
    println!("active energies: {e50:.0} / {e200:.0} / {e1000:.0} uJ (paper 946/395/368)");
    assert!(e50 / e200 > 1.6 && e50 / e200 < 4.5, "50/200 ratio {:.2}", e50 / e200);
    assert!(e200 / e1000 > 1.0 && e200 / e1000 < 1.5, "200/1000 ratio {:.2}", e200 / e1000);
    // Absolute scale within ~2× of the paper.
    assert!((400.0..2200.0).contains(&e50), "e50 {e50:.0} uJ");
    assert!((150.0..900.0).contains(&e200), "e200 {e200:.0} uJ");

    // ---- Fig 5 shape: savings vs CoarseGrain ----------------------------
    let mut fig5_failures: Vec<String> = Vec::new();
    for (ms, lo, hi, paper) in [
        (50.0, 0.04, 0.30, 0.14),
        (200.0, 0.15, 0.55, 0.38),
        (1000.0, 0.02, 0.20, 0.07),
    ] {
        let d = Time::from_ms(ms);
        let cg = coarse_grain_app_dvfs(&w, &platform, &profiles, &model, d).unwrap();
        let m = medea.schedule(&w, d).unwrap();
        let saving = 1.0 - m.total_energy(&platform).raw() / cg.total_energy(&platform).raw();
        println!("MEDEA vs CG @{ms} ms: {:.1} % (paper {:.0} %)", saving * 100.0, paper * 100.0);
        if !(lo..hi).contains(&saving) {
            fig5_failures.push(format!(
                "saving at {ms} ms = {:.1} % outside [{:.0}, {:.0}] %",
                saving * 100.0,
                lo * 100.0,
                hi * 100.0
            ));
        }
    }

    // ---- Fig 8 shape: per-feature ablation savings ----------------------
    let ablate = |feats: MedeaFeatures, ms: f64| {
        let d = Time::from_ms(ms);
        let full = medea.schedule(&w, d).unwrap().total_energy(&platform);
        let abl = Medea::new(&platform, &profiles, &model)
            .with_features(feats)
            .schedule(&w, d)
            .unwrap()
            .total_energy(&platform);
        1.0 - full.raw() / abl.raw()
    };

    // Kernel-level DVFS: ~5.6 % @50, ~31.3 % @200, 0 % @1000.
    let kd50 = ablate(MedeaFeatures::without_kernel_dvfs(), 50.0);
    let kd200 = ablate(MedeaFeatures::without_kernel_dvfs(), 200.0);
    let kd1000 = ablate(MedeaFeatures::without_kernel_dvfs(), 1000.0);
    println!("KerDVFS savings: {:.1} / {:.1} / {:.1} % (paper 5.6/31.3/0)", kd50 * 100.0, kd200 * 100.0, kd1000 * 100.0);
    assert!(kd200 > kd50, "DVFS must matter most at the 200 ms sweet spot");
    assert!((0.10..0.50).contains(&kd200), "KerDVFS@200 {:.3}", kd200);
    assert!(kd1000.abs() < 0.01, "KerDVFS@1000 must vanish: {:.3}", kd1000);
    assert!((0.0..0.20).contains(&kd50), "KerDVFS@50 {:.3}", kd50);

    // Adaptive tiling: ~8.1 / 8.5 / 4.8 %.
    let at50 = ablate(MedeaFeatures::without_adaptive_tiling(), 50.0);
    let at200 = ablate(MedeaFeatures::without_adaptive_tiling(), 200.0);
    let at1000 = ablate(MedeaFeatures::without_adaptive_tiling(), 1000.0);
    println!("AdapTile savings: {:.1} / {:.1} / {:.1} % (paper 8.1/8.5/4.8)", at50 * 100.0, at200 * 100.0, at1000 * 100.0);
    for (v, tag) in [(at50, "50"), (at200, "200"), (at1000, "1000")] {
        assert!((0.01..0.20).contains(&v), "AdapTile@{tag} {:.3}", v);
    }

    // Kernel-level scheduling: ~1.0–2.8 %.
    let ks50 = ablate(MedeaFeatures::without_kernel_sched(), 50.0);
    let ks200 = ablate(MedeaFeatures::without_kernel_sched(), 200.0);
    let ks1000 = ablate(MedeaFeatures::without_kernel_sched(), 1000.0);
    println!("KerSched savings: {:.1} / {:.1} / {:.1} % (paper 2.8/1.0/1.1)", ks50 * 100.0, ks200 * 100.0, ks1000 * 100.0);
    for (v, tag) in [(ks50, "50"), (ks200, "200"), (ks1000, "1000")] {
        assert!((-0.005..0.12).contains(&v), "KerSched@{tag} {:.3}", v);
    }

    assert!(fig5_failures.is_empty(), "{fig5_failures:?}");

    // ---- Fig 5: full baseline sweep printed for the record --------------
    for ms in [50.0, 200.0, 1000.0] {
        let d = Time::from_ms(ms);
        for (name, s) in [
            ("cpu", cpu_max_vf(&w, &platform, &profiles, &model, d).unwrap()),
            ("sa-max", static_accel_max_vf(&w, &platform, &profiles, &model, d).unwrap()),
            ("sa-dvfs", static_accel_app_dvfs(&w, &platform, &profiles, &model, d).unwrap()),
            ("cg", coarse_grain_app_dvfs(&w, &platform, &profiles, &model, d).unwrap()),
            ("medea", medea.schedule(&w, d).unwrap()),
        ] {
            println!(
                "fig5 @{ms:>4} ms {name:>8}: E_t {:>7.0} uJ, T_a {:>6.1} ms, meets={}",
                s.total_energy(&platform).as_uj(),
                s.active_time().as_ms(),
                s.meets_deadline()
            );
        }
    }
}
