//! The repo-wide gate behind the `medea lint` tentpole: the entire `src/`
//! tree must lint clean in every plain `cargo test` run, so a new
//! unjustified atomic ordering, serving-path `.unwrap()`, nested shard
//! lock, or design-time wall-clock read fails CI without anyone having to
//! remember to run the linter.

use medea::analysis::lint_paths;
use std::path::PathBuf;

#[test]
fn repo_sources_lint_clean() {
    let src = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let findings = lint_paths(&[src]).expect("walking rust/src");
    let rendered: Vec<String> = findings.iter().map(|f| f.display()).collect();
    assert!(
        findings.is_empty(),
        "`medea lint` must be clean over src/ — fix or justify:\n{}",
        rendered.join("\n")
    );
}
