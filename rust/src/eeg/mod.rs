//! Synthetic EEG generation + the Rust-side FFT-magnitude frontend.
//!
//! The TUSZ corpus is gated, so end-to-end validation uses synthetic EEG
//! (DESIGN.md substitution ledger): 1/f-shaped background activity with
//! superimposed 3 Hz spike-wave bursts during seizure episodes — the
//! textbook electrographic signature the TSD case study detects.

pub mod frontend;
pub mod synth;

pub use frontend::{fft_magnitude, window_features, Fft};
pub use synth::{EegGenerator, EegWindow, SynthConfig};
