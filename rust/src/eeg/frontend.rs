//! Radix-2 FFT + magnitude frontend in Rust.
//!
//! Mirrors `python/compile/model.py::frontend` exactly (same segmentation,
//! same magnitude, same per-patch max-normalization) so the coordinator can
//! stage features for the `tsd_core` artifact without Python; also used to
//! cross-check the `tsd_full` artifact's in-graph frontend.

use std::f64::consts::PI;

/// An iterative radix-2 decimation-in-time FFT (power-of-two sizes) with a
/// precomputed twiddle table.
pub struct Fft {
    n: usize,
    twiddle_re: Vec<f64>,
    twiddle_im: Vec<f64>,
}

impl Fft {
    pub fn new(n: usize) -> Fft {
        assert!(n.is_power_of_two() && n >= 2, "FFT size must be a power of two");
        let half = n / 2;
        let mut twiddle_re = Vec::with_capacity(half);
        let mut twiddle_im = Vec::with_capacity(half);
        for k in 0..half {
            let ang = -2.0 * PI * k as f64 / n as f64;
            twiddle_re.push(ang.cos());
            twiddle_im.push(ang.sin());
        }
        Fft {
            n,
            twiddle_re,
            twiddle_im,
        }
    }

    pub fn size(&self) -> usize {
        self.n
    }

    /// In-place complex FFT over `(re, im)`.
    pub fn forward(&self, re: &mut [f64], im: &mut [f64]) {
        let n = self.n;
        assert_eq!(re.len(), n);
        assert_eq!(im.len(), n);
        // Bit-reversal permutation.
        let bits = n.trailing_zeros();
        for i in 0..n {
            let j = i.reverse_bits() >> (usize::BITS - bits);
            if j > i {
                re.swap(i, j);
                im.swap(i, j);
            }
        }
        // Butterflies.
        let mut len = 2;
        while len <= n {
            let half = len / 2;
            let step = n / len;
            for start in (0..n).step_by(len) {
                for k in 0..half {
                    let w_re = self.twiddle_re[k * step];
                    let w_im = self.twiddle_im[k * step];
                    let a = start + k;
                    let b = a + half;
                    let t_re = re[b] * w_re - im[b] * w_im;
                    let t_im = re[b] * w_im + im[b] * w_re;
                    re[b] = re[a] - t_re;
                    im[b] = im[a] - t_im;
                    re[a] += t_re;
                    im[a] += t_im;
                }
            }
            len *= 2;
        }
    }

    /// Magnitudes of the first `bins` rFFT bins of a real signal.
    pub fn magnitude(&self, signal: &[f32], bins: usize) -> Vec<f32> {
        assert_eq!(signal.len(), self.n);
        assert!(bins <= self.n / 2 + 1);
        let mut re: Vec<f64> = signal.iter().map(|&v| v as f64).collect();
        let mut im = vec![0.0; self.n];
        self.forward(&mut re, &mut im);
        (0..bins)
            .map(|k| ((re[k] * re[k] + im[k] * im[k]).sqrt()) as f32)
            .collect()
    }
}

/// Magnitude spectrum (first `bins` bins) of each `n_fft`-sample segment.
pub fn fft_magnitude(signal: &[f32], n_fft: usize, bins: usize) -> Vec<Vec<f32>> {
    let fft = Fft::new(n_fft);
    signal
        .chunks_exact(n_fft)
        .map(|seg| fft.magnitude(seg, bins))
        .collect()
}

/// The full frontend: (channels × samples) EEG window → (patches ×
/// patch_dim) features, max-normalized per patch. Mirrors
/// `model.py::frontend`.
pub fn window_features(
    data: &[Vec<f32>],
    n_fft: usize,
    patch_dim: usize,
) -> Vec<Vec<f32>> {
    let fft = Fft::new(n_fft);
    let mut feats = Vec::new();
    for chan in data {
        for seg in chan.chunks_exact(n_fft) {
            let mut mag = fft.magnitude(seg, patch_dim);
            let max = mag.iter().fold(0f32, |a, &b| a.max(b)) + 1e-6;
            for v in &mut mag {
                *v /= max;
            }
            feats.push(mag);
        }
    }
    feats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pure_tone_lands_in_its_bin() {
        let n = 256;
        let signal: Vec<f32> = (0..n)
            .map(|i| (2.0 * PI * 8.0 * i as f64 / n as f64).sin() as f32)
            .collect();
        let fft = Fft::new(n);
        let mag = fft.magnitude(&signal, n / 2);
        let peak = mag
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert_eq!(peak, 8);
        // Parseval-ish: tone magnitude ≈ n/2.
        assert!((mag[8] - n as f32 / 2.0).abs() < 1.0);
    }

    #[test]
    fn dc_component() {
        let fft = Fft::new(64);
        let signal = vec![2.0f32; 64];
        let mag = fft.magnitude(&signal, 4);
        assert!((mag[0] - 128.0).abs() < 1e-3);
        assert!(mag[1] < 1e-3);
    }

    #[test]
    fn fft_linearity() {
        let fft = Fft::new(128);
        let a: Vec<f32> = (0..128).map(|i| (i as f32 * 0.1).sin()).collect();
        let b: Vec<f32> = (0..128).map(|i| (i as f32 * 0.37).cos()).collect();
        let sum: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        // |FFT(a+b)| ≤ |FFT(a)| + |FFT(b)| with equality only in-phase;
        // verify via complex parts instead: FFT(a+b) = FFT(a) + FFT(b).
        let run = |s: &[f32]| {
            let mut re: Vec<f64> = s.iter().map(|&v| v as f64).collect();
            let mut im = vec![0.0; s.len()];
            fft.forward(&mut re, &mut im);
            (re, im)
        };
        let (ra, ia) = run(&a);
        let (rb, ib) = run(&b);
        let (rs, is_) = run(&sum);
        // The sum is formed in f32, so linearity holds to f32 rounding.
        for k in 0..128 {
            assert!((rs[k] - (ra[k] + rb[k])).abs() < 1e-3);
            assert!((is_[k] - (ia[k] + ib[k])).abs() < 1e-3);
        }
    }

    #[test]
    fn round_trip_against_naive_dft() {
        let n = 64;
        let signal: Vec<f32> = (0..n).map(|i| ((i * i) % 17) as f32 / 17.0 - 0.5).collect();
        let fft = Fft::new(n);
        let mag = fft.magnitude(&signal, n / 2);
        // Naive DFT.
        for k in 0..n / 2 {
            let (mut re, mut im) = (0.0f64, 0.0f64);
            for (i, &v) in signal.iter().enumerate() {
                let ang = -2.0 * PI * (k * i) as f64 / n as f64;
                re += v as f64 * ang.cos();
                im += v as f64 * ang.sin();
            }
            let want = (re * re + im * im).sqrt() as f32;
            assert!((mag[k] - want).abs() < 1e-4, "bin {k}: {} vs {want}", mag[k]);
        }
    }

    #[test]
    fn window_features_shape_and_normalization() {
        let data = vec![vec![0.5f32; 1536]; 16];
        let feats = window_features(&data, 256, 80);
        assert_eq!(feats.len(), 96);
        assert_eq!(feats[0].len(), 80);
        for p in &feats {
            let max = p.iter().fold(0f32, |a, &b| a.max(b));
            assert!(max <= 1.0 + 1e-5);
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_power_of_two() {
        Fft::new(100);
    }
}
