//! Synthetic multichannel EEG with labeled seizure episodes.

use crate::util::rng::Rng;

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    pub channels: usize,
    /// Samples per channel per window (matches `TsdConfig.window_samples`).
    pub window_samples: usize,
    /// Sampling rate in Hz.
    pub fs: f64,
    /// Background amplitude (arbitrary units; EEG is µV-scale).
    pub background_amp: f64,
    /// Spike-wave amplitude multiplier during seizures.
    pub seizure_amp: f64,
    /// Probability that a generated window contains a seizure.
    pub seizure_prob: f64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            channels: 16,
            window_samples: 1536,
            fs: 256.0,
            background_amp: 1.0,
            seizure_amp: 3.5,
            seizure_prob: 0.3,
        }
    }
}

/// One labeled EEG window: `data[channel][sample]`.
#[derive(Debug, Clone)]
pub struct EegWindow {
    pub data: Vec<Vec<f32>>,
    pub seizure: bool,
    pub index: usize,
}

impl EegWindow {
    /// Flatten to (channels × samples) row-major f32 (the PJRT input layout).
    pub fn flat(&self) -> Vec<f32> {
        self.data.iter().flatten().copied().collect()
    }
}

/// Deterministic (seeded) EEG stream generator.
pub struct EegGenerator {
    cfg: SynthConfig,
    rng: Rng,
    next_index: usize,
    /// Pink-noise filter state per channel (leaky integrators).
    pink_state: Vec<[f64; 3]>,
}

impl EegGenerator {
    pub fn new(cfg: SynthConfig, seed: u64) -> EegGenerator {
        let channels = cfg.channels;
        EegGenerator {
            cfg,
            rng: Rng::new(seed),
            next_index: 0,
            pink_state: vec![[0.0; 3]; channels],
        }
    }

    /// Approximate pink (1/f) noise via three leaky integrators.
    fn pink(&mut self, ch: usize) -> f64 {
        let white = self.rng.gaussian();
        let s = &mut self.pink_state[ch];
        s[0] = 0.997 * s[0] + 0.029 * white;
        s[1] = 0.985 * s[1] + 0.032 * white;
        s[2] = 0.950 * s[2] + 0.048 * white;
        s[0] + s[1] + s[2] + 0.05 * white
    }

    /// Generate the next window (seizure label drawn per `seizure_prob`).
    pub fn next_window(&mut self) -> EegWindow {
        let seizure = self.rng.f64() < self.cfg.seizure_prob;
        self.window_with_label(seizure)
    }

    /// Generate a window with a forced label (tests / demos).
    pub fn window_with_label(&mut self, seizure: bool) -> EegWindow {
        let n = self.cfg.window_samples;
        let fs = self.cfg.fs;
        let mut data = Vec::with_capacity(self.cfg.channels);
        // Seizures are generalized here: all channels show spike-wave, with
        // per-channel phase jitter.
        let spike_f = 3.0; // Hz, classic absence-seizure spike-wave
        for ch in 0..self.cfg.channels {
            let phase = self.rng.range_f64(0.0, 0.4);
            let mut chan = Vec::with_capacity(n);
            for i in 0..n {
                let t = i as f64 / fs;
                let mut v = self.cfg.background_amp * self.pink(ch);
                // Posterior-dominant alpha-ish rhythm in the background.
                v += 0.3 * self.cfg.background_amp * (2.0 * std::f64::consts::PI * 10.0 * t).sin();
                if seizure {
                    // Spike-wave: sharp transient + slow wave each cycle.
                    let cyc = ((t + phase) * spike_f).fract();
                    let spike = if cyc < 0.12 { (1.0 - cyc / 0.12) * 2.2 } else { 0.0 };
                    let wave = (2.0 * std::f64::consts::PI * spike_f * (t + phase)).sin();
                    v += self.cfg.seizure_amp * self.cfg.background_amp * (spike + 0.8 * wave);
                }
                chan.push(v as f32);
            }
            data.push(chan);
        }
        let w = EegWindow {
            data,
            seizure,
            index: self.next_index,
        };
        self.next_index += 1;
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let mut g1 = EegGenerator::new(SynthConfig::default(), 7);
        let mut g2 = EegGenerator::new(SynthConfig::default(), 7);
        let w1 = g1.next_window();
        let w2 = g2.next_window();
        assert_eq!(w1.data.len(), 16);
        assert_eq!(w1.data[0].len(), 1536);
        assert_eq!(w1.flat(), w2.flat());
        assert_eq!(w1.flat().len(), 16 * 1536);
    }

    #[test]
    fn seizure_windows_have_more_low_freq_power() {
        let mut g = EegGenerator::new(SynthConfig::default(), 3);
        let bg = g.window_with_label(false);
        let sz = g.window_with_label(true);
        let power = |w: &EegWindow| -> f64 {
            w.data[0].iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / w.data[0].len() as f64
        };
        assert!(
            power(&sz) > 3.0 * power(&bg),
            "seizure {} vs background {}",
            power(&sz),
            power(&bg)
        );
    }

    #[test]
    fn label_rate_tracks_probability() {
        let mut g = EegGenerator::new(
            SynthConfig {
                seizure_prob: 0.5,
                ..Default::default()
            },
            11,
        );
        let seizures = (0..200).filter(|_| g.next_window().seizure).count();
        assert!((60..140).contains(&seizures), "{seizures}");
    }

    #[test]
    fn signal_is_finite_and_bounded() {
        let mut g = EegGenerator::new(SynthConfig::default(), 5);
        let w = g.window_with_label(true);
        for ch in &w.data {
            for &v in ch {
                assert!(v.is_finite());
                assert!(v.abs() < 100.0);
            }
        }
    }
}
