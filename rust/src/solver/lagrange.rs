//! Lagrangian relaxation for MCKP.
//!
//! Dualize the deadline: `L(λ) = Σ_i min_j (e_ij + λ·t_ij) − λ·T_d`.
//! For each λ the inner minimization decomposes per group; `L(λ)` is a lower
//! bound on the optimal energy for every λ ≥ 0. Bisection finds the λ where
//! the relaxed choice's total time crosses the deadline; the feasible side's
//! choice is returned as the (near-optimal) schedule, the maximal `L(λ)` as
//! the certified bound.

use super::{Instance, McKpSolver, Solution};

pub struct LagrangeSolver {
    pub iterations: usize,
}

impl Default for LagrangeSolver {
    fn default() -> Self {
        LagrangeSolver { iterations: 60 }
    }
}

impl LagrangeSolver {
    /// Per-group argmin of `e + λ·t`.
    fn relaxed_picks(inst: &Instance, lambda: f64) -> (Vec<usize>, f64, f64) {
        let mut picks = Vec::with_capacity(inst.groups.len());
        let mut time = 0.0;
        let mut energy = 0.0;
        for g in &inst.groups {
            let (j, item) = g
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| {
                    (a.energy + lambda * a.time).total_cmp(&(b.energy + lambda * b.time))
                })
                .expect("MCKP group is non-empty");
            picks.push(j);
            time += item.time;
            energy += item.energy;
        }
        (picks, time, energy)
    }

    /// Certified lower bound on the optimal energy (max over probed λ).
    pub fn lower_bound(&self, inst: &Instance) -> Option<f64> {
        self.solve_full(inst).map(|(_, lb)| lb)
    }

    fn solve_full(&self, inst: &Instance) -> Option<(Solution, f64)> {
        if inst.min_time() > inst.deadline {
            return None;
        }
        // λ = 0: unconstrained energy optimum.
        let (picks0, t0, e0) = Self::relaxed_picks(inst, 0.0);
        if t0 <= inst.deadline {
            let sol = Solution::evaluate(picks0, inst, true);
            return Some((sol, e0));
        }

        // Find an upper λ that makes the relaxed choice feasible.
        let mut lo = 0.0f64;
        let mut hi = 1.0f64;
        let mut best_feasible: Option<Solution> = None;
        let mut best_bound = f64::NEG_INFINITY;
        for _ in 0..64 {
            let (picks, t, e) = Self::relaxed_picks(inst, hi);
            best_bound = best_bound.max(e + hi * (t - inst.deadline));
            if t <= inst.deadline {
                best_feasible = Some(Solution::evaluate(picks, inst, false));
                break;
            }
            hi *= 4.0;
        }
        best_feasible.as_ref()?;

        // Bisect λ between infeasible (lo) and feasible (hi).
        for _ in 0..self.iterations {
            let mid = 0.5 * (lo + hi);
            let (picks, t, e) = Self::relaxed_picks(inst, mid);
            best_bound = best_bound.max(e + mid * (t - inst.deadline));
            if t <= inst.deadline {
                let sol = Solution::evaluate(picks, inst, false);
                if best_feasible
                    .as_ref()
                    .map(|b| sol.total_energy < b.total_energy)
                    .unwrap_or(true)
                {
                    best_feasible = Some(sol);
                }
                hi = mid;
            } else {
                lo = mid;
            }
        }
        best_feasible.map(|s| (s, best_bound))
    }
}

impl McKpSolver for LagrangeSolver {
    fn name(&self) -> &'static str {
        "lagrange"
    }

    fn solve(&self, inst: &Instance) -> Option<Solution> {
        self.solve_full(inst).map(|(s, _)| s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::{random_instance, DpSolver, McKpSolver};
    use crate::util::rng::Rng;

    #[test]
    fn bound_sandwiches_optimum() {
        let mut rng = Rng::new(99);
        for case in 0..25 {
            let inst = random_instance(&mut rng, 10, 5);
            let solver = LagrangeSolver::default();
            let Some((sol, bound)) = solver.solve_full(&inst) else {
                continue;
            };
            let opt = DpSolver::with_resolution(50_000).solve(&inst).unwrap();
            assert!(sol.total_time <= inst.deadline + 1e-9, "case {case}");
            // bound ≤ optimal ≤ heuristic
            assert!(
                bound <= opt.total_energy + 1e-9,
                "case {case}: bound {bound} > opt {}",
                opt.total_energy
            );
            assert!(
                sol.total_energy >= opt.total_energy - opt.total_energy * 1e-3,
                "case {case}"
            );
            // Duality gap should be modest on these instances.
            assert!(
                sol.total_energy - bound <= 0.15 * opt.total_energy.abs() + 1e-9,
                "case {case}: gap {} vs opt {}",
                sol.total_energy - bound,
                opt.total_energy
            );
        }
    }

    #[test]
    fn unconstrained_is_exact() {
        let mut rng = Rng::new(5);
        let mut inst = random_instance(&mut rng, 8, 4);
        inst.deadline = 1e9;
        let sol = LagrangeSolver::default().solve(&inst).unwrap();
        assert!(sol.optimal);
        let opt = DpSolver::default().solve(&inst).unwrap();
        assert!((sol.total_energy - opt.total_energy).abs() < 1e-9);
    }

    #[test]
    fn infeasible_none() {
        let mut rng = Rng::new(6);
        let mut inst = random_instance(&mut rng, 8, 4);
        inst.deadline = inst.min_time() * 0.5;
        assert!(LagrangeSolver::default().solve(&inst).is_none());
    }
}
