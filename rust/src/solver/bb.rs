//! Branch-and-bound MCKP solver on continuous time, exact up to a
//! configurable relative optimality gap (default 1e-4, MIP-gap semantics).
//!
//! Depth-first over groups (largest energy spread first), bounding each node
//! with the LP relaxation of the remaining subproblem: the remaining groups'
//! minimum-energy choices if slack allows, otherwise the convex-hull greedy
//! with a fractional last step (a valid lower bound for MCKP). The incumbent
//! starts from [`GreedySolver`], so pruning is effective immediately.

use super::dp::DpSolver;
use super::greedy::GreedySolver;
use super::{Instance, McKpSolver, Solution};

/// One convex-hull upgrade step for the LP bound.
#[derive(Debug, Clone, Copy)]
struct BoundStep {
    /// Owning group's position in the branch order.
    pos: usize,
    d_time: f64,
    d_energy: f64, // negative
}

pub struct BranchBound {
    /// Safety valve: give up exactness beyond this many explored nodes and
    /// return the incumbent (marked non-optimal).
    pub node_limit: usize,
    /// Relative optimality gap (MIP-gap semantics): subtrees that cannot
    /// improve the incumbent by more than `gap` relative are pruned. MEDEA
    /// instances have huge plateaus of near-tied (PE, V-F) configurations;
    /// proving the last 0.01 % exactly costs millions of nodes for no
    /// schedulable difference (§Perf).
    pub gap: f64,
}

impl Default for BranchBound {
    fn default() -> Self {
        BranchBound {
            node_limit: 2_000_000,
            gap: 1e-4,
        }
    }
}

struct SearchCtx<'a> {
    inst: &'a Instance,
    order: Vec<usize>,
    /// Per-group convex hull (for LP bounds), ordered by time.
    hulls: Vec<Vec<usize>>,
    /// Per-group full Pareto frontier (for branching), ordered by time.
    paretos: Vec<Vec<usize>>,
    /// Suffix minima over `order`: min possible time / energy of groups
    /// `order[d..]`.
    suffix_min_time: Vec<f64>,
    suffix_min_energy: Vec<f64>,
    /// Time when every group in `order[d..]` takes its min-energy item.
    suffix_min_energy_time: Vec<f64>,
    /// Suffix sums of the per-group fastest-item (time, energy) base.
    suffix_base_time: Vec<f64>,
    suffix_base_energy: Vec<f64>,
    gap: f64,
    /// All hull upgrade steps, globally sorted by ratio (desc). `pos` is
    /// the owning group's position in `order`; a step is active at depth d
    /// iff `pos >= d` — this makes the LP bound O(S) with no per-node sort
    /// or allocation (§Perf).
    steps_sorted: Vec<BoundStep>,
    best_energy: f64,
    best_picks: Vec<usize>,
    nodes: usize,
    node_limit: usize,
    exhausted: bool,
}

impl<'a> SearchCtx<'a> {
    /// LP-style lower bound for groups `order[depth..]` given `slack` time:
    /// start each at its fastest hull point, then take hull steps in global
    /// ratio order, last one fractionally.
    fn suffix_bound(&self, depth: usize, slack: f64) -> f64 {
        // Cheap bound first: all remaining at unconstrained min energy.
        if self.suffix_min_energy_time[depth] <= slack {
            return self.suffix_min_energy[depth];
        }
        let time = self.suffix_base_time[depth];
        if time > slack {
            return f64::INFINITY; // infeasible suffix
        }
        let mut energy = self.suffix_base_energy[depth];
        let mut remaining = slack - time;
        // Steps pre-sorted by ratio; active iff the owning group is still
        // undecided (pos >= depth).
        for s in &self.steps_sorted {
            if s.pos < depth {
                continue;
            }
            if remaining <= 0.0 {
                break;
            }
            if s.d_time <= remaining {
                remaining -= s.d_time;
                energy += s.d_energy;
            } else {
                energy += s.d_energy * (remaining / s.d_time); // fractional
                remaining = 0.0;
            }
        }
        energy
    }

    fn dfs(&mut self, depth: usize, time: f64, energy: f64, picks: &mut Vec<usize>) {
        if self.nodes >= self.node_limit {
            self.exhausted = true;
            return;
        }
        self.nodes += 1;
        if depth == self.order.len() {
            if energy < self.best_energy {
                self.best_energy = energy;
                // picks is ordered by `order`; scatter to group positions.
                let mut full = vec![0usize; self.inst.groups.len()];
                for (d, &g) in self.order.iter().enumerate() {
                    full[g] = picks[d];
                }
                self.best_picks = full;
            }
            return;
        }
        let slack = self.inst.deadline - time;
        // Prune: feasibility + bound.
        if self.suffix_min_time[depth] > slack {
            return;
        }
        // Prune within the configured relative optimality gap.
        if energy + self.suffix_bound(depth, slack) >= self.best_energy * (1.0 - self.gap) {
            return;
        }
        let g = self.order[depth];
        // Branch over the full Pareto frontier (hull-only branching can miss
        // the ILP optimum), cheapest energy first for good incumbents.
        let pareto = self.paretos[g].clone();
        for &j in pareto.iter().rev() {
            let item = self.inst.groups[g][j];
            if time + item.time > self.inst.deadline {
                continue;
            }
            picks.push(j);
            self.dfs(depth + 1, time + item.time, energy + item.energy, picks);
            picks.pop();
            if self.exhausted {
                return;
            }
        }
    }
}

impl McKpSolver for BranchBound {
    fn name(&self) -> &'static str {
        "bb"
    }

    fn solve(&self, inst: &Instance) -> Option<Solution> {
        if inst.groups.is_empty() {
            return Some(Solution {
                picks: vec![],
                total_time: 0.0,
                total_energy: 0.0,
                optimal: true,
            });
        }
        let (mut incumbent, hulls, _) = GreedySolver::solve_with_state(inst)?;
        // Warm start: a coarse DP solution is near-optimal and prunes the
        // search far harder than the greedy incumbent (§Perf). Exactness is
        // unaffected — the DP pick is just an incumbent.
        if let Some(dp) = DpSolver::with_resolution(8_000).solve(inst) {
            if dp.total_energy < incumbent.total_energy {
                incumbent = dp;
            }
        }
        // Full Pareto frontiers for branching.
        let (filtered, maps) = inst.pareto_filtered();
        let paretos: Vec<Vec<usize>> = filtered
            .groups
            .iter()
            .zip(&maps)
            .map(|(g, map)| (0..g.len()).map(|i| map[i]).collect())
            .collect();

        // Branch order: groups with the largest energy spread first.
        let mut order: Vec<usize> = (0..inst.groups.len()).collect();
        let spread = |g: usize| {
            let h = &hulls[g];
            let items = &inst.groups[g];
            items[h[0]].energy - items[*h.last().unwrap()].energy
        };
        order.sort_by(|&a, &b| spread(b).total_cmp(&spread(a)));

        let n = order.len();
        let mut suffix_min_time = vec![0.0; n + 1];
        let mut suffix_min_energy = vec![0.0; n + 1];
        let mut suffix_min_energy_time = vec![0.0; n + 1];
        let mut suffix_base_time = vec![0.0; n + 1];
        let mut suffix_base_energy = vec![0.0; n + 1];
        for d in (0..n).rev() {
            let g = order[d];
            let h = &hulls[g];
            let items = &inst.groups[g];
            suffix_min_time[d] = suffix_min_time[d + 1] + items[h[0]].time;
            suffix_min_energy[d] =
                suffix_min_energy[d + 1] + items[*h.last().unwrap()].energy;
            suffix_min_energy_time[d] =
                suffix_min_energy_time[d + 1] + items[*h.last().unwrap()].time;
            suffix_base_time[d] = suffix_base_time[d + 1] + items[h[0]].time;
            suffix_base_energy[d] = suffix_base_energy[d + 1] + items[h[0]].energy;
        }

        // Position of each group in the branch order, then the global
        // ratio-sorted step list for the O(S) LP bound.
        let mut pos_of_group = vec![0usize; n];
        for (d, &g) in order.iter().enumerate() {
            pos_of_group[g] = d;
        }
        let mut steps_sorted: Vec<BoundStep> = Vec::new();
        for (g, h) in hulls.iter().enumerate() {
            let items = &inst.groups[g];
            for w in 0..h.len().saturating_sub(1) {
                let a = items[h[w]];
                let b = items[h[w + 1]];
                let dt = b.time - a.time;
                let de = b.energy - a.energy;
                if dt > 0.0 && de < 0.0 {
                    steps_sorted.push(BoundStep {
                        pos: pos_of_group[g],
                        d_time: dt,
                        d_energy: de,
                    });
                }
            }
        }
        steps_sorted.sort_by(|a, b| {
            let ra = -a.d_energy / a.d_time;
            let rb = -b.d_energy / b.d_time;
            rb.total_cmp(&ra)
        });

        let mut ctx = SearchCtx {
            inst,
            order,
            hulls,
            paretos,
            suffix_min_time,
            suffix_min_energy,
            suffix_min_energy_time,
            suffix_base_time,
            suffix_base_energy,
            gap: self.gap,
            steps_sorted,
            best_energy: incumbent.total_energy,
            best_picks: incumbent.picks.clone(),
            nodes: 0,
            node_limit: self.node_limit,
            exhausted: false,
        };
        let mut picks = Vec::with_capacity(n);
        ctx.dfs(0, 0.0, 0.0, &mut picks);
        if std::env::var("MEDEA_BB_DEBUG").is_ok() {
            eprintln!("bb: {} nodes, {} steps", ctx.nodes, ctx.steps_sorted.len());
        }

        Some(Solution::evaluate(ctx.best_picks, inst, !ctx.exhausted))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::{random_instance, DpSolver};
    use crate::util::rng::Rng;

    #[test]
    fn matches_dp_on_random_instances() {
        let mut rng = Rng::new(4242);
        for case in 0..30 {
            let inst = random_instance(&mut rng, 10, 6);
            let bb = BranchBound::default().solve(&inst);
            let dp = DpSolver::with_resolution(100_000).solve(&inst);
            match (bb, dp) {
                (Some(b), Some(d)) => {
                    assert!(b.total_time <= inst.deadline + 1e-9);
                    let rel =
                        (b.total_energy - d.total_energy).abs() / d.total_energy.max(1e-12);
                    assert!(
                        rel < 5e-3,
                        "case {case}: bb {} vs dp {}",
                        b.total_energy,
                        d.total_energy
                    );
                }
                (None, None) => {}
                (b, d) => panic!("case {case}: {b:?} vs {d:?}"),
            }
        }
    }

    #[test]
    fn infeasible_is_none() {
        let mut rng = Rng::new(1);
        let mut inst = random_instance(&mut rng, 5, 3);
        inst.deadline = inst.min_time() * 0.9;
        assert!(BranchBound::default().solve(&inst).is_none());
    }

    #[test]
    fn larger_instance_is_fast_and_optimal() {
        let mut rng = Rng::new(77);
        let inst = random_instance(&mut rng, 120, 12);
        let sol = BranchBound::default().solve(&inst).unwrap();
        assert!(sol.optimal, "node limit hit on a medium instance");
        assert!(sol.total_time <= inst.deadline + 1e-9);
    }
}
