//! Dominance-filtered incremental-efficiency greedy for MCKP.
//!
//! Classic construction: start every group at its fastest item (the only
//! guaranteed-feasible base), then repeatedly apply the single upgrade step
//! with the best energy-saved-per-extra-time ratio that still fits the
//! remaining slack. With LP-convex upgrade lists this is the integral
//! truncation of the LP optimum — typically within a fraction of a percent
//! of optimal on MEDEA instances, and what [`super::bb`] uses for bounds.

use super::{Instance, Item, McKpSolver, Solution};

pub struct GreedySolver;

/// A potential upgrade step inside one group's convex frontier.
#[derive(Debug, Clone, Copy)]
struct Step {
    group: usize,
    to_item: usize,
    d_time: f64,
    ratio: f64, // energy saved per extra second (≥ 0)
}

/// Build each group's convex (lower-hull) frontier over (time, energy),
/// returning per-group hull item indices sorted by increasing time.
pub(crate) fn convex_frontiers(inst: &Instance) -> Vec<Vec<usize>> {
    inst.groups
        .iter()
        .map(|g| {
            let mut idx: Vec<usize> = (0..g.len()).collect();
            // total_cmp: NaN items (corrupt estimates) order totally and
            // deterministically instead of panicking; NaN energies never
            // beat a finite `best_e` below, so they drop out of the hull.
            idx.sort_by(|&a, &b| {
                g[a].time
                    .total_cmp(&g[b].time)
                    .then(g[a].energy.total_cmp(&g[b].energy))
            });
            // Pareto filter (strictly decreasing energy with time).
            let mut pareto: Vec<usize> = Vec::new();
            let mut best_e = f64::INFINITY;
            for i in idx {
                if g[i].energy < best_e {
                    best_e = g[i].energy;
                    pareto.push(i);
                }
            }
            // Lower convex hull over (time, energy).
            let mut hull: Vec<usize> = Vec::new();
            for &i in &pareto {
                while hull.len() >= 2 {
                    let a = g[hull[hull.len() - 2]];
                    let b = g[hull[hull.len() - 1]];
                    let c = g[i];
                    // slope(a→b) must be steeper (more saving/time) than
                    // slope(b→c); otherwise b is not on the hull.
                    let s_ab = (b.energy - a.energy) / (b.time - a.time);
                    let s_bc = (c.energy - b.energy) / (c.time - b.time);
                    if s_ab >= s_bc {
                        hull.pop();
                    } else {
                        break;
                    }
                }
                hull.push(i);
            }
            hull
        })
        .collect()
}

impl GreedySolver {
    /// Shared with the LP bound: returns (solution, per-group hull position).
    pub(crate) fn solve_with_state(inst: &Instance) -> Option<(Solution, Vec<Vec<usize>>, Vec<usize>)> {
        if inst.min_time() > inst.deadline {
            return None;
        }
        let hulls = convex_frontiers(inst);
        // Start at the fastest hull item per group.
        let mut pos: Vec<usize> = vec![0; inst.groups.len()];
        let mut time: f64 = inst
            .groups
            .iter()
            .zip(&hulls)
            .map(|(g, h)| g[h[0]].time)
            .sum();

        // All candidate steps, best ratio first.
        let mut steps: Vec<Step> = Vec::new();
        for (gi, h) in hulls.iter().enumerate() {
            for w in 0..h.len().saturating_sub(1) {
                let a: Item = inst.groups[gi][h[w]];
                let b: Item = inst.groups[gi][h[w + 1]];
                let d_time = b.time - a.time;
                let d_energy = b.energy - a.energy;
                if d_time <= 0.0 || d_energy >= 0.0 {
                    continue;
                }
                steps.push(Step {
                    group: gi,
                    to_item: w + 1,
                    d_time,
                    ratio: -d_energy / d_time,
                });
            }
        }
        steps.sort_by(|a, b| b.ratio.total_cmp(&a.ratio));

        // Apply steps in ratio order; hull convexity guarantees in-group
        // steps appear in position order among applicable ones.
        for s in &steps {
            if pos[s.group] + 1 != s.to_item {
                continue; // an earlier (steeper) step in this group was skipped
            }
            if time + s.d_time <= inst.deadline {
                pos[s.group] = s.to_item;
                time += s.d_time;
            }
        }

        let picks: Vec<usize> = pos.iter().zip(&hulls).map(|(&p, h)| h[p]).collect();
        Some((Solution::evaluate(picks, inst, false), hulls, pos))
    }
}

impl McKpSolver for GreedySolver {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn solve(&self, inst: &Instance) -> Option<Solution> {
        Self::solve_with_state(inst).map(|(s, _, _)| s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::{random_instance, DpSolver};
    use crate::util::rng::Rng;

    #[test]
    fn hull_drops_non_convex_points() {
        let inst = Instance {
            groups: vec![vec![
                Item { time: 1.0, energy: 10.0 },
                Item { time: 2.0, energy: 9.5 }, // shallow then steep: off-hull
                Item { time: 3.0, energy: 2.0 },
            ]],
            deadline: 10.0,
        };
        let hulls = convex_frontiers(&inst);
        assert_eq!(hulls[0], vec![0, 2]);
    }

    #[test]
    fn feasible_and_close_to_optimal() {
        let mut rng = Rng::new(7);
        let mut worst_gap: f64 = 0.0;
        for _ in 0..40 {
            let inst = random_instance(&mut rng, 12, 6);
            let g = GreedySolver.solve(&inst).unwrap();
            assert!(g.total_time <= inst.deadline + 1e-9);
            let opt = DpSolver::with_resolution(50_000).solve(&inst).unwrap();
            let gap = (g.total_energy - opt.total_energy) / opt.total_energy;
            worst_gap = worst_gap.max(gap);
        }
        assert!(worst_gap < 0.08, "greedy gap too large: {worst_gap:.4}");
    }

    #[test]
    fn infeasible_none() {
        let inst = Instance {
            groups: vec![vec![Item { time: 5.0, energy: 1.0 }]],
            deadline: 1.0,
        };
        assert!(GreedySolver.solve(&inst).is_none());
    }
}
