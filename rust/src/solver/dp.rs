//! Exact MCKP dynamic program over discretized time.
//!
//! Time is discretized into `resolution` buckets across `[0, deadline]`;
//! item times are rounded **up** to buckets so any schedule the DP deems
//! feasible is feasible in continuous time. With the default 40 000 buckets
//! a 200 ms deadline quantizes at 5 µs — the rounding loss across ~164
//! kernels is well under 2 ms and only ever conservative.
//!
//! `dp[g][t] = min energy over the first g groups using exactly t buckets`.
//!
//! Performance (§Perf in EXPERIMENTS.md): the hot loop is a pure
//! `next[t] = min(next[t], prev[t-w] + e)` sweep with no parent-pointer
//! writes (LLVM vectorizes it); picks are reconstructed by a backward pass
//! over the retained DP rows. Items are Pareto-filtered per group first,
//! items wider than the whole budget are skipped, and the sweep range is
//! bounded by the populated high-water mark.

use super::{Instance, McKpSolver, Solution};

pub struct DpSolver {
    /// Number of time buckets spanning the deadline.
    pub resolution: usize,
}

impl Default for DpSolver {
    fn default() -> Self {
        DpSolver { resolution: 40_000 }
    }
}

impl DpSolver {
    pub fn with_resolution(resolution: usize) -> DpSolver {
        assert!(resolution >= 2);
        DpSolver { resolution }
    }
}

const INF: f64 = f64::INFINITY;

impl McKpSolver for DpSolver {
    fn name(&self) -> &'static str {
        "dp"
    }

    fn solve(&self, inst: &Instance) -> Option<Solution> {
        if inst.groups.is_empty() {
            return Some(Solution {
                picks: vec![],
                total_time: 0.0,
                total_energy: 0.0,
                optimal: true,
            });
        }
        if inst.min_time() > inst.deadline {
            return None;
        }
        // Solvers only ever pick Pareto points; filtering shrinks the item
        // lists (and the hot loop) without changing the optimum.
        let (filtered, maps) = inst.pareto_filtered();

        let t_buckets = self.resolution;
        let bucket = filtered.deadline / t_buckets as f64;
        let weights: Vec<Vec<usize>> = filtered
            .groups
            .iter()
            .map(|g| g.iter().map(|i| (i.time / bucket).ceil() as usize).collect())
            .collect();

        let n_groups = filtered.groups.len();
        // All DP rows retained for the backward reconstruction pass.
        let mut rows: Vec<Vec<f64>> = Vec::with_capacity(n_groups + 1);
        let mut first = vec![INF; t_buckets + 1];
        first[0] = 0.0;
        rows.push(first);

        // Populated high-water mark of the previous row.
        let mut reach = 0usize;
        for g in 0..n_groups {
            let mut next = vec![INF; t_buckets + 1];
            let mut max_w = 0usize;
            {
                let prev = rows.last().unwrap();
                for (&w, item) in weights[g].iter().zip(&filtered.groups[g]) {
                    if w > t_buckets {
                        continue; // item alone exceeds the whole budget
                    }
                    max_w = max_w.max(w);
                    let e = item.energy;
                    let hi = (reach + w).min(t_buckets);
                    // Pure min-sweep over slices: bounds-check-free and
                    // auto-vectorized (vminpd), no parent writes.
                    let src = &prev[0..=hi - w];
                    let dst = &mut next[w..=hi];
                    for (d, s) in dst.iter_mut().zip(src) {
                        let cand = s + e;
                        *d = if cand < *d { cand } else { *d };
                    }
                }
            }
            if max_w == 0 {
                return None; // no feasible item in this group
            }
            reach = (reach + max_w).min(t_buckets);
            rows.push(next);
        }

        // Best terminal state.
        let last = rows.last().unwrap();
        let mut best_t = usize::MAX;
        let mut best_e = INF;
        for (t, &e) in last.iter().enumerate() {
            if e < best_e {
                best_e = e;
                best_t = t;
            }
        }
        if best_t == usize::MAX {
            return None;
        }

        // Backward reconstruction: find, per group, the item that produced
        // dp[g+1][t] from dp[g][t - w].
        let mut picks = vec![0usize; n_groups];
        let mut t = best_t;
        for g in (0..n_groups).rev() {
            let target = rows[g + 1][t];
            let prev = &rows[g];
            let mut found = false;
            for (j, (&w, item)) in weights[g].iter().zip(&filtered.groups[g]).enumerate() {
                if w > t {
                    continue;
                }
                let cand = prev[t - w] + item.energy;
                // Exact float equality holds: `target` was computed as this
                // very expression; tolerate one ulp for safety.
                if cand == target || (cand - target).abs() <= target.abs() * 1e-15 {
                    picks[g] = j;
                    t -= w;
                    found = true;
                    break;
                }
            }
            debug_assert!(found, "broken DP reconstruction at group {g}");
            if !found {
                // Defensive fallback (should be unreachable).
                picks[g] = 0;
                t = t.saturating_sub(weights[g][0].min(t));
            }
        }

        Some(Solution::evaluate(picks, &filtered, true).translate(&maps))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::{random_instance, Item};
    use crate::util::rng::Rng;

    fn tiny() -> Instance {
        Instance {
            groups: vec![
                vec![
                    Item { time: 1.0, energy: 10.0 },
                    Item { time: 2.0, energy: 4.0 },
                    Item { time: 4.0, energy: 1.0 },
                ],
                vec![
                    Item { time: 1.0, energy: 8.0 },
                    Item { time: 3.0, energy: 2.0 },
                ],
            ],
            deadline: 5.0,
        }
    }

    #[test]
    fn solves_tiny_optimally() {
        // Budget 5: best energy meeting it is (2.0,4.0)+(3.0,2.0):
        // time 5, energy 6.
        let sol = DpSolver::default().solve(&tiny()).unwrap();
        assert_eq!(sol.picks, vec![1, 1]);
        assert!((sol.total_energy - 6.0).abs() < 1e-9);
        assert!(sol.total_time <= 5.0 + 1e-9);
        assert!(sol.optimal);
    }

    #[test]
    fn infeasible_returns_none() {
        let mut inst = tiny();
        inst.deadline = 1.5;
        assert!(DpSolver::default().solve(&inst).is_none());
    }

    #[test]
    fn relaxed_deadline_gives_min_energy() {
        let mut inst = tiny();
        inst.deadline = 100.0;
        let sol = DpSolver::default().solve(&inst).unwrap();
        assert!((sol.total_energy - 3.0).abs() < 1e-9); // 1.0 + 2.0
    }

    #[test]
    fn empty_instance() {
        let sol = DpSolver::default()
            .solve(&Instance {
                groups: vec![],
                deadline: 1.0,
            })
            .unwrap();
        assert!(sol.picks.is_empty());
    }

    #[test]
    fn picks_reference_original_item_indices() {
        // Dominated items must not disturb pick indices after filtering.
        let inst = Instance {
            groups: vec![vec![
                Item { time: 2.0, energy: 9.0 },  // dominated by 2
                Item { time: 1.0, energy: 10.0 },
                Item { time: 2.0, energy: 4.0 },
                Item { time: 4.0, energy: 1.0 },
            ]],
            deadline: 2.5,
        };
        let sol = DpSolver::default().solve(&inst).unwrap();
        assert_eq!(sol.picks, vec![2]);
        assert!((sol.total_energy - 4.0).abs() < 1e-12);
    }

    #[test]
    fn exhaustive_cross_check_small_random() {
        let mut rng = Rng::new(2024);
        for case in 0..30 {
            let inst = random_instance(&mut rng, 6, 4);
            let dp = DpSolver::with_resolution(50_000).solve(&inst);
            let brute = brute_force(&inst);
            match (dp, brute) {
                (Some(d), Some(b)) => {
                    assert!(
                        d.total_energy <= b.total_energy * 1.001 + 1e-12,
                        "case {case}: dp {} vs brute {}",
                        d.total_energy,
                        b.total_energy
                    );
                    assert!(d.total_time <= inst.deadline + 1e-9);
                    // Reconstructed picks must reproduce the reported totals.
                    let check = Solution::evaluate(d.picks.clone(), &inst, true);
                    assert!((check.total_energy - d.total_energy).abs() < 1e-12);
                }
                (None, None) => {}
                (d, b) => panic!("case {case}: feasibility mismatch {d:?} vs {b:?}"),
            }
        }
    }

    fn brute_force(inst: &Instance) -> Option<Solution> {
        let mut best: Option<Solution> = None;
        let mut picks = vec![0usize; inst.groups.len()];
        loop {
            let sol = Solution::evaluate(picks.clone(), inst, true);
            if sol.total_time <= inst.deadline
                && best
                    .as_ref()
                    .map(|b| sol.total_energy < b.total_energy)
                    .unwrap_or(true)
            {
                best = Some(sol);
            }
            let mut g = 0;
            loop {
                if g == picks.len() {
                    return best;
                }
                picks[g] += 1;
                if picks[g] < inst.groups[g].len() {
                    break;
                }
                picks[g] = 0;
                g += 1;
            }
        }
    }
}
