//! Multiple-Choice Knapsack Problem solvers (§3.3).
//!
//! MEDEA's optimization — pick one configuration per kernel minimizing total
//! energy subject to `Σ time ≤ T_d` — is an MCKP with kernel = item group,
//! energy = value (minimized), time = weight, deadline = capacity. The paper
//! solves it with an off-the-shelf ILP solver (PuLP); this crate implements
//! the solvers directly:
//!
//! * [`dp`] — exact dynamic program over discretized time (the default).
//! * [`bb`] — exact branch-and-bound on continuous time with the MCKP
//!   LP-relaxation bound.
//! * [`lagrange`] — Lagrangian relaxation (bisection on λ): a fast feasible
//!   heuristic plus a certified lower bound.
//! * [`greedy`] — the classic dominance-filtered incremental-efficiency
//!   heuristic.
//!
//! All solvers consume the same [`Instance`] and return a [`Solution`]
//! picking one item index per group (indices refer to the instance's item
//! lists, which the caller maps back to `ω_ij` configurations).

pub mod bb;
pub mod dp;
pub mod greedy;
pub mod lagrange;

pub use bb::BranchBound;
pub use dp::DpSolver;
pub use greedy::GreedySolver;
pub use lagrange::LagrangeSolver;

/// One item: `weight` = execution time (seconds), `value` = energy (joules).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Item {
    pub time: f64,
    pub energy: f64,
}

/// An MCKP instance: one item must be chosen from each group; total time
/// must not exceed `deadline`; total energy is minimized.
#[derive(Debug, Clone, Default)]
pub struct Instance {
    pub groups: Vec<Vec<Item>>,
    pub deadline: f64,
}

impl Instance {
    /// Fastest possible total time — infeasibility threshold.
    pub fn min_time(&self) -> f64 {
        self.groups
            .iter()
            .map(|g| g.iter().map(|i| i.time).fold(f64::INFINITY, f64::min))
            .sum()
    }

    /// Slowest possible total time — beyond this, extra deadline slack
    /// cannot change the optimum (used to bound schedule-atlas sweeps).
    pub fn max_time(&self) -> f64 {
        self.groups
            .iter()
            .map(|g| g.iter().map(|i| i.time).fold(0.0, f64::max))
            .sum()
    }

    /// Per-group Pareto filter (drop items that are no faster *and* no
    /// cheaper than another). Returns index maps from filtered to original
    /// positions so solutions can be translated back.
    pub fn pareto_filtered(&self) -> (Instance, Vec<Vec<usize>>) {
        let mut groups = Vec::with_capacity(self.groups.len());
        let mut maps = Vec::with_capacity(self.groups.len());
        for g in &self.groups {
            let mut idx: Vec<usize> = (0..g.len()).collect();
            idx.sort_by(|&a, &b| {
                g[a].time
                    .total_cmp(&g[b].time)
                    .then(g[a].energy.total_cmp(&g[b].energy))
            });
            let mut kept_items = Vec::new();
            let mut kept_map = Vec::new();
            let mut best_energy = f64::INFINITY;
            for i in idx {
                if g[i].energy < best_energy {
                    best_energy = g[i].energy;
                    kept_items.push(g[i]);
                    kept_map.push(i);
                }
            }
            groups.push(kept_items);
            maps.push(kept_map);
        }
        (
            Instance {
                groups,
                deadline: self.deadline,
            },
            maps,
        )
    }
}

/// A solution: `picks[i]` is the chosen item index in group `i`.
#[derive(Debug, Clone, PartialEq)]
pub struct Solution {
    pub picks: Vec<usize>,
    pub total_time: f64,
    pub total_energy: f64,
    /// Whether the producing solver certifies optimality.
    pub optimal: bool,
}

impl Solution {
    /// Recompute totals from picks (validation helper).
    pub fn evaluate(picks: Vec<usize>, inst: &Instance, optimal: bool) -> Solution {
        let mut total_time = 0.0;
        let mut total_energy = 0.0;
        for (g, &p) in inst.groups.iter().zip(&picks) {
            total_time += g[p].time;
            total_energy += g[p].energy;
        }
        Solution {
            picks,
            total_time,
            total_energy,
            optimal,
        }
    }

    /// Translate picks through the Pareto-filter index maps.
    pub fn translate(mut self, maps: &[Vec<usize>]) -> Solution {
        for (pick, map) in self.picks.iter_mut().zip(maps) {
            *pick = map[*pick];
        }
        self
    }
}

/// Common solver interface.
pub trait McKpSolver {
    fn name(&self) -> &'static str;
    /// `None` when the instance is infeasible (even the fastest choice per
    /// group exceeds the deadline).
    fn solve(&self, inst: &Instance) -> Option<Solution>;
}

/// Build a random instance (tests / benches).
pub fn random_instance(rng: &mut crate::util::rng::Rng, groups: usize, items: usize) -> Instance {
    let mut inst = Instance::default();
    for _ in 0..groups {
        let mut g = Vec::new();
        for _ in 0..items {
            let time = rng.range_f64(0.1e-3, 5e-3);
            // Loosely anti-correlated energy so tradeoffs exist.
            let energy = rng.range_f64(0.5e-6, 2e-6) / time.sqrt();
            g.push(Item { time, energy });
        }
        inst.groups.push(g);
    }
    let min_t = inst.min_time();
    let max_t: f64 = inst
        .groups
        .iter()
        .map(|g| g.iter().map(|i| i.time).fold(0.0, f64::max))
        .sum();
    inst.deadline = rng.range_f64(min_t, 0.5 * (min_t + max_t));
    inst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pareto_filter_keeps_frontier() {
        let inst = Instance {
            groups: vec![vec![
                Item { time: 1.0, energy: 5.0 },
                Item { time: 2.0, energy: 6.0 }, // dominated
                Item { time: 2.0, energy: 3.0 },
                Item { time: 3.0, energy: 3.5 }, // dominated
                Item { time: 4.0, energy: 1.0 },
            ]],
            deadline: 10.0,
        };
        let (f, maps) = inst.pareto_filtered();
        assert_eq!(f.groups[0].len(), 3);
        assert_eq!(maps[0], vec![0, 2, 4]);
    }

    #[test]
    fn solution_translate() {
        let inst = Instance {
            groups: vec![vec![Item { time: 1.0, energy: 1.0 }; 3]],
            deadline: 10.0,
        };
        let sol = Solution::evaluate(vec![1], &inst, true);
        let t = sol.translate(&[vec![5, 7, 9]]);
        assert_eq!(t.picks, vec![7]);
    }

    #[test]
    fn min_time_sums_fastest() {
        let inst = Instance {
            groups: vec![
                vec![Item { time: 1.0, energy: 0.0 }, Item { time: 0.5, energy: 9.0 }],
                vec![Item { time: 2.0, energy: 0.0 }],
            ],
            deadline: 0.0,
        };
        assert!((inst.min_time() - 2.5).abs() < 1e-12);
        assert!((inst.max_time() - 3.0).abs() < 1e-12);
    }
}
