//! The schedule `A = {ω_1*, …, ω_N*}` emitted by a scheduler.

use crate::ir::Workload;
use crate::platform::{PeId, Platform};
use crate::tiling::modes::TilingMode;
use crate::util::json::{parse, Json, JsonObj};
use crate::util::units::{Energy, Time};

/// One per-kernel decision `ω_i* = (p*, v*, c*)` with its estimates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Decision {
    /// Kernel index in the workload.
    pub kernel: usize,
    pub pe: PeId,
    pub vf_idx: usize,
    pub mode: TilingMode,
    /// Estimated `T_a(ω*)`.
    pub time: Time,
    /// Estimated `E_a(ω*)`.
    pub energy: Energy,
}

/// A complete schedule for a workload under a deadline.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Producing scheduler ("medea", "cpu-maxvf", …).
    pub scheduler: String,
    pub workload: String,
    pub deadline: Time,
    pub decisions: Vec<Decision>,
    /// Whether the producing solver certified optimality (always false for
    /// baselines).
    pub optimal: bool,
}

impl Schedule {
    /// Estimated total active time `T_{t,a}`.
    pub fn active_time(&self) -> Time {
        self.decisions.iter().map(|d| d.time).sum()
    }

    /// Estimated total active energy `E_{t,a}`.
    pub fn active_energy(&self) -> Energy {
        self.decisions.iter().map(|d| d.energy).sum()
    }

    /// Estimated sleep time within the deadline window.
    pub fn sleep_time(&self) -> Time {
        Time((self.deadline - self.active_time()).raw().max(0.0))
    }

    /// Estimated total energy `E_t = E_{t,a} + P_slp·max(0, T_d − T_{t,a})`
    /// (Eq. 7).
    pub fn total_energy(&self, platform: &Platform) -> Energy {
        self.active_energy() + platform.sleep_power * self.sleep_time()
    }

    pub fn meets_deadline(&self) -> bool {
        self.active_time().raw() <= self.deadline.raw() * (1.0 + 1e-9)
    }

    /// Number of V-F transitions along the kernel sequence (the sim charges
    /// each one `vf_switch_cycles`).
    pub fn vf_switch_count(&self) -> usize {
        self.decisions
            .windows(2)
            .filter(|w| w[0].vf_idx != w[1].vf_idx)
            .count()
    }

    /// Distinct (pe, vf) histogram — used by the Fig 6 snapshot. Built by
    /// [`fold_assignments`], so it shares one decomposition with the
    /// telemetry energy ledger and comes out already sorted.
    pub fn assignment_histogram(&self) -> Vec<((PeId, usize), usize)> {
        let mut hist: Vec<((PeId, usize), usize)> = Vec::new();
        fold_assignments(&self.decisions, |pe, vf, count, _, _| {
            hist.push(((pe, vf), count));
        });
        hist
    }

    /// Structural validation against the workload/platform: one decision per
    /// kernel, in order, referencing valid PEs/V-F indices, and every
    /// decision's (PE, type, width) is allowed by `Λ_op`.
    pub fn validate(&self, workload: &Workload, platform: &Platform) -> Result<(), String> {
        if self.decisions.len() != workload.len() {
            return Err(format!(
                "schedule has {} decisions for {} kernels",
                self.decisions.len(),
                workload.len()
            ));
        }
        for (i, d) in self.decisions.iter().enumerate() {
            if d.kernel != i {
                return Err(format!("decision {i} refers to kernel {}", d.kernel));
            }
            if d.pe.0 >= platform.pes.len() {
                return Err(format!("decision {i}: invalid pe {}", d.pe));
            }
            if d.vf_idx >= platform.vf.len() {
                return Err(format!("decision {i}: invalid vf index {}", d.vf_idx));
            }
            let k = &workload.kernels()[i];
            if !platform.constraints.supports(d.pe, k.ty, k.dw) {
                return Err(format!(
                    "decision {i}: kernel `{}` not executable on {}",
                    k.name, d.pe
                ));
            }
            if d.time.raw() < 0.0 || d.energy.raw() < 0.0 {
                return Err(format!("decision {i}: negative estimate"));
            }
        }
        Ok(())
    }

    // ---- JSON ----------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("scheduler", self.scheduler.clone());
        o.insert("workload", self.workload.clone());
        o.insert("deadline_ms", self.deadline.as_ms());
        o.insert("optimal", self.optimal);
        o.insert("active_time_ms", self.active_time().as_ms());
        o.insert("active_energy_uj", self.active_energy().as_uj());
        let ds: Vec<Json> = self
            .decisions
            .iter()
            .map(|d| {
                let mut dj = JsonObj::new();
                dj.insert("kernel", d.kernel);
                dj.insert("pe", d.pe.0);
                dj.insert("vf", d.vf_idx);
                dj.insert("mode", d.mode.name());
                dj.insert("time_us", d.time.as_us());
                dj.insert("energy_uj", d.energy.as_uj());
                Json::Obj(dj)
            })
            .collect();
        o.insert("decisions", Json::Arr(ds));
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<Schedule, String> {
        let mut decisions = Vec::new();
        for dv in v.req("decisions")?.as_arr().ok_or("decisions")? {
            decisions.push(Decision {
                kernel: dv.req("kernel")?.as_usize().ok_or("kernel")?,
                pe: PeId(dv.req("pe")?.as_usize().ok_or("pe")?),
                vf_idx: dv.req("vf")?.as_usize().ok_or("vf")?,
                mode: TilingMode::from_name(dv.req("mode")?.as_str().ok_or("mode")?)
                    .ok_or("mode")?,
                time: Time::from_us(dv.req("time_us")?.as_f64().ok_or("time_us")?),
                energy: Energy::from_uj(dv.req("energy_uj")?.as_f64().ok_or("energy_uj")?),
            });
        }
        Ok(Schedule {
            scheduler: v.req("scheduler")?.as_str().ok_or("scheduler")?.to_string(),
            workload: v.req("workload")?.as_str().ok_or("workload")?.to_string(),
            deadline: Time::from_ms(v.req("deadline_ms")?.as_f64().ok_or("deadline_ms")?),
            decisions,
            optimal: v.req("optimal")?.as_bool().ok_or("optimal")?,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<(), String> {
        std::fs::write(path, self.to_json().to_pretty()).map_err(|e| e.to_string())
    }

    pub fn load(path: &std::path::Path) -> Result<Schedule, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Schedule::from_json(&parse(&text).map_err(|e| e.to_string())?)
    }
}

/// Decompose a decision list into per-(PE, V-F) groups without allocating:
/// `emit` is called exactly once per distinct `(pe, vf_idx)` pair, in
/// ascending `(pe.0, vf_idx)` order, with the group's kernel count and
/// summed time/energy. This is the one decomposition primitive shared by
/// [`Schedule::assignment_histogram`] and the telemetry energy ledger's
/// per-dispatch attribution — the latter runs on the serving hot path, so
/// the walk keeps to a repeated min-scan: O(groups × decisions) with the
/// group count bounded by `pes × vf points`, a small platform constant.
pub fn fold_assignments(
    decisions: &[Decision],
    mut emit: impl FnMut(PeId, usize, usize, Time, Energy),
) {
    let mut last: Option<(usize, usize)> = None;
    loop {
        // Smallest (pe, vf) key strictly above the last emitted group.
        let mut next: Option<(usize, usize)> = None;
        for d in decisions {
            let key = (d.pe.0, d.vf_idx);
            if last.is_some_and(|l| key <= l) {
                continue;
            }
            let better = match next {
                Some(n) => key < n,
                None => true,
            };
            if better {
                next = Some(key);
            }
        }
        let Some(key) = next else { break };
        let mut count = 0usize;
        let mut time = Time(0.0);
        let mut energy = Energy(0.0);
        for d in decisions {
            if (d.pe.0, d.vf_idx) == key {
                count += 1;
                time = time + d.time;
                energy = energy + d.energy;
            }
        }
        emit(PeId(key.0), key.1, count, time, energy);
        last = Some(key);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Schedule {
        Schedule {
            scheduler: "test".into(),
            workload: "w".into(),
            deadline: Time::from_ms(200.0),
            decisions: vec![
                Decision {
                    kernel: 0,
                    pe: PeId(1),
                    vf_idx: 0,
                    mode: TilingMode::DoubleBuffer,
                    time: Time::from_ms(60.0),
                    energy: Energy::from_uj(100.0),
                },
                Decision {
                    kernel: 1,
                    pe: PeId(0),
                    vf_idx: 2,
                    mode: TilingMode::SingleBuffer,
                    time: Time::from_ms(40.0),
                    energy: Energy::from_uj(50.0),
                },
            ],
            optimal: true,
        }
    }

    #[test]
    fn totals_and_sleep() {
        let s = sample();
        assert!((s.active_time().as_ms() - 100.0).abs() < 1e-9);
        assert!((s.active_energy().as_uj() - 150.0).abs() < 1e-9);
        assert!((s.sleep_time().as_ms() - 100.0).abs() < 1e-9);
        assert!(s.meets_deadline());
        assert_eq!(s.vf_switch_count(), 1);
    }

    #[test]
    fn total_energy_includes_sleep() {
        let s = sample();
        let p = crate::platform::heeptimize::heeptimize();
        let e = s.total_energy(&p);
        // 150 µJ + 129 µW × 100 ms = 150 + 12.9 µJ
        assert!((e.as_uj() - 162.9).abs() < 0.01);
    }

    #[test]
    fn json_round_trip() {
        let s = sample();
        let j = s.to_json().to_pretty();
        let back = Schedule::from_json(&parse(&j).unwrap()).unwrap();
        assert_eq!(back.decisions.len(), s.decisions.len());
        for (a, b) in back.decisions.iter().zip(&s.decisions) {
            assert_eq!((a.kernel, a.pe, a.vf_idx, a.mode), (b.kernel, b.pe, b.vf_idx, b.mode));
            assert!((a.time.raw() - b.time.raw()).abs() < 1e-12);
            assert!((a.energy.raw() - b.energy.raw()).abs() < 1e-15);
        }
        assert_eq!(back.scheduler, s.scheduler);
        assert!((back.deadline.raw() - s.deadline.raw()).abs() < 1e-12);
    }

    #[test]
    fn assignment_histogram_counts() {
        let s = sample();
        let hist = s.assignment_histogram();
        assert_eq!(hist.len(), 2);
        assert_eq!(hist[0], ((PeId(0), 2), 1));
        assert_eq!(hist[1], ((PeId(1), 0), 1));
    }

    #[test]
    fn fold_assignments_groups_sorted_with_totals() {
        // Interleaved duplicates across three groups; emission must come
        // back grouped, sorted by (pe, vf), with exact sums.
        let d = |kernel, pe, vf, ms, uj| Decision {
            kernel,
            pe: PeId(pe),
            vf_idx: vf,
            mode: TilingMode::SingleBuffer,
            time: Time::from_ms(ms),
            energy: Energy::from_uj(uj),
        };
        let decisions = vec![
            d(0, 1, 2, 10.0, 5.0),
            d(1, 0, 1, 20.0, 7.0),
            d(2, 1, 2, 30.0, 11.0),
            d(3, 0, 1, 40.0, 13.0),
            d(4, 1, 0, 50.0, 17.0),
        ];
        let mut seen = Vec::new();
        fold_assignments(&decisions, |pe, vf, n, t, e| {
            seen.push((pe.0, vf, n, t.as_ms(), e.as_uj()));
        });
        assert_eq!(seen.len(), 3);
        assert_eq!((seen[0].0, seen[0].1, seen[0].2), (0, 1, 2));
        assert!((seen[0].3 - 60.0).abs() < 1e-9 && (seen[0].4 - 20.0).abs() < 1e-9);
        assert_eq!((seen[1].0, seen[1].1, seen[1].2), (1, 0, 1));
        assert!((seen[1].3 - 50.0).abs() < 1e-9 && (seen[1].4 - 17.0).abs() < 1e-9);
        assert_eq!((seen[2].0, seen[2].1, seen[2].2), (1, 2, 2));
        assert!((seen[2].3 - 40.0).abs() < 1e-9 && (seen[2].4 - 16.0).abs() < 1e-9);
        // Group counts agree with the histogram built on the same fold.
        let mut s = sample();
        s.decisions = decisions;
        let hist = s.assignment_histogram();
        assert_eq!(hist, vec![((PeId(0), 1), 2), ((PeId(1), 0), 1), ((PeId(1), 2), 2)]);
        // Empty input emits nothing.
        fold_assignments(&[], |_, _, _, _, _| panic!("no groups in an empty list"));
    }
}
