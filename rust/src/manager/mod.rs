//! The MEDEA manager (§3.3): timing-constrained energy-minimal scheduling.

pub mod medea;
pub mod schedule;

pub use medea::{Medea, MedeaFeatures};
pub use schedule::{Decision, Schedule};
