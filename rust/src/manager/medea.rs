//! The MEDEA manager: configuration enumeration → MCKP → schedule
//! extraction, with the §5.3 feature switches.

use super::schedule::{Decision, Schedule};
use crate::config::estimator::{Estimator, TilingPolicy};
use crate::ir::Workload;
use crate::platform::Platform;
use crate::profile::Profiles;
use crate::solver::{BranchBound, DpSolver, GreedySolver, Instance, Item, LagrangeSolver, McKpSolver};
use crate::timing::cycle_model::CycleModel;
use crate::util::units::{Energy, Time};

/// The three core features of §5.3; disabling one reproduces the
/// corresponding ablation row of Table 6 / Fig 8.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MedeaFeatures {
    /// Kernel-level DVFS; disabled ⇒ one application-level V-F (the lowest
    /// meeting the deadline), per-kernel PE choice retained.
    pub kernel_dvfs: bool,
    /// Kernel-level scheduling; disabled ⇒ §4.4 coarse groups share one
    /// (PE, V-F), with unsupported kernels offloaded to the CPU.
    pub kernel_sched: bool,
    /// Memory-aware adaptive tiling; disabled ⇒ tiling pinned to `t_db`.
    pub adaptive_tiling: bool,
}

impl Default for MedeaFeatures {
    fn default() -> Self {
        MedeaFeatures {
            kernel_dvfs: true,
            kernel_sched: true,
            adaptive_tiling: true,
        }
    }
}

impl MedeaFeatures {
    pub fn without_kernel_dvfs() -> Self {
        MedeaFeatures {
            kernel_dvfs: false,
            ..Default::default()
        }
    }
    pub fn without_kernel_sched() -> Self {
        MedeaFeatures {
            kernel_sched: false,
            ..Default::default()
        }
    }
    pub fn without_adaptive_tiling() -> Self {
        MedeaFeatures {
            adaptive_tiling: false,
            ..Default::default()
        }
    }
}

/// Which MCKP solver backs the optimization.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SolverKind {
    /// Exact discretized-time DP (default).
    #[default]
    Dp,
    /// Exact branch-and-bound.
    Bb,
    /// Lagrangian-relaxation heuristic.
    Lagrange,
    /// Incremental-efficiency greedy heuristic.
    Greedy,
}

impl SolverKind {
    pub fn from_name(s: &str) -> Option<SolverKind> {
        match s {
            "dp" => Some(SolverKind::Dp),
            "bb" => Some(SolverKind::Bb),
            "lagrange" => Some(SolverKind::Lagrange),
            "greedy" => Some(SolverKind::Greedy),
            _ => None,
        }
    }

    fn build(self) -> Box<dyn McKpSolver> {
        match self {
            SolverKind::Dp => Box::new(DpSolver::default()),
            SolverKind::Bb => Box::new(BranchBound::default()),
            SolverKind::Lagrange => Box::new(LagrangeSolver::default()),
            SolverKind::Greedy => Box::new(GreedySolver),
        }
    }
}

/// Scheduling failure modes.
#[derive(Debug, Clone)]
pub enum ScheduleError {
    Infeasible { min_ms: f64, deadline_ms: f64 },
    NoGroups,
    EnergyBudgetInfeasible { budget_uj: f64, min_uj: f64 },
}

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleError::Infeasible { min_ms, deadline_ms } => write!(
                f,
                "infeasible: fastest schedule needs {min_ms:.2} ms > deadline {deadline_ms:.2} ms"
            ),
            ScheduleError::NoGroups => write!(
                f,
                "workload has no coarse groups covering all kernels (required when kernel-level scheduling is disabled)"
            ),
            ScheduleError::EnergyBudgetInfeasible { budget_uj, min_uj } => write!(
                f,
                "energy budget {budget_uj:.0} uJ below the unconstrained minimum {min_uj:.0} uJ"
            ),
        }
    }
}

impl std::error::Error for ScheduleError {}

/// The design-time manager.
pub struct Medea<'a> {
    pub platform: &'a Platform,
    pub profiles: &'a Profiles,
    pub model: &'a CycleModel,
    pub features: MedeaFeatures,
    pub solver: SolverKind,
}

/// One scheduling *unit*: a kernel (kernel-level) or a §4.4 group
/// (coarse-level), with its valid configurations. Each unit config carries
/// the per-kernel decisions it expands to.
struct Unit {
    configs: Vec<UnitConfig>,
}

struct UnitConfig {
    time: Time,
    energy: Energy,
    decisions: Vec<Decision>,
}

impl<'a> Medea<'a> {
    pub fn new(platform: &'a Platform, profiles: &'a Profiles, model: &'a CycleModel) -> Self {
        Medea {
            platform,
            profiles,
            model,
            features: MedeaFeatures::default(),
            solver: SolverKind::Dp,
        }
    }

    pub fn with_features(mut self, features: MedeaFeatures) -> Self {
        self.features = features;
        self
    }

    pub fn with_solver(mut self, solver: SolverKind) -> Self {
        self.solver = solver;
        self
    }

    fn estimator(&self) -> Estimator<'a> {
        let policy = if self.features.adaptive_tiling {
            TilingPolicy::Adaptive
        } else {
            TilingPolicy::ForceDouble
        };
        Estimator::new(self.platform, self.profiles, self.model).with_policy(policy)
    }

    /// The estimator-level feasibility floor: the fastest achievable
    /// makespan across all configurations. Deadlines below this are
    /// infeasible for [`Medea::schedule`]; the serving atlas uses it to
    /// reject requests up front instead of failing a solve per request.
    pub fn min_makespan(&self, workload: &Workload) -> Result<Time, ScheduleError> {
        let (inst, _) = self.build_instance(workload, Time(1.0))?;
        Ok(Time(inst.min_time()))
    }

    /// The slowest single-choice makespan: past this deadline extra slack
    /// cannot change the optimum, so it bounds deadline sweeps.
    pub fn max_makespan(&self, workload: &Workload) -> Result<Time, ScheduleError> {
        let (inst, _) = self.build_instance(workload, Time(1.0))?;
        Ok(Time(inst.max_time()))
    }

    fn build_instance(
        &self,
        workload: &Workload,
        deadline: Time,
    ) -> Result<(Instance, Vec<Vec<usize>>), ScheduleError> {
        let est = self.estimator();
        let units = if self.features.kernel_sched {
            self.kernel_units(workload, &est)
        } else {
            self.group_units(workload, &est)?
        };
        Ok(Self::instance(&units, deadline, None))
    }

    /// Generate the energy-minimal schedule for `workload` under `deadline`.
    pub fn schedule(&self, workload: &Workload, deadline: Time) -> Result<Schedule, ScheduleError> {
        let est = self.estimator();
        let units = if self.features.kernel_sched {
            self.kernel_units(workload, &est)
        } else {
            self.group_units(workload, &est)?
        };

        let scheduler_name = self.scheduler_name();
        if self.features.kernel_dvfs {
            let (inst, maps) = Self::instance(&units, deadline, None);
            let sol = self
                .solver
                .build()
                .solve(&inst)
                .ok_or_else(|| ScheduleError::Infeasible {
                    min_ms: Time(inst.min_time()).as_ms(),
                    deadline_ms: deadline.as_ms(),
                })?
                .translate(&maps);
            Ok(Self::extract(
                workload,
                &units,
                &sol.picks,
                deadline,
                scheduler_name,
                sol.optimal,
            ))
        } else {
            // Application-level DVFS: the lowest single V-F meeting the
            // deadline (PE/tiling choice still optimized per unit).
            let mut min_ms = f64::INFINITY;
            for vf_idx in 0..self.platform.vf.len() {
                let (inst, maps) = Self::instance(&units, deadline, Some(vf_idx));
                min_ms = min_ms.min(Time(inst.min_time()).as_ms());
                if let Some(sol) = self.solver.build().solve(&inst) {
                    let sol = sol.translate(&maps);
                    return Ok(Self::extract(
                        workload,
                        &units,
                        &sol.picks,
                        deadline,
                        scheduler_name,
                        sol.optimal,
                    ));
                }
            }
            Err(ScheduleError::Infeasible {
                min_ms,
                deadline_ms: deadline.as_ms(),
            })
        }
    }

    /// The *dual* objective (an AxoNN-style extension the paper contrasts
    /// with in §2): minimize execution time subject to an energy budget.
    /// Solved by bisection over the deadline: `schedule(T)` yields the
    /// minimum energy achievable within `T`, which is non-increasing in
    /// `T`, so the fastest schedule fitting the budget is found at the
    /// smallest feasible `T` whose optimal energy fits the budget.
    pub fn schedule_energy_budget(
        &self,
        workload: &Workload,
        budget: Energy,
        iterations: usize,
    ) -> Result<Schedule, ScheduleError> {
        // Bracket: the fastest feasible deadline and a relaxed one.
        let est = self.estimator();
        let units = if self.features.kernel_sched {
            self.kernel_units(workload, &est)
        } else {
            self.group_units(workload, &est)?
        };
        let (inst, _) = Self::instance(&units, Time(1.0), None);
        let t_min = Time(inst.min_time());
        let t_max = t_min * 16.0;

        // The energy-optimal (unconstrained) schedule: if even that exceeds
        // the budget, the budget is unmeetable.
        let relaxed = self.schedule(workload, t_max)?;
        if relaxed.active_energy().raw() > budget.raw() {
            return Err(ScheduleError::EnergyBudgetInfeasible {
                budget_uj: budget.as_uj(),
                min_uj: relaxed.active_energy().as_uj(),
            });
        }

        let mut lo = t_min;
        let mut hi = t_max;
        let mut best = relaxed;
        for _ in 0..iterations {
            let mid = Time(0.5 * (lo.raw() + hi.raw()));
            match self.schedule(workload, mid) {
                Ok(s) if s.active_energy().raw() <= budget.raw() => {
                    best = s;
                    hi = mid;
                }
                _ => lo = mid,
            }
        }
        Ok(best)
    }

    fn scheduler_name(&self) -> String {
        let f = self.features;
        match (f.kernel_dvfs, f.kernel_sched, f.adaptive_tiling) {
            (true, true, true) => "medea".into(),
            (false, true, true) => "medea-w/o-kerdvfs".into(),
            (true, false, true) => "medea-w/o-kersched".into(),
            (true, true, false) => "medea-w/o-adaptile".into(),
            _ => format!(
                "medea[dvfs={},sched={},tile={}]",
                f.kernel_dvfs, f.kernel_sched, f.adaptive_tiling
            ),
        }
    }

    /// Kernel-level units: one per kernel, configs over (PE × V-F).
    fn kernel_units(&self, workload: &Workload, est: &Estimator) -> Vec<Unit> {
        workload
            .kernels()
            .iter()
            .enumerate()
            .map(|(i, kernel)| {
                let mut configs = Vec::new();
                for pe in self.platform.pe_ids() {
                    let Some((mode, _)) = est.best_mode(pe, kernel) else {
                        continue;
                    };
                    for vf_idx in 0..self.platform.vf.len() {
                        let Some(time) = est.time(pe, kernel, vf_idx, mode) else {
                            continue;
                        };
                        let energy = est.power(pe, kernel, vf_idx) * time;
                        configs.push(UnitConfig {
                            time,
                            energy,
                            decisions: vec![Decision {
                                kernel: i,
                                pe,
                                vf_idx,
                                mode,
                                time,
                                energy,
                            }],
                        });
                    }
                }
                assert!(!configs.is_empty(), "kernel {i} has no valid config");
                Unit { configs }
            })
            .collect()
    }

    /// Group-level units (§4.4 grouping): every group shares one (PE, V-F);
    /// kernels the PE cannot run are offloaded to the CPU at the group V-F.
    fn group_units(&self, workload: &Workload, est: &Estimator) -> Result<Vec<Unit>, ScheduleError> {
        if !workload.groups_cover_all() {
            return Err(ScheduleError::NoGroups);
        }
        let cpu = self.platform.cpu().id;
        let mut units = Vec::new();
        for group in workload.groups() {
            let mut configs = Vec::new();
            for pe in self.platform.pe_ids() {
                for vf_idx in 0..self.platform.vf.len() {
                    let mut decisions = Vec::new();
                    let mut t_total = Time::ZERO;
                    let mut e_total = Energy::ZERO;
                    let mut ok = true;
                    for ki in group.range.clone() {
                        let kernel = &workload.kernels()[ki];
                        // Preferred PE, else CPU offload.
                        let (use_pe, mode) = match est.best_mode(pe, kernel) {
                            Some((mode, _)) => (pe, mode),
                            None => match est.best_mode(cpu, kernel) {
                                Some((mode, _)) => (cpu, mode),
                                None => {
                                    ok = false;
                                    break;
                                }
                            },
                        };
                        let Some(time) = est.time(use_pe, kernel, vf_idx, mode) else {
                            ok = false;
                            break;
                        };
                        let energy = est.power(use_pe, kernel, vf_idx) * time;
                        t_total += time;
                        e_total += energy;
                        decisions.push(Decision {
                            kernel: ki,
                            pe: use_pe,
                            vf_idx,
                            mode,
                            time,
                            energy,
                        });
                    }
                    if ok {
                        configs.push(UnitConfig {
                            time: t_total,
                            energy: e_total,
                            decisions,
                        });
                    }
                }
            }
            assert!(!configs.is_empty(), "group `{}` has no valid config", group.name);
            units.push(Unit { configs });
        }
        Ok(units)
    }

    /// Build the MCKP instance, optionally restricted to one V-F index
    /// (every decision in a config shares it by construction). Returns the
    /// per-unit index map from instance item position → config position.
    fn instance(units: &[Unit], deadline: Time, vf_only: Option<usize>) -> (Instance, Vec<Vec<usize>>) {
        let mut maps = Vec::with_capacity(units.len());
        let groups = units
            .iter()
            .map(|u| {
                let mut map = Vec::new();
                let items: Vec<Item> = u
                    .configs
                    .iter()
                    .enumerate()
                    .filter(|(_, c)| {
                        vf_only.is_none_or(|vf| c.decisions.iter().all(|d| d.vf_idx == vf))
                    })
                    .map(|(i, c)| {
                        map.push(i);
                        Item {
                            time: c.time.raw(),
                            energy: c.energy.raw(),
                        }
                    })
                    .collect();
                maps.push(map);
                items
            })
            .collect();
        (
            Instance {
                groups,
                deadline: deadline.raw(),
            },
            maps,
        )
    }

    fn extract(
        workload: &Workload,
        units: &[Unit],
        picks: &[usize],
        deadline: Time,
        scheduler: String,
        optimal: bool,
    ) -> Schedule {
        // `picks` index the *filtered* config list when vf_only was used;
        // rebuild with the same filter order — instance() keeps config order,
        // so map through the same iterator logic via stored decisions.
        let mut decisions: Vec<Decision> = Vec::with_capacity(workload.len());
        for (u, &p) in units.iter().zip(picks) {
            decisions.extend(u.configs[p].decisions.iter().copied());
        }
        decisions.sort_by_key(|d| d.kernel);
        Schedule {
            scheduler,
            workload: workload.name.clone(),
            deadline,
            decisions,
            optimal,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::tsd::{tsd_core, TsdParams};
    use crate::platform::heeptimize::heeptimize;
    use crate::profile::characterize;

    struct Ctx {
        platform: Platform,
        profiles: Profiles,
        model: CycleModel,
    }

    fn ctx() -> Ctx {
        let platform = heeptimize();
        let model = CycleModel::heeptimize();
        let profiles = characterize(&platform, &model);
        Ctx {
            platform,
            profiles,
            model,
        }
    }

    #[test]
    fn full_medea_meets_all_paper_deadlines() {
        let c = ctx();
        let medea = Medea::new(&c.platform, &c.profiles, &c.model);
        let w = tsd_core(&TsdParams::default());
        for ms in [50.0, 200.0, 1000.0] {
            let s = medea.schedule(&w, Time::from_ms(ms)).unwrap();
            s.validate(&w, &c.platform).unwrap();
            assert!(s.meets_deadline(), "deadline {ms} ms");
            assert!(s.optimal);
        }
    }

    #[test]
    fn energy_monotone_in_deadline() {
        // More slack can never cost more active energy.
        let c = ctx();
        let medea = Medea::new(&c.platform, &c.profiles, &c.model);
        let w = tsd_core(&TsdParams::default());
        let mut last = f64::INFINITY;
        for ms in [50.0, 100.0, 200.0, 500.0, 1000.0] {
            let s = medea.schedule(&w, Time::from_ms(ms)).unwrap();
            let e = s.active_energy().as_uj();
            assert!(e <= last * 1.001, "deadline {ms}: {e} > {last}");
            last = e;
        }
    }

    #[test]
    fn ablations_never_beat_full_medea() {
        let c = ctx();
        let w = tsd_core(&TsdParams::default());
        for ms in [50.0, 200.0, 1000.0] {
            let full = Medea::new(&c.platform, &c.profiles, &c.model)
                .schedule(&w, Time::from_ms(ms))
                .unwrap();
            for feats in [
                MedeaFeatures::without_kernel_dvfs(),
                MedeaFeatures::without_kernel_sched(),
                MedeaFeatures::without_adaptive_tiling(),
            ] {
                let abl = Medea::new(&c.platform, &c.profiles, &c.model)
                    .with_features(feats)
                    .schedule(&w, Time::from_ms(ms))
                    .unwrap();
                assert!(abl.meets_deadline());
                // Ablations measure *estimated* energy on their own policy;
                // full MEDEA must be at least as good (small tolerance for
                // DP quantization).
                assert!(
                    full.active_energy().raw() <= abl.active_energy().raw() * 1.005,
                    "{:?} at {ms} ms: full {} vs ablated {}",
                    feats,
                    full.active_energy().as_uj(),
                    abl.active_energy().as_uj()
                );
            }
        }
    }

    #[test]
    fn tight_deadline_uses_higher_vf() {
        let c = ctx();
        let medea = Medea::new(&c.platform, &c.profiles, &c.model);
        let w = tsd_core(&TsdParams::default());
        let tight = medea.schedule(&w, Time::from_ms(50.0)).unwrap();
        let relaxed = medea.schedule(&w, Time::from_ms(1000.0)).unwrap();
        let avg_vf = |s: &Schedule| {
            s.decisions.iter().map(|d| d.vf_idx as f64).sum::<f64>() / s.decisions.len() as f64
        };
        assert!(avg_vf(&tight) > avg_vf(&relaxed) + 0.5);
        // Relaxed: everything at the lowest V-F (paper Fig 6).
        assert!(relaxed.decisions.iter().all(|d| d.vf_idx == 0));
    }

    #[test]
    fn energy_budget_dual_objective() {
        let c = ctx();
        let medea = Medea::new(&c.platform, &c.profiles, &c.model);
        let w = tsd_core(&TsdParams::default());
        // The unconstrained minimum energy (relaxed deadline).
        let relaxed = medea.schedule(&w, Time::from_ms(2000.0)).unwrap();
        let e_min = relaxed.active_energy();
        // A budget 1.5x above the minimum must be schedulable, faster than
        // the relaxed schedule, and within the budget.
        let s = medea
            .schedule_energy_budget(&w, e_min * 1.5, 24)
            .unwrap();
        assert!(s.active_energy().raw() <= e_min.raw() * 1.5 * 1.0001);
        assert!(s.active_time().raw() < relaxed.active_time().raw());
        // An impossible budget errors cleanly.
        let err = medea
            .schedule_energy_budget(&w, e_min * 0.5, 8)
            .unwrap_err();
        assert!(matches!(err, ScheduleError::EnergyBudgetInfeasible { .. }));
    }

    #[test]
    fn energy_budget_monotone_in_budget() {
        // Looser energy budgets can only slow the time-optimal schedule
        // down -- never speed it up.
        let c = ctx();
        let medea = Medea::new(&c.platform, &c.profiles, &c.model);
        let w = tsd_core(&TsdParams::default());
        let e_min = medea
            .schedule(&w, Time::from_ms(2000.0))
            .unwrap()
            .active_energy();
        let tight = medea.schedule_energy_budget(&w, e_min * 1.2, 20).unwrap();
        let loose = medea.schedule_energy_budget(&w, e_min * 2.5, 20).unwrap();
        assert!(loose.active_time().raw() <= tight.active_time().raw() * 1.01);
    }

    #[test]
    fn makespan_bounds_bracket_feasibility() {
        let c = ctx();
        let medea = Medea::new(&c.platform, &c.profiles, &c.model);
        let w = tsd_core(&TsdParams::default());
        let t_min = medea.min_makespan(&w).unwrap();
        let t_max = medea.max_makespan(&w).unwrap();
        assert!(t_min.raw() > 0.0);
        assert!(t_max.raw() > t_min.raw());
        // Slightly above the floor is schedulable (1 % covers the DP's
        // per-item round-up, ≤ 164/40000 of the deadline); below is not.
        assert!(medea.schedule(&w, t_min * 1.01).is_ok());
        assert!(medea.schedule(&w, t_min * 0.9).is_err());
    }

    #[test]
    fn infeasible_deadline_errors() {
        let c = ctx();
        let medea = Medea::new(&c.platform, &c.profiles, &c.model);
        let w = tsd_core(&TsdParams::default());
        let err = medea.schedule(&w, Time::from_ms(1.0)).unwrap_err();
        assert!(matches!(err, ScheduleError::Infeasible { .. }));
    }

    #[test]
    fn solver_backends_agree_on_energy() {
        let c = ctx();
        let w = tsd_core(&TsdParams::default());
        let dp = Medea::new(&c.platform, &c.profiles, &c.model)
            .with_solver(SolverKind::Dp)
            .schedule(&w, Time::from_ms(200.0))
            .unwrap();
        let bb = Medea::new(&c.platform, &c.profiles, &c.model)
            .with_solver(SolverKind::Bb)
            .schedule(&w, Time::from_ms(200.0))
            .unwrap();
        let greedy = Medea::new(&c.platform, &c.profiles, &c.model)
            .with_solver(SolverKind::Greedy)
            .schedule(&w, Time::from_ms(200.0))
            .unwrap();
        let e_dp = dp.active_energy().as_uj();
        let e_bb = bb.active_energy().as_uj();
        let e_gr = greedy.active_energy().as_uj();
        assert!((e_dp - e_bb).abs() / e_dp < 5e-3, "dp {e_dp} vs bb {e_bb}");
        // Greedy works in continuous time while the DP rounds item times up
        // to buckets, so greedy may come in a hair *below* the DP.
        assert!(e_gr >= e_dp * 0.99 && e_gr <= e_dp * 1.05, "greedy {e_gr} vs dp {e_dp}");
    }

    #[test]
    fn without_kerdvfs_uses_single_vf() {
        let c = ctx();
        let w = tsd_core(&TsdParams::default());
        let s = Medea::new(&c.platform, &c.profiles, &c.model)
            .with_features(MedeaFeatures::without_kernel_dvfs())
            .schedule(&w, Time::from_ms(200.0))
            .unwrap();
        let vf0 = s.decisions[0].vf_idx;
        assert!(s.decisions.iter().all(|d| d.vf_idx == vf0));
    }

    #[test]
    fn without_kersched_uniform_within_groups() {
        let c = ctx();
        let w = tsd_core(&TsdParams::default());
        let s = Medea::new(&c.platform, &c.profiles, &c.model)
            .with_features(MedeaFeatures::without_kernel_sched())
            .schedule(&w, Time::from_ms(200.0))
            .unwrap();
        let cpu = c.platform.cpu().id;
        for g in w.groups() {
            // All non-CPU decisions in a group share one PE; V-F uniform.
            let ds = &s.decisions[g.range.clone()];
            let vf0 = ds[0].vf_idx;
            assert!(ds.iter().all(|d| d.vf_idx == vf0), "group {}", g.name);
            let pes: Vec<_> = ds.iter().map(|d| d.pe).filter(|&p| p != cpu).collect();
            assert!(
                pes.windows(2).all(|w| w[0] == w[1]),
                "group {} mixes accelerators",
                g.name
            );
        }
    }
}
