//! Operand footprint helpers shared by the tile planner.

use crate::ir::{DataWidth, Shape};
use crate::util::units::Bytes;

/// Accumulator element width used for matmul/conv partial sums held in LM.
/// Int8/int16 kernels accumulate into 32-bit registers (requantized on
/// write-out), so the in-LM output tile is 4 B/element while the written-out
/// bytes stay at the kernel's data width.
pub fn accum_bytes(dw: DataWidth) -> u64 {
    match dw {
        DataWidth::Int8 | DataWidth::Int16 => 4,
        DataWidth::Int32 | DataWidth::Float32 => 4,
    }
}

/// LM bytes needed to hold a matmul tile: an `m_t×k_c` A-strip, a `k_c×n_t`
/// B-panel and an `m_t×n_t` 32-bit accumulator tile.
pub fn matmul_tile_bytes(m_t: u64, k_c: u64, n_t: u64, dw: DataWidth) -> Bytes {
    let b = dw.bytes();
    Bytes(m_t * k_c * b + k_c * n_t * b + m_t * n_t * accum_bytes(dw))
}

/// Whether the whole (untiled) kernel fits a given LM budget.
pub fn fits_untiled(shape: Shape, dw: DataWidth, budget: Bytes) -> bool {
    let needed = match shape {
        Shape::MatMul { m, k, n } => matmul_tile_bytes(m, k, n, dw),
        Shape::Conv2d {
            h,
            w,
            c_in,
            c_out,
            kh,
            kw,
        } => {
            // im2col view: input patch matrix + filters + accumulators.
            matmul_tile_bytes(h * w, kh * kw * c_in, c_out, dw)
        }
        other => other.total_bytes(dw),
    };
    needed.raw() <= budget.raw()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DataWidth::*;

    #[test]
    fn matmul_tile_accounting() {
        // 28×128 A (int8) + 128×256 B + 28×256 int32 C
        let b = matmul_tile_bytes(28, 128, 256, Int8);
        assert_eq!(b.raw(), 28 * 128 + 128 * 256 + 28 * 256 * 4);
    }

    #[test]
    fn ff1_does_not_fit_64k() {
        // TSD ff1: 97×128×256 int8 → A 12.4K + B 32K + C-acc 99K > 64 KiB.
        let s = Shape::MatMul { m: 97, k: 128, n: 256 };
        assert!(!fits_untiled(s, Int8, Bytes::from_kib(64)));
        // per-head QKV projection fits: 97×128×32.
        let s2 = Shape::MatMul { m: 97, k: 128, n: 32 };
        assert!(fits_untiled(s2, Int8, Bytes::from_kib(64)));
    }

    #[test]
    fn elementwise_fits_by_total_bytes() {
        let s = Shape::Elementwise { n: 97 * 128, arity: 2 };
        // in 2×12416 + out 12416 = 37 KiB < 64 KiB
        assert!(fits_untiled(s, Int8, Bytes::from_kib(64)));
        assert!(!fits_untiled(s, Int8, Bytes::from_kib(32)));
    }
}
