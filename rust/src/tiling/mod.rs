//! Memory-aware adaptive tiling (§3.2).
//!
//! When a kernel's operands exceed a PE's local memory `C_LM` (or its
//! `Λ_op` dimension bound), MEDEA decomposes it into tiles and chooses
//! between two execution modes:
//!
//! * **Single-buffer** `t_sb`: tiles sized against the *full* LM budget —
//!   maximal tiles, minimal traffic amplification and per-tile overhead,
//!   but zero compute/transfer overlap.
//! * **Double-buffer** `t_db`: tiles sized against *half* the LM budget so
//!   the next tile streams in while the current one computes — overlap
//!   hides transfer latency, at the price of smaller tiles (more per-tile
//!   overhead and, for matmul, more B-panel reloads) and, on the NMC, VRF
//!   bank contention between the DMA and the vector unit.
//!
//! [`plan`] produces the tile decomposition + traffic model; [`modes`]
//! turns a plan into total execution cycles for each mode. MEDEA pre-selects
//! the cycle-minimal mode per (kernel, PE, V-F) — §3.3.

pub mod footprint;
pub mod modes;
pub mod plan;

pub use modes::{execution_cycles, mode_cycles, TilingMode};
pub use plan::plan_kernel;
