//! Execution-cycle models for the two tiling modes (§3.2).

use super::plan::plan_kernel;
use crate::ir::Kernel;
use crate::platform::pe::{Pe, PeClass};
use crate::platform::Platform;
use crate::timing::cycle_model::CycleModel;
use crate::util::units::{Bytes, Cycles};
use std::fmt;

/// The tiling/execution mode `c_i ∈ {t_sb, t_db}`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TilingMode {
    SingleBuffer,
    DoubleBuffer,
}

impl TilingMode {
    pub const BOTH: [TilingMode; 2] = [TilingMode::SingleBuffer, TilingMode::DoubleBuffer];

    pub fn name(self) -> &'static str {
        match self {
            TilingMode::SingleBuffer => "sb",
            TilingMode::DoubleBuffer => "db",
        }
    }

    pub fn from_name(s: &str) -> Option<TilingMode> {
        match s {
            "sb" => Some(TilingMode::SingleBuffer),
            "db" => Some(TilingMode::DoubleBuffer),
            _ => None,
        }
    }
}

impl fmt::Display for TilingMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// VRF bank-contention penalty on the NMC: while the host DMA streams into
/// the vector register file, the vector unit loses a fraction of its LM
/// bandwidth, so the overlapped phase of `t_db` is inflated by
/// `NMC_CONTENTION · min(compute, dma)` per steady-state step.
pub const NMC_CONTENTION: f64 = 0.25;

/// Total execution cycles for `kernel` on `pe` under `mode`, or `None` when
/// the kernel cannot be tiled into the mode's LM budget (or the PE cannot
/// execute the kernel type/width at all).
///
/// The CPU has no LM and operates on L2-resident data: both modes collapse
/// to pure compute + launch overhead.
pub fn mode_cycles(
    platform: &Platform,
    model: &CycleModel,
    pe: &Pe,
    kernel: &Kernel,
    mode: TilingMode,
) -> Option<Cycles> {
    let compute = model.kernel_cycles(pe.class, kernel)?;
    mode_cycles_with(
        platform,
        pe,
        kernel,
        compute,
        model.launch(pe.class),
        model.per_tile(pe.class),
        mode,
    )
}

/// Core mode-cycle computation with the processing-cycle count supplied by
/// the caller (the estimator feeds profiled/extrapolated counts here, the
/// [`mode_cycles`] wrapper feeds the analytical model directly).
pub fn mode_cycles_with(
    platform: &Platform,
    pe: &Pe,
    kernel: &Kernel,
    compute: Cycles,
    launch: Cycles,
    per_tile_oh: Cycles,
    mode: TilingMode,
) -> Option<Cycles> {
    let constraint = platform.constraints.get(pe.id, kernel.ty)?;
    if !constraint.allows_width(kernel.dw) {
        return None;
    }

    let (Some(lm), Some(dma)) = (pe.lm, pe.dma) else {
        // Host CPU path: no staging, no tiling.
        return Some(launch + compute);
    };

    let budget = match mode {
        TilingMode::SingleBuffer => lm,
        TilingMode::DoubleBuffer => Bytes(lm.raw() / 2),
    };
    let plan = plan_kernel(kernel, budget, constraint.max_dim)?;
    if plan.n_tiles == 0 {
        return Some(launch);
    }
    let oh_total = Cycles(per_tile_oh.raw() * plan.n_tiles);

    // DMA cycles: per-tile setup + bandwidth-limited streaming. Untiled
    // single-buffer execution chains the activation operand from the
    // previous kernel's LM-resident output (skipping its L2→LM transfer);
    // double-buffering ping-pongs the LM and cannot preserve residency.
    let n = plan.n_tiles;
    let traffic_in = match mode {
        TilingMode::SingleBuffer => plan.traffic_in.saturating_sub(plan.chainable_in),
        TilingMode::DoubleBuffer => plan.traffic_in,
    };
    let din_total = dma_total(dma, traffic_in, n);
    let dout_total = dma_total(dma, plan.traffic_out, n);

    match mode {
        TilingMode::SingleBuffer => {
            // Strictly serialized: load, compute, store per tile.
            Some(launch + compute + din_total + dout_total + oh_total)
        }
        TilingMode::DoubleBuffer => {
            // Pipelined: fill (first tile in), n−1 steady steps where the
            // next tile's in + previous tile's out overlap compute, then the
            // last compute + drain.
            let c_tile = compute.raw() as f64 / n as f64;
            let din_tile = din_total.raw() as f64 / n as f64;
            let dout_tile = dout_total.raw() as f64 / n as f64;
            let contention = if pe.class == PeClass::Nmc {
                NMC_CONTENTION
            } else {
                0.0
            };
            let steady_step = {
                let c = c_tile;
                let d = din_tile + dout_tile;
                c.max(d) + contention * c.min(d)
            };
            let total = din_tile                      // fill
                + (n.saturating_sub(1)) as f64 * steady_step
                + c_tile                              // last compute
                + dout_tile; // drain
            Some(launch + Cycles(total.ceil() as u64) + oh_total)
        }
    }
}

fn dma_total(spec: crate::platform::pe::DmaSpec, traffic: Bytes, n_tiles: u64) -> Cycles {
    if traffic == Bytes::ZERO {
        return Cycles::ZERO;
    }
    // Per-tile setup, aggregate streaming.
    let stream = (traffic.raw() as f64 / spec.bytes_per_cycle).ceil() as u64;
    Cycles(spec.setup_cycles * n_tiles + stream)
}

/// The adaptive choice: cycles for the better of the two modes, plus which
/// mode won. This is the "pre-select the execution mode that yields the
/// minimum execution cycles" step of §3.3.
pub fn execution_cycles(
    platform: &Platform,
    model: &CycleModel,
    pe: &Pe,
    kernel: &Kernel,
) -> Option<(Cycles, TilingMode)> {
    let sb = mode_cycles(platform, model, pe, kernel, TilingMode::SingleBuffer);
    let db = mode_cycles(platform, model, pe, kernel, TilingMode::DoubleBuffer);
    match (sb, db) {
        (Some(s), Some(d)) => {
            if d < s {
                Some((d, TilingMode::DoubleBuffer))
            } else {
                Some((s, TilingMode::SingleBuffer))
            }
        }
        (Some(s), None) => Some((s, TilingMode::SingleBuffer)),
        (None, Some(d)) => Some((d, TilingMode::DoubleBuffer)),
        (None, None) => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataWidth::*, KernelType, Shape};
    use crate::platform::heeptimize::{heeptimize, CARUS, CGRA, CPU};

    fn setup() -> (Platform, CycleModel) {
        (heeptimize(), CycleModel::heeptimize())
    }

    fn mm(m: u64, k: u64, n: u64) -> Kernel {
        Kernel::new("mm", KernelType::MatMul, Shape::MatMul { m, k, n }, Int8)
    }

    #[test]
    fn cpu_ignores_tiling() {
        let (p, m) = setup();
        let k = mm(97, 128, 256);
        let sb = mode_cycles(&p, &m, p.pe(CPU), &k, TilingMode::SingleBuffer).unwrap();
        let db = mode_cycles(&p, &m, p.pe(CPU), &k, TilingMode::DoubleBuffer).unwrap();
        assert_eq!(sb, db);
    }

    #[test]
    fn db_wins_on_large_compute_bound_kernels() {
        // ff1 (97×128×256) on Carus: DMA-heavy via the 4 B/cycle port but
        // compute still dominates; overlap should win.
        let (p, m) = setup();
        let k = mm(97, 128, 256);
        let (_, mode) = execution_cycles(&p, &m, p.pe(CARUS), &k).unwrap();
        assert_eq!(mode, TilingMode::DoubleBuffer);
    }

    #[test]
    fn sb_wins_on_small_kernels() {
        // A small add fits LM in one tile: sb avoids the pipeline split.
        let (p, m) = setup();
        let add = Kernel::new(
            "add",
            KernelType::Add,
            Shape::Elementwise { n: 97 * 128, arity: 2 },
            Int8,
        );
        let (_, mode) = execution_cycles(&p, &m, p.pe(CARUS), &add).unwrap();
        assert_eq!(mode, TilingMode::SingleBuffer);
    }

    #[test]
    fn unsupported_kernel_is_none() {
        let (p, m) = setup();
        let sm = Kernel::new(
            "sm",
            KernelType::Softmax,
            Shape::Rowwise { rows: 97, cols: 97 },
            Int16,
        );
        assert!(execution_cycles(&p, &m, p.pe(CGRA), &sm).is_none());
        assert!(execution_cycles(&p, &m, p.pe(CPU), &sm).is_some());
    }

    #[test]
    fn forced_db_never_faster_than_adaptive() {
        let (p, m) = setup();
        for k in [
            mm(97, 128, 32),
            mm(97, 128, 256),
            mm(97, 32, 97),
            mm(1, 128, 2),
        ] {
            for pe in [CGRA, CARUS] {
                let (best, _) = execution_cycles(&p, &m, p.pe(pe), &k).unwrap();
                let db = mode_cycles(&p, &m, p.pe(pe), &k, TilingMode::DoubleBuffer).unwrap();
                assert!(best <= db, "{k:?} on {pe}");
            }
        }
    }

    #[test]
    fn vector_unit_wins_elementwise() {
        // Equal DMA bandwidth (both stage via the system DMA channel), so
        // Carus' faster vector element-wise path and cheaper launch must win.
        let (p, m) = setup();
        let add = Kernel::new(
            "add",
            KernelType::Add,
            Shape::Elementwise { n: 50_000, arity: 2 },
            Int8,
        );
        let cgra = mode_cycles(&p, &m, p.pe(CGRA), &add, TilingMode::SingleBuffer).unwrap();
        let carus = mode_cycles(&p, &m, p.pe(CARUS), &add, TilingMode::SingleBuffer).unwrap();
        assert!(carus < cgra);
    }

    #[test]
    fn mode_round_trip_names() {
        for m in TilingMode::BOTH {
            assert_eq!(TilingMode::from_name(m.name()), Some(m));
        }
    }
}
