//! The tile planner: decompose a kernel into LM-sized tiles and model the
//! resulting L2↔LM traffic.

use super::footprint::{accum_bytes, matmul_tile_bytes};
use crate::ir::{DataWidth, Kernel, Shape};
use crate::util::units::Bytes;

/// A tile decomposition of one kernel for one PE's LM budget.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TilePlan {
    /// Number of tiles executed sequentially.
    pub n_tiles: u64,
    /// Total bytes streamed L2 → LM (includes operand re-reads).
    pub traffic_in: Bytes,
    /// Total bytes streamed LM → L2.
    pub traffic_out: Bytes,
    /// True when the kernel runs as a single tile (no decomposition).
    pub untiled: bool,
    /// Activation-operand bytes that may be skipped from `traffic_in` when
    /// the kernel runs untiled and the producing kernel ran on the same PE
    /// (single-buffer LM residency chaining — see [`super::modes`]).
    pub chainable_in: Bytes,
}

/// Plan the tiling of `kernel` into an LM of `budget` bytes, honoring an
/// optional `Λ_op` max-dimension bound. Returns `None` when no legal tile
/// exists (e.g. one operand row alone exceeds the budget).
pub fn plan_kernel(kernel: &Kernel, budget: Bytes, max_dim: Option<u64>) -> Option<TilePlan> {
    if budget == Bytes::ZERO {
        return None;
    }
    let dw = kernel.dw;
    let mut plan = match kernel.shape {
        Shape::MatMul { m, k, n } => plan_matmul(m, k, n, dw, budget, max_dim),
        Shape::Conv2d {
            h,
            w,
            c_in,
            c_out,
            kh,
            kw,
        } => {
            // im2col formulation; input patches are re-materialized per tile
            // by the DMA's 2-D addressing, so traffic follows the matmul
            // model with `k = kh·kw·c_in`.
            plan_matmul(h * w, kh * kw * c_in, c_out, dw, budget, max_dim)
        }
        Shape::Elementwise { n, arity } => {
            // Vector PEs chunk long element-wise streams internally, so the
            // Λ_op dimension bound does not limit the tile length here.
            plan_streaming(n, arity * dw.bytes(), dw.bytes(), budget, None)
        }
        Shape::Rowwise { rows, cols } => {
            // Whole rows must be resident (reduction over a row).
            let row_bytes = cols * dw.bytes();
            plan_streaming(rows, row_bytes, row_bytes, budget, max_dim)
                .filter(|_| max_dim.is_none_or(|d| cols <= d))
        }
        Shape::Transpose { rows, cols } => {
            // Tile over rows; the transposed tile is written back strided.
            let row_bytes = cols * dw.bytes();
            plan_streaming(rows, row_bytes, row_bytes, budget, None)
                .filter(|_| max_dim.is_none_or(|d| cols <= d))
        }
        Shape::Fft { n_fft, batch } => {
            // One FFT at a time minimum: input + scratch (complex) + output.
            let unit = n_fft * dw.bytes() + 2 * n_fft * dw.bytes() + (n_fft / 2) * dw.bytes();
            plan_streaming(batch, unit, (n_fft / 2) * dw.bytes(), budget, None)
                .filter(|_| max_dim.is_none_or(|d| n_fft <= d))
        }
        Shape::Concat { rows, cols } => {
            let row_bytes = cols * dw.bytes();
            plan_streaming(rows + 1, row_bytes, row_bytes, budget, None)
                .filter(|_| max_dim.is_none_or(|d| cols <= d))
        }
    }?;
    // Untiled single-tile plans can chain their activation input from the
    // previous kernel's LM-resident output (applied by the sb mode model).
    if plan.untiled && plan.n_tiles == 1 {
        plan.chainable_in = kernel.shape.activation_bytes(dw).min(plan.traffic_in);
    }
    Some(plan)
}

/// Streaming decomposition: `units` independent work units of `in_bytes` +
/// `out_bytes` each; tiles are groups of units. No traffic amplification.
fn plan_streaming(
    units: u64,
    in_bytes_per_unit: u64,
    out_bytes_per_unit: u64,
    budget: Bytes,
    max_units_per_tile: Option<u64>,
) -> Option<TilePlan> {
    if units == 0 {
        return Some(TilePlan {
            n_tiles: 0,
            traffic_in: Bytes::ZERO,
            traffic_out: Bytes::ZERO,
            untiled: true,
            chainable_in: Bytes::ZERO,
        });
    }
    let unit = in_bytes_per_unit + out_bytes_per_unit;
    if unit == 0 || unit > budget.raw() {
        return None;
    }
    let mut per_tile = budget.raw() / unit;
    if let Some(cap) = max_units_per_tile {
        if cap == 0 {
            return None;
        }
        per_tile = per_tile.min(cap);
    }
    if per_tile == 0 {
        return None;
    }
    let n_tiles = units.div_ceil(per_tile);
    Some(TilePlan {
        n_tiles,
        traffic_in: Bytes(units * in_bytes_per_unit),
        traffic_out: Bytes(units * out_bytes_per_unit),
        untiled: n_tiles == 1,
        chainable_in: Bytes::ZERO,
    })
}

/// Matmul decomposition: outer loop over `m_t`-row strips of A (loaded
/// once each), inner loop over `n_t`-column panels of B (each panel loaded
/// once per strip ⇒ B traffic amplifies by the strip count), 32-bit
/// accumulator tile resident. If `k` exceeds the dimension bound it is
/// chunked with the accumulator kept in LM (each chunk adds one pass over
/// A and B but not C).
fn plan_matmul(
    m: u64,
    k: u64,
    n: u64,
    dw: DataWidth,
    budget: Bytes,
    max_dim: Option<u64>,
) -> Option<TilePlan> {
    if m == 0 || k == 0 || n == 0 {
        return Some(TilePlan {
            n_tiles: 0,
            traffic_in: Bytes::ZERO,
            traffic_out: Bytes::ZERO,
            untiled: true,
            chainable_in: Bytes::ZERO,
        });
    }
    let b = dw.bytes();
    let cap = max_dim.unwrap_or(u64::MAX);
    let k_c = k.min(cap);
    let k_chunks = k.div_ceil(k_c);

    // Untiled fast path.
    if k_chunks == 1
        && m <= cap
        && n <= cap
        && matmul_tile_bytes(m, k, n, dw).raw() <= budget.raw()
    {
        return Some(TilePlan {
            n_tiles: 1,
            traffic_in: Bytes((m * k + k * n) * b),
            traffic_out: Bytes(m * n * b),
            untiled: true,
            chainable_in: Bytes::ZERO,
        });
    }

    // Choose n_t as large as legal, then the largest m_t that fits; shrink
    // n_t geometrically if even one A-row + B-panel + C-row cannot fit.
    let mut n_t = n.min(cap);
    loop {
        if n_t == 0 {
            return None;
        }
        // m_t from: m_t·k_c·b + k_c·n_t·b + m_t·n_t·acc ≤ budget
        let fixed = k_c * n_t * b;
        if fixed >= budget.raw() {
            n_t /= 2;
            continue;
        }
        let per_row = k_c * b + n_t * accum_bytes(dw);
        let m_t = ((budget.raw() - fixed) / per_row).min(m).min(cap);
        if m_t == 0 {
            n_t /= 2;
            continue;
        }
        let n_m = m.div_ceil(m_t);
        let n_n = n.div_ceil(n_t);
        // Traffic model for the strip/panel loop nest:
        //   for m-strip { for n-panel { for k-chunk { A-chunk, B-chunk } C } }
        // A strips stay resident across panels when k is unchunked (loaded
        // once, m·k); with k-chunking each panel revisits every A chunk
        // (n_n·m·k). B panels are re-read once per strip (n_m·k·n). C is
        // written once, requantized to `dw` on write-out.
        let a_traffic = if k_chunks == 1 { m * k * b } else { n_n * m * k * b };
        let traffic_in = a_traffic + n_m * k * n * b;
        let traffic_out = m * n * b;
        return Some(TilePlan {
            n_tiles: n_m * n_n * k_chunks,
            traffic_in: Bytes(traffic_in),
            traffic_out: Bytes(traffic_out),
            untiled: false,
            chainable_in: Bytes::ZERO,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataWidth::*, Kernel, KernelType};
    use crate::util::units::Bytes;

    fn mm(m: u64, k: u64, n: u64) -> Kernel {
        Kernel::new("mm", KernelType::MatMul, Shape::MatMul { m, k, n }, Int8)
    }

    const LM64: Bytes = Bytes(64 * 1024);
    const LM32: Bytes = Bytes(32 * 1024);

    #[test]
    fn small_matmul_untiled() {
        let p = plan_kernel(&mm(97, 128, 32), LM64, Some(512)).unwrap();
        assert!(p.untiled);
        assert_eq!(p.n_tiles, 1);
        assert_eq!(p.traffic_in.raw(), 97 * 128 + 128 * 32);
        assert_eq!(p.traffic_out.raw(), 97 * 32);
    }

    #[test]
    fn ff1_tiles_and_amplifies_b_traffic() {
        // 97×128×256 int8 does not fit 64 KiB: B panels are re-read.
        let p = plan_kernel(&mm(97, 128, 256), LM64, Some(512)).unwrap();
        assert!(!p.untiled);
        assert!(p.n_tiles > 1);
        let min_traffic = (97 * 128 + 128 * 256) as u64;
        assert!(p.traffic_in.raw() > min_traffic, "{p:?}");
    }

    #[test]
    fn half_budget_amplifies_more() {
        // The t_db-vs-t_sb asymmetry: half the budget ⇒ smaller strips ⇒
        // more B re-reads.
        let full = plan_kernel(&mm(97, 128, 256), LM64, Some(512)).unwrap();
        let half = plan_kernel(&mm(97, 128, 256), LM32, Some(512)).unwrap();
        assert!(half.traffic_in.raw() >= full.traffic_in.raw());
        assert!(half.n_tiles >= full.n_tiles);
    }

    #[test]
    fn max_dim_forces_k_chunking() {
        let p = plan_kernel(&mm(64, 2048, 64), LM64, Some(512)).unwrap();
        assert!(!p.untiled);
        // k chunked into 4 passes.
        assert!(p.n_tiles >= 4, "{p:?}");
    }

    #[test]
    fn impossible_tile_returns_none() {
        // One B panel row (k·b) exceeds even the whole budget at n_t=1 …
        let k = Kernel::new(
            "mm",
            KernelType::MatMul,
            Shape::MatMul { m: 4, k: 100_000, n: 4 },
            Int32,
        );
        assert!(plan_kernel(&k, Bytes(1024), None).is_none());
    }

    #[test]
    fn rowwise_needs_whole_rows() {
        let norm = Kernel::new(
            "norm",
            KernelType::Norm,
            Shape::Rowwise { rows: 97, cols: 128 },
            Int16,
        );
        let p = plan_kernel(&norm, LM64, Some(512)).unwrap();
        assert!(p.untiled); // 97·128·2·2 = 49 KiB fits
        // With a tiny budget it tiles by rows.
        let p2 = plan_kernel(&norm, Bytes(4096), Some(512)).unwrap();
        assert!(p2.n_tiles > 1);
        // A row wider than the budget is impossible.
        assert!(plan_kernel(&norm, Bytes(256), Some(512)).is_none());
    }

    #[test]
    fn elementwise_streaming_no_amplification() {
        let add = Kernel::new(
            "add",
            KernelType::Add,
            Shape::Elementwise { n: 97 * 128, arity: 2 },
            Int8,
        );
        let p64 = plan_kernel(&add, LM64, None).unwrap();
        let p8 = plan_kernel(&add, Bytes(8 * 1024), None).unwrap();
        assert_eq!(p64.traffic_in, p8.traffic_in);
        assert_eq!(p64.traffic_out, p8.traffic_out);
        assert!(p8.n_tiles > p64.n_tiles);
    }

    #[test]
    fn zero_sized_shapes() {
        let p = plan_kernel(&mm(0, 8, 8), LM64, None).unwrap();
        assert_eq!(p.n_tiles, 0);
        assert_eq!(p.traffic_in, Bytes::ZERO);
    }
}
