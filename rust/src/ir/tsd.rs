//! The TSD (Transformer for Seizure Detection) case-study workload (§4.3).
//!
//! A ViT-style model over EEG windows: FFT-magnitude frontend (the paper's
//! ULP modification replacing log-amplitude), patch embedding, four
//! transformer encoder blocks (MHSA + FFN), and a classifier head. The
//! decomposition into kernels follows the paper's Fig 4; the ULP
//! modifications (Taylor softmax, PWL GeLU, FFT magnitude) appear both here
//! (as kernel types whose cycle models reflect the cheap approximations —
//! Table 4) and in the JAX model (`python/compile/model.py`).

use super::builder::{classifier, encoder_block, patch_embedding, TransformerDims};
use super::kernel::{DataWidth, Kernel, KernelType, Shape};
use super::workload::Workload;

/// TSD model hyper-parameters.
///
/// Defaults are sized so the transformer core lands in the paper's cycle
/// envelope (meets 50 ms only with acceleration; CPU-only misses it — §5.1).
#[derive(Debug, Clone, Copy)]
pub struct TsdParams {
    /// EEG channels in the input window.
    pub channels: u64,
    /// FFT length per channel segment.
    pub n_fft: u64,
    /// Number of FFT segments (patches) per window.
    pub patches: u64,
    /// Feature dimension of each patch fed to the embedding.
    pub patch_dim: u64,
    /// Embedding width.
    pub d_model: u64,
    /// Encoder block count.
    pub blocks: u64,
    /// Attention heads.
    pub heads: u64,
    /// FFN hidden width.
    pub d_ff: u64,
    /// Output classes (seizure / background).
    pub n_classes: u64,
    /// Linear-algebra data width.
    pub dw: DataWidth,
    /// Row-wise (norm/softmax) data width.
    pub dw_row: DataWidth,
}

impl Default for TsdParams {
    fn default() -> Self {
        TsdParams {
            channels: 20,
            n_fft: 256,
            patches: 96,
            patch_dim: 80,
            d_model: 128,
            blocks: 4,
            heads: 4,
            d_ff: 256,
            n_classes: 2,
            dw: DataWidth::Int8,
            dw_row: DataWidth::Int16,
        }
    }
}

impl TsdParams {
    /// A lighter TSD variant (half the patches, narrower/shallower core):
    /// the second workload of a heterogeneous serving fleet, and a fast
    /// stand-in for tests that need two structurally distinct networks.
    pub fn small() -> TsdParams {
        TsdParams {
            patches: 48,
            d_model: 64,
            blocks: 2,
            heads: 2,
            d_ff: 128,
            ..TsdParams::default()
        }
    }

    pub fn dims(&self) -> TransformerDims {
        TransformerDims {
            seq: self.patches + 1, // + class token
            d_model: self.d_model,
            heads: self.heads,
            d_ff: self.d_ff,
            dw: self.dw,
            dw_row: self.dw_row,
        }
    }
}

/// The full TSD workload: FFT frontend + embedding + encoder stack +
/// classifier.
pub fn tsd_full(p: &TsdParams) -> Workload {
    let mut w = Workload::new("tsd-full");
    // Frontend: per-channel FFT magnitudes (CPU-only in Λ_op; the paper's
    // modification drops the log). Float32: runs on the RISC-V host.
    w.push_group(
        "frontend",
        vec![Kernel::new(
            "frontend.fft_mag",
            KernelType::FftMag,
            Shape::Fft {
                n_fft: p.n_fft,
                batch: p.channels * p.patches / p.channels.max(1),
            },
            DataWidth::Float32,
        )],
    );
    patch_embedding(&mut w, "in", p.patches, p.patch_dim, p.d_model, p.dw);
    let dims = p.dims();
    for b in 0..p.blocks {
        encoder_block(&mut w, &format!("enc{b}"), dims);
    }
    classifier(&mut w, "out", p.d_model, p.n_classes, dims);
    debug_assert!(w.groups_cover_all());
    w
}

/// The transformer core only (what the paper uses "for most comparative
/// analyses" — §4.3): embedding + encoders + classifier, no FFT frontend.
pub fn tsd_core(p: &TsdParams) -> Workload {
    let mut w = Workload::new("tsd-core");
    patch_embedding(&mut w, "in", p.patches, p.patch_dim, p.d_model, p.dw);
    let dims = p.dims();
    for b in 0..p.blocks {
        encoder_block(&mut w, &format!("enc{b}"), dims);
    }
    classifier(&mut w, "out", p.d_model, p.n_classes, dims);
    debug_assert!(w.groups_cover_all());
    w
}

/// The transformer core at [`TsdParams::small`] dimensioning, under its own
/// workload name so the fleet layer treats it as a distinct network.
pub fn tsd_small() -> Workload {
    let mut w = tsd_core(&TsdParams::small());
    w.name = "tsd-small".to_string();
    w
}

/// The matmul subset of the TSD core that is executable on *both*
/// accelerators — used by the Fig 7 crossover study.
pub fn tsd_matmul_subset(p: &TsdParams) -> Workload {
    tsd_core(p).filter("tsd-matmul-subset", |k| k.ty == KernelType::MatMul)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_has_frontend_core_does_not() {
        let p = TsdParams::default();
        let full = tsd_full(&p);
        let core = tsd_core(&p);
        assert!(full.kernels().iter().any(|k| k.ty == KernelType::FftMag));
        assert!(!core.kernels().iter().any(|k| k.ty == KernelType::FftMag));
        assert_eq!(full.len(), core.len() + 1);
    }

    #[test]
    fn core_kernel_count() {
        let p = TsdParams::default();
        let core = tsd_core(&p);
        // embed(2) + 4 blocks × 40 + classifier(2)
        assert_eq!(core.len(), 2 + 4 * 40 + 2);
        assert!(core.groups_cover_all());
    }

    #[test]
    fn small_variant_is_smaller_and_covered() {
        let small = tsd_small();
        let core = tsd_core(&TsdParams::default());
        assert_eq!(small.name, "tsd-small");
        assert!(small.len() < core.len() / 2);
        assert!(small.groups_cover_all());
        assert!(small.total_ops() < core.total_ops() / 3);
    }

    #[test]
    fn matmul_subset_is_all_matmul() {
        let p = TsdParams::default();
        let sub = tsd_matmul_subset(&p);
        assert!(!sub.is_empty());
        assert!(sub.kernels().iter().all(|k| k.ty == KernelType::MatMul));
        // 4 blocks × (4 heads × 5 mm + proj + 2 ffn) + embed + class head
        assert_eq!(sub.len(), 4 * (4 * 5 + 1 + 2) + 1 + 1);
    }

    #[test]
    fn workload_scale_sanity() {
        // The core must be dominated by matmul MACs, in the tens of millions:
        // large enough that CPU-only misses 50 ms, small enough that the
        // accelerators make it at low voltage within 1000 ms (§5 envelope).
        let p = TsdParams::default();
        let core = tsd_core(&p);
        let total = core.total_ops();
        assert!(total > 20_000_000, "total ops {total}");
        assert!(total < 200_000_000, "total ops {total}");
    }

    #[test]
    fn json_round_trip_of_tsd() {
        let p = TsdParams::default();
        let core = tsd_core(&p);
        let j = core.to_json().to_pretty();
        let back = Workload::from_json(&crate::util::json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.len(), core.len());
        assert_eq!(back.groups().len(), core.groups().len());
    }
}
