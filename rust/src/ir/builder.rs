//! Builders that lower higher-level DNN descriptions to kernel workloads.
//!
//! The paper (§3.1.1) notes "helper utilities are provided to aid in
//! generating `W` from higher-level descriptions (e.g., DNN model layers)".
//! These are those utilities: a ViT-style transformer encoder decomposition
//! (matching the paper's Fig 4 kernel granularity) and a small CNN builder
//! used by tests and the custom-platform example.

use super::kernel::{DataWidth, Kernel, KernelType, Shape};
use super::workload::Workload;

/// Transformer dimensioning for [`encoder_block`].
#[derive(Debug, Clone, Copy)]
pub struct TransformerDims {
    /// Token count (sequence length including any class token).
    pub seq: u64,
    /// Model (embedding) width.
    pub d_model: u64,
    /// Attention head count; `d_model % heads == 0`.
    pub heads: u64,
    /// FFN hidden width.
    pub d_ff: u64,
    /// Data width of accelerated linear algebra.
    pub dw: DataWidth,
    /// Data width of row-wise ops (norm/softmax run at higher precision).
    pub dw_row: DataWidth,
}

impl TransformerDims {
    pub fn d_head(&self) -> u64 {
        self.d_model / self.heads
    }
}

/// Append one transformer encoder block, decomposed into kernels exactly as
/// the paper's Fig 4 (N, per-head MM/T/S/SM chains, projection+residual, FFN)
/// and grouped per §4.4 (norm / each MHA head / FFN / residual groups).
pub fn encoder_block(w: &mut Workload, prefix: &str, d: TransformerDims) {
    assert_eq!(d.d_model % d.heads, 0, "d_model must divide into heads");
    let dh = d.d_head();

    // Pre-attention layer norm.
    w.push_group(
        format!("{prefix}.norm1"),
        vec![Kernel::new(
            format!("{prefix}.norm1"),
            KernelType::Norm,
            Shape::Rowwise {
                rows: d.seq,
                cols: d.d_model,
            },
            d.dw_row,
        )],
    );

    // Each attention head is its own coarse group.
    for h in 0..d.heads {
        let p = format!("{prefix}.h{h}");
        w.push_group(
            p.clone(),
            vec![
                Kernel::new(
                    format!("{p}.mm_q"),
                    KernelType::MatMul,
                    Shape::MatMul {
                        m: d.seq,
                        k: d.d_model,
                        n: dh,
                    },
                    d.dw,
                ),
                Kernel::new(
                    format!("{p}.mm_k"),
                    KernelType::MatMul,
                    Shape::MatMul {
                        m: d.seq,
                        k: d.d_model,
                        n: dh,
                    },
                    d.dw,
                ),
                Kernel::new(
                    format!("{p}.mm_v"),
                    KernelType::MatMul,
                    Shape::MatMul {
                        m: d.seq,
                        k: d.d_model,
                        n: dh,
                    },
                    d.dw,
                ),
                Kernel::new(
                    format!("{p}.t_k"),
                    KernelType::Transpose,
                    Shape::Transpose {
                        rows: d.seq,
                        cols: dh,
                    },
                    d.dw,
                ),
                Kernel::new(
                    format!("{p}.mm_qk"),
                    KernelType::MatMul,
                    Shape::MatMul {
                        m: d.seq,
                        k: dh,
                        n: d.seq,
                    },
                    d.dw,
                ),
                Kernel::new(
                    format!("{p}.scale"),
                    KernelType::Scale,
                    Shape::Elementwise {
                        n: d.seq * d.seq,
                        arity: 1,
                    },
                    d.dw,
                ),
                Kernel::new(
                    format!("{p}.softmax"),
                    KernelType::Softmax,
                    Shape::Rowwise {
                        rows: d.seq,
                        cols: d.seq,
                    },
                    d.dw_row,
                ),
                Kernel::new(
                    format!("{p}.mm_av"),
                    KernelType::MatMul,
                    Shape::MatMul {
                        m: d.seq,
                        k: d.seq,
                        n: dh,
                    },
                    d.dw,
                ),
            ],
        );
    }

    // Output projection + first residual add.
    w.push_group(
        format!("{prefix}.residual1"),
        vec![
            Kernel::new(
                format!("{prefix}.mm_proj"),
                KernelType::MatMul,
                Shape::MatMul {
                    m: d.seq,
                    k: d.d_model,
                    n: d.d_model,
                },
                d.dw,
            ),
            Kernel::new(
                format!("{prefix}.add1"),
                KernelType::Add,
                Shape::Elementwise {
                    n: d.seq * d.d_model,
                    arity: 2,
                },
                d.dw,
            ),
        ],
    );

    // Pre-FFN layer norm.
    w.push_group(
        format!("{prefix}.norm2"),
        vec![Kernel::new(
            format!("{prefix}.norm2"),
            KernelType::Norm,
            Shape::Rowwise {
                rows: d.seq,
                cols: d.d_model,
            },
            d.dw_row,
        )],
    );

    // FFN: MM -> GeLU -> MM.
    w.push_group(
        format!("{prefix}.ffn"),
        vec![
            Kernel::new(
                format!("{prefix}.mm_ff1"),
                KernelType::MatMul,
                Shape::MatMul {
                    m: d.seq,
                    k: d.d_model,
                    n: d.d_ff,
                },
                d.dw,
            ),
            Kernel::new(
                format!("{prefix}.gelu"),
                KernelType::Gelu,
                Shape::Elementwise {
                    n: d.seq * d.d_ff,
                    arity: 1,
                },
                d.dw,
            ),
            Kernel::new(
                format!("{prefix}.mm_ff2"),
                KernelType::MatMul,
                Shape::MatMul {
                    m: d.seq,
                    k: d.d_ff,
                    n: d.d_model,
                },
                d.dw,
            ),
        ],
    );

    // Second residual add.
    w.push_group(
        format!("{prefix}.residual2"),
        vec![Kernel::new(
            format!("{prefix}.add2"),
            KernelType::Add,
            Shape::Elementwise {
                n: d.seq * d.d_model,
                arity: 2,
            },
            d.dw,
        )],
    );
}

/// Append an input-embedding group: patch projection matmul + class-token
/// concatenation (the ViT front of Fig 4). `patches` tokens of `patch_dim`
/// features projected to `d_model`.
pub fn patch_embedding(
    w: &mut Workload,
    prefix: &str,
    patches: u64,
    patch_dim: u64,
    d_model: u64,
    dw: DataWidth,
) {
    w.push_group(
        format!("{prefix}.embed"),
        vec![
            Kernel::new(
                format!("{prefix}.mm_embed"),
                KernelType::MatMul,
                Shape::MatMul {
                    m: patches,
                    k: patch_dim,
                    n: d_model,
                },
                dw,
            ),
            Kernel::new(
                format!("{prefix}.class_concat"),
                KernelType::ClassConcat,
                Shape::Concat {
                    rows: patches,
                    cols: d_model,
                },
                dw,
            ),
        ],
    );
}

/// Append the classifier head: final norm + projection to `n_classes`.
pub fn classifier(w: &mut Workload, prefix: &str, d_model: u64, n_classes: u64, d: TransformerDims) {
    w.push_group(
        format!("{prefix}.classifier"),
        vec![
            Kernel::new(
                format!("{prefix}.norm_final"),
                KernelType::Norm,
                Shape::Rowwise {
                    rows: 1,
                    cols: d_model,
                },
                d.dw_row,
            ),
            Kernel::new(
                format!("{prefix}.mm_class"),
                KernelType::MatMul,
                Shape::MatMul {
                    m: 1,
                    k: d_model,
                    n: n_classes,
                },
                d.dw,
            ),
        ],
    );
}

/// A small CNN (conv/norm/gelu stacks + classifier) used by tests and the
/// `custom_platform` example to show MEDEA is not transformer-specific.
pub fn small_cnn(name: &str, h: u64, w_: u64, c: &[u64], n_classes: u64, dw: DataWidth) -> Workload {
    assert!(c.len() >= 2, "need at least input+one conv channel count");
    let mut w = Workload::new(name);
    for (i, win) in c.windows(2).enumerate() {
        let (cin, cout) = (win[0], win[1]);
        w.push_group(
            format!("conv{i}"),
            vec![
                Kernel::new(
                    format!("conv{i}.conv"),
                    KernelType::Conv2d,
                    Shape::Conv2d {
                        h,
                        w: w_,
                        c_in: cin,
                        c_out: cout,
                        kh: 3,
                        kw: 3,
                    },
                    dw,
                ),
                Kernel::new(
                    format!("conv{i}.norm"),
                    KernelType::Norm,
                    Shape::Rowwise {
                        rows: h * w_,
                        cols: cout,
                    },
                    DataWidth::Int16,
                ),
                Kernel::new(
                    format!("conv{i}.gelu"),
                    KernelType::Gelu,
                    Shape::Elementwise {
                        n: h * w_ * cout,
                        arity: 1,
                    },
                    dw,
                ),
            ],
        );
    }
    let c_last = *c.last().unwrap();
    w.push_group(
        "classifier",
        vec![Kernel::new(
            "mm_class",
            KernelType::MatMul,
            Shape::MatMul {
                m: 1,
                k: h * w_ * c_last,
                n: n_classes,
            },
            dw,
        )],
    );
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> TransformerDims {
        TransformerDims {
            seq: 97,
            d_model: 128,
            heads: 4,
            d_ff: 256,
            dw: DataWidth::Int8,
            dw_row: DataWidth::Int16,
        }
    }

    #[test]
    fn encoder_block_kernel_count() {
        let mut w = Workload::new("t");
        encoder_block(&mut w, "enc0", dims());
        // 1 norm + 4 heads × 8 + (proj+add) + norm + 3 ffn + add = 40
        assert_eq!(w.len(), 1 + 4 * 8 + 2 + 1 + 3 + 1);
        assert!(w.groups_cover_all());
        // groups: norm1, 4 heads, residual1, norm2, ffn, residual2
        assert_eq!(w.groups().len(), 1 + 4 + 1 + 1 + 1 + 1);
    }

    #[test]
    fn encoder_block_shapes_are_consistent() {
        let mut w = Workload::new("t");
        encoder_block(&mut w, "enc0", dims());
        for k in w.kernels() {
            assert!(k.shape_matches_type(), "{k}");
        }
        // Per-head QK^T matmul is seq×dh×seq.
        let qk = w
            .kernels()
            .iter()
            .find(|k| k.name == "enc0.h0.mm_qk")
            .unwrap();
        assert_eq!(
            qk.shape,
            Shape::MatMul {
                m: 97,
                k: 32,
                n: 97
            }
        );
    }

    #[test]
    fn embedding_and_classifier() {
        let mut w = Workload::new("t");
        patch_embedding(&mut w, "in", 96, 80, 128, DataWidth::Int8);
        classifier(&mut w, "out", 128, 2, dims());
        assert_eq!(w.len(), 4);
        assert!(w.groups_cover_all());
    }

    #[test]
    fn cnn_builder() {
        let w = small_cnn("cnn", 16, 16, &[3, 8, 16], 10, DataWidth::Int8);
        assert_eq!(w.len(), 2 * 3 + 1);
        assert!(w.groups_cover_all());
        assert!(w.total_ops() > 0);
    }
}
