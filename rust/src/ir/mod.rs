//! Kernel-level workload representation (§3.1.1 of the paper).
//!
//! A workload `W = {k_1, …, k_N}` is an ordered list of computational
//! kernels; each kernel is a `(τ_i, s_i, δ_i)` tuple of type, operational
//! size, and data width. This kernel granularity is the unit MEDEA schedules.

pub mod builder;
pub mod kernel;
pub mod tsd;
pub mod workload;

pub use kernel::{DataWidth, Kernel, KernelType, Shape};
pub use workload::{Group, Workload};
