//! The kernel tuple `(τ, s, δ)`: type, operational size, data width.

use crate::util::units::Bytes;
use std::fmt;

/// Kernel (operator) type `τ ∈ T_ops`.
///
/// Matches the decomposition used by the paper's TSD case study (Fig 4):
/// MatMul, Conv2d, Add, Norm, Softmax (Taylor-approximated), GeLU (PWL),
/// Transpose, Scale, ClassConcat, and the FFT-magnitude frontend.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum KernelType {
    MatMul,
    Conv2d,
    Add,
    Norm,
    Softmax,
    Gelu,
    Transpose,
    Scale,
    ClassConcat,
    FftMag,
}

impl KernelType {
    pub const ALL: [KernelType; 10] = [
        KernelType::MatMul,
        KernelType::Conv2d,
        KernelType::Add,
        KernelType::Norm,
        KernelType::Softmax,
        KernelType::Gelu,
        KernelType::Transpose,
        KernelType::Scale,
        KernelType::ClassConcat,
        KernelType::FftMag,
    ];

    pub fn name(self) -> &'static str {
        match self {
            KernelType::MatMul => "matmul",
            KernelType::Conv2d => "conv2d",
            KernelType::Add => "add",
            KernelType::Norm => "norm",
            KernelType::Softmax => "softmax",
            KernelType::Gelu => "gelu",
            KernelType::Transpose => "transpose",
            KernelType::Scale => "scale",
            KernelType::ClassConcat => "class_concat",
            KernelType::FftMag => "fft_mag",
        }
    }

    pub fn from_name(name: &str) -> Option<KernelType> {
        KernelType::ALL.into_iter().find(|t| t.name() == name)
    }
}

impl fmt::Display for KernelType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Data width `δ` of a kernel's operands.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DataWidth {
    Int8,
    Int16,
    Int32,
    Float32,
}

impl DataWidth {
    pub fn bytes(self) -> u64 {
        match self {
            DataWidth::Int8 => 1,
            DataWidth::Int16 => 2,
            DataWidth::Int32 | DataWidth::Float32 => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DataWidth::Int8 => "int8",
            DataWidth::Int16 => "int16",
            DataWidth::Int32 => "int32",
            DataWidth::Float32 => "float32",
        }
    }

    pub fn from_name(name: &str) -> Option<DataWidth> {
        match name {
            "int8" => Some(DataWidth::Int8),
            "int16" => Some(DataWidth::Int16),
            "int32" => Some(DataWidth::Int32),
            "float32" => Some(DataWidth::Float32),
            _ => None,
        }
    }
}

impl fmt::Display for DataWidth {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Operational size `s` of a kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Shape {
    /// `C[m,n] = A[m,k] · B[k,n]`
    MatMul { m: u64, k: u64, n: u64 },
    /// 2-D convolution over an `h×w×c_in` input producing `c_out` maps with a
    /// `kh×kw` filter and unit stride ("same" padding assumed for sizing).
    Conv2d {
        h: u64,
        w: u64,
        c_in: u64,
        c_out: u64,
        kh: u64,
        kw: u64,
    },
    /// Element-wise over `n` elements, `arity` input operands (1 for
    /// activation/scale, 2 for add).
    Elementwise { n: u64, arity: u64 },
    /// Row-wise reduction+map (layer norm, softmax) over a `rows×cols` matrix.
    Rowwise { rows: u64, cols: u64 },
    /// Matrix transpose `rows×cols → cols×rows`.
    Transpose { rows: u64, cols: u64 },
    /// `batch` independent FFTs of `n_fft` points each, magnitude output.
    Fft { n_fft: u64, batch: u64 },
    /// Concatenate a class token row onto a `rows×cols` matrix.
    Concat { rows: u64, cols: u64 },
}

impl Shape {
    /// "Useful work" operation count: MACs for matmul/conv, element ops
    /// otherwise. This is the quantity cycle models scale with.
    pub fn ops(self) -> u64 {
        match self {
            Shape::MatMul { m, k, n } => m * k * n,
            Shape::Conv2d {
                h,
                w,
                c_in,
                c_out,
                kh,
                kw,
            } => h * w * c_in * c_out * kh * kw,
            Shape::Elementwise { n, .. } => n,
            // reduction + normalization passes
            Shape::Rowwise { rows, cols } => 3 * rows * cols,
            Shape::Transpose { rows, cols } => rows * cols,
            Shape::Fft { n_fft, batch } => {
                // radix-2 butterfly count ~ (n/2)·log2(n) complex MACs
                let log2 = 64 - n_fft.leading_zeros() as u64 - 1;
                batch * (n_fft / 2) * log2.max(1)
            }
            Shape::Concat { rows, cols } => rows * cols,
        }
    }

    /// Total bytes of input operands at data width `dw`.
    pub fn input_bytes(self, dw: DataWidth) -> Bytes {
        let b = dw.bytes();
        Bytes(match self {
            Shape::MatMul { m, k, n } => (m * k + k * n) * b,
            Shape::Conv2d {
                h,
                w,
                c_in,
                c_out,
                kh,
                kw,
            } => (h * w * c_in + kh * kw * c_in * c_out) * b,
            Shape::Elementwise { n, arity } => n * arity * b,
            Shape::Rowwise { rows, cols } => rows * cols * b,
            Shape::Transpose { rows, cols } => rows * cols * b,
            Shape::Fft { n_fft, batch } => n_fft * batch * b,
            Shape::Concat { rows, cols } => (rows * cols + cols) * b,
        })
    }

    /// Total bytes of output at data width `dw`.
    pub fn output_bytes(self, dw: DataWidth) -> Bytes {
        let b = dw.bytes();
        Bytes(match self {
            Shape::MatMul { m, n, .. } => m * n * b,
            Shape::Conv2d { h, w, c_out, .. } => h * w * c_out * b,
            Shape::Elementwise { n, .. } => n * b,
            Shape::Rowwise { rows, cols } => rows * cols * b,
            Shape::Transpose { rows, cols } => rows * cols * b,
            Shape::Fft { n_fft, batch } => (n_fft / 2) * batch * b,
            Shape::Concat { rows, cols } => (rows + 1) * cols * b,
        })
    }

    /// Total operand footprint (inputs + output).
    pub fn total_bytes(self, dw: DataWidth) -> Bytes {
        self.input_bytes(dw) + self.output_bytes(dw)
    }

    /// Bytes of the *activation* input operand — the tensor produced by the
    /// preceding kernel in a sequential DNN (A for matmul, the feature map
    /// for conv, the first operand for element-wise ops). When a kernel runs
    /// untiled in single-buffer mode, this operand can stay resident in the
    /// PE's LM from the previous kernel and skip the L2→LM transfer.
    pub fn activation_bytes(self, dw: DataWidth) -> Bytes {
        let b = dw.bytes();
        Bytes(match self {
            Shape::MatMul { m, k, .. } => m * k * b,
            Shape::Conv2d { h, w, c_in, .. } => h * w * c_in * b,
            Shape::Elementwise { n, .. } => n * b,
            Shape::Rowwise { rows, cols } => rows * cols * b,
            Shape::Transpose { rows, cols } => rows * cols * b,
            Shape::Fft { n_fft, batch } => n_fft * batch * b,
            Shape::Concat { rows, cols } => rows * cols * b,
        })
    }

    /// The largest single dimension (used by `Λ_op` dimension constraints).
    pub fn max_dim(self) -> u64 {
        match self {
            Shape::MatMul { m, k, n } => m.max(k).max(n),
            Shape::Conv2d { h, w, c_in, c_out, .. } => h.max(w).max(c_in).max(c_out),
            Shape::Elementwise { n, .. } => n,
            Shape::Rowwise { rows, cols } => rows.max(cols),
            Shape::Transpose { rows, cols } => rows.max(cols),
            Shape::Fft { n_fft, .. } => n_fft,
            Shape::Concat { rows, cols } => rows.max(cols),
        }
    }

    /// The dimension actually bounded by a `Λ_op` `max_dim` constraint: the
    /// *indivisible* addressing unit the PE must handle at once. Streaming
    /// lengths that the PE (or tiler) chunks internally — element-wise
    /// vectors, row counts, FFT batches — are not bounded; a matmul's
    /// largest dimension and a row reduction's width are.
    pub fn constrained_dim(self) -> u64 {
        match self {
            Shape::MatMul { m, k, n } => m.max(k).max(n),
            Shape::Conv2d { c_in, c_out, kh, kw, .. } => (kh * kw * c_in).max(c_out),
            Shape::Elementwise { .. } => 0,
            Shape::Rowwise { cols, .. } => cols,
            Shape::Transpose { cols, .. } => cols,
            Shape::Fft { n_fft, .. } => n_fft,
            Shape::Concat { cols, .. } => cols,
        }
    }
}

/// One computational kernel `k_i = (τ_i, s_i, δ_i)` plus bookkeeping.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Kernel {
    /// Position-independent display name, e.g. `enc0.h1.mm_qk`.
    pub name: String,
    pub ty: KernelType,
    pub shape: Shape,
    pub dw: DataWidth,
}

impl Kernel {
    pub fn new(name: impl Into<String>, ty: KernelType, shape: Shape, dw: DataWidth) -> Kernel {
        let k = Kernel {
            name: name.into(),
            ty,
            shape,
            dw,
        };
        debug_assert!(k.shape_matches_type(), "shape/type mismatch in {k:?}");
        k
    }

    /// Sanity: the shape variant must be meaningful for the kernel type.
    pub fn shape_matches_type(&self) -> bool {
        matches!(
            (self.ty, self.shape),
            (KernelType::MatMul, Shape::MatMul { .. })
                | (KernelType::Conv2d, Shape::Conv2d { .. })
                | (KernelType::Add, Shape::Elementwise { arity: 2, .. })
                | (KernelType::Scale, Shape::Elementwise { arity: 1, .. })
                | (KernelType::Gelu, Shape::Elementwise { arity: 1, .. })
                | (KernelType::Norm, Shape::Rowwise { .. })
                | (KernelType::Softmax, Shape::Rowwise { .. })
                | (KernelType::Transpose, Shape::Transpose { .. })
                | (KernelType::ClassConcat, Shape::Concat { .. })
                | (KernelType::FftMag, Shape::Fft { .. })
        )
    }

    pub fn ops(&self) -> u64 {
        self.shape.ops()
    }

    pub fn total_bytes(&self) -> Bytes {
        self.shape.total_bytes(self.dw)
    }
}

impl fmt::Display for Kernel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}/{}]", self.name, self.ty, self.dw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_ops_and_bytes() {
        let s = Shape::MatMul { m: 97, k: 128, n: 128 };
        assert_eq!(s.ops(), 97 * 128 * 128);
        assert_eq!(s.input_bytes(DataWidth::Int8).raw(), 97 * 128 + 128 * 128);
        assert_eq!(s.output_bytes(DataWidth::Int8).raw(), 97 * 128);
        assert_eq!(
            s.total_bytes(DataWidth::Int16).raw(),
            2 * (97 * 128 + 128 * 128 + 97 * 128)
        );
    }

    #[test]
    fn fft_ops_scale_nlogn() {
        let s = Shape::Fft { n_fft: 256, batch: 4 };
        assert_eq!(s.ops(), 4 * 128 * 8);
    }

    #[test]
    fn elementwise_arity() {
        let add = Shape::Elementwise { n: 100, arity: 2 };
        assert_eq!(add.input_bytes(DataWidth::Int8).raw(), 200);
        assert_eq!(add.output_bytes(DataWidth::Int8).raw(), 100);
    }

    #[test]
    fn kernel_type_round_trip() {
        for ty in KernelType::ALL {
            assert_eq!(KernelType::from_name(ty.name()), Some(ty));
        }
        assert_eq!(KernelType::from_name("bogus"), None);
    }

    #[test]
    fn data_width_round_trip() {
        for dw in [
            DataWidth::Int8,
            DataWidth::Int16,
            DataWidth::Int32,
            DataWidth::Float32,
        ] {
            assert_eq!(DataWidth::from_name(dw.name()), Some(dw));
        }
    }

    #[test]
    fn shape_type_validation() {
        let good = Kernel::new(
            "mm",
            KernelType::MatMul,
            Shape::MatMul { m: 1, k: 1, n: 1 },
            DataWidth::Int8,
        );
        assert!(good.shape_matches_type());
        let bad = Kernel {
            name: "bad".into(),
            ty: KernelType::Softmax,
            shape: Shape::MatMul { m: 1, k: 1, n: 1 },
            dw: DataWidth::Int8,
        };
        assert!(!bad.shape_matches_type());
    }

    #[test]
    fn max_dim() {
        assert_eq!(Shape::MatMul { m: 4, k: 512, n: 8 }.max_dim(), 512);
        assert_eq!(Shape::Transpose { rows: 3, cols: 9 }.max_dim(), 9);
    }

    #[test]
    fn display() {
        let k = Kernel::new(
            "enc0.mm_q",
            KernelType::MatMul,
            Shape::MatMul { m: 97, k: 128, n: 128 },
            DataWidth::Int8,
        );
        assert_eq!(k.to_string(), "enc0.mm_q[matmul/int8]");
    }
}
