//! The ordered workload `W` and its coarse-grain group structure.

use super::kernel::{DataWidth, Kernel, KernelType, Shape};
use crate::util::json::{Json, JsonObj};
use crate::util::units::Bytes;
use std::ops::Range;

/// A contiguous range of kernels treated as one scheduling unit by
/// coarse-grained baselines (§4.4: embedding / per-encoder norm, MHA head,
/// FFN, residual / classifier).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Group {
    pub name: String,
    pub range: Range<usize>,
}

/// An ordered list of kernels plus the coarse group partition.
#[derive(Debug, Clone, Default)]
pub struct Workload {
    pub name: String,
    kernels: Vec<Kernel>,
    groups: Vec<Group>,
}

impl Workload {
    pub fn new(name: impl Into<String>) -> Workload {
        Workload {
            name: name.into(),
            kernels: Vec::new(),
            groups: Vec::new(),
        }
    }

    /// Append one kernel (it joins no group until `close_group`).
    pub fn push(&mut self, kernel: Kernel) {
        self.kernels.push(kernel);
    }

    /// Append kernels and record them as one coarse group.
    pub fn push_group(&mut self, name: impl Into<String>, kernels: Vec<Kernel>) {
        let start = self.kernels.len();
        self.kernels.extend(kernels);
        self.groups.push(Group {
            name: name.into(),
            range: start..self.kernels.len(),
        });
    }

    pub fn kernels(&self) -> &[Kernel] {
        &self.kernels
    }

    pub fn len(&self) -> usize {
        self.kernels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kernels.is_empty()
    }

    pub fn groups(&self) -> &[Group] {
        &self.groups
    }

    /// True when every kernel belongs to exactly one group, in order.
    pub fn groups_cover_all(&self) -> bool {
        let mut next = 0;
        for g in &self.groups {
            if g.range.start != next {
                return false;
            }
            next = g.range.end;
        }
        next == self.kernels.len()
    }

    /// Total "useful ops" across the workload.
    pub fn total_ops(&self) -> u64 {
        self.kernels.iter().map(|k| k.ops()).sum()
    }

    /// Total operand traffic footprint.
    pub fn total_bytes(&self) -> Bytes {
        self.kernels.iter().map(|k| k.total_bytes()).sum()
    }

    /// Histogram of kernel types (for reporting).
    pub fn type_histogram(&self) -> Vec<(KernelType, usize)> {
        let mut hist: Vec<(KernelType, usize)> = Vec::new();
        for ty in KernelType::ALL {
            let n = self.kernels.iter().filter(|k| k.ty == ty).count();
            if n > 0 {
                hist.push((ty, n));
            }
        }
        hist
    }

    /// Restrict the workload to a kernel subrange (used by Fig 6/7 subsets).
    pub fn slice(&self, range: Range<usize>) -> Workload {
        let kernels = self.kernels[range.clone()].to_vec();
        let groups = self
            .groups
            .iter()
            .filter(|g| g.range.start >= range.start && g.range.end <= range.end)
            .map(|g| Group {
                name: g.name.clone(),
                range: g.range.start - range.start..g.range.end - range.start,
            })
            .collect();
        Workload {
            name: format!("{}[{}..{}]", self.name, range.start, range.end),
            kernels,
            groups,
        }
    }

    /// Keep only kernels satisfying `pred` (groups are dropped: a filtered
    /// workload is no longer contiguous).
    pub fn filter(&self, name: &str, pred: impl Fn(&Kernel) -> bool) -> Workload {
        Workload {
            name: name.to_string(),
            kernels: self.kernels.iter().filter(|k| pred(k)).cloned().collect(),
            groups: Vec::new(),
        }
    }

    // ---- JSON round-trip ---------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("name", self.name.clone());
        let kernels: Vec<Json> = self.kernels.iter().map(kernel_to_json).collect();
        o.insert("kernels", Json::Arr(kernels));
        let groups: Vec<Json> = self
            .groups
            .iter()
            .map(|g| {
                let mut go = JsonObj::new();
                go.insert("name", g.name.clone());
                go.insert("start", g.range.start);
                go.insert("end", g.range.end);
                Json::Obj(go)
            })
            .collect();
        o.insert("groups", Json::Arr(groups));
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<Workload, String> {
        let name = v.req("name")?.as_str().ok_or("name not a string")?.to_string();
        let mut w = Workload::new(name);
        for kv in v.req("kernels")?.as_arr().ok_or("kernels not an array")? {
            w.push(kernel_from_json(kv)?);
        }
        if let Some(gs) = v.get("groups").and_then(|g| g.as_arr()) {
            for gv in gs {
                let gname = gv.req("name")?.as_str().ok_or("group name")?.to_string();
                let start = gv.req("start")?.as_usize().ok_or("group start")?;
                let end = gv.req("end")?.as_usize().ok_or("group end")?;
                if end > w.kernels.len() || start > end {
                    return Err(format!("group `{gname}` range {start}..{end} out of bounds"));
                }
                w.groups.push(Group {
                    name: gname,
                    range: start..end,
                });
            }
        }
        Ok(w)
    }
}

fn kernel_to_json(k: &Kernel) -> Json {
    let mut o = JsonObj::new();
    o.insert("name", k.name.clone());
    o.insert("type", k.ty.name());
    o.insert("dw", k.dw.name());
    let mut s = JsonObj::new();
    match k.shape {
        Shape::MatMul { m, k: kk, n } => {
            s.insert("kind", "matmul");
            s.insert("m", m);
            s.insert("k", kk);
            s.insert("n", n);
        }
        Shape::Conv2d {
            h,
            w,
            c_in,
            c_out,
            kh,
            kw,
        } => {
            s.insert("kind", "conv2d");
            s.insert("h", h);
            s.insert("w", w);
            s.insert("c_in", c_in);
            s.insert("c_out", c_out);
            s.insert("kh", kh);
            s.insert("kw", kw);
        }
        Shape::Elementwise { n, arity } => {
            s.insert("kind", "elementwise");
            s.insert("n", n);
            s.insert("arity", arity);
        }
        Shape::Rowwise { rows, cols } => {
            s.insert("kind", "rowwise");
            s.insert("rows", rows);
            s.insert("cols", cols);
        }
        Shape::Transpose { rows, cols } => {
            s.insert("kind", "transpose");
            s.insert("rows", rows);
            s.insert("cols", cols);
        }
        Shape::Fft { n_fft, batch } => {
            s.insert("kind", "fft");
            s.insert("n_fft", n_fft);
            s.insert("batch", batch);
        }
        Shape::Concat { rows, cols } => {
            s.insert("kind", "concat");
            s.insert("rows", rows);
            s.insert("cols", cols);
        }
    }
    o.insert("shape", Json::Obj(s));
    Json::Obj(o)
}

fn kernel_from_json(v: &Json) -> Result<Kernel, String> {
    let name = v.req("name")?.as_str().ok_or("kernel name")?.to_string();
    let ty = KernelType::from_name(v.req("type")?.as_str().ok_or("kernel type")?)
        .ok_or("unknown kernel type")?;
    let dw = DataWidth::from_name(v.req("dw")?.as_str().ok_or("kernel dw")?)
        .ok_or("unknown data width")?;
    let sv = v.req("shape")?;
    let dim = |key: &str| -> Result<u64, String> {
        sv.req(key)?.as_u64().ok_or_else(|| format!("shape.{key}"))
    };
    let shape = match sv.req("kind")?.as_str().ok_or("shape.kind")? {
        "matmul" => Shape::MatMul {
            m: dim("m")?,
            k: dim("k")?,
            n: dim("n")?,
        },
        "conv2d" => Shape::Conv2d {
            h: dim("h")?,
            w: dim("w")?,
            c_in: dim("c_in")?,
            c_out: dim("c_out")?,
            kh: dim("kh")?,
            kw: dim("kw")?,
        },
        "elementwise" => Shape::Elementwise {
            n: dim("n")?,
            arity: dim("arity")?,
        },
        "rowwise" => Shape::Rowwise {
            rows: dim("rows")?,
            cols: dim("cols")?,
        },
        "transpose" => Shape::Transpose {
            rows: dim("rows")?,
            cols: dim("cols")?,
        },
        "fft" => Shape::Fft {
            n_fft: dim("n_fft")?,
            batch: dim("batch")?,
        },
        "concat" => Shape::Concat {
            rows: dim("rows")?,
            cols: dim("cols")?,
        },
        other => return Err(format!("unknown shape kind `{other}`")),
    };
    let k = Kernel {
        name,
        ty,
        shape,
        dw,
    };
    if !k.shape_matches_type() {
        return Err(format!("shape kind does not match kernel type for `{}`", k.name));
    }
    Ok(k)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mm(name: &str, m: u64, k: u64, n: u64) -> Kernel {
        Kernel::new(name, KernelType::MatMul, Shape::MatMul { m, k, n }, DataWidth::Int8)
    }

    #[test]
    fn groups_cover_detection() {
        let mut w = Workload::new("t");
        w.push_group("g0", vec![mm("a", 2, 2, 2), mm("b", 2, 2, 2)]);
        w.push_group("g1", vec![mm("c", 2, 2, 2)]);
        assert!(w.groups_cover_all());
        w.push(mm("loose", 2, 2, 2));
        assert!(!w.groups_cover_all());
    }

    #[test]
    fn totals() {
        let mut w = Workload::new("t");
        w.push(mm("a", 4, 4, 4));
        w.push(mm("b", 2, 2, 2));
        assert_eq!(w.total_ops(), 64 + 8);
        assert_eq!(w.total_bytes().raw(), (16 + 16 + 16) + (4 + 4 + 4));
    }

    #[test]
    fn slice_remaps_groups() {
        let mut w = Workload::new("t");
        w.push_group("g0", vec![mm("a", 2, 2, 2)]);
        w.push_group("g1", vec![mm("b", 2, 2, 2), mm("c", 2, 2, 2)]);
        let s = w.slice(1..3);
        assert_eq!(s.len(), 2);
        assert_eq!(s.groups().len(), 1);
        assert_eq!(s.groups()[0].range, 0..2);
    }

    #[test]
    fn filter_by_type() {
        let mut w = Workload::new("t");
        w.push(mm("a", 2, 2, 2));
        w.push(Kernel::new(
            "sm",
            KernelType::Softmax,
            Shape::Rowwise { rows: 4, cols: 4 },
            DataWidth::Int16,
        ));
        let only_mm = w.filter("mm-only", |k| k.ty == KernelType::MatMul);
        assert_eq!(only_mm.len(), 1);
        assert_eq!(only_mm.kernels()[0].name, "a");
    }

    #[test]
    fn json_round_trip() {
        let mut w = Workload::new("rt");
        w.push_group(
            "g0",
            vec![
                mm("a", 97, 128, 128),
                Kernel::new(
                    "sm",
                    KernelType::Softmax,
                    Shape::Rowwise { rows: 97, cols: 97 },
                    DataWidth::Int16,
                ),
                Kernel::new(
                    "fft",
                    KernelType::FftMag,
                    Shape::Fft { n_fft: 256, batch: 20 },
                    DataWidth::Float32,
                ),
            ],
        );
        let j = w.to_json();
        let parsed = Workload::from_json(&crate::util::json::parse(&j.to_pretty()).unwrap()).unwrap();
        assert_eq!(parsed.len(), 3);
        assert_eq!(parsed.kernels()[0], w.kernels()[0]);
        assert_eq!(parsed.kernels()[2], w.kernels()[2]);
        assert_eq!(parsed.groups(), w.groups());
    }

    #[test]
    fn json_rejects_mismatched_shape() {
        let text = r#"{"name":"x","kernels":[{"name":"k","type":"softmax","dw":"int8",
            "shape":{"kind":"matmul","m":1,"k":1,"n":1}}],"groups":[]}"#;
        let v = crate::util::json::parse(text).unwrap();
        assert!(Workload::from_json(&v).is_err());
    }

    #[test]
    fn type_histogram_counts() {
        let mut w = Workload::new("t");
        w.push(mm("a", 2, 2, 2));
        w.push(mm("b", 2, 2, 2));
        let hist = w.type_histogram();
        assert_eq!(hist, vec![(KernelType::MatMul, 2)]);
    }
}
