//! Enumeration of the valid configuration sets `Ω_i` for every kernel.

use super::estimator::Estimator;
use crate::ir::Workload;
use crate::platform::PeId;
use crate::tiling::modes::TilingMode;
use crate::util::units::{Energy, Time};

/// One valid execution configuration `ω_ij` with its estimated time/energy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Config {
    pub pe: PeId,
    pub vf_idx: usize,
    pub mode: TilingMode,
    /// `T_a(ω)` (Eq. 8).
    pub time: Time,
    /// `E_a(ω)` (Eq. 9).
    pub energy: Energy,
}

/// The per-kernel configuration sets for a workload.
#[derive(Debug, Clone, Default)]
pub struct ConfigSpace {
    /// `per_kernel[i]` = `Ω_i`, sorted by ascending time.
    pub per_kernel: Vec<Vec<Config>>,
}

impl ConfigSpace {
    /// Enumerate `Ω_i` for every kernel: all (PE, V-F) pairs the platform
    /// supports, with the cycle-minimal tiling mode pre-selected per pair
    /// (§3.3 dimensionality reduction). Panics if some kernel has no valid
    /// configuration (a platform that cannot run the workload at all).
    pub fn enumerate(workload: &Workload, est: &Estimator) -> ConfigSpace {
        let platform = est.platform;
        let per_kernel = workload
            .kernels()
            .iter()
            .map(|kernel| {
                let mut configs = Vec::new();
                for pe in platform.pe_ids() {
                    // Tiling mode choice is V-F independent (cycle counts
                    // are); pre-select once per PE.
                    let Some((mode, _cycles)) = est.best_mode(pe, kernel) else {
                        continue;
                    };
                    for vf_idx in 0..platform.vf.len() {
                        let Some(time) = est.time(pe, kernel, vf_idx, mode) else {
                            continue;
                        };
                        let energy = est.power(pe, kernel, vf_idx) * time;
                        configs.push(Config {
                            pe,
                            vf_idx,
                            mode,
                            time,
                            energy,
                        });
                    }
                }
                assert!(
                    !configs.is_empty(),
                    "kernel `{}` has no valid configuration on platform `{}`",
                    kernel.name,
                    platform.name
                );
                // total_cmp: a NaN estimate (corrupt calibration) must not
                // panic enumeration — the order stays total and
                // deterministic (NaNs sort to the extremes by sign bit).
                configs.sort_by(|a, b| a.time.raw().total_cmp(&b.time.raw()));
                configs
            })
            .collect();
        ConfigSpace { per_kernel }
    }

    pub fn n_kernels(&self) -> usize {
        self.per_kernel.len()
    }

    pub fn total_configs(&self) -> usize {
        self.per_kernel.iter().map(|c| c.len()).sum()
    }

    /// Fastest achievable total time (lower bound on the deadline below
    /// which no schedule exists).
    pub fn min_total_time(&self) -> Time {
        self.per_kernel
            .iter()
            .map(|cs| {
                cs.iter()
                    .map(|c| c.time)
                    .fold(Time(f64::INFINITY), Time::min)
            })
            .sum()
    }

    /// Total time/energy of the per-kernel energy-greedy choice (the
    /// unconstrained energy optimum; feasible only for relaxed deadlines).
    pub fn min_energy_choice(&self) -> (Time, Energy) {
        let mut t = Time::ZERO;
        let mut e = Energy::ZERO;
        for cs in &self.per_kernel {
            let best = cs
                .iter()
                .min_by(|a, b| a.energy.raw().total_cmp(&b.energy.raw()))
                .unwrap();
            t += best.time;
            e += best.energy;
        }
        (t, e)
    }

    /// Remove configurations dominated within their kernel (≥ time and
    /// ≥ energy than another). Solvers only ever pick Pareto points, so this
    /// is a pure speedup; returns the number removed.
    pub fn prune_dominated(&mut self) -> usize {
        let mut removed = 0;
        for cs in &mut self.per_kernel {
            // cs sorted by time ascending; sweep keeping strictly
            // decreasing energy.
            let mut kept: Vec<Config> = Vec::with_capacity(cs.len());
            for c in cs.iter() {
                if kept.iter().any(|k| k.energy.raw() <= c.energy.raw()) {
                    removed += 1;
                } else {
                    kept.push(*c);
                }
            }
            *cs = kept;
        }
        removed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::tsd::{tsd_core, TsdParams};
    use crate::platform::heeptimize::heeptimize;
    use crate::profile::characterize;
    use crate::timing::cycle_model::CycleModel;

    fn space() -> ConfigSpace {
        let platform = heeptimize();
        let model = CycleModel::heeptimize();
        let profiles = characterize(&platform, &model);
        let est = Estimator::new(&platform, &profiles, &model);
        ConfigSpace::enumerate(&tsd_core(&TsdParams::default()), &est)
    }

    #[test]
    fn every_kernel_has_configs() {
        let s = space();
        assert_eq!(s.n_kernels(), 164);
        for (i, cs) in s.per_kernel.iter().enumerate() {
            assert!(!cs.is_empty(), "kernel {i}");
            // CPU-only kernels: exactly 4 V-F configs; 3-PE kernels: 12.
            assert!(cs.len() == 4 || cs.len() == 12, "kernel {i}: {}", cs.len());
            // Sorted by time.
            for w in cs.windows(2) {
                assert!(w[0].time.raw() <= w[1].time.raw());
            }
        }
    }

    #[test]
    fn min_time_below_min_energy_time() {
        let s = space();
        let (t_e, _) = s.min_energy_choice();
        assert!(s.min_total_time().raw() <= t_e.raw());
        assert!(s.min_total_time().raw() > 0.0);
    }

    #[test]
    fn pruning_keeps_extremes() {
        let mut s = space();
        let (_, e_min_before) = s.min_energy_choice();
        let t_min_before = s.min_total_time();
        let removed = s.prune_dominated();
        assert!(removed > 0);
        let (_, e_min_after) = s.min_energy_choice();
        assert!((e_min_after.raw() - e_min_before.raw()).abs() < 1e-15);
        assert!((s.min_total_time().raw() - t_min_before.raw()).abs() < 1e-15);
    }
}
