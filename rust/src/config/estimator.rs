//! The timing (`G_T`) and power (`G_P`) estimators of §3.3.
//!
//! `G_T(k, p, v, c)`: total cycle count (profiled/extrapolated processing
//! cycles + tiling-dependent data movement + overheads) divided by the
//! frequency of the chosen voltage level. `G_P(k, p, v)`: characterized
//! power, assumed independent of the kernel's operational size.

use crate::ir::Kernel;
use crate::platform::{PeId, Platform};
use crate::profile::Profiles;
use crate::timing::cycle_model::CycleModel;
use crate::tiling::modes::{mode_cycles_with, TilingMode};
use crate::util::units::{Cycles, Energy, Power, Time};

/// How the tiling mode is chosen per (kernel, PE) — [`TilingPolicy::Adaptive`]
/// is MEDEA's memory-aware adaptive tiling; [`TilingPolicy::ForceDouble`]
/// pins `t_db` (the §5.3.3 ablation and the §4.4 baseline convention).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TilingPolicy {
    #[default]
    Adaptive,
    ForceDouble,
}

/// Bundles platform + profiles + overhead constants into the §3.3 models.
pub struct Estimator<'a> {
    pub platform: &'a Platform,
    pub profiles: &'a Profiles,
    /// Overhead constants (launch / per-tile); processing cycles always come
    /// from the profiles, mirroring the paper's measured-profile flow.
    pub model: &'a CycleModel,
    pub policy: TilingPolicy,
}

impl<'a> Estimator<'a> {
    pub fn new(platform: &'a Platform, profiles: &'a Profiles, model: &'a CycleModel) -> Self {
        Estimator {
            platform,
            profiles,
            model,
            policy: TilingPolicy::Adaptive,
        }
    }

    pub fn with_policy(mut self, policy: TilingPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Profiled/extrapolated processing-only cycles of `kernel` on `pe`.
    pub fn processing_cycles(&self, pe: PeId, kernel: &Kernel) -> Option<Cycles> {
        self.profiles
            .processing_cycles(pe, kernel.ty, kernel.dw, kernel.shape.ops())
    }

    /// Total execution cycles of `kernel` on `pe` under tiling mode `mode`.
    pub fn total_cycles(&self, pe: PeId, kernel: &Kernel, mode: TilingMode) -> Option<Cycles> {
        let pe_ref = self.platform.pe(pe);
        let compute = self.processing_cycles(pe, kernel)?;
        mode_cycles_with(
            self.platform,
            pe_ref,
            kernel,
            compute,
            self.model.launch(pe_ref.class),
            self.model.per_tile(pe_ref.class),
            mode,
        )
    }

    /// `G_T`: wall-clock execution time at V-F index `vf_idx`.
    pub fn time(&self, pe: PeId, kernel: &Kernel, vf_idx: usize, mode: TilingMode) -> Option<Time> {
        let cycles = self.total_cycles(pe, kernel, mode)?;
        let vf = self.platform.vf.get(vf_idx);
        Some(cycles.at(vf.f))
    }

    /// `G_P`: characterized power for `(pe, kernel type)` at `vf_idx`.
    pub fn power(&self, pe: PeId, kernel: &Kernel, vf_idx: usize) -> Power {
        self.profiles.power_or_model(
            self.platform,
            pe,
            kernel.ty,
            vf_idx,
            self.platform.vf.get(vf_idx),
        )
    }

    /// Active energy `E_a(ω) = G_P(ω) · G_T(ω)` (Eq. 9).
    pub fn energy(
        &self,
        pe: PeId,
        kernel: &Kernel,
        vf_idx: usize,
        mode: TilingMode,
    ) -> Option<Energy> {
        let t = self.time(pe, kernel, vf_idx, mode)?;
        Some(self.power(pe, kernel, vf_idx) * t)
    }

    /// The tiling mode for `(kernel, pe)` under the estimator's policy.
    /// Adaptive: the cycle-minimal mode — the §3.3 pre-selection step (mode
    /// choice is V-F independent since cycle counts are; frequency only
    /// scales time). ForceDouble: `t_db`, falling back to `t_sb` only when
    /// the kernel cannot be tiled into half the LM at all (feasibility
    /// guard, noted in DESIGN.md).
    pub fn best_mode(&self, pe: PeId, kernel: &Kernel) -> Option<(TilingMode, Cycles)> {
        let sb = self.total_cycles(pe, kernel, TilingMode::SingleBuffer);
        let db = self.total_cycles(pe, kernel, TilingMode::DoubleBuffer);
        match self.policy {
            TilingPolicy::Adaptive => match (sb, db) {
                (Some(s), Some(d)) if d < s => Some((TilingMode::DoubleBuffer, d)),
                (Some(s), _) => Some((TilingMode::SingleBuffer, s)),
                (None, Some(d)) => Some((TilingMode::DoubleBuffer, d)),
                (None, None) => None,
            },
            TilingPolicy::ForceDouble => match (db, sb) {
                (Some(d), _) => Some((TilingMode::DoubleBuffer, d)),
                (None, Some(s)) => Some((TilingMode::SingleBuffer, s)),
                (None, None) => None,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataWidth::*, KernelType, Shape};
    use crate::platform::heeptimize::{heeptimize, CARUS, CGRA, CPU};
    use crate::profile::characterize;

    fn mm(m: u64, k: u64, n: u64) -> Kernel {
        Kernel::new("mm", KernelType::MatMul, Shape::MatMul { m, k, n }, Int8)
    }

    #[test]
    fn estimator_end_to_end() {
        let platform = heeptimize();
        let model = CycleModel::heeptimize();
        let profiles = characterize(&platform, &model);
        let est = Estimator::new(&platform, &profiles, &model);

        let k = mm(97, 128, 256);
        // Accelerators must beat the CPU in time at equal V-F.
        let t_cpu = est.time(CPU, &k, 3, TilingMode::SingleBuffer).unwrap();
        let (mode, _) = est.best_mode(CARUS, &k).unwrap();
        let t_carus = est.time(CARUS, &k, 3, mode).unwrap();
        assert!(t_carus.raw() < t_cpu.raw() / 4.0);

        // Time shrinks and power grows with V-F; energy is not monotone.
        let t_lo = est.time(CARUS, &k, 0, mode).unwrap();
        let t_hi = est.time(CARUS, &k, 3, mode).unwrap();
        assert!(t_hi < t_lo);
        assert!(est.power(CARUS, &k, 3) > est.power(CARUS, &k, 0));
    }

    #[test]
    fn energy_minimum_at_lowest_vf_for_accel() {
        // With P ≈ c·V²f dominating, energy per kernel falls with voltage,
        // so the per-kernel energy-optimal V-F is the lowest — the reason
        // relaxed deadlines collapse to 0.5 V (paper Fig 6).
        let platform = heeptimize();
        let model = CycleModel::heeptimize();
        let profiles = characterize(&platform, &model);
        let est = Estimator::new(&platform, &profiles, &model);
        let k = mm(97, 128, 32);
        for pe in [CGRA, CARUS] {
            let (mode, _) = est.best_mode(pe, &k).unwrap();
            let e0 = est.energy(pe, &k, 0, mode).unwrap();
            let e3 = est.energy(pe, &k, 3, mode).unwrap();
            assert!(e0 < e3, "pe={pe}: {e0} !< {e3}");
        }
    }

    #[test]
    fn fig7_crossover_exists() {
        // CGRA more energy-efficient than Carus at 0.5 V, Carus better at
        // 0.9 V, for a representative TSD matmul — the paper's Fig 7.
        let platform = heeptimize();
        let model = CycleModel::heeptimize();
        let profiles = characterize(&platform, &model);
        let est = Estimator::new(&platform, &profiles, &model);
        let k = mm(97, 128, 32);
        let e = |pe: crate::platform::PeId, vf: usize| {
            let (mode, _) = est.best_mode(pe, &k).unwrap();
            est.energy(pe, &k, vf, mode).unwrap()
        };
        let lo_ratio = e(CGRA, 0) / e(CARUS, 0);
        let hi_ratio = e(CGRA, 3) / e(CARUS, 3);
        assert!(lo_ratio < 1.0, "CGRA must win at 0.5V: ratio {lo_ratio:.3}");
        assert!(hi_ratio > 1.0, "Carus must win at 0.9V: ratio {hi_ratio:.3}");
    }

    #[test]
    fn unsupported_configs_are_none() {
        let platform = heeptimize();
        let model = CycleModel::heeptimize();
        let profiles = characterize(&platform, &model);
        let est = Estimator::new(&platform, &profiles, &model);
        let sm = Kernel::new(
            "sm",
            KernelType::Softmax,
            Shape::Rowwise { rows: 97, cols: 97 },
            Int16,
        );
        assert!(est.best_mode(CGRA, &sm).is_none());
        assert!(est.best_mode(CPU, &sm).is_some());
    }
}
