//! Per-kernel execution-configuration space `Ω_i` (§3.3).
//!
//! A configuration `ω_ij = (p_ij, v_ij, c_ij)` fixes the PE, the V-F point,
//! and the (pre-selected, cycle-minimal) tiling mode for kernel `k_i`.
//! [`estimator`] implements the timing model `G_T` (profiled cycles +
//! extrapolation + tiling/DMA composition) and power model `G_P`;
//! [`space`] enumerates all valid configurations per kernel.

pub mod estimator;
pub mod space;

pub use estimator::Estimator;
pub use space::{Config, ConfigSpace};
