//! # MEDEA — Manager for Energy-efficient DNNs on hEterogeneous ULP Architectures
//!
//! A reproduction of *"MEDEA: A Design-Time Multi-Objective Manager for
//! Energy-Efficient DNN Inference on Heterogeneous Ultra-Low Power Platforms"*
//! (Taji et al., 2025) as a three-layer Rust + JAX + Pallas stack.
//!
//! The library is organized bottom-up:
//!
//! * [`util`] — zero-dependency substrates (JSON codec, CLI parser, typed
//!   units, statistics, deterministic RNG, table formatting).
//! * [`ir`] — the kernel-level workload representation `W = {k_1..k_N}` with
//!   each kernel a `(τ, s, δ)` tuple, plus builders (transformer blocks, the
//!   TSD seizure-detection model of the paper's case study).
//! * [`platform`] — heterogeneous ULP platform descriptions: processing
//!   elements `P`, V-F operating points `S_vf`, local-memory capacities
//!   `C_LM`, kernel-PE operational constraints `Λ_op`; includes the
//!   HEEPtimize preset (RISC-V CPU + OpenEdgeCGRA + Carus NMC, GF 22 nm FDX
//!   characterization anchors from the paper).
//! * [`timing`] / [`power`] — the characterization models standing in for the
//!   paper's FPGA prototype (cycle counts) and ASIC power flow (PrimePower):
//!   per-PE analytical cycle models and `P_stat + C_eff·V²·f` power models.
//! * [`tiling`] — memory-aware adaptive tiling: footprint computation, tile
//!   planning under `C_LM` and `Λ_op`, single- vs double-buffer execution
//!   cycle estimation.
//! * [`profile`] — the characterization harness that produces the timing
//!   (`S_c`) and power (`S_P`) profiles MEDEA consumes, and their JSON
//!   round-trip.
//! * [`config`] — enumeration of the per-kernel configuration space `Ω_i`
//!   (PE × V-F, with the cycle-minimal tiling mode pre-selected).
//! * [`solver`] — Multiple-Choice Knapsack solvers: exact discretized-time DP,
//!   exact branch-and-bound, Lagrangian relaxation, and a dominance-filtered
//!   greedy heuristic.
//! * [`manager`] — the MEDEA manager itself (§3.3 of the paper) with feature
//!   switches for the §5.3 ablations, and the schedule type it emits.
//! * [`baselines`] — the four comparison schedulers of §4.4.
//! * [`sim`] — a tile-granular discrete-event simulator that *replays* a
//!   schedule on the platform model, independently accounting time and energy
//!   (DMA/compute overlap, V-F switches, sleep).
//! * [`eeg`] — synthetic EEG generation and the FFT-magnitude frontend.
//! * [`runtime`] — the PJRT path: loads AOT-compiled HLO artifacts (produced
//!   by `python/compile/aot.py`) and executes them from Rust.
//! * [`serve`] — the online serving subsystem: a precomputed **schedule
//!   atlas** (all MCKP solves moved to startup; requests resolve by binary
//!   search), an EDF admission queue with typed shedding, a sharded
//!   multi-worker pool, and cross-worker metrics.
//! * [`telemetry`] — live observability for the serving layers: a lock-free
//!   per-worker metrics registry (atomic counters + log-linear histograms),
//!   Prometheus text exposition over `std::net`, a bounded dispatch-event
//!   trace ring (chrome://tracing dumps), and a periodic one-line reporter.
//! * [`fleet`] — the multi-platform atlas **library**: content-keyed entries
//!   (platform fingerprint × workload hash) each carrying a deadline atlas
//!   and an energy-budget atlas, an epoch-versioned registry with live
//!   `Arc`-swap, an on-disk store, and a pool that routes requests tagged
//!   with (platform preset, workload preset, deadline-or-energy demand).
//! * [`coordinator`] — the legacy threaded inference service, now a thin
//!   single-worker compatibility wrapper over [`serve`].
//! * [`exp`] / [`report`] — drivers that regenerate every table and figure of
//!   the paper's evaluation, and their formatting helpers.
//! * [`analysis`] — self-hosted static analysis (`medea lint`): a line lexer
//!   plus rule engine that machine-checks the serving stack's concurrency
//!   and determinism invariants (NaN-safe comparisons, no panicking
//!   extractors on the serving path, justified atomic orderings, shard-lock
//!   discipline, deterministic design-time code).

pub mod analysis;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod eeg;
pub mod exp;
pub mod fleet;
pub mod ir;
pub mod manager;
pub mod platform;
pub mod power;
pub mod profile;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod solver;
pub mod telemetry;
pub mod tiling;
pub mod timing;
pub mod util;

pub use ir::{Kernel, KernelType, Workload};
pub use manager::{Medea, MedeaFeatures, Schedule};
pub use platform::{Platform, PeId, VfPoint};

/// Library version (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
