//! The characterization harness — FPGA + PrimePower campaign stand-in.
//!
//! Sweeps a grid of representative kernel sizes per (PE, kernel type,
//! width), "measures" processing-only cycles via the analytical cycle model
//! (the FPGA's role) and whole-SoC power via the platform power model at
//! every V-F point (the ASIC flow's role), and returns the populated
//! [`Profiles`]. Only combinations permitted by `Λ_op` are profiled —
//! exactly like a real campaign can only measure kernels the PE implements.

use super::tables::Profiles;
use crate::ir::{DataWidth, KernelType, Shape};
use crate::platform::Platform;
use crate::timing::cycle_model::CycleModel;

/// Representative shapes per kernel type — a size ladder wide enough that
/// extrapolation covers the TSD model and the CNN example.
fn representative_shapes(ty: KernelType) -> Vec<Shape> {
    match ty {
        KernelType::MatMul => [8u64, 32, 64, 96, 128, 256]
            .iter()
            .map(|&d| Shape::MatMul { m: d, k: d, n: d })
            .chain([
                Shape::MatMul { m: 97, k: 128, n: 32 },
                Shape::MatMul { m: 97, k: 128, n: 256 },
                Shape::MatMul { m: 1, k: 128, n: 2 },
            ])
            .collect(),
        KernelType::Conv2d => vec![
            Shape::Conv2d { h: 8, w: 8, c_in: 3, c_out: 8, kh: 3, kw: 3 },
            Shape::Conv2d { h: 16, w: 16, c_in: 8, c_out: 16, kh: 3, kw: 3 },
            Shape::Conv2d { h: 32, w: 32, c_in: 16, c_out: 32, kh: 3, kw: 3 },
        ],
        KernelType::Add | KernelType::Scale | KernelType::Gelu => {
            let arity = if ty == KernelType::Add { 2 } else { 1 };
            [1_000u64, 10_000, 50_000, 100_000]
                .iter()
                .map(|&n| Shape::Elementwise { n, arity })
                .collect()
        }
        KernelType::Norm | KernelType::Softmax => vec![
            Shape::Rowwise { rows: 16, cols: 64 },
            Shape::Rowwise { rows: 97, cols: 97 },
            Shape::Rowwise { rows: 97, cols: 128 },
            Shape::Rowwise { rows: 256, cols: 256 },
        ],
        KernelType::Transpose => vec![
            Shape::Transpose { rows: 32, cols: 32 },
            Shape::Transpose { rows: 97, cols: 32 },
            Shape::Transpose { rows: 128, cols: 128 },
        ],
        KernelType::ClassConcat => vec![
            Shape::Concat { rows: 96, cols: 128 },
            Shape::Concat { rows: 16, cols: 64 },
        ],
        KernelType::FftMag => vec![
            Shape::Fft { n_fft: 128, batch: 8 },
            Shape::Fft { n_fft: 256, batch: 96 },
            Shape::Fft { n_fft: 512, batch: 16 },
        ],
    }
}

/// Widths to profile per kernel type (mirrors what the deployment uses).
fn representative_widths(ty: KernelType) -> Vec<DataWidth> {
    match ty {
        KernelType::FftMag => vec![DataWidth::Float32],
        KernelType::Norm | KernelType::Softmax => vec![DataWidth::Int16, DataWidth::Float32],
        _ => vec![
            DataWidth::Int8,
            DataWidth::Int16,
            DataWidth::Int32,
            DataWidth::Float32,
        ],
    }
}

/// Run the full characterization campaign.
pub fn characterize(platform: &Platform, model: &CycleModel) -> Profiles {
    let mut profiles = Profiles::new();
    for pe in &platform.pes {
        for ty in KernelType::ALL {
            let Some(constraint) = platform.constraints.get(pe.id, ty) else {
                continue; // PE does not implement this kernel type
            };
            // Timing: profile each width the PE supports.
            for dw in representative_widths(ty) {
                if !constraint.allows_width(dw) {
                    continue;
                }
                for shape in representative_shapes(ty) {
                    if let Some(d) = constraint.max_dim {
                        // Only the indivisible addressing unit is bounded;
                        // streaming lengths are chunked by the tiler.
                        if shape.constrained_dim() > d {
                            continue; // not measurable on this PE
                        }
                    }
                    let ops = shape.ops();
                    if let Some(cycles) = model.cycles_for_ops(pe.class, ty, dw, ops) {
                        profiles.record_timing(pe.id, ty, dw, ops, cycles);
                    }
                }
            }
            // Power: one entry per V-F point (size-independent, §3.3).
            for (vf_idx, &vf) in platform.vf.points().iter().enumerate() {
                let p = crate::power::kernel_power(platform, pe.id, ty, vf);
                profiles.record_power(pe.id, ty, vf_idx, p);
            }
        }
    }
    profiles.finalize();
    profiles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::heeptimize::{heeptimize, CARUS, CGRA, CPU};
    use crate::util::units::Cycles;

    #[test]
    fn campaign_covers_expected_combos() {
        let p = heeptimize();
        let prof = characterize(&p, &CycleModel::heeptimize());
        assert!(prof.timing_entry_count() > 100);
        // CPU softmax profiled; CGRA softmax not.
        assert!(prof
            .processing_cycles(CPU, KernelType::Softmax, DataWidth::Int16, 10_000)
            .is_some());
        assert!(prof
            .processing_cycles(CGRA, KernelType::Softmax, DataWidth::Int16, 10_000)
            .is_none());
        // Accelerators profiled for int matmul, not float.
        assert!(prof
            .processing_cycles(CARUS, KernelType::MatMul, DataWidth::Int8, 1_000_000)
            .is_some());
        assert!(prof
            .processing_cycles(CARUS, KernelType::MatMul, DataWidth::Float32, 1_000_000)
            .is_none());
    }

    #[test]
    fn extrapolation_matches_model_closely() {
        // The paper extrapolates non-profiled sizes; our fit should stay
        // within a few percent of the underlying model on a fresh size.
        let p = heeptimize();
        let model = CycleModel::heeptimize();
        let prof = characterize(&p, &model);
        let ops = Shape::MatMul { m: 77, k: 111, n: 55 }.ops();
        let fit = prof
            .processing_cycles(CARUS, KernelType::MatMul, DataWidth::Int8, ops)
            .unwrap();
        let direct = model
            .cycles_for_ops(
                crate::platform::PeClass::Nmc,
                KernelType::MatMul,
                DataWidth::Int8,
                ops,
            )
            .unwrap();
        let rel = (fit.raw() as f64 - direct.raw() as f64).abs() / direct.raw() as f64;
        assert!(rel < 0.05, "extrapolation off by {rel:.3}: {fit} vs {direct}");
        let _ = Cycles(0);
    }

    #[test]
    fn power_entries_for_all_vf_points() {
        let p = heeptimize();
        let prof = characterize(&p, &CycleModel::heeptimize());
        for vf_idx in 0..p.vf.len() {
            assert!(prof.power(CGRA, KernelType::MatMul, vf_idx).is_some());
        }
        assert!(prof.power(CGRA, KernelType::MatMul, p.vf.len()).is_none());
    }
}
