//! Platform characterization profiles (§3.1.3): timing `S_c` + power `S_P`.
//!
//! [`harness`] plays the role of the paper's FPGA measurement campaign: it
//! "executes" a grid of representative kernel sizes per (PE, kernel type,
//! width) against the analytical cycle model and records exact cycle counts.
//! [`tables`] stores the resulting profiles, fits extrapolators for
//! non-profiled sizes (§3.3), and round-trips to JSON so characterized
//! platforms can be shipped without the harness.

pub mod harness;
pub mod tables;

pub use harness::characterize;
pub use tables::Profiles;
