//! Profile storage, extrapolation and JSON round-trip.

use crate::ir::{DataWidth, KernelType};
use crate::platform::{PeId, Platform, VfPoint};
use crate::power::kernel_power;
use crate::timing::extrapolate::{Extrapolator, ProfilePoint};
use crate::util::json::{parse, Json, JsonObj};
use crate::util::units::{Cycles, Power};
use std::collections::BTreeMap;

type TimingKey = (usize, KernelType, DataWidth);

/// Characterized platform profiles: per-(PE, type, width) processing-cycle
/// tables with least-squares extrapolation, and per-(PE, type, V-F) power.
#[derive(Debug, Clone, Default)]
pub struct Profiles {
    timing_points: BTreeMap<TimingKey, Vec<ProfilePoint>>,
    fits: BTreeMap<TimingKey, Extrapolator>,
    /// (pe, type, vf index) → characterized power.
    power: BTreeMap<(usize, KernelType, usize), Power>,
}

impl Profiles {
    pub fn new() -> Profiles {
        Profiles::default()
    }

    /// Record one timing measurement (harness-side).
    pub fn record_timing(
        &mut self,
        pe: PeId,
        ty: KernelType,
        dw: DataWidth,
        ops: u64,
        cycles: Cycles,
    ) {
        self.timing_points
            .entry((pe.0, ty, dw))
            .or_default()
            .push(ProfilePoint {
                ops,
                cycles: cycles.raw(),
            });
        self.fits.remove(&(pe.0, ty, dw)); // invalidate fit
    }

    /// Record one power measurement (harness-side).
    pub fn record_power(&mut self, pe: PeId, ty: KernelType, vf_idx: usize, p: Power) {
        self.power.insert((pe.0, ty, vf_idx), p);
    }

    /// Fit all extrapolators (idempotent).
    pub fn finalize(&mut self) {
        for (key, pts) in &self.timing_points {
            self.fits
                .entry(*key)
                .or_insert_with(|| Extrapolator::fit(pts));
        }
    }

    /// Profiled/extrapolated processing-only cycles, `None` if the
    /// combination was never profiled (⇒ not executable).
    pub fn processing_cycles(
        &self,
        pe: PeId,
        ty: KernelType,
        dw: DataWidth,
        ops: u64,
    ) -> Option<Cycles> {
        self.fits.get(&(pe.0, ty, dw)).map(|e| e.cycles(ops))
    }

    /// Characterized power for `(pe, ty)` at V-F index `vf_idx`.
    pub fn power(&self, pe: PeId, ty: KernelType, vf_idx: usize) -> Option<Power> {
        self.power.get(&(pe.0, ty, vf_idx)).copied()
    }

    /// Power via the platform model, for combos not measured (used as a
    /// fallback and in tests).
    pub fn power_or_model(
        &self,
        platform: &Platform,
        pe: PeId,
        ty: KernelType,
        vf_idx: usize,
        vf: VfPoint,
    ) -> Power {
        self.power(pe, ty, vf_idx)
            .unwrap_or_else(|| kernel_power(platform, pe, ty, vf))
    }

    pub fn timing_entry_count(&self) -> usize {
        self.timing_points.values().map(|v| v.len()).sum()
    }

    pub fn power_entry_count(&self) -> usize {
        self.power.len()
    }

    /// Keys that have timing profiles (used to enumerate executable combos).
    pub fn timing_keys(&self) -> impl Iterator<Item = (PeId, KernelType, DataWidth)> + '_ {
        self.timing_points
            .keys()
            .map(|(pe, ty, dw)| (PeId(*pe), *ty, *dw))
    }

    // ---- JSON ----------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        let timing: Vec<Json> = self
            .timing_points
            .iter()
            .map(|((pe, ty, dw), pts)| {
                let mut e = JsonObj::new();
                e.insert("pe", *pe);
                e.insert("type", ty.name());
                e.insert("dw", dw.name());
                let points: Vec<Json> = pts
                    .iter()
                    .map(|p| {
                        let mut pj = JsonObj::new();
                        pj.insert("ops", p.ops);
                        pj.insert("cycles", p.cycles);
                        Json::Obj(pj)
                    })
                    .collect();
                e.insert("points", Json::Arr(points));
                Json::Obj(e)
            })
            .collect();
        o.insert("timing", Json::Arr(timing));
        let power: Vec<Json> = self
            .power
            .iter()
            .map(|((pe, ty, vf), p)| {
                let mut e = JsonObj::new();
                e.insert("pe", *pe);
                e.insert("type", ty.name());
                e.insert("vf", *vf);
                e.insert("power_uw", p.as_uw());
                Json::Obj(e)
            })
            .collect();
        o.insert("power", Json::Arr(power));
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<Profiles, String> {
        let mut p = Profiles::new();
        for e in v.req("timing")?.as_arr().ok_or("timing")? {
            let pe = PeId(e.req("pe")?.as_usize().ok_or("pe")?);
            let ty = KernelType::from_name(e.req("type")?.as_str().ok_or("type")?)
                .ok_or("unknown type")?;
            let dw =
                DataWidth::from_name(e.req("dw")?.as_str().ok_or("dw")?).ok_or("unknown dw")?;
            for pt in e.req("points")?.as_arr().ok_or("points")? {
                p.record_timing(
                    pe,
                    ty,
                    dw,
                    pt.req("ops")?.as_u64().ok_or("ops")?,
                    Cycles(pt.req("cycles")?.as_u64().ok_or("cycles")?),
                );
            }
        }
        for e in v.req("power")?.as_arr().ok_or("power")? {
            p.record_power(
                PeId(e.req("pe")?.as_usize().ok_or("pe")?),
                KernelType::from_name(e.req("type")?.as_str().ok_or("type")?)
                    .ok_or("unknown type")?,
                e.req("vf")?.as_usize().ok_or("vf")?,
                Power::from_uw(e.req("power_uw")?.as_f64().ok_or("power_uw")?),
            );
        }
        p.finalize();
        Ok(p)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<(), String> {
        std::fs::write(path, self.to_json().to_pretty()).map_err(|e| e.to_string())
    }

    pub fn load(path: &std::path::Path) -> Result<Profiles, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Profiles::from_json(&parse(&text).map_err(|e| e.to_string())?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_fit_query() {
        let mut p = Profiles::new();
        let pe = PeId(1);
        p.record_timing(pe, KernelType::MatMul, DataWidth::Int8, 1000, Cycles(300));
        p.record_timing(pe, KernelType::MatMul, DataWidth::Int8, 2000, Cycles(600));
        p.finalize();
        assert_eq!(
            p.processing_cycles(pe, KernelType::MatMul, DataWidth::Int8, 4000),
            Some(Cycles(1200))
        );
        assert!(p
            .processing_cycles(pe, KernelType::Softmax, DataWidth::Int8, 10)
            .is_none());
    }

    #[test]
    fn json_round_trip() {
        let mut p = Profiles::new();
        p.record_timing(PeId(0), KernelType::Add, DataWidth::Int16, 500, Cycles(1300));
        p.record_timing(PeId(0), KernelType::Add, DataWidth::Int16, 1000, Cycles(2600));
        p.record_power(PeId(0), KernelType::Add, 2, Power::from_uw(4200.0));
        p.finalize();
        let j = p.to_json().to_pretty();
        let back = Profiles::from_json(&parse(&j).unwrap()).unwrap();
        assert_eq!(back.timing_entry_count(), 2);
        assert_eq!(back.power_entry_count(), 1);
        assert_eq!(
            back.processing_cycles(PeId(0), KernelType::Add, DataWidth::Int16, 2000),
            Some(Cycles(5200))
        );
        assert!((back.power(PeId(0), KernelType::Add, 2).unwrap().as_uw() - 4200.0).abs() < 1e-9);
    }
}
