//! The lint rule catalog.
//!
//! Each rule is a named invariant the serving stack has already been burned
//! by (see `CHANGES.md` PRs 3–5): the ids are stable — they appear in
//! findings, in `// lint: allow(<rule>): <reason>` suppressions, and in the
//! `--json` output that future CI tooling diffs across commits.

/// One lint rule: a stable id plus the sentence shown in `--help`/README.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rule {
    pub id: &'static str,
    pub summary: &'static str,
    /// Where the rule applies, as prose (the engine encodes the real check).
    pub scope: &'static str,
}

/// NaN-unsafe float comparison: `partial_cmp` silently reorders under NaN;
/// the PR-3 sweep replaced every call site with `total_cmp`.
pub const NO_PARTIAL_CMP: &str = "no-partial-cmp";
/// Panicking extractors on the serving path take a pool worker down.
pub const NO_UNWRAP: &str = "no-unwrap";
/// Every atomic ordering choice must carry an adjacent `// ordering:`
/// justification so reviewers inherit the proof, not just the code.
pub const ORDERING_COMMENT: &str = "ordering-comment";
/// The PR-4 deadlock-freedom invariant: never a second `.lock()` while a
/// shard guard is live in the same scope.
pub const LOCK_DISCIPLINE: &str = "lock-discipline";
/// Design-time code must be deterministic: no wall-clock reads in the
/// simulator, solvers, manager, or timing models.
pub const NO_WALL_CLOCK: &str = "no-wall-clock";
/// Sleeping while holding a lock turns a pause into a pile-up.
pub const SLEEP_UNDER_LOCK: &str = "sleep-under-lock";
/// Meta-rule: a malformed suppression (unknown rule id, or no reason) is
/// itself a finding — silent blanket allows defeat the audit trail.
pub const BAD_SUPPRESSION: &str = "bad-suppression";

/// Every rule the engine can emit, in reporting order.
pub const ALL: [Rule; 7] = [
    Rule {
        id: NO_PARTIAL_CMP,
        summary: "use `total_cmp`, not NaN-unsafe `partial_cmp`",
        scope: "all source",
    },
    Rule {
        id: NO_UNWRAP,
        summary: "no `.unwrap()` / `.expect(` on the serving path",
        scope: "serve/, fleet/, telemetry/ outside tests",
    },
    Rule {
        id: ORDERING_COMMENT,
        summary: "atomic `Ordering::*` sites need an adjacent `// ordering:` justification",
        scope: "all source",
    },
    Rule {
        id: LOCK_DISCIPLINE,
        summary: "no second `.lock()` while a shard guard is live in the same scope",
        scope: "serve/pool.rs, fleet/pool.rs outside tests",
    },
    Rule {
        id: NO_WALL_CLOCK,
        summary: "no `Instant::now()` / `SystemTime::now()` in design-time code",
        scope: "sim/, solver/, manager/, timing/ outside tests",
    },
    Rule {
        id: SLEEP_UNDER_LOCK,
        summary: "no `thread::sleep` while a lock guard is live",
        scope: "all source outside tests",
    },
    Rule {
        id: BAD_SUPPRESSION,
        summary: "`// lint: allow(<rule>): <reason>` needs a known rule and a non-empty reason",
        scope: "all source",
    },
];

/// Is `id` a rule the engine knows (and can therefore be suppressed)?
pub fn is_known(id: &str) -> bool {
    ALL.iter().any(|r| r.id == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_known_and_unique() {
        for r in &ALL {
            assert!(is_known(r.id));
        }
        let mut ids: Vec<_> = ALL.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), ALL.len());
        assert!(!is_known("bogus-rule"));
    }
}
