//! The lint engine: path scoping, `#[cfg(test)]` tracking, suppression
//! directives, lock-guard liveness, and the per-line rule checks.
//!
//! The engine is deliberately line-oriented (see the caveats on
//! [`crate::analysis::lexer`]): every check is a substring test over the
//! lexer's blanked code channel, plus three pieces of file-level state —
//! brace depth (scopes + `#[cfg(test)]` regions), live lock guards, and the
//! suppression map. That is enough to machine-check the invariants listed in
//! [`crate::analysis::rules`] over rustfmt-formatted source, which CI
//! guarantees this repo is.

use crate::analysis::lexer::{lex, LexedLine};
use crate::analysis::rules;
use crate::util::json::{Json, JsonObj};
use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One lint violation at a file:line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Display path, `/`-separated, exactly as the lint was invoked.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Stable rule id from [`rules::ALL`].
    pub rule: &'static str,
    pub message: String,
}

impl Finding {
    /// The human-readable one-liner printed by `medea lint`.
    pub fn display(&self) -> String {
        format!("{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Render findings as the stable machine-readable document behind
/// `medea lint --json`. Key order is fixed (`schema`, `count`, `findings`;
/// each finding `file`, `line`, `rule`, `message`) so two runs diff cleanly.
pub fn findings_to_json(findings: &[Finding]) -> String {
    let mut root = JsonObj::new();
    root.insert("schema", "medea.lint.v1");
    root.insert("count", findings.len());
    let arr: Vec<Json> = findings
        .iter()
        .map(|f| {
            let mut o = JsonObj::new();
            o.insert("file", f.file.as_str());
            o.insert("line", f.line);
            o.insert("rule", f.rule);
            o.insert("message", f.message.as_str());
            Json::Obj(o)
        })
        .collect();
    root.insert("findings", Json::Arr(arr));
    Json::Obj(root).to_pretty()
}

/// Lint every `.rs` file under each of `paths` (files or directories).
///
/// Directory walks skip `target/`, dot-directories, and `lint_fixtures/`
/// corpora (which are intentionally dirty) — unless such a directory is the
/// explicitly given root. Findings come back sorted by (file, line, rule).
pub fn lint_paths(paths: &[PathBuf]) -> io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    for p in paths {
        collect_rs(p, &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut out = Vec::new();
    for f in &files {
        let src = fs::read_to_string(f)?;
        let display = f.to_string_lossy().replace('\\', "/");
        out.extend(lint_source(&display, &src));
    }
    sort_findings(&mut out);
    Ok(out)
}

fn sort_findings(findings: &mut [Finding]) {
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
}

fn collect_rs(path: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let meta = fs::metadata(path)?;
    if meta.is_file() {
        if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    for entry in fs::read_dir(path)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy().into_owned();
        if entry.file_type()?.is_dir() {
            if name == "target" || name == "lint_fixtures" || name.starts_with('.') {
                continue;
            }
            collect_rs(&entry.path(), out)?;
        } else if name.ends_with(".rs") {
            out.push(entry.path());
        }
    }
    Ok(())
}

/// Which rules apply to this file, derived from its display path.
struct Scope {
    /// serve/, fleet/, telemetry/ and not under a tests/ directory.
    no_unwrap: bool,
    /// serve/pool.rs or fleet/pool.rs.
    lock_discipline: bool,
    /// sim/, solver/, manager/, timing/ and not under a tests/ directory.
    no_wall_clock: bool,
    /// Not under a tests/ directory (integration tests sleep and lock as
    /// they please; the unit-test regions inside src files are handled by
    /// the `#[cfg(test)]` tracker instead).
    sleep_under_lock: bool,
}

impl Scope {
    fn of(display: &str) -> Scope {
        // Fixture corpora replicate the source layout under a
        // `lint_fixtures/` root; scope them as if that root were `src/`.
        let comps: Vec<&str> = match display.rfind("lint_fixtures/") {
            Some(pos) => display[pos + "lint_fixtures/".len()..].split('/').collect(),
            None => display.split('/').collect(),
        };
        let has = |dir: &str| comps.iter().rev().skip(1).any(|c| *c == dir);
        let tests_dir = has("tests");
        let file = comps.last().copied().unwrap_or("");
        let parent = comps.len().checked_sub(2).map(|i| comps[i]).unwrap_or("");
        Scope {
            no_unwrap: !tests_dir && (has("serve") || has("fleet") || has("telemetry")),
            lock_discipline: file == "pool.rs" && (parent == "serve" || parent == "fleet"),
            no_wall_clock: !tests_dir
                && (has("sim") || has("solver") || has("manager") || has("timing")),
            sleep_under_lock: !tests_dir,
        }
    }
}

const ORDERING_TOKENS: [&str; 5] = [
    "Ordering::Relaxed",
    "Ordering::Acquire",
    "Ordering::Release",
    "Ordering::AcqRel",
    "Ordering::SeqCst",
];

/// Per-line structural facts from the brace/cfg(test) pass.
struct LineInfo {
    /// Inside a `#[cfg(test)] { … }` region (including the opening line).
    test: bool,
    /// Brace depth at the start of the line.
    start_depth: usize,
    /// Minimum depth reached while scanning the line (leading `}`s).
    min_depth: usize,
}

/// A live `let`-bound lock guard.
struct Guard {
    name: String,
    /// `start_depth` of the acquiring line: the guard dies when the
    /// enclosing block closes (depth falls below this).
    depth: usize,
    line: usize,
}

/// Lint one file's source. `display` is the path used in findings *and* for
/// rule scoping (see [`Scope`]) — callers with synthetic sources pass a
/// layout-shaped path like `"serve/pool.rs"`.
pub fn lint_source(display: &str, source: &str) -> Vec<Finding> {
    let lines = lex(source);
    let scope = Scope::of(display);
    let info = structure_pass(&lines);
    let mut findings = Vec::new();
    let allow = suppression_pass(display, &lines, &mut findings);
    let allowed =
        |idx: usize, rule: &str| allow.get(&idx).is_some_and(|set| set.contains(rule));

    let mut guards: Vec<Guard> = Vec::new();
    // Memo for ordering-comment run propagation: was line idx an
    // ordering-bearing line whose justification requirement is satisfied?
    let mut ordering_ok = vec![false; lines.len()];

    for (idx, line) in lines.iter().enumerate() {
        let li = &info[idx];
        let code = line.code.as_str();

        // Guards whose block closed on an earlier line.
        guards.retain(|g| g.depth <= li.start_depth);

        if !li.test {
            for name in dropped_names(code) {
                guards.retain(|g| g.name != name);
            }

            if scope.sleep_under_lock
                && code.contains("thread::sleep")
                && !allowed(idx, rules::SLEEP_UNDER_LOCK)
            {
                if let Some(g) = guards.first() {
                    findings.push(Finding {
                        file: display.to_string(),
                        line: line.number,
                        rule: rules::SLEEP_UNDER_LOCK,
                        message: format!(
                            "`thread::sleep` while guard `{}` (line {}) is live",
                            g.name, g.line
                        ),
                    });
                }
            }

            let locks = code.matches(".lock(").count();
            if locks > 0 {
                if scope.lock_discipline && !allowed(idx, rules::LOCK_DISCIPLINE) {
                    if let Some(g) = guards.first() {
                        findings.push(Finding {
                            file: display.to_string(),
                            line: line.number,
                            rule: rules::LOCK_DISCIPLINE,
                            message: format!(
                                "`.lock()` while guard `{}` (line {}) is still live — \
                                 shard locks must never nest",
                                g.name, g.line
                            ),
                        });
                    } else if locks > 1 {
                        findings.push(Finding {
                            file: display.to_string(),
                            line: line.number,
                            rule: rules::LOCK_DISCIPLINE,
                            message: "two lock acquisitions in one statement".to_string(),
                        });
                    }
                }
                if let Some(name) = let_binding(code) {
                    // Same-name rebind replaces the tracked guard (the old
                    // binding is shadowed or was consumed; either way the
                    // name now refers to the fresh guard).
                    guards.retain(|g| g.name != name);
                    guards.push(Guard {
                        name,
                        depth: li.start_depth,
                        line: line.number,
                    });
                }
            }
        }

        if code.contains("partial_cmp") && !allowed(idx, rules::NO_PARTIAL_CMP) {
            findings.push(Finding {
                file: display.to_string(),
                line: line.number,
                rule: rules::NO_PARTIAL_CMP,
                message: "`partial_cmp` is NaN-unsafe; use `total_cmp` \
                          (a PartialOrd impl delegating to Ord may be suppressed)"
                    .to_string(),
            });
        }

        if scope.no_unwrap && !li.test && !allowed(idx, rules::NO_UNWRAP) {
            if code.contains(".unwrap()") {
                findings.push(Finding {
                    file: display.to_string(),
                    line: line.number,
                    rule: rules::NO_UNWRAP,
                    message: "`.unwrap()` on the serving path can take a worker down; \
                              bubble the error instead"
                        .to_string(),
                });
            } else if code.contains(".expect(") {
                findings.push(Finding {
                    file: display.to_string(),
                    line: line.number,
                    rule: rules::NO_UNWRAP,
                    message: "`.expect(…)` on the serving path; if this is a real \
                              invariant, add `// lint: allow(no-unwrap): <why>`"
                        .to_string(),
                });
            }
        }

        if scope.no_wall_clock
            && !li.test
            && (code.contains("Instant::now(") || code.contains("SystemTime::now("))
            && !allowed(idx, rules::NO_WALL_CLOCK)
        {
            findings.push(Finding {
                file: display.to_string(),
                line: line.number,
                rule: rules::NO_WALL_CLOCK,
                message: "wall-clock read in design-time code; thread a simulated \
                          clock through instead"
                    .to_string(),
            });
        }

        if ORDERING_TOKENS.iter().any(|t| code.contains(t)) {
            let satisfied = line.comment.contains("ordering:")
                || comment_block_above_has_ordering(&lines, idx)
                || (idx > 0 && ordering_ok[idx - 1]);
            ordering_ok[idx] = satisfied;
            if !satisfied && !allowed(idx, rules::ORDERING_COMMENT) {
                findings.push(Finding {
                    file: display.to_string(),
                    line: line.number,
                    rule: rules::ORDERING_COMMENT,
                    message: "atomic ordering choice without an adjacent \
                              `// ordering:` justification"
                        .to_string(),
                });
            }
        }

        // Guards whose block closed *on* this line (trailing `}`s).
        guards.retain(|g| g.depth <= li.min_depth);
    }

    sort_findings(&mut findings);
    findings
}

/// Brace-depth scan: start/min depth per line plus `#[cfg(test)]` regions.
fn structure_pass(lines: &[LexedLine]) -> Vec<LineInfo> {
    let mut depth = 0usize;
    let mut pending_test_attr = false;
    // Depth at which the current `#[cfg(test)]` block closes, if inside one.
    let mut test_until: Option<usize> = None;
    let mut out = Vec::with_capacity(lines.len());
    for line in lines {
        let start_depth = depth;
        let mut test = test_until.is_some();
        if line.code.contains("#[") && line.code.contains("cfg(test)") {
            pending_test_attr = true;
        }
        let mut min_depth = depth;
        for ch in line.code.chars() {
            match ch {
                '{' => {
                    if pending_test_attr {
                        if test_until.is_none() {
                            test_until = Some(depth);
                            test = true;
                        }
                        pending_test_attr = false;
                    }
                    depth += 1;
                }
                '}' => {
                    depth = depth.saturating_sub(1);
                    min_depth = min_depth.min(depth);
                    if test_until == Some(depth) {
                        test_until = None;
                    }
                }
                _ => {}
            }
        }
        out.push(LineInfo {
            test,
            start_depth,
            min_depth,
        });
    }
    out
}

/// Parse suppression directives: a comment *beginning* with
/// `lint: allow(<rule>): <reason>` (after the `//`/`/*` decoration).
/// Requiring the leading position lets prose *mention* the syntax — as this
/// doc comment just did — without being parsed as a directive. Well-formed
/// directives land in the returned line→rules map (a directive on a
/// comment-only line attaches to the next code line); malformed ones become
/// [`rules::BAD_SUPPRESSION`] findings.
fn suppression_pass(
    display: &str,
    lines: &[LexedLine],
    findings: &mut Vec<Finding>,
) -> BTreeMap<usize, BTreeSet<&'static str>> {
    let mut allow: BTreeMap<usize, BTreeSet<&'static str>> = BTreeMap::new();
    for (idx, line) in lines.iter().enumerate() {
        let stripped = line
            .comment
            .trim_start_matches(|c: char| c == '/' || c == '*' || c == '!' || c.is_whitespace());
        if !stripped.starts_with("lint: allow(") {
            continue;
        }
        let mut rest = stripped;
        while let Some(pos) = rest.find("lint: allow(") {
            rest = &rest[pos + "lint: allow(".len()..];
            let Some(close) = rest.find(')') else {
                findings.push(bad_suppression(display, line, "unterminated `allow(`"));
                break;
            };
            let rule_name = rest[..close].trim();
            rest = &rest[close + 1..];
            let Some(rule) = rules::ALL.iter().find(|r| r.id == rule_name) else {
                findings.push(bad_suppression(
                    display,
                    line,
                    &format!("unknown rule `{rule_name}`"),
                ));
                continue;
            };
            let reason = rest
                .trim_start()
                .strip_prefix(':')
                .map(|r| {
                    // The reason runs to the next directive on the same
                    // comment, or to end of comment.
                    let end = r.find("lint: allow(").unwrap_or(r.len());
                    r[..end].trim()
                })
                .unwrap_or("");
            if reason.is_empty() {
                findings.push(bad_suppression(
                    display,
                    line,
                    &format!("suppression of `{}` needs a `: <reason>`", rule.id),
                ));
                continue;
            }
            if let Some(target) = attach_line(lines, idx) {
                allow.entry(target).or_default().insert(rule.id);
            }
        }
    }
    allow
}

fn bad_suppression(display: &str, line: &LexedLine, why: &str) -> Finding {
    Finding {
        file: display.to_string(),
        line: line.number,
        rule: rules::BAD_SUPPRESSION,
        message: why.to_string(),
    }
}

/// A directive on a code line guards that line; on a comment-only line it
/// guards the next code line (skipping the rest of the comment block).
fn attach_line(lines: &[LexedLine], idx: usize) -> Option<usize> {
    if lines[idx].has_code() {
        return Some(idx);
    }
    for (j, line) in lines.iter().enumerate().skip(idx + 1) {
        if line.has_code() {
            return Some(j);
        }
        if !line.has_comment() {
            return None; // blank line: the directive dangles
        }
    }
    None
}

/// Does the contiguous comment block directly above line `idx` carry an
/// `ordering:` justification?
fn comment_block_above_has_ordering(lines: &[LexedLine], idx: usize) -> bool {
    for j in (0..idx).rev() {
        let l = &lines[j];
        if l.has_code() || !l.has_comment() {
            return false;
        }
        if l.comment.contains("ordering:") {
            return true;
        }
    }
    false
}

/// Names consumed by a bare `drop(name)` on this line.
fn dropped_names(code: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = code;
    while let Some(pos) = rest.find("drop(") {
        rest = &rest[pos + "drop(".len()..];
        let name: String = rest
            .chars()
            .take_while(|c| c.is_alphanumeric() || *c == '_')
            .collect();
        // Only a *bare* identifier counts: `drop(guard)` kills the guard,
        // `drop(cv.wait_timeout(g, d))` does not (the move is visible to a
        // human, not to a line lexer — rebind or scope-close handles those).
        if !name.is_empty() && rest[name.len()..].starts_with(')') {
            out.push(name);
        }
    }
    out
}

/// The identifier bound by a leading `let [mut] name =`, if any.
fn let_binding(code: &str) -> Option<String> {
    let t = code.trim_start().strip_prefix("let ")?.trim_start();
    let t = t.strip_prefix("mut ").map(str::trim_start).unwrap_or(t);
    let name: String = t
        .chars()
        .take_while(|c| c.is_alphanumeric() || *c == '_')
        .collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_at(findings: &[Finding]) -> Vec<(usize, &'static str)> {
        findings.iter().map(|f| (f.line, f.rule)).collect()
    }

    #[test]
    fn nan_unsafe_cmp_flagged_everywhere_and_suppressible() {
        let src = "fn f(a: f64, b: f64) {\n\
                   let _ = a.partial_cmp(&b);\n\
                   // lint: allow(no-partial-cmp): trait impl must exist\n\
                   let _ = a.partial_cmp(&b);\n\
                   }\n";
        let f = lint_source("util/x.rs", src);
        assert_eq!(rules_at(&f), vec![(2, rules::NO_PARTIAL_CMP)]);
    }

    #[test]
    fn unwrap_scope_and_test_regions() {
        let src = "fn f() { x.unwrap(); }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   fn g() { y.unwrap(); z.expect(\"boom\"); }\n\
                   }\n\
                   fn h() { w.expect(\"msg\"); }\n";
        let f = lint_source("serve/pool.rs", src);
        assert_eq!(
            rules_at(&f)
                .into_iter()
                .filter(|(_, r)| *r == rules::NO_UNWRAP)
                .collect::<Vec<_>>(),
            vec![(1, rules::NO_UNWRAP), (6, rules::NO_UNWRAP)]
        );
        // Same file outside the scoped directories: no findings.
        assert!(lint_source("util/x.rs", src)
            .iter()
            .all(|f| f.rule != rules::NO_UNWRAP));
    }

    #[test]
    fn ordering_comment_adjacency_and_runs() {
        let src = "fn f(a: &AtomicU64) {\n\
                   a.load(Ordering::Relaxed); // ordering: counter, no sync\n\
                   a.load(Ordering::Acquire);\n\
                   // ordering: the block below publishes the payload\n\
                   a.store(1, Ordering::Release);\n\
                   a.store(2, Ordering::Relaxed);\n\
                   \n\
                   a.store(3, Ordering::SeqCst);\n\
                   }\n";
        let f = lint_source("util/x.rs", src);
        // Line 3 has no justification and does NOT inherit line 2's
        // same-line comment? It does: contiguous run propagation.
        // Lines 5-6 are covered by the block comment; line 8 (after the
        // blank) is bare.
        assert_eq!(rules_at(&f), vec![(8, rules::ORDERING_COMMENT)]);
    }

    #[test]
    fn lock_discipline_and_sleep() {
        let src = "fn f(&self) {\n\
                   let mut st = self.shards[0].queue.lock().unwrap();\n\
                   std::thread::sleep(d);\n\
                   let sib = self.shards[1].queue.lock().unwrap();\n\
                   drop(st);\n\
                   let ok = self.shards[2].queue.lock().unwrap();\n\
                   }\n\
                   fn g(&self) {\n\
                   let solo = self.state.lock().unwrap();\n\
                   }\n";
        let f = lint_source("fleet/pool.rs", src);
        let got = rules_at(&f);
        assert!(got.contains(&(3, rules::SLEEP_UNDER_LOCK)));
        assert!(got.contains(&(4, rules::LOCK_DISCIPLINE)));
        // Line 6: `st` was dropped, `sib` still live -> still a finding.
        assert!(got.contains(&(6, rules::LOCK_DISCIPLINE)));
        // Line 9: fresh scope, no live guard.
        assert!(!got.contains(&(9, rules::LOCK_DISCIPLINE)));
    }

    #[test]
    fn guard_dies_with_its_block() {
        let src = "fn f(&self) {\n\
                   {\n\
                   let st = self.a.lock().unwrap();\n\
                   }\n\
                   let other = self.b.lock().unwrap();\n\
                   }\n";
        let f = lint_source("serve/pool.rs", src);
        assert!(f.iter().all(|f| f.rule != rules::LOCK_DISCIPLINE));
    }

    #[test]
    fn wall_clock_scoping() {
        let src = "fn f() { let t = Instant::now(); }\n";
        assert_eq!(
            rules_at(&lint_source("sim/engine.rs", src)),
            vec![(1, rules::NO_WALL_CLOCK)]
        );
        assert!(lint_source("serve/pool.rs", src).is_empty());
    }

    #[test]
    fn bad_suppressions_are_findings() {
        let src = "// lint: allow(not-a-rule): whatever\n\
                   // lint: allow(no-unwrap)\n\
                   fn f() {}\n";
        let f = lint_source("util/x.rs", src);
        assert_eq!(
            rules_at(&f),
            vec![(1, rules::BAD_SUPPRESSION), (2, rules::BAD_SUPPRESSION)]
        );
    }

    #[test]
    fn standalone_suppression_attaches_to_next_code_line() {
        let src = "fn f(a: f64, b: f64) {\n\
                   // lint: allow(no-partial-cmp): testing attachment\n\
                   // (continuation of the comment block)\n\
                   let _ = a.partial_cmp(&b);\n\
                   }\n";
        assert!(lint_source("util/x.rs", src).is_empty());
    }

    #[test]
    fn strings_and_comments_never_trigger() {
        let src = "fn f() {\n\
                   let s = \"x.unwrap() partial_cmp Instant::now()\";\n\
                   // x.unwrap() partial_cmp thread::sleep\n\
                   }\n";
        assert!(lint_source("serve/pool.rs", src).is_empty());
        assert!(lint_source("sim/engine.rs", src).is_empty());
    }

    #[test]
    fn json_output_is_stable() {
        let findings = vec![Finding {
            file: "serve/pool.rs".to_string(),
            line: 7,
            rule: rules::NO_UNWRAP,
            message: "msg".to_string(),
        }];
        let doc = findings_to_json(&findings);
        let schema_pos = doc.find("\"schema\"").expect("schema key");
        let count_pos = doc.find("\"count\"").expect("count key");
        let findings_pos = doc.find("\"findings\"").expect("findings key");
        assert!(schema_pos < count_pos && count_pos < findings_pos);
        let v = crate::util::json::parse(&doc).expect("parses");
        assert_eq!(v.get("count").and_then(|c| c.as_usize()), Some(1));
    }
}
