//! Self-hosted static analysis: the `medea lint` engine.
//!
//! The serving stack's correctness rests on a handful of invariants that
//! used to live only in reviewer memory: `total_cmp` everywhere floats are
//! ordered (the PR-3 NaN sweep), no panicking extractors on the serving
//! path, a justification next to every atomic-ordering choice, the PR-4
//! "never hold two shard locks" rule, deterministic design-time code, and
//! no sleeping under a lock. This module machine-checks all of them — the
//! same design-time-guarantees philosophy MEDEA applies to timing and
//! memory constraints, turned on the codebase itself.
//!
//! Layout:
//!
//! * [`lexer`] — a comment/string/raw-string/char-literal-aware line lexer
//!   (no `syn`, zero dependencies) that separates code text from comments.
//! * [`rules`] — the stable rule catalog ([`rules::ALL`]).
//! * [`engine`] — path scoping, `#[cfg(test)]` and lock-guard tracking,
//!   `// lint: allow(<rule>): <reason>` suppressions, findings and their
//!   `--json` rendering.
//!
//! The binary front end is `medea lint [--json] [paths…]` (non-zero exit on
//! findings); `tests/lint_clean.rs` runs the same engine over `src/` in
//! plain `cargo test`, so tier-1 CI self-gates the repo.

pub mod engine;
pub mod lexer;
pub mod rules;

pub use engine::{findings_to_json, lint_paths, lint_source, Finding};
pub use rules::Rule;
