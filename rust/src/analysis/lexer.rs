//! A minimal line lexer for Rust source, built for the lint engine.
//!
//! The rules in [`crate::analysis::engine`] are substring checks over *code*
//! text, so the lexer's one job is separating code from everything that
//! merely looks like code: line comments, (nested) block comments, string
//! literals, raw strings, byte strings, and character literals. No `syn`, no
//! grammar — a file-wide state machine that emits, per physical line, the
//! code text with literal contents blanked to spaces (columns preserved) and
//! the comment text found on that line.
//!
//! Deliberate scope limits, documented because the engine inherits them:
//! the lexer is line-oriented (a `let g = m.lock()` split across lines by
//! hand would evade the lock-discipline rule — rustfmt keeps such statements
//! on one line, and `cargo fmt --check` is enforced in CI), and macro bodies
//! are treated as ordinary code.

/// One physical source line, split into code and comment channels.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LexedLine {
    /// 1-based line number.
    pub number: usize,
    /// Code text: literal contents blanked with spaces, comments removed.
    /// Delimiters (`"`, `'`) survive so the text stays recognizably shaped.
    pub code: String,
    /// Comment text on this line (both `//` rest-of-line and the in-line
    /// slice of a `/* */` block), concatenated in order of appearance.
    pub comment: String,
}

impl LexedLine {
    /// True when the code channel holds anything but whitespace.
    pub fn has_code(&self) -> bool {
        !self.code.trim().is_empty()
    }

    /// True when the comment channel holds anything but whitespace.
    pub fn has_comment(&self) -> bool {
        !self.comment.trim().is_empty()
    }
}

/// Lexer state carried across physical lines.
enum State {
    Normal,
    /// Inside a block comment; Rust block comments nest, so track depth.
    BlockComment(u32),
    /// Inside a `"…"` (or `b"…"`) string literal.
    Str,
    /// Inside a raw string `r##"…"##` with this many `#` marks.
    RawStr(u32),
}

/// Split `source` into [`LexedLine`]s.
pub fn lex(source: &str) -> Vec<LexedLine> {
    let mut state = State::Normal;
    let mut out = Vec::with_capacity(source.lines().count());
    for (idx, raw) in source.lines().enumerate() {
        let chars: Vec<char> = raw.chars().collect();
        let mut code = String::with_capacity(raw.len());
        let mut comment = String::new();
        let mut i = 0usize;
        while i < chars.len() {
            match state {
                State::BlockComment(depth) => {
                    if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                        state = State::BlockComment(depth + 1);
                        comment.push_str("/*");
                        i += 2;
                    } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        state = if depth <= 1 {
                            State::Normal
                        } else {
                            State::BlockComment(depth - 1)
                        };
                        comment.push_str("*/");
                        i += 2;
                    } else {
                        comment.push(chars[i]);
                        i += 1;
                    }
                }
                State::Str => {
                    if chars[i] == '\\' {
                        code.push(' ');
                        if i + 1 < chars.len() {
                            code.push(' ');
                        }
                        i += 2;
                    } else if chars[i] == '"' {
                        code.push('"');
                        state = State::Normal;
                        i += 1;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                State::RawStr(hashes) => {
                    let h = hashes as usize;
                    let closes = chars[i] == '"'
                        && (1..=h).all(|k| chars.get(i + k) == Some(&'#'));
                    if closes {
                        code.push('"');
                        for _ in 0..h {
                            code.push('#');
                        }
                        state = State::Normal;
                        i += 1 + h;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                State::Normal => {
                    let c = chars[i];
                    let prev_ident = i
                        .checked_sub(1)
                        .and_then(|p| chars.get(p))
                        .is_some_and(|p| p.is_alphanumeric() || *p == '_');
                    if c == '/' && chars.get(i + 1) == Some(&'/') {
                        for &ch in &chars[i..] {
                            comment.push(ch);
                        }
                        i = chars.len();
                    } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                        state = State::BlockComment(1);
                        comment.push_str("/*");
                        i += 2;
                    } else if c == '"' {
                        code.push('"');
                        state = State::Str;
                        i += 1;
                    } else if (c == 'r' || c == 'b') && !prev_ident {
                        if let Some(consumed) = raw_or_byte_prefix(&chars, i) {
                            match consumed {
                                Prefix::RawStr { skip, hashes } => {
                                    for &ch in &chars[i..i + skip] {
                                        code.push(ch);
                                    }
                                    state = State::RawStr(hashes);
                                    i += skip;
                                }
                                Prefix::ByteStr { skip } => {
                                    for &ch in &chars[i..i + skip] {
                                        code.push(ch);
                                    }
                                    state = State::Str;
                                    i += skip;
                                }
                                Prefix::ByteChar => {
                                    code.push('b');
                                    i += 1;
                                    i = consume_char_literal(&chars, i, &mut code);
                                }
                            }
                        } else {
                            code.push(c);
                            i += 1;
                        }
                    } else if c == '\'' {
                        i = consume_char_literal(&chars, i, &mut code);
                    } else {
                        code.push(c);
                        i += 1;
                    }
                }
            }
        }
        // A line comment or char literal never spans lines; an unterminated
        // string at end-of-line is malformed input we simply carry forward.
        out.push(LexedLine {
            number: idx + 1,
            code,
            comment,
        });
    }
    out
}

enum Prefix {
    /// `r"`, `r#"`, `br##"`, … — skip the prefix chars, then raw-string mode.
    RawStr { skip: usize, hashes: u32 },
    /// `b"` — byte string, same escaping as an ordinary string.
    ByteStr { skip: usize },
    /// `b'x'` — byte char literal.
    ByteChar,
}

/// Classify a possible raw/byte literal prefix starting at `chars[i]`
/// (which is `r` or `b`). Returns `None` when it is just an identifier char.
fn raw_or_byte_prefix(chars: &[char], i: usize) -> Option<Prefix> {
    let c = chars[i];
    if c == 'b' {
        match chars.get(i + 1) {
            Some('\'') => return Some(Prefix::ByteChar),
            Some('"') => return Some(Prefix::ByteStr { skip: 2 }),
            Some('r') => {
                let mut h = 0usize;
                while chars.get(i + 2 + h) == Some(&'#') {
                    h += 1;
                }
                if chars.get(i + 2 + h) == Some(&'"') {
                    return Some(Prefix::RawStr {
                        skip: 3 + h,
                        hashes: h as u32,
                    });
                }
                return None;
            }
            _ => return None,
        }
    }
    // c == 'r'
    let mut h = 0usize;
    while chars.get(i + 1 + h) == Some(&'#') {
        h += 1;
    }
    if chars.get(i + 1 + h) == Some(&'"') {
        // `r#ident` (raw identifier) has no quote and falls through to None.
        return Some(Prefix::RawStr {
            skip: 2 + h,
            hashes: h as u32,
        });
    }
    None
}

/// Consume a `'…'` char literal (or decide it is a lifetime) starting at the
/// opening `'` at `chars[i]`. Pushes blanked text to `code`, returns the new
/// index.
fn consume_char_literal(chars: &[char], i: usize, code: &mut String) -> usize {
    debug_assert_eq!(chars[i], '\'');
    match chars.get(i + 1) {
        Some('\\') => {
            // Escaped literal: `'\n'`, `'\''`, `'\u{1F600}'` — skip the
            // escape head, then blank to the terminating quote.
            code.push('\'');
            code.push(' ');
            code.push(' ');
            let mut j = i + 3; // opening quote, backslash, escape head
            while j < chars.len() && chars[j] != '\'' {
                code.push(' ');
                j += 1;
            }
            if j < chars.len() {
                code.push('\'');
                j += 1;
            }
            j
        }
        Some(_) if chars.get(i + 2) == Some(&'\'') => {
            // Simple `'x'`.
            code.push('\'');
            code.push(' ');
            code.push('\'');
            i + 3
        }
        _ => {
            // A lifetime (`'a`) or loop label (`'outer:`) — keep the quote,
            // the identifier chars flow through the normal path.
            code.push('\'');
            i + 1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        lex(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn line_comments_leave_code_channel() {
        let lines = lex("let x = 1; // partial_cmp here is commentary\n");
        assert_eq!(lines.len(), 1);
        assert_eq!(lines[0].code, "let x = 1; ");
        assert!(lines[0].comment.contains("partial_cmp"));
    }

    #[test]
    fn string_contents_are_blanked() {
        let lines = lex("let s = \"call .unwrap() /* not a comment */\";\n");
        assert!(!lines[0].code.contains("unwrap"));
        assert!(!lines[0].comment.contains("not a comment"));
        assert!(lines[0].code.starts_with("let s = \""));
        assert!(lines[0].code.ends_with("\";"));
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let lines = lex(r#"let s = "a\"b.unwrap()"; let t = 1;"#);
        assert!(!lines[0].code.contains("unwrap"));
        assert!(lines[0].code.contains("let t = 1;"));
    }

    #[test]
    fn raw_strings_ignore_escapes_and_quotes() {
        let src = "let s = r#\"no \\ escape \" .unwrap() \"# ; let u = 2;";
        let lines = lex(src);
        assert!(!lines[0].code.contains("unwrap"));
        assert!(lines[0].code.contains("let u = 2;"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let src = "a /* one /* two */ still */ b\nc /* open\nstill comment .unwrap()\n*/ d\n";
        let codes = code_of(src);
        assert!(codes[0].contains('a') && codes[0].contains('b'));
        assert!(codes[1].contains('c') && !codes[1].contains("open"));
        assert!(codes[2].trim().is_empty());
        assert!(codes[3].contains('d'));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let lines = lex("fn f<'a>(x: &'a str) -> char { if x == \"y\" { '{' } else { '\\'' } }");
        // The brace inside the char literal must not leak into code.
        let opens = lines[0].code.matches('{').count();
        let closes = lines[0].code.matches('}').count();
        assert_eq!(opens, closes);
        assert!(lines[0].code.contains("fn f<'a>"));
    }

    #[test]
    fn byte_literals() {
        let lines = lex(r##"let a = b"x.unwrap()"; let c = b'"'; let d = br#"y"#;"##);
        assert!(!lines[0].code.contains("unwrap"));
        assert!(lines[0].code.contains("let c ="));
        assert!(lines[0].code.contains("let d ="));
    }

    #[test]
    fn identifier_ending_in_r_is_not_a_raw_string() {
        let lines = lex("let var = 3; for x in y {}");
        assert_eq!(lines[0].code, "let var = 3; for x in y {}");
    }
}
