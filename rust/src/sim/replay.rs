//! Schedule replay: build the tile-level job graph per kernel and execute
//! it on the event engine, with independent time/energy accounting.

use super::engine::{Engine, JobId, Resource};
use crate::ir::Workload;
use crate::manager::schedule::Schedule;
use crate::platform::{PeId, Platform};
use crate::power::kernel_power;
use crate::timing::cycle_model::CycleModel;
use crate::tiling::modes::{TilingMode, NMC_CONTENTION};
use crate::tiling::plan::plan_kernel;
use crate::util::units::{Bytes, Cycles, Energy, Time};

const DMA: Resource = Resource(0);
const PE: Resource = Resource(1);

/// Simulation outcome for one schedule.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub active_time: Time,
    pub active_energy: Energy,
    pub sleep_time: Time,
    pub sleep_energy: Energy,
    /// Wall time each PE spent executing kernels (indexed by PE id).
    pub pe_busy: Vec<Time>,
    /// Total time the DMA channel was moving data.
    pub dma_time: Time,
    /// V-F transitions performed.
    pub vf_switches: usize,
    /// Discrete events processed across all kernels.
    pub events: usize,
    pub deadline_met: bool,
    /// Count of kernels whose LM-residency chaining assumption (made
    /// optimistically by the estimator) did NOT hold in actual execution
    /// order — the estimator-vs-sim divergence driver.
    pub broken_chains: usize,
}

impl SimReport {
    pub fn total_energy(&self) -> Energy {
        self.active_energy + self.sleep_energy
    }
}

/// Replay `schedule` for `workload` on `platform`.
///
/// Kernels execute strictly in order (the platform runs one kernel at a
/// time); within a kernel, tiles pipeline according to the decision's
/// tiling mode using two resources: the system DMA channel and the PE.
pub fn simulate(
    workload: &Workload,
    platform: &Platform,
    model: &CycleModel,
    schedule: &Schedule,
) -> SimReport {
    assert_eq!(schedule.decisions.len(), workload.len(), "schedule/workload mismatch");

    let mut active_time = Time::ZERO;
    let mut active_energy = Energy::ZERO;
    let mut pe_busy = vec![Time::ZERO; platform.pes.len()];
    let mut dma_time = Time::ZERO;
    let mut vf_switches = 0usize;
    let mut events = 0usize;
    let mut broken_chains = 0usize;

    // Residency: (pe, true) when the previous kernel left its output in
    // that PE's LM (untiled single-buffer execution).
    let mut resident_in: Option<PeId> = None;
    let mut current_vf: Option<usize> = None;

    for d in &schedule.decisions {
        let kernel = &workload.kernels()[d.kernel];
        let pe = platform.pe(d.pe);
        let vf = platform.vf.get(d.vf_idx);

        // V-F switch stall (charged at base power, platform-wide).
        if current_vf != Some(d.vf_idx) {
            if current_vf.is_some() {
                vf_switches += 1;
                let stall = Cycles(platform.vf_switch_cycles).at(vf.f);
                active_time += stall;
                active_energy += platform.active_base.p_total(kernel.ty, vf.v, vf.f) * stall;
            }
            current_vf = Some(d.vf_idx);
        }

        let power = kernel_power(platform, d.pe, kernel.ty, vf);
        let compute = model
            .kernel_cycles(pe.class, kernel)
            .expect("schedule references an unsupported (pe, kernel)");

        let (wall, kernel_dma_time, kernel_events, chain_broken) = match (pe.lm, pe.dma) {
            (Some(lm), Some(dma_spec)) => {
                let budget = match d.mode {
                    TilingMode::SingleBuffer => lm,
                    TilingMode::DoubleBuffer => Bytes(lm.raw() / 2),
                };
                let constraint = platform
                    .constraints
                    .get(d.pe, kernel.ty)
                    .expect("unsupported kernel in schedule");
                let plan = plan_kernel(kernel, budget, constraint.max_dim)
                    .expect("untileable kernel in schedule");

                // Actual residency: the estimator assumed the activation
                // could be chained whenever the plan is untiled sb; the sim
                // only grants it when the *previous* kernel really left its
                // output in this PE's LM.
                let chain_assumed =
                    d.mode == TilingMode::SingleBuffer && plan.untiled && plan.chainable_in.raw() > 0;
                let chain_holds = chain_assumed && resident_in == Some(d.pe);
                let traffic_in = if chain_holds {
                    plan.traffic_in.saturating_sub(plan.chainable_in)
                } else {
                    plan.traffic_in
                };

                let n = plan.n_tiles.max(1);
                let f = vf.f;
                let sec = |c: f64| c / f.raw();
                let din_tile = sec(dma_spec.setup_cycles as f64
                    + traffic_in.raw() as f64 / dma_spec.bytes_per_cycle / n as f64);
                let dout_tile = sec(dma_spec.setup_cycles as f64
                    + plan.traffic_out.raw() as f64 / dma_spec.bytes_per_cycle / n as f64);
                let mut c_tile = sec(compute.raw() as f64 / n as f64);
                // NMC bank contention during overlapped phases (db only).
                if d.mode == TilingMode::DoubleBuffer
                    && pe.class == crate::platform::PeClass::Nmc
                {
                    let d_tile = din_tile + dout_tile;
                    c_tile += NMC_CONTENTION * c_tile.min(d_tile);
                }
                let oh_tile = sec(model.per_tile(pe.class).raw() as f64);
                let launch = sec(model.launch(pe.class).raw() as f64);

                let mut eng = Engine::new(2);
                let launch_job = eng.add_job(PE, launch, &[]);
                let mut prev_comp: Option<JobId> = None;
                let mut prev_out: Option<JobId> = None;
                let mut comp_jobs: Vec<JobId> = Vec::with_capacity(n as usize);
                for i in 0..n {
                    let mut din_deps: Vec<JobId> = vec![launch_job];
                    match d.mode {
                        TilingMode::SingleBuffer => {
                            // No prefetch: wait for the previous tile to
                            // fully drain.
                            if let Some(po) = prev_out {
                                din_deps.push(po);
                            }
                        }
                        TilingMode::DoubleBuffer => {
                            // Two buffers: tile i's load waits for tile
                            // i-2's compute to free a buffer.
                            if i >= 2 {
                                din_deps.push(comp_jobs[(i - 2) as usize]);
                            }
                        }
                    }
                    let din = eng.add_job(DMA, din_tile, &din_deps);
                    let mut comp_deps = vec![din];
                    if let Some(pc) = prev_comp {
                        comp_deps.push(pc);
                    }
                    let comp = eng.add_job(PE, c_tile + oh_tile, &comp_deps);
                    let dout = eng.add_job(DMA, dout_tile, &[comp]);
                    prev_comp = Some(comp);
                    prev_out = Some(dout);
                    comp_jobs.push(comp);
                }
                let wall = Time(eng.run());
                let kernel_dma = Time((din_tile + dout_tile) * n as f64);
                (wall, kernel_dma, eng.events_processed(), chain_assumed && !chain_holds)
            }
            _ => {
                // Host CPU: launch + compute, no staging.
                let cycles = model.launch(pe.class) + compute;
                (cycles.at(vf.f), Time::ZERO, 1, false)
            }
        };

        active_time += wall;
        active_energy += power * wall;
        pe_busy[d.pe.0] += wall;
        dma_time += kernel_dma_time;
        events += kernel_events;
        if chain_broken {
            broken_chains += 1;
        }

        // Update residency for the next kernel.
        resident_in = match (pe.lm, d.mode) {
            (Some(lm), TilingMode::SingleBuffer) => {
                let constraint = platform.constraints.get(d.pe, kernel.ty).unwrap();
                let untiled = plan_kernel(kernel, lm, constraint.max_dim)
                    .map(|p| p.untiled)
                    .unwrap_or(false);
                untiled.then_some(d.pe)
            }
            _ => None, // CPU (L2-resident) or ping-pong db: no LM chaining
        };
    }

    let sleep_time = Time((schedule.deadline - active_time).raw().max(0.0));
    let sleep_energy = platform.sleep_power * sleep_time;
    SimReport {
        deadline_met: active_time.raw() <= schedule.deadline.raw() * (1.0 + 1e-9),
        active_time,
        active_energy,
        sleep_time,
        sleep_energy,
        pe_busy,
        dma_time,
        vf_switches,
        events,
        broken_chains,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::coarse_grain_app_dvfs;
    use crate::ir::tsd::{tsd_core, TsdParams};
    use crate::manager::medea::Medea;
    use crate::profile::characterize;
    use crate::platform::heeptimize::heeptimize;
    use crate::util::stats::rel_diff;

    struct Ctx {
        platform: Platform,
        profiles: crate::profile::Profiles,
        model: CycleModel,
        workload: Workload,
    }

    fn ctx() -> Ctx {
        let platform = heeptimize();
        let model = CycleModel::heeptimize();
        let profiles = characterize(&platform, &model);
        Ctx {
            workload: tsd_core(&TsdParams::default()),
            platform,
            profiles,
            model,
        }
    }

    #[test]
    fn sim_validates_estimator_within_tolerance() {
        // The independent replay must land close to the closed-form
        // estimates MEDEA optimized with (divergences: pipeline formula vs
        // event pipeline, VF switch stalls, broken chains).
        let c = ctx();
        let medea = Medea::new(&c.platform, &c.profiles, &c.model);
        for ms in [50.0, 200.0, 1000.0] {
            let s = medea.schedule(&c.workload, Time::from_ms(ms)).unwrap();
            let r = simulate(&c.workload, &c.platform, &c.model, &s);
            let dt = rel_diff(r.active_time.raw(), s.active_time().raw());
            let de = rel_diff(r.active_energy.raw(), s.active_energy().raw());
            println!(
                "@{ms} ms: sim {:.2} ms/{:.0} uJ vs est {:.2} ms/{:.0} uJ (dt {:.3}, de {:.3}, broken {} / events {})",
                r.active_time.as_ms(),
                r.active_energy.as_uj(),
                s.active_time().as_ms(),
                s.active_energy().as_uj(),
                dt,
                de,
                r.broken_chains,
                r.events
            );
            assert!(dt < 0.08, "time divergence {dt:.3} at {ms} ms");
            assert!(de < 0.08, "energy divergence {de:.3} at {ms} ms");
        }
    }

    #[test]
    fn sim_confirms_deadline_met_with_margin_policy() {
        // The estimator is optimistic about chaining; the sim must still
        // land within a small overshoot of the deadline (the paper's flow
        // would fold this into the profiling margin).
        let c = ctx();
        let medea = Medea::new(&c.platform, &c.profiles, &c.model);
        for ms in [50.0, 200.0, 1000.0] {
            let s = medea.schedule(&c.workload, Time::from_ms(ms)).unwrap();
            let r = simulate(&c.workload, &c.platform, &c.model, &s);
            assert!(
                r.active_time.raw() <= s.deadline.raw() * 1.06,
                "sim overshoot at {ms} ms: {:.2} ms",
                r.active_time.as_ms()
            );
        }
    }

    #[test]
    fn pe_busy_distribution_is_heterogeneous() {
        let c = ctx();
        let medea = Medea::new(&c.platform, &c.profiles, &c.model);
        let s = medea.schedule(&c.workload, Time::from_ms(200.0)).unwrap();
        let r = simulate(&c.workload, &c.platform, &c.model, &s);
        // CPU must be busy (softmax/gelu are host-only) and at least one
        // accelerator must carry the matmul load.
        assert!(r.pe_busy[0].raw() > 0.0);
        assert!(r.pe_busy[1].raw() + r.pe_busy[2].raw() > r.pe_busy[0].raw());
        // DMA moved data.
        assert!(r.dma_time.raw() > 0.0);
        assert!(r.events > c.workload.len());
    }

    #[test]
    fn sim_ranks_schedulers_like_the_estimator() {
        let c = ctx();
        let d = Time::from_ms(200.0);
        let medea = Medea::new(&c.platform, &c.profiles, &c.model)
            .schedule(&c.workload, d)
            .unwrap();
        let cg = coarse_grain_app_dvfs(&c.workload, &c.platform, &c.profiles, &c.model, d).unwrap();
        let r_m = simulate(&c.workload, &c.platform, &c.model, &medea);
        let r_cg = simulate(&c.workload, &c.platform, &c.model, &cg);
        assert!(
            r_m.total_energy().raw() < r_cg.total_energy().raw(),
            "sim must confirm MEDEA wins: {} vs {}",
            r_m.total_energy().as_uj(),
            r_cg.total_energy().as_uj()
        );
    }

    #[test]
    fn sleep_accounting() {
        let c = ctx();
        let medea = Medea::new(&c.platform, &c.profiles, &c.model);
        let s = medea.schedule(&c.workload, Time::from_ms(1000.0)).unwrap();
        let r = simulate(&c.workload, &c.platform, &c.model, &s);
        assert!(r.sleep_time.raw() > 0.5, "relaxed deadline must sleep");
        let expected = c.platform.sleep_power * r.sleep_time;
        assert!((r.sleep_energy.raw() - expected.raw()).abs() < 1e-12);
    }
}
