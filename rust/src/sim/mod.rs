//! Discrete-event replay simulator.
//!
//! Independently validates schedules: where the estimator (`G_T`) uses
//! closed-form pipeline formulas, the simulator executes the tile-level
//! job graph (DMA-in → compute → DMA-out per tile, with the mode's overlap
//! rules, V-F switch stalls, NMC bank contention, and *actual* LM-residency
//! tracking for single-buffer chaining) on an event queue with two
//! resources (the system DMA channel and the target PE). The gap between
//! estimated and simulated time/energy is itself a reported metric
//! (EXPERIMENTS.md).

pub mod engine;
pub mod replay;

pub use engine::{Engine, JobId, Resource};
pub use replay::{simulate, SimReport};
