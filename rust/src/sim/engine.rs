//! A small discrete-event engine: jobs with dependencies competing for
//! exclusive resources, executed in earliest-start order.
//!
//! Semantics: a job becomes *ready* when all dependencies finished; a ready
//! job starts as soon as its resource is free (FIFO per resource, by
//! insertion order among ready jobs). Time is `f64` seconds.

use std::collections::BinaryHeap;

/// Job identifier (index into the engine's job list).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct JobId(pub usize);

/// Resource identifier (exclusive, one job at a time).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Resource(pub usize);

#[derive(Debug, Clone)]
struct Job {
    resource: Resource,
    duration: f64,
    deps: Vec<JobId>,
    unfinished_deps: usize,
    /// Earliest time the job may start (max of dep finish times).
    ready_at: f64,
    start: f64,
    finish: f64,
    done: bool,
}

/// Min-heap entry: (time, sequence) so simultaneous events pop FIFO.
#[derive(PartialEq)]
struct HeapEntry {
    time: f64,
    seq: usize,
    job: usize,
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    // lint: allow(no-partial-cmp): canonical PartialOrd delegating to the
    // total `Ord` below (which uses total_cmp); never NaN-lossy.
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reverse (other vs self) for min-heap semantics under std's
        // max-heap; tie-break on sequence so simultaneous events pop FIFO.
        // total_cmp: a NaN duration must not panic the simulator mid-replay
        // (NaN times sink to the back of the event order instead).
        other
            .time
            .total_cmp(&self.time)
            .then(other.seq.cmp(&self.seq))
    }
}

/// The event-driven executor.
#[derive(Default)]
pub struct Engine {
    jobs: Vec<Job>,
    n_resources: usize,
    events_processed: usize,
}

impl Engine {
    pub fn new(n_resources: usize) -> Engine {
        Engine {
            jobs: Vec::new(),
            n_resources,
            events_processed: 0,
        }
    }

    /// Add a job; returns its id. Dependencies must already exist.
    pub fn add_job(&mut self, resource: Resource, duration: f64, deps: &[JobId]) -> JobId {
        assert!(resource.0 < self.n_resources, "unknown resource");
        assert!(duration >= 0.0, "negative duration");
        for d in deps {
            assert!(d.0 < self.jobs.len(), "dependency on future job");
        }
        self.jobs.push(Job {
            resource,
            duration,
            deps: deps.to_vec(),
            unfinished_deps: deps.len(),
            ready_at: 0.0,
            start: 0.0,
            finish: 0.0,
            done: false,
        });
        JobId(self.jobs.len() - 1)
    }

    /// Run all jobs to completion; returns the makespan.
    pub fn run(&mut self) -> f64 {
        let n = self.jobs.len();
        // Ready queues per resource (FIFO by job index).
        let mut ready: Vec<std::collections::VecDeque<usize>> =
            vec![Default::default(); self.n_resources];
        let mut free_at: Vec<f64> = vec![0.0; self.n_resources];
        let mut busy: Vec<Option<usize>> = vec![None; self.n_resources];
        let mut heap: BinaryHeap<HeapEntry> = BinaryHeap::new();
        let mut seq = 0usize;
        let mut remaining = n;
        let mut makespan = 0.0f64;

        for (i, j) in self.jobs.iter().enumerate() {
            if j.unfinished_deps == 0 {
                ready[j.resource.0].push_back(i);
            }
        }
        // Try to start jobs on every resource at t=0.
        let mut now = 0.0f64;
        loop {
            // Start any startable jobs.
            for r in 0..self.n_resources {
                if busy[r].is_none() {
                    // Find first ready job whose ready_at ≤ max(now, free_at).
                    if let Some(&cand) = ready[r].front() {
                        let start = now.max(free_at[r]).max(self.jobs[cand].ready_at);
                        if start <= now + 1e-18 {
                            ready[r].pop_front();
                            let job = &mut self.jobs[cand];
                            job.start = now;
                            job.finish = now + job.duration;
                            busy[r] = Some(cand);
                            heap.push(HeapEntry {
                                time: job.finish,
                                seq,
                                job: cand,
                            });
                            seq += 1;
                        } else {
                            // Job not ready yet; schedule a wake-up.
                            heap.push(HeapEntry {
                                time: start,
                                seq,
                                job: usize::MAX, // wake-up marker
                            });
                            seq += 1;
                        }
                    }
                }
            }
            if remaining == 0 {
                break;
            }
            let Some(entry) = heap.pop() else {
                panic!("deadlock: {remaining} jobs cannot run (dependency cycle?)");
            };
            self.events_processed += 1;
            now = now.max(entry.time);
            if entry.job == usize::MAX {
                continue; // wake-up only
            }
            // Completion event.
            let job_idx = entry.job;
            let resource = self.jobs[job_idx].resource.0;
            self.jobs[job_idx].done = true;
            makespan = makespan.max(self.jobs[job_idx].finish);
            busy[resource] = None;
            free_at[resource] = self.jobs[job_idx].finish;
            remaining -= 1;
            // Release dependents.
            let finish = self.jobs[job_idx].finish;
            for i in 0..n {
                if !self.jobs[i].done && self.jobs[i].deps.contains(&JobId(job_idx)) {
                    let dj = &mut self.jobs[i];
                    dj.unfinished_deps -= 1;
                    dj.ready_at = dj.ready_at.max(finish);
                    if dj.unfinished_deps == 0 {
                        ready[dj.resource.0].push_back(i);
                    }
                }
            }
        }
        makespan
    }

    pub fn job_window(&self, id: JobId) -> (f64, f64) {
        let j = &self.jobs[id.0];
        (j.start, j.finish)
    }

    pub fn events_processed(&self) -> usize {
        self.events_processed
    }

    pub fn n_jobs(&self) -> usize {
        self.jobs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DMA: Resource = Resource(0);
    const PE: Resource = Resource(1);

    #[test]
    fn sequential_chain() {
        let mut e = Engine::new(2);
        let a = e.add_job(DMA, 1.0, &[]);
        let b = e.add_job(PE, 2.0, &[a]);
        let c = e.add_job(DMA, 0.5, &[b]);
        let makespan = e.run();
        assert!((makespan - 3.5).abs() < 1e-12);
        assert_eq!(e.job_window(c).0, 3.0);
    }

    #[test]
    fn double_buffer_overlap() {
        // Two tiles: dma1, compute1 ∥ dma2, compute2 — classic pipeline.
        let mut e = Engine::new(2);
        let d1 = e.add_job(DMA, 1.0, &[]);
        let c1 = e.add_job(PE, 3.0, &[d1]);
        let d2 = e.add_job(DMA, 1.0, &[d1]); // prefetch after d1 frees the channel
        let c2 = e.add_job(PE, 3.0, &[d2, c1]);
        let makespan = e.run();
        // d1: 0-1, c1: 1-4, d2: 1-2 (overlapped), c2: 4-7.
        assert!((makespan - 7.0).abs() < 1e-12);
        assert_eq!(e.job_window(d2), (1.0, 2.0));
    }

    #[test]
    fn resource_serialization() {
        // Two independent jobs on one resource run back-to-back.
        let mut e = Engine::new(1);
        let a = e.add_job(Resource(0), 2.0, &[]);
        let b = e.add_job(Resource(0), 2.0, &[]);
        let makespan = e.run();
        assert!((makespan - 4.0).abs() < 1e-12);
        let (s_a, _) = e.job_window(a);
        let (s_b, _) = e.job_window(b);
        assert!(s_a < s_b, "FIFO order");
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn cycle_detection_via_deadlock() {
        // Engine can't express forward deps; simulate deadlock with a dep
        // on a job that never finishes is impossible by construction, so
        // fabricate: job depends on itself via unfinished_deps hack is not
        // constructible — instead verify the panic path with an impossible
        // dependency by adding a job whose dep list includes itself.
        let mut e = Engine::new(1);
        // add_job asserts deps exist; a self-dep (same index) passes the
        // bound check only if we add it as the next index — craft:
        let a = e.add_job(Resource(0), 1.0, &[]);
        // Manually corrupt to create a never-ready job.
        e.jobs[a.0].unfinished_deps = 1;
        e.run();
    }

    #[test]
    fn zero_duration_jobs() {
        let mut e = Engine::new(1);
        let a = e.add_job(Resource(0), 0.0, &[]);
        let b = e.add_job(Resource(0), 0.0, &[a]);
        let makespan = e.run();
        assert_eq!(makespan, 0.0);
        let _ = b;
    }

    #[test]
    fn heap_pops_min_time_then_fifo_among_equal_times() {
        // Regression pin for the reversed comparator: the event heap must
        // behave as a *min*-heap on time, FIFO (ascending seq) among
        // equal-time events. Batched serving leans on replay determinism,
        // so a reordering here would silently skew every batch makespan.
        let mut heap: BinaryHeap<HeapEntry> = BinaryHeap::new();
        for (time, seq, job) in [
            (2.0, 0, 10),
            (1.0, 1, 11),
            (1.0, 2, 12), // same instant as seq 1: must pop after it
            (0.5, 3, 13),
            (1.0, 4, 14),
        ] {
            heap.push(HeapEntry { time, seq, job });
        }
        let order: Vec<(f64, usize)> =
            std::iter::from_fn(|| heap.pop().map(|e| (e.time, e.job))).collect();
        assert_eq!(
            order,
            vec![(0.5, 13), (1.0, 11), (1.0, 12), (1.0, 14), (2.0, 10)]
        );
    }

    #[test]
    fn heap_survives_nan_times() {
        // A NaN event time orders last (total_cmp) instead of panicking.
        let mut heap: BinaryHeap<HeapEntry> = BinaryHeap::new();
        heap.push(HeapEntry { time: f64::NAN, seq: 0, job: 0 });
        heap.push(HeapEntry { time: 1.0, seq: 1, job: 1 });
        assert_eq!(heap.pop().unwrap().job, 1);
        assert!(heap.pop().unwrap().time.is_nan());
    }

    #[test]
    fn deterministic_tie_breaking() {
        let mut times = Vec::new();
        for _ in 0..3 {
            let mut e = Engine::new(2);
            let mut prev: Option<JobId> = None;
            for i in 0..20 {
                let r = Resource(i % 2);
                let deps: Vec<JobId> = prev.into_iter().collect();
                prev = Some(e.add_job(r, 0.5, &deps));
            }
            times.push(e.run());
        }
        assert!(times.windows(2).all(|w| w[0] == w[1]));
    }
}
