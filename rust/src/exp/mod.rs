//! Experiment drivers — one per table/figure of the paper's evaluation.
//!
//! | driver | paper artifact |
//! |---|---|
//! | [`tables::table2`] | Table 2 — max frequency vs voltage |
//! | [`tables::table3`] | Table 3 — post-synthesis area breakdown |
//! | [`tables::table4`] | Table 4 — CPU-cycle reduction from TSD modifications |
//! | [`tables::table5`] | Table 5 — MEDEA end-to-end time/energy breakdown |
//! | [`fig5::run`]      | Fig 5 — energy/time, MEDEA vs baselines × deadlines |
//! | [`fig6::run`]      | Fig 6 — per-kernel (PE, V-F) schedule snapshot |
//! | [`fig7::run`]      | Fig 7 — CGRA/Carus ratios vs V-F (crossover) |
//! | [`fig8::run`]      | Fig 8 + Table 6 — feature-ablation energy savings |
//!
//! Each driver returns [`crate::util::table::Table`]s so the CLI, benches
//! and EXPERIMENTS.md generation share one code path.

pub mod context;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod sensitivity;
pub mod tables;

pub use context::ExpContext;
