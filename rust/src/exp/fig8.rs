//! Fig 8 + Table 6: feature-ablation study — energy with one core MEDEA
//! feature disabled at a time, and the percentage saving the feature
//! contributes.

use super::context::ExpContext;
use crate::manager::medea::MedeaFeatures;
use crate::util::table::{fnum, fpct, Table};
use crate::util::units::Time;

/// The ablation setups of §5.3.
pub const SETUPS: [(&str, fn() -> MedeaFeatures); 3] = [
    ("w/o KerDVFS", MedeaFeatures::without_kernel_dvfs),
    ("w/o AdapTile", MedeaFeatures::without_adaptive_tiling),
    ("w/o KerSched", MedeaFeatures::without_kernel_sched),
];

/// Total energy (µJ) per (setup × deadline), full MEDEA first — Table 6.
pub fn table6(ctx: &ExpContext) -> Table {
    let mut t = Table::new(&["Sched. Setup", "50 ms", "200 ms", "1000 ms"])
        .with_title("Table 6 — total energy (uJ) for the MEDEA feature analysis")
        .label_first();

    let energy = |features: MedeaFeatures, ms: f64| -> f64 {
        ctx.medea_with(features)
            .schedule(&ctx.workload, Time::from_ms(ms))
            .expect("feasible")
            .total_energy(&ctx.platform)
            .as_uj()
    };

    let mut row = vec!["Full MEDEA".to_string()];
    for ms in ExpContext::DEADLINES_MS {
        row.push(fnum(energy(MedeaFeatures::default(), ms), 0));
    }
    t.row(row);
    for (name, features) in SETUPS {
        let mut row = vec![name.to_string()];
        for ms in ExpContext::DEADLINES_MS {
            row.push(fnum(energy(features(), ms), 0));
        }
        t.row(row);
    }
    t
}

/// Percentage savings per feature — Fig 8:
/// `(E_w/oFeat − E_full) / E_w/oFeat × 100`.
pub fn run(ctx: &ExpContext) -> Table {
    let mut t = Table::new(&["Feature", "50 ms", "200 ms", "1000 ms"])
        .with_title("Fig 8 — energy saving from each MEDEA feature")
        .label_first();

    let energy = |features: MedeaFeatures, ms: f64| -> f64 {
        ctx.medea_with(features)
            .schedule(&ctx.workload, Time::from_ms(ms))
            .expect("feasible")
            .total_energy(&ctx.platform)
            .raw()
    };

    for (name, features) in SETUPS {
        let mut row = vec![name.replace("w/o ", "").to_string()];
        for ms in ExpContext::DEADLINES_MS {
            let full = energy(MedeaFeatures::default(), ms);
            let without = energy(features(), ms);
            row.push(fpct((without - full) / without * 100.0));
        }
        t.row(row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_and_fig8_render_consistently() {
        let ctx = ExpContext::paper();
        let t6 = table6(&ctx);
        assert_eq!(t6.num_rows(), 4);
        let f8 = run(&ctx);
        assert_eq!(f8.num_rows(), 3);
        // Parse fig8 csv: all savings within [-1, 50] %.
        for line in f8.to_csv().lines().skip(1) {
            for cell in line.split(',').skip(1) {
                let v: f64 = cell.trim_end_matches(" %").parse().unwrap();
                assert!((-1.0..50.0).contains(&v), "{line}");
            }
        }
    }
}
