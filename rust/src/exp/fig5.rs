//! Fig 5: total energy and active time of one window — MEDEA vs the four
//! baselines across the three timing constraints.

use super::context::ExpContext;
use crate::baselines::{
    coarse_grain_app_dvfs, cpu_max_vf, static_accel_app_dvfs, static_accel_max_vf,
};
use crate::manager::schedule::Schedule;
use crate::sim::replay::simulate;
use crate::util::table::{fnum, fpct, Table};
use crate::util::units::Time;

/// One Fig 5 bar: scheduler × deadline.
pub struct Fig5Row {
    pub scheduler: String,
    pub deadline_ms: f64,
    pub total_energy_uj: f64,
    pub active_time_ms: f64,
    pub meets_deadline: bool,
}

/// All schedulers for one deadline.
pub fn schedules_for(ctx: &ExpContext, deadline: Time) -> Vec<Schedule> {
    let w = &ctx.workload;
    let (p, pr, m) = (&ctx.platform, &ctx.profiles, &ctx.model);
    vec![
        cpu_max_vf(w, p, pr, m, deadline).expect("cpu baseline"),
        static_accel_max_vf(w, p, pr, m, deadline).expect("static accel"),
        static_accel_app_dvfs(w, p, pr, m, deadline).expect("static accel dvfs"),
        coarse_grain_app_dvfs(w, p, pr, m, deadline).expect("coarse grain"),
        ctx.schedule_margined(Default::default(), deadline)
            .expect("medea"),
    ]
}

/// Compute all Fig 5 rows (simulator-accounted).
pub fn rows(ctx: &ExpContext) -> Vec<Fig5Row> {
    let mut out = Vec::new();
    for ms in ExpContext::DEADLINES_MS {
        for s in schedules_for(ctx, Time::from_ms(ms)) {
            let r = simulate(&ctx.workload, &ctx.platform, &ctx.model, &s);
            out.push(Fig5Row {
                scheduler: s.scheduler.clone(),
                deadline_ms: ms,
                total_energy_uj: r.total_energy().as_uj(),
                active_time_ms: r.active_time.as_ms(),
                meets_deadline: r.deadline_met,
            });
        }
    }
    out
}

/// Render the figure data as a table, including MEDEA's saving vs each
/// baseline.
pub fn run(ctx: &ExpContext) -> Table {
    let mut t = Table::new(&[
        "Deadline (ms)",
        "Scheduler",
        "Total Energy (uJ)",
        "Active Time (ms)",
        "Meets Deadline",
        "MEDEA Saving",
    ])
    .with_title("Fig 5 — total energy / active time per inference window")
    .label_first();

    let all = rows(ctx);
    for ms in ExpContext::DEADLINES_MS {
        let group: Vec<&Fig5Row> = all.iter().filter(|r| r.deadline_ms == ms).collect();
        let medea_e = group
            .iter()
            .find(|r| r.scheduler == "medea")
            .expect("medea row")
            .total_energy_uj;
        for r in group {
            let saving = if r.scheduler == "medea" {
                "-".to_string()
            } else {
                fpct((1.0 - medea_e / r.total_energy_uj) * 100.0)
            };
            t.row(vec![
                fnum(ms, 0),
                r.scheduler.clone(),
                fnum(r.total_energy_uj, 0),
                fnum(r.active_time_ms, 1),
                if r.meets_deadline { "yes" } else { "NO" }.into(),
                saving,
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_reproduces_paper_shape() {
        let ctx = ExpContext::paper();
        let all = rows(&ctx);
        assert_eq!(all.len(), 15);

        // CPU misses the 50 ms deadline (paper §5.1).
        let cpu50 = all
            .iter()
            .find(|r| r.scheduler == "cpu-maxvf" && r.deadline_ms == 50.0)
            .unwrap();
        assert!(!cpu50.meets_deadline);

        // MEDEA meets every deadline and wins every comparison.
        for ms in ExpContext::DEADLINES_MS {
            let group: Vec<&Fig5Row> = all.iter().filter(|r| r.deadline_ms == ms).collect();
            let medea = group.iter().find(|r| r.scheduler == "medea").unwrap();
            assert!(medea.meets_deadline, "medea misses {ms} ms");
            for r in &group {
                if r.scheduler != "medea" {
                    assert!(
                        medea.total_energy_uj < r.total_energy_uj,
                        "{} beats medea at {ms} ms",
                        r.scheduler
                    );
                }
            }
        }
    }
}
