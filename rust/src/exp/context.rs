//! Shared experiment context: platform + characterization + workload.

use crate::ir::tsd::{tsd_core, TsdParams};
use crate::ir::Workload;
use crate::manager::medea::{Medea, MedeaFeatures, SolverKind};
use crate::platform::Platform;
use crate::profile::{characterize, Profiles};
use crate::timing::cycle_model::CycleModel;

/// Everything the experiment drivers need, built once.
pub struct ExpContext {
    pub platform: Platform,
    pub model: CycleModel,
    pub profiles: Profiles,
    pub workload: Workload,
    pub solver: SolverKind,
}

impl ExpContext {
    /// HEEPtimize + TSD core, the paper's §4 setup.
    pub fn paper() -> ExpContext {
        let platform = crate::platform::heeptimize::heeptimize();
        let model = CycleModel::heeptimize();
        let profiles = characterize(&platform, &model);
        ExpContext {
            workload: tsd_core(&TsdParams::default()),
            platform,
            model,
            profiles,
            solver: SolverKind::Dp,
        }
    }

    /// A MEDEA manager over this context.
    pub fn medea(&self) -> Medea<'_> {
        Medea::new(&self.platform, &self.profiles, &self.model).with_solver(self.solver)
    }

    /// A MEDEA manager with specific feature switches.
    pub fn medea_with(&self, features: MedeaFeatures) -> Medea<'_> {
        self.medea().with_features(features)
    }

    /// Schedule with the deployment margin (3 %): the estimator's
    /// LM-residency chaining is optimistic, so schedules destined for the
    /// event-level simulator target 97 % of the deadline (the label on the
    /// returned schedule stays the full deadline). This mirrors the margin
    /// a real deployment folds into its profiling data.
    pub fn schedule_margined(
        &self,
        features: MedeaFeatures,
        deadline: crate::util::units::Time,
    ) -> Result<crate::manager::Schedule, crate::manager::medea::ScheduleError> {
        let mut s = self
            .medea_with(features)
            .schedule(&self.workload, deadline * Self::SIM_MARGIN)?;
        s.deadline = deadline;
        Ok(s)
    }

    /// Deadline fraction targeted when a schedule will be replayed on the
    /// simulator.
    pub const SIM_MARGIN: f64 = 0.97;

    /// The paper's three evaluation deadlines (ms).
    pub const DEADLINES_MS: [f64; 3] = [50.0, 200.0, 1000.0];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_builds() {
        let ctx = ExpContext::paper();
        assert_eq!(ctx.workload.len(), 164);
        assert_eq!(ctx.platform.pes.len(), 3);
        assert!(ctx.profiles.timing_entry_count() > 0);
    }
}
