//! Fig 7: CGRA/Carus ratios (energy, power, time) for the TSD matmul
//! subset across the V-F range — the efficiency crossover that forces
//! joint PE + V-F optimization.

use super::context::ExpContext;
use crate::config::estimator::Estimator;
use crate::ir::tsd::{tsd_matmul_subset, TsdParams};
use crate::platform::heeptimize::{CARUS, CGRA};
use crate::util::table::{fnum, Table};

/// Ratios per V-F point.
pub struct Fig7Row {
    pub vf_label: String,
    pub energy_ratio: f64,
    pub power_ratio: f64,
    pub time_ratio: f64,
}

pub fn rows(ctx: &ExpContext) -> Vec<Fig7Row> {
    let subset = tsd_matmul_subset(&TsdParams::default());
    let est = Estimator::new(&ctx.platform, &ctx.profiles, &ctx.model);
    let mut out = Vec::new();
    for vf_idx in 0..ctx.platform.vf.len() {
        let mut e = [0.0f64; 2];
        let mut t = [0.0f64; 2];
        let mut p = [0.0f64; 2];
        for (i, pe) in [CGRA, CARUS].into_iter().enumerate() {
            for k in subset.kernels() {
                let (mode, _) = est.best_mode(pe, k).expect("matmul runs on both");
                let time = est.time(pe, k, vf_idx, mode).unwrap();
                let power = est.power(pe, k, vf_idx);
                t[i] += time.raw();
                e[i] += (power * time).raw();
            }
            p[i] = e[i] / t[i]; // average power over the subset
        }
        out.push(Fig7Row {
            vf_label: ctx.platform.vf.get(vf_idx).label(),
            energy_ratio: e[0] / e[1],
            power_ratio: p[0] / p[1],
            time_ratio: t[0] / t[1],
        });
    }
    out
}

pub fn run(ctx: &ExpContext) -> Table {
    let mut t = Table::new(&[
        "V-F point",
        "Energy (CGRA/Carus)",
        "Power (CGRA/Carus)",
        "Time (CGRA/Carus)",
    ])
    .with_title("Fig 7 — TSD matmul subset: CGRA/Carus metric ratios vs V-F")
    .label_first();
    for r in rows(ctx) {
        t.row(vec![
            r.vf_label,
            fnum(r.energy_ratio, 3),
            fnum(r.power_ratio, 3),
            fnum(r.time_ratio, 3),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crossover_shape_matches_paper() {
        let ctx = ExpContext::paper();
        let rs = rows(&ctx);
        assert_eq!(rs.len(), 4);

        // Power ratio decreases significantly at lower V-F (paper Fig 7).
        assert!(
            rs[0].power_ratio < 0.8 * rs[3].power_ratio,
            "power ratio must fall at low V: {} vs {}",
            rs[0].power_ratio,
            rs[3].power_ratio
        );
        // Time ratio is essentially constant (same cycle counts, same f).
        let tmin = rs.iter().map(|r| r.time_ratio).fold(f64::INFINITY, f64::min);
        let tmax = rs.iter().map(|r| r.time_ratio).fold(0.0, f64::max);
        assert!((tmax - tmin) / tmax < 0.05, "time ratio drifts: {tmin}..{tmax}");
        // Efficiency crossover: CGRA wins at 0.5 V, Carus at 0.9 V.
        assert!(rs[0].energy_ratio < 1.0, "CGRA must win at 0.5 V");
        assert!(rs[3].energy_ratio > 1.0, "Carus must win at 0.9 V");
    }
}
