//! Fig 6: snapshot of MEDEA's per-kernel (PE, V-F) decisions for a
//! subsequence of the TSD workload under the three deadlines, plus the
//! assignment histograms that show PE re-assignment across deadlines.

use super::context::ExpContext;
use crate::util::table::{fnum, Table};
use crate::util::units::Time;

/// Render the decision snapshot for kernels `[start, start+len)`.
pub fn run(ctx: &ExpContext, start: usize, len: usize) -> Table {
    let mut headers: Vec<String> = vec!["Kernel".into()];
    for ms in ExpContext::DEADLINES_MS {
        headers.push(format!("@{ms:.0}ms PE"));
        headers.push(format!("@{ms:.0}ms V-F"));
        headers.push(format!("@{ms:.0}ms tile"));
    }
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&header_refs)
        .with_title("Fig 6 — MEDEA per-kernel decisions vs deadline (snapshot)")
        .label_first();

    let medea = ctx.medea();
    let schedules: Vec<_> = ExpContext::DEADLINES_MS
        .iter()
        .map(|&ms| medea.schedule(&ctx.workload, Time::from_ms(ms)).unwrap())
        .collect();

    let end = (start + len).min(ctx.workload.len());
    for i in start..end {
        let mut row = vec![ctx.workload.kernels()[i].name.clone()];
        for s in &schedules {
            let d = &s.decisions[i];
            row.push(ctx.platform.pe(d.pe).name.clone());
            row.push(ctx.platform.vf.get(d.vf_idx).label());
            row.push(d.mode.name().into());
        }
        t.row(row);
    }
    t
}

/// The per-deadline (PE, V-F) assignment histogram (the aggregate view of
/// Fig 6: how kernels migrate between PEs/V-F levels as deadlines tighten).
pub fn histogram(ctx: &ExpContext) -> Table {
    let mut t = Table::new(&["Deadline (ms)", "PE", "V-F", "Kernels"])
        .with_title("Fig 6 (aggregate) — kernel count per (PE, V-F) assignment")
        .label_first();
    let medea = ctx.medea();
    for ms in ExpContext::DEADLINES_MS {
        let s = medea.schedule(&ctx.workload, Time::from_ms(ms)).unwrap();
        for ((pe, vf), n) in s.assignment_histogram() {
            t.row(vec![
                fnum(ms, 0),
                ctx.platform.pe(pe).name.clone(),
                ctx.platform.vf.get(vf).label(),
                n.to_string(),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::heeptimize::{CARUS, CGRA};
    use crate::util::units::Time;

    #[test]
    fn snapshot_renders() {
        let ctx = ExpContext::paper();
        let t = run(&ctx, 2, 10);
        assert_eq!(t.num_rows(), 10);
        let text = t.to_text();
        assert!(text.contains("enc0"));
    }

    #[test]
    fn vf_tightens_with_deadline_and_pe_reassignment_occurs() {
        // The two headline behaviours of Fig 6: (1) tighter deadlines use
        // higher V-F; (2) the PE choice itself changes with the deadline
        // (the Fig 7 crossover in action).
        let ctx = ExpContext::paper();
        let medea = ctx.medea();
        let s50 = medea.schedule(&ctx.workload, Time::from_ms(50.0)).unwrap();
        let s1000 = medea.schedule(&ctx.workload, Time::from_ms(1000.0)).unwrap();

        let avg_vf = |s: &crate::manager::Schedule| {
            s.decisions.iter().map(|d| d.vf_idx as f64).sum::<f64>() / s.decisions.len() as f64
        };
        assert!(avg_vf(&s50) > avg_vf(&s1000));

        // Count matmuls on each accelerator at both deadlines.
        let counts = |s: &crate::manager::Schedule, pe| {
            s.decisions
                .iter()
                .filter(|d| {
                    d.pe == pe && ctx.workload.kernels()[d.kernel].ty == crate::ir::KernelType::MatMul
                })
                .count()
        };
        // Relaxed deadline (0.5 V): CGRA is the energy-efficient matmul
        // engine; tight deadline shifts matmuls toward Carus (cheaper at
        // high V-F) — the dynamic re-assignment the paper highlights.
        assert!(
            counts(&s1000, CGRA) > counts(&s1000, CARUS),
            "at 0.5 V the CGRA must carry the matmuls"
        );
        assert!(
            counts(&s50, CARUS) > counts(&s1000, CARUS),
            "tightening the deadline must migrate matmuls toward Carus"
        );
    }
}
