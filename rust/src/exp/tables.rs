//! Tables 2–5 of the paper.

use super::context::ExpContext;
use crate::ir::{KernelType, Shape};
use crate::platform::heeptimize::AREA_BREAKDOWN;
use crate::platform::PeClass;
use crate::sim::replay::simulate;
use crate::util::table::{fnum, Table};
use crate::util::units::Time;

/// Table 2: maximum operating frequency per voltage.
pub fn table2(ctx: &ExpContext) -> Table {
    let mut t = Table::new(&["Voltage (V)", "Max Freq. (MHz)"])
        .with_title("Table 2 — HEEPtimize maximum operating frequency vs voltage");
    for p in ctx.platform.vf.points() {
        t.row(vec![fnum(p.v.raw(), 2), fnum(p.f.as_mhz(), 0)]);
    }
    t
}

/// Table 3: post-synthesis area breakdown (carried verbatim — reporting
/// constants, not a measurement this reproduction can re-derive).
pub fn table3(_ctx: &ExpContext) -> Table {
    let mut t = Table::new(&["Component", "Area (mm^2)"])
        .with_title("Table 3 — post-synthesis area breakdown (GF 22 nm FDX, SSG)")
        .label_first();
    let mut total = 0.0;
    for (name, area) in AREA_BREAKDOWN {
        t.row(vec![name.to_string(), fnum(area, 3)]);
        total += area;
    }
    t.row(vec!["Total Area".into(), format!("~{}", fnum(total, 3))]);
    t
}

/// Table 4: CPU cycles, original vs ULP-modified TSD kernels.
pub fn table4(ctx: &ExpContext) -> Table {
    let mut t = Table::new(&[
        "Operation",
        "Original Cycles (M)",
        "Modified Cycles (M)",
        "Reduction",
    ])
    .with_title("Table 4 — CPU cycle reduction from the TSD model modifications")
    .label_first();

    // Whole-model shapes for the three modified operations.
    let p = crate::ir::tsd::TsdParams::default();
    let entries: [(&str, KernelType, Shape, u64); 3] = [
        (
            "Log-Amplitude FFT -> FFT magnitude",
            KernelType::FftMag,
            Shape::Fft { n_fft: p.n_fft, batch: p.patches },
            1,
        ),
        (
            "Softmax -> 3-coeff Taylor",
            KernelType::Softmax,
            Shape::Rowwise { rows: p.patches + 1, cols: p.patches + 1 },
            (p.blocks * p.heads) as u64,
        ),
        (
            "GeLU -> piecewise linear",
            KernelType::Gelu,
            Shape::Elementwise { n: (p.patches + 1) * p.d_ff, arity: 1 },
            p.blocks,
        ),
    ];
    for (name, ty, shape, count) in entries {
        let orig = ctx.model.original_cpu_cycles(ty, shape).raw() * count;
        let dw = match ty {
            KernelType::FftMag => crate::ir::DataWidth::Float32,
            KernelType::Softmax => crate::ir::DataWidth::Int16,
            _ => crate::ir::DataWidth::Int8,
        };
        let modi = ctx
            .model
            .cycles_for_ops(PeClass::RiscvCpu, ty, dw, shape.ops())
            .unwrap()
            .raw()
            * count;
        t.row(vec![
            name.to_string(),
            fnum(orig as f64 / 1e6, 2),
            fnum(modi as f64 / 1e6, 2),
            format!("{:.0}x", orig as f64 / modi as f64),
        ]);
    }
    t
}

/// Table 5: MEDEA end-to-end time/energy breakdown across deadlines,
/// accounted by the discrete-event simulator.
pub fn table5(ctx: &ExpContext) -> Table {
    let mut t = Table::new(&[
        "Deadline (ms)",
        "Active Time (ms)",
        "Sleep Time (ms)",
        "Active Energy (uJ)",
        "Sleep Energy (uJ)",
    ])
    .with_title(format!(
        "Table 5 — end-to-end breakdown for the TSD workload (P_slp = {:.0} uW)",
        ctx.platform.sleep_power.as_uw()
    ));
    for ms in ExpContext::DEADLINES_MS {
        let s = ctx
            .schedule_margined(Default::default(), Time::from_ms(ms))
            .expect("paper deadlines are feasible");
        let r = simulate(&ctx.workload, &ctx.platform, &ctx.model, &s);
        t.row(vec![
            fnum(ms, 0),
            fnum(r.active_time.as_ms(), 1),
            fnum(r.sleep_time.as_ms(), 1),
            fnum(r.active_energy.as_uj(), 0),
            fnum(r.sleep_energy.as_uj(), 0),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tables_render() {
        let ctx = ExpContext::paper();
        assert_eq!(table2(&ctx).num_rows(), 4);
        assert_eq!(table3(&ctx).num_rows(), 8);
        assert_eq!(table4(&ctx).num_rows(), 3);
        let t5 = table5(&ctx);
        assert_eq!(t5.num_rows(), 3);
        let text = t5.to_text();
        assert!(text.contains("129 uW"));
    }

    #[test]
    fn table4_shows_large_reductions() {
        let ctx = ExpContext::paper();
        let csv = table4(&ctx).to_csv();
        // Every row must show a >10x reduction.
        for line in csv.lines().skip(1) {
            let factor: f64 = line
                .rsplit(',')
                .next()
                .unwrap()
                .trim_end_matches('x')
                .parse()
                .unwrap();
            assert!(factor > 10.0, "{line}");
        }
    }
}
