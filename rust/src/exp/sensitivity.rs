//! Sensitivity study: how robust are the reproduced conclusions to the
//! calibrated substrate constants? DESIGN.md names three modeling choices
//! whose values were calibrated rather than measured: the system DMA
//! bandwidth, the NMC's voltage-independent array energy (`e_fixed`, the
//! Fig 7 crossover driver), and the solver backend. This driver sweeps
//! each and reports the headline metrics, showing which conclusions are
//! structural and which are calibration-dependent.

use super::context::ExpContext;
use crate::baselines::coarse_grain_app_dvfs;
use crate::ir::tsd::{tsd_core, TsdParams};
use crate::manager::medea::{Medea, MedeaFeatures, SolverKind};
use crate::platform::heeptimize::{heeptimize, CARUS, CGRA};
use crate::profile::characterize;
use crate::timing::cycle_model::CycleModel;
use crate::util::table::{fnum, Table};
use crate::util::units::Time;

/// Headline metrics for one platform variant.
struct Headline {
    medea_vs_cg_200ms_pct: f64,
    kerdvfs_200ms_pct: f64,
    adaptile_200ms_pct: f64,
    crossover_voltage: Option<f64>,
}

fn headline(platform: &crate::platform::Platform, model: &CycleModel) -> Headline {
    let profiles = characterize(platform, model);
    let workload = tsd_core(&TsdParams::default());
    let d = Time::from_ms(200.0);
    let medea = Medea::new(platform, &profiles, model);

    let full = medea.schedule(&workload, d).unwrap();
    let cg = coarse_grain_app_dvfs(&workload, platform, &profiles, model, d).unwrap();
    let medea_vs_cg = (1.0
        - full.total_energy(platform).raw() / cg.total_energy(platform).raw())
        * 100.0;

    let ablate = |feats: MedeaFeatures| -> f64 {
        let abl = Medea::new(platform, &profiles, model)
            .with_features(feats)
            .schedule(&workload, d)
            .unwrap();
        (1.0 - full.total_energy(platform).raw() / abl.total_energy(platform).raw()) * 100.0
    };

    // Crossover: lowest voltage at which Carus beats the CGRA on the
    // matmul subset (None = no crossover in the V-F range).
    let est = crate::config::Estimator::new(platform, &profiles, model);
    let subset = crate::ir::tsd::tsd_matmul_subset(&TsdParams::default());
    let mut crossover = None;
    for vf_idx in 0..platform.vf.len() {
        let energy = |pe| -> f64 {
            subset
                .kernels()
                .iter()
                .map(|k| {
                    let (mode, _) = est.best_mode(pe, k).unwrap();
                    est.energy(pe, k, vf_idx, mode).unwrap().raw()
                })
                .sum()
        };
        if energy(CARUS) < energy(CGRA) {
            crossover = Some(platform.vf.get(vf_idx).v.raw());
            break;
        }
    }

    Headline {
        medea_vs_cg_200ms_pct: medea_vs_cg,
        kerdvfs_200ms_pct: ablate(MedeaFeatures::without_kernel_dvfs()),
        adaptile_200ms_pct: ablate(MedeaFeatures::without_adaptive_tiling()),
        crossover_voltage: crossover,
    }
}

/// Sweep the system DMA bandwidth (both accelerators).
pub fn dma_sweep(_ctx: &ExpContext) -> Table {
    let mut t = Table::new(&[
        "DMA (B/cycle)",
        "MEDEA vs CG @200ms",
        "KerDVFS @200ms",
        "AdapTile @200ms",
    ])
    .with_title("Sensitivity — system DMA bandwidth (calibrated value: 1.3 B/cycle)");
    let model = CycleModel::heeptimize();
    for bw in [0.8, 1.3, 2.6, 4.0] {
        let mut p = heeptimize();
        for pe in [CGRA, CARUS] {
            p.pes[pe.0].dma.as_mut().unwrap().bytes_per_cycle = bw;
        }
        let h = headline(&p, &model);
        t.row(vec![
            fnum(bw, 1),
            format!("{:.1} %", h.medea_vs_cg_200ms_pct),
            format!("{:.1} %", h.kerdvfs_200ms_pct),
            format!("{:.1} %", h.adaptile_200ms_pct),
        ]);
    }
    t
}

/// Sweep the NMC array energy `e_fixed` (the crossover driver).
pub fn efixed_sweep(_ctx: &ExpContext) -> Table {
    let mut t = Table::new(&[
        "Carus e_fixed (pJ/cyc)",
        "Crossover (Carus wins from)",
        "MEDEA vs CG @200ms",
    ])
    .with_title("Sensitivity — NMC array energy (calibrated value: 12 pJ/cycle)");
    let model = CycleModel::heeptimize();
    for pj in [0.0, 6.0, 12.0, 18.0] {
        let mut p = heeptimize();
        p.pes[CARUS.0].power.e_fixed = pj * 1e-12;
        let h = headline(&p, &model);
        t.row(vec![
            fnum(pj, 0),
            match h.crossover_voltage {
                Some(v) => format!("{v:.2} V"),
                None => "never".into(),
            },
            format!("{:.1} %", h.medea_vs_cg_200ms_pct),
        ]);
    }
    t
}

/// Compare solver backends on the full pipeline (schedule quality + the
/// §3.3 optimality claim).
pub fn solver_sweep(ctx: &ExpContext) -> Table {
    let mut t = Table::new(&["Solver", "E_active @200ms (uJ)", "vs DP", "Optimal?"])
        .with_title("Sensitivity — MCKP solver backend")
        .label_first();
    let d = Time::from_ms(200.0);
    let dp_energy = ctx
        .medea()
        .schedule(&ctx.workload, d)
        .unwrap()
        .active_energy()
        .as_uj();
    for (name, kind) in [
        ("dp", SolverKind::Dp),
        ("bb", SolverKind::Bb),
        ("lagrange", SolverKind::Lagrange),
        ("greedy", SolverKind::Greedy),
    ] {
        let s = ctx
            .medea()
            .with_solver(kind)
            .schedule(&ctx.workload, d)
            .unwrap();
        let e = s.active_energy().as_uj();
        t.row(vec![
            name.into(),
            fnum(e, 1),
            format!("{:+.2} %", (e / dp_energy - 1.0) * 100.0),
            if s.optimal { "yes" } else { "no" }.into(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conclusions_robust_across_dma_sweep() {
        // MEDEA must beat CoarseGrain at 200 ms for every swept bandwidth
        // (the headline conclusion is structural, not calibration luck).
        let ctx = ExpContext::paper();
        let t = dma_sweep(&ctx);
        assert_eq!(t.num_rows(), 4);
        for line in t.to_csv().lines().skip(1) {
            let cells: Vec<&str> = line.split(',').collect();
            let saving: f64 = cells[1].trim_end_matches(" %").parse().unwrap();
            assert!(saving > 5.0, "MEDEA advantage collapsed: {line}");
        }
    }

    #[test]
    fn crossover_depends_on_efixed() {
        // Removing the NMC array-energy term must move (or remove) the
        // crossover — demonstrating it is the modeled driver.
        let ctx = ExpContext::paper();
        let t = efixed_sweep(&ctx);
        let csv = t.to_csv();
        let rows: Vec<&str> = csv.lines().skip(1).collect();
        // At the calibrated 12 pJ the crossover exists.
        assert!(rows[2].contains("V"), "calibrated row lost its crossover: {}", rows[2]);
        // Crossover voltage is monotonically pushed up (or out) as e_fixed
        // grows; at 0 pJ Carus dominates from a lower voltage than at 18 pJ.
        let volts = |row: &str| -> f64 {
            let c = row.split(',').nth(1).unwrap();
            if c == "never" {
                f64::INFINITY
            } else {
                c.trim_end_matches(" V").parse().unwrap()
            }
        };
        assert!(volts(rows[0]) <= volts(rows[3]));
    }

    #[test]
    fn solver_backends_close_to_dp() {
        let ctx = ExpContext::paper();
        let t = solver_sweep(&ctx);
        for line in t.to_csv().lines().skip(1) {
            let cells: Vec<&str> = line.split(',').collect();
            let delta: f64 = cells[2].trim_end_matches(" %").parse().unwrap();
            // dp/bb are (gap-)exact, greedy is the LP truncation; the
            // Lagrangian heuristic's duality gap is real on this plateau
            // instance (its role is the certified lower bound) — allow it
            // a wider band and document it in the table.
            let band = if cells[0] == "lagrange" { 25.0 } else { 5.0 };
            assert!(delta.abs() < band, "{line}");
        }
    }
}
