//! Named presets the fleet layer can (re)build entries from.
//!
//! Library entries persist their atlases but not the platform's cycle model
//! (which is code, not data), so every entry records the *preset names* it
//! was built from; loading resolves those names here and verifies the
//! content keys still match (see [`crate::fleet::entry`]). A preset rename
//! is harmless — keys are content hashes — but a preset whose constants
//! drifted since the entry was built fails the key check and is rebuilt.

use crate::ir::tsd::{tsd_core, tsd_full, tsd_small, TsdParams};
use crate::ir::Workload;
use crate::platform::heeptimize::heeptimize;
use crate::platform::presets::heeptimize_hp;
use crate::platform::Platform;
use crate::timing::cycle_model::CycleModel;

/// Platform presets servable by the fleet layer.
pub const PLATFORM_PRESETS: [&str; 2] = ["heeptimize", "heeptimize-hp"];

/// Workload presets servable by the fleet layer.
pub const WORKLOAD_PRESETS: [&str; 3] = ["tsd-core", "tsd-small", "tsd-full"];

/// Resolve a platform preset name to its description and cycle model.
pub fn platform_preset(name: &str) -> Option<(Platform, CycleModel)> {
    match name {
        "heeptimize" => Some((heeptimize(), CycleModel::heeptimize())),
        // Same microarchitectural families, so the calibrated per-class
        // cycle model carries over; the platform constants differ.
        "heeptimize-hp" => Some((heeptimize_hp(), CycleModel::heeptimize())),
        _ => None,
    }
}

/// Resolve a workload preset name to its kernel workload.
pub fn workload_preset(name: &str) -> Option<Workload> {
    match name {
        "tsd-core" => Some(tsd_core(&TsdParams::default())),
        "tsd-small" => Some(tsd_small()),
        "tsd-full" => Some(tsd_full(&TsdParams::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_listed_presets_resolve() {
        for name in PLATFORM_PRESETS {
            let (p, _) = platform_preset(name).expect(name);
            p.validate().unwrap();
            assert_eq!(p.name, name);
        }
        for name in WORKLOAD_PRESETS {
            let w = workload_preset(name).expect(name);
            assert_eq!(w.name, name);
            assert!(!w.is_empty());
        }
    }

    #[test]
    fn unknown_presets_are_none() {
        assert!(platform_preset("no-such-soc").is_none());
        assert!(workload_preset("no-such-net").is_none());
    }
}
