//! The energy-budget atlas: the dual objective, precomputed.
//!
//! [`crate::serve::atlas::ScheduleAtlas`] answers "cheapest schedule meeting
//! deadline `T_d`"; this module answers the dual — "fastest schedule within
//! energy cap `E_b`" — with the same design-time discipline. A geometric
//! sweep over energy budgets (bounded by the Pareto front the deadline atlas
//! already traced) solves [`crate::manager::medea::Medea::schedule_energy_budget`]
//! once per knot and validates every knot on the event-level simulator, so a
//! request carrying an energy cap resolves by `O(log n)` binary search to a
//! schedule whose *simulated* active energy fits the cap.

use crate::ir::Workload;
use crate::manager::medea::{Medea, ScheduleError};
use crate::manager::schedule::Schedule;
use crate::serve::atlas::ScheduleAtlas;
use crate::sim::replay::simulate;
use crate::util::json::{Json, JsonObj};
use crate::util::units::{Energy, Time};
use std::fmt;

/// Sweep parameters for [`EnergyAtlas::build`].
#[derive(Debug, Clone)]
pub struct EnergyAtlasConfig {
    /// Geometric budget spacing between adjacent knots (> 1). Bounds the
    /// relative energy headroom a lookup can leave unused.
    pub growth: f64,
    /// Hard cap on the number of knots; truncation is logged, never silent.
    pub max_knots: usize,
    /// Fraction of each knot budget handed to the solver, so the event-level
    /// replay (which does not always grant the estimator's optimistic
    /// LM-residency chaining) still lands inside the budget.
    pub margin: f64,
    /// Bisection iterations per `schedule_energy_budget` solve.
    pub bisect_iters: usize,
}

impl Default for EnergyAtlasConfig {
    fn default() -> Self {
        EnergyAtlasConfig {
            growth: 1.25,
            max_knots: 48,
            margin: 0.97,
            bisect_iters: 18,
        }
    }
}

/// One precomputed point: the fastest schedule whose simulated active energy
/// fits `budget`.
#[derive(Debug, Clone)]
pub struct EnergyKnot {
    pub budget: Energy,
    /// The budget actually handed to the solver (margin folded in, then
    /// tightened further if the simulator overshot).
    pub solve_budget: Energy,
    /// Simulated active time of the schedule, recorded at build time (the
    /// quantity a budget-capped caller is trading energy against).
    pub sim_time: Time,
    /// Simulated active energy (≤ `budget` by construction).
    pub sim_energy: Energy,
    pub schedule: Schedule,
}

impl EnergyKnot {
    /// Sim-anchored batch makespan for `n` stacked windows (see
    /// [`crate::serve::batch`]): `sim_time · (1 + a·(n−1))`.
    pub fn batch_makespan(&self, n: usize, amortization: f64) -> Time {
        crate::serve::batch::batch_makespan(self.sim_time, n, amortization)
    }

    /// Per-member active-energy share of an `n`-window batch: total batch
    /// energy scales like the makespan (same power envelope), so each member
    /// is charged `sim_energy · scale(n) / n` — non-increasing in `n`, and
    /// exactly the sim-validated solo energy at `n = 1`. This is the dual
    /// admission check: a member joins a batch only while the share fits
    /// every member's requested cap.
    pub fn batch_energy_per_member(&self, n: usize, amortization: f64) -> Energy {
        crate::serve::batch::batch_energy_share(self.sim_energy, n, amortization)
    }
}

/// Typed lookup failure: the cap is below the tightest sim-validated budget.
#[derive(Debug, Clone, PartialEq)]
pub struct BelowEnergyFloor {
    pub requested: Energy,
    pub floor: Energy,
}

impl fmt::Display for BelowEnergyFloor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "energy budget {:.1} uJ below the atlas energy floor {:.1} uJ",
            self.requested.as_uj(),
            self.floor.as_uj()
        )
    }
}

impl std::error::Error for BelowEnergyFloor {}

/// A budget-indexed library of precomputed dual-objective schedules, sorted
/// by ascending budget with simulated time non-increasing along the knots.
#[derive(Debug, Clone)]
pub struct EnergyAtlas {
    /// Workload the schedules were generated for (checked on load).
    pub workload: String,
    knots: Vec<EnergyKnot>,
}

impl EnergyAtlas {
    /// Sweep energy budgets across the Pareto range traced by `atlas` and
    /// precompute one time-optimal schedule per knot.
    pub fn build(
        medea: &Medea<'_>,
        workload: &Workload,
        atlas: &ScheduleAtlas,
        cfg: &EnergyAtlasConfig,
    ) -> Result<EnergyAtlas, ScheduleError> {
        assert!(cfg.growth > 1.0, "energy atlas growth must be > 1");
        assert!(cfg.max_knots >= 2, "energy atlas needs at least 2 knots");
        assert!(cfg.margin > 0.0 && cfg.margin <= 1.0, "energy atlas margin in (0, 1]");

        // The deadline atlas already traced the energy Pareto front: its
        // laxest knot is the unconstrained energy minimum, its tightest the
        // most energy any useful budget can demand.
        let knots = atlas.knots();
        let e_min = knots[knots.len() - 1].schedule.active_energy();
        let e_max = knots[0].schedule.active_energy();

        // Geometric grid. The 2 % fudge above the estimator minimum mirrors
        // the deadline atlas's floor slack: nothing at the exact estimator
        // optimum survives simulator validation.
        let lo = Energy(e_min.raw() * 1.02 / cfg.margin);
        let hi = Energy(e_max.raw().max(lo.raw() * cfg.growth));
        let mut grid = Vec::new();
        let mut b = lo;
        while b.raw() < hi.raw() {
            grid.push(b);
            b = b * cfg.growth;
        }
        grid.push(hi);
        if grid.len() > cfg.max_knots {
            crate::log_warn!(
                "energy atlas knot cap {} reached: truncating sweep from {} grid points \
                 (budgets above {:.1} uJ collapse onto the final knot)",
                cfg.max_knots,
                grid.len(),
                grid[cfg.max_knots - 2].as_uj()
            );
            grid.truncate(cfg.max_knots - 1);
            grid.push(hi);
        }

        let mut kept: Vec<EnergyKnot> = Vec::with_capacity(grid.len());
        for budget in grid {
            let Some(knot) = Self::solve_knot(medea, workload, budget, cfg)? else {
                continue;
            };
            // Dedup the flat tail: keep a knot only when the extra budget
            // actually buys simulated time.
            let improves = kept
                .last()
                .map(|prev| knot.sim_time.raw() < prev.sim_time.raw() * (1.0 - 1e-9))
                .unwrap_or(true);
            if improves {
                kept.push(knot);
            }
        }
        if kept.is_empty() {
            return Err(ScheduleError::EnergyBudgetInfeasible {
                budget_uj: hi.as_uj(),
                min_uj: e_min.as_uj(),
            });
        }
        Ok(EnergyAtlas {
            workload: workload.name.clone(),
            knots: kept,
        })
    }

    /// Solve the dual objective for one budget and validate on the
    /// event-level simulator, retrying with a proportionally tighter solve
    /// budget when the replayed energy overshoots. `Ok(None)` when no
    /// sim-valid schedule exists within this budget.
    fn solve_knot(
        medea: &Medea<'_>,
        workload: &Workload,
        budget: Energy,
        cfg: &EnergyAtlasConfig,
    ) -> Result<Option<EnergyKnot>, ScheduleError> {
        let mut target = budget * cfg.margin;
        for _ in 0..4 {
            let schedule = match medea.schedule_energy_budget(workload, target, cfg.bisect_iters) {
                Ok(s) => s,
                Err(ScheduleError::EnergyBudgetInfeasible { .. }) => return Ok(None),
                Err(e) => return Err(e),
            };
            let sim = simulate(workload, medea.platform, medea.model, &schedule);
            if sim.active_energy.raw() <= budget.raw() {
                return Ok(Some(EnergyKnot {
                    budget,
                    solve_budget: target,
                    sim_time: sim.active_time,
                    sim_energy: sim.active_energy,
                    schedule,
                }));
            }
            target = Energy(target.raw() * budget.raw() / sim.active_energy.raw() * 0.998);
        }
        Ok(None)
    }

    /// The tightest budget this atlas can serve.
    pub fn floor(&self) -> Energy {
        self.knots[0].budget
    }

    pub fn len(&self) -> usize {
        self.knots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.knots.is_empty()
    }

    pub fn knots(&self) -> &[EnergyKnot] {
        &self.knots
    }

    /// `O(log n)` lookup: the highest knot whose budget is ≤ `budget` —
    /// i.e. the fastest precomputed schedule that fits the cap (knot time is
    /// non-increasing in knot budget by construction).
    pub fn lookup(&self, budget: Energy) -> Result<&EnergyKnot, BelowEnergyFloor> {
        let idx = self
            .knots
            .partition_point(|k| k.budget.raw() <= budget.raw());
        if idx == 0 {
            return Err(BelowEnergyFloor {
                requested: budget,
                floor: self.floor(),
            });
        }
        Ok(&self.knots[idx - 1])
    }

    /// Like [`EnergyAtlas::lookup`], but clones the schedule (its deadline
    /// stays the bisected deadline the dual solve converged to).
    pub fn resolve(&self, budget: Energy) -> Result<Schedule, BelowEnergyFloor> {
        Ok(self.lookup(budget)?.schedule.clone())
    }

    // ---- JSON ----------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("workload", self.workload.clone());
        let knots: Vec<Json> = self
            .knots
            .iter()
            .map(|k| {
                let mut kj = JsonObj::new();
                kj.insert("budget_uj", k.budget.as_uj());
                kj.insert("solve_budget_uj", k.solve_budget.as_uj());
                kj.insert("sim_time_ms", k.sim_time.as_ms());
                kj.insert("sim_energy_uj", k.sim_energy.as_uj());
                kj.insert("schedule", k.schedule.to_json());
                Json::Obj(kj)
            })
            .collect();
        o.insert("knots", Json::Arr(knots));
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<EnergyAtlas, String> {
        let workload = v.req("workload")?.as_str().ok_or("workload")?.to_string();
        let mut knots = Vec::new();
        for kv in v.req("knots")?.as_arr().ok_or("knots")? {
            knots.push(EnergyKnot {
                budget: Energy::from_uj(kv.req("budget_uj")?.as_f64().ok_or("budget_uj")?),
                solve_budget: Energy::from_uj(
                    kv.req("solve_budget_uj")?.as_f64().ok_or("solve_budget_uj")?,
                ),
                sim_time: Time::from_ms(kv.req("sim_time_ms")?.as_f64().ok_or("sim_time_ms")?),
                sim_energy: Energy::from_uj(
                    kv.req("sim_energy_uj")?.as_f64().ok_or("sim_energy_uj")?,
                ),
                schedule: Schedule::from_json(kv.req("schedule")?)?,
            });
        }
        if knots.is_empty() {
            return Err("energy atlas has no knots".to_string());
        }
        for w in knots.windows(2) {
            if w[1].budget.raw() <= w[0].budget.raw() {
                return Err("energy atlas knots not in ascending budget order".to_string());
            }
        }
        Ok(EnergyAtlas { workload, knots })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::ExpContext;
    use crate::ir::tsd::tsd_small;
    use crate::serve::atlas::AtlasConfig;
    use crate::util::json::parse;

    fn small_atlas_cfg() -> AtlasConfig {
        AtlasConfig {
            relax_factor: 8.0,
            growth: 1.5,
            refine_rel_energy: 0.0,
            max_knots: 16,
            ..AtlasConfig::default()
        }
    }

    fn small_energy_cfg() -> EnergyAtlasConfig {
        EnergyAtlasConfig {
            growth: 1.6,
            max_knots: 8,
            bisect_iters: 10,
            ..EnergyAtlasConfig::default()
        }
    }

    struct Built {
        ctx: ExpContext,
        atlas: EnergyAtlas,
    }

    fn built() -> Built {
        let mut ctx = ExpContext::paper();
        ctx.workload = tsd_small();
        let medea = ctx.medea();
        let deadline_atlas =
            ScheduleAtlas::build(&medea, &ctx.workload, &small_atlas_cfg()).unwrap();
        let atlas =
            EnergyAtlas::build(&medea, &ctx.workload, &deadline_atlas, &small_energy_cfg())
                .unwrap();
        Built { ctx, atlas }
    }

    #[test]
    fn knots_are_sorted_and_time_monotone() {
        let b = built();
        assert!(!b.atlas.is_empty());
        assert_eq!(b.atlas.workload, "tsd-small");
        for w in b.atlas.knots().windows(2) {
            assert!(w[1].budget.raw() > w[0].budget.raw());
            assert!(
                w[1].sim_time.raw() < w[0].sim_time.raw(),
                "extra budget must buy simulated time"
            );
        }
    }

    #[test]
    fn every_knot_is_sim_validated() {
        let b = built();
        for k in b.atlas.knots() {
            let sim = simulate(&b.ctx.workload, &b.ctx.platform, &b.ctx.model, &k.schedule);
            assert!(
                sim.active_energy.raw() <= k.budget.raw() * (1.0 + 1e-9),
                "knot {:.1} uJ: sim energy {:.1} uJ over budget",
                k.budget.as_uj(),
                sim.active_energy.as_uj()
            );
            assert!((sim.active_energy.raw() - k.sim_energy.raw()).abs() < 1e-12);
        }
    }

    #[test]
    fn lookup_picks_fastest_fitting_knot() {
        let b = built();
        assert!(b.atlas.len() >= 2, "degenerate energy atlas: {} knots", b.atlas.len());
        let k_lo = &b.atlas.knots()[0];
        let k_hi = &b.atlas.knots()[1];
        let mid = Energy(0.5 * (k_lo.budget.raw() + k_hi.budget.raw()));
        let hit = b.atlas.lookup(mid).unwrap();
        assert!((hit.budget.raw() - k_lo.budget.raw()).abs() < 1e-15);
        // A huge cap resolves to the fastest (last) knot.
        let last = b.atlas.knots().last().unwrap();
        let hit = b.atlas.lookup(last.budget * 50.0).unwrap();
        assert!((hit.budget.raw() - last.budget.raw()).abs() < 1e-15);
    }

    #[test]
    fn below_floor_is_typed() {
        let b = built();
        let bad = b.atlas.floor() * 0.5;
        let err = b.atlas.lookup(bad).unwrap_err();
        assert_eq!(err.floor.raw(), b.atlas.floor().raw());
        assert!(err.to_string().contains("energy floor"));
    }

    #[test]
    fn batch_share_never_exceeds_solo_energy() {
        let b = built();
        for k in b.atlas.knots() {
            let solo = k.batch_energy_per_member(1, 0.85);
            assert!((solo.raw() - k.sim_energy.raw()).abs() < 1e-15);
            for n in 2..=8usize {
                let share = k.batch_energy_per_member(n, 0.85);
                // Batching only ever lowers the per-member charge, so a
                // budget the solo path fits, every batch size fits too.
                assert!(share.raw() <= k.sim_energy.raw() + 1e-15);
                assert!(share.raw() <= k.batch_energy_per_member(n - 1, 0.85).raw() + 1e-15);
                // And the makespan grows sublinearly off the sim anchor.
                assert!(k.batch_makespan(n, 0.85).raw() > k.batch_makespan(n - 1, 0.85).raw());
            }
        }
    }

    #[test]
    fn json_round_trip() {
        let b = built();
        let text = b.atlas.to_json().to_pretty();
        let back = EnergyAtlas::from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(back.len(), b.atlas.len());
        assert_eq!(back.workload, b.atlas.workload);
        let cap = b.atlas.floor() * 1.7;
        let a = b.atlas.resolve(cap).unwrap();
        let c = back.resolve(cap).unwrap();
        assert_eq!(a.decisions.len(), c.decisions.len());
        assert!((a.active_energy().raw() - c.active_energy().raw()).abs() < 1e-15);
    }
}
