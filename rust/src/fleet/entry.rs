//! One fleet library entry: everything needed to serve a (platform,
//! workload) pair.
//!
//! An entry bundles the deadline atlas and the energy-budget atlas with the
//! resolved platform description, cycle model, and workload — the read-only
//! state a pool worker needs to replay any resolved schedule on the
//! event-level simulator. Entries are built from *preset names*
//! ([`crate::fleet::catalog`]) and keyed by *content*
//! ([`crate::fleet::key`]): the persisted form stores both, and loading
//! fails closed when a preset's constants have drifted since the entry was
//! built (a stale atlas must be rebuilt, never served).

use super::catalog;
use super::energy::{EnergyAtlas, EnergyAtlasConfig};
use super::key::FleetKey;
use crate::ir::Workload;
use crate::manager::medea::Medea;
use crate::platform::Platform;
use crate::profile::characterize;
use crate::serve::atlas::{AtlasConfig, ScheduleAtlas};
use crate::timing::cycle_model::CycleModel;
use crate::util::json::{Json, JsonObj};

/// Build parameters for a fleet entry (both atlases).
#[derive(Debug, Clone, Default)]
pub struct FleetConfig {
    pub atlas: AtlasConfig,
    pub energy: EnergyAtlasConfig,
}

/// A servable (platform, workload) pair with its precomputed atlases.
#[derive(Debug, Clone)]
pub struct FleetEntry {
    pub key: FleetKey,
    pub platform_preset: String,
    pub workload_preset: String,
    pub platform: Platform,
    pub model: CycleModel,
    pub workload: Workload,
    pub atlas: ScheduleAtlas,
    pub energy: EnergyAtlas,
}

impl FleetEntry {
    /// Characterize the preset pair and sweep both atlases.
    pub fn build(
        platform_preset: &str,
        workload_preset: &str,
        cfg: &FleetConfig,
    ) -> Result<FleetEntry, String> {
        let (platform, model) = catalog::platform_preset(platform_preset)
            .ok_or_else(|| format!("unknown platform preset `{platform_preset}`"))?;
        let workload = catalog::workload_preset(workload_preset)
            .ok_or_else(|| format!("unknown workload preset `{workload_preset}`"))?;
        let profiles = characterize(&platform, &model);
        let medea = Medea::new(&platform, &profiles, &model);
        let atlas = ScheduleAtlas::build(&medea, &workload, &cfg.atlas)
            .map_err(|e| format!("{platform_preset}/{workload_preset}: atlas build failed: {e}"))?;
        let energy = EnergyAtlas::build(&medea, &workload, &atlas, &cfg.energy).map_err(|e| {
            format!("{platform_preset}/{workload_preset}: energy atlas build failed: {e}")
        })?;
        let key = FleetKey::of(&platform, &workload);
        Ok(FleetEntry {
            key,
            platform_preset: platform_preset.to_string(),
            workload_preset: workload_preset.to_string(),
            platform,
            model,
            workload,
            atlas,
            energy,
        })
    }

    // ---- JSON ----------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("key", self.key.to_string());
        o.insert("platform_preset", self.platform_preset.clone());
        o.insert("workload_preset", self.workload_preset.clone());
        o.insert("atlas", self.atlas.to_json());
        o.insert("energy", self.energy.to_json());
        Json::Obj(o)
    }

    /// Re-resolve the presets and verify the stored content key still
    /// matches — the library's staleness check: if the platform constants or
    /// the workload definition drifted since this entry was built, its
    /// schedules no longer describe the hardware and the entry must be
    /// rebuilt.
    pub fn from_json(v: &Json) -> Result<FleetEntry, String> {
        let platform_preset = v
            .req("platform_preset")?
            .as_str()
            .ok_or("platform_preset")?
            .to_string();
        let workload_preset = v
            .req("workload_preset")?
            .as_str()
            .ok_or("workload_preset")?
            .to_string();
        let stored_key = FleetKey::parse(v.req("key")?.as_str().ok_or("key")?)
            .ok_or("key: not a fleet key")?;
        let (platform, model) = catalog::platform_preset(&platform_preset)
            .ok_or_else(|| format!("unknown platform preset `{platform_preset}`"))?;
        let workload = catalog::workload_preset(&workload_preset)
            .ok_or_else(|| format!("unknown workload preset `{workload_preset}`"))?;
        let key = FleetKey::of(&platform, &workload);
        if key != stored_key {
            return Err(format!(
                "stale entry for {platform_preset}/{workload_preset}: stored key {stored_key} \
                 no longer matches current content key {key}; rebuild the entry"
            ));
        }
        let atlas = ScheduleAtlas::from_json(v.req("atlas")?)?;
        if atlas.workload != workload.name {
            return Err(format!(
                "entry atlas was built for workload `{}`, preset resolves to `{}`",
                atlas.workload, workload.name
            ));
        }
        let energy = EnergyAtlas::from_json(v.req("energy")?)?;
        if energy.workload != workload.name {
            return Err(format!(
                "entry energy atlas was built for workload `{}`, preset resolves to `{}`",
                energy.workload, workload.name
            ));
        }
        Ok(FleetEntry {
            key,
            platform_preset,
            workload_preset,
            platform,
            model,
            workload,
            atlas,
            energy,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn fast_cfg() -> FleetConfig {
        FleetConfig {
            atlas: AtlasConfig {
                relax_factor: 6.0,
                growth: 1.7,
                refine_rel_energy: 0.0,
                max_knots: 12,
                ..AtlasConfig::default()
            },
            energy: EnergyAtlasConfig {
                growth: 1.7,
                max_knots: 6,
                bisect_iters: 10,
                ..EnergyAtlasConfig::default()
            },
        }
    }

    #[test]
    fn build_and_round_trip() {
        let entry = FleetEntry::build("heeptimize", "tsd-small", &fast_cfg()).unwrap();
        assert_eq!(entry.platform.name, "heeptimize");
        assert_eq!(entry.workload.name, "tsd-small");
        assert!(!entry.atlas.is_empty() && !entry.energy.is_empty());

        let text = entry.to_json().to_pretty();
        let back = FleetEntry::from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(back.key, entry.key);
        assert_eq!(back.atlas.len(), entry.atlas.len());
        assert_eq!(back.energy.len(), entry.energy.len());
    }

    #[test]
    fn drifted_key_is_rejected_as_stale() {
        let entry = FleetEntry::build("heeptimize", "tsd-small", &fast_cfg()).unwrap();
        let mut j = entry.to_json();
        if let Json::Obj(ref mut o) = j {
            o.insert("key", "0000000000000000-0000000000000000");
        }
        let err = FleetEntry::from_json(&j).unwrap_err();
        assert!(err.contains("stale"), "{err}");
    }

    #[test]
    fn unknown_presets_fail_to_build() {
        assert!(FleetEntry::build("no-such-soc", "tsd-small", &fast_cfg()).is_err());
        assert!(FleetEntry::build("heeptimize", "no-such-net", &fast_cfg()).is_err());
    }
}
