//! Canonical keying: content hashes for platforms and workloads.
//!
//! A fleet library is indexed by *what* is being served, not what it is
//! called: two platform descriptions that differ only in display names (or
//! two identical networks exported under different model names) must map to
//! the same atlas. Keys are therefore FNV-1a hashes over a **canonical JSON
//! projection** of each description — the structural fields that feed the
//! characterization and the solver, with every free-form label stripped.
//! The JSON codec emits deterministically (insertion-ordered keys, shortest
//! round-trippable numbers), so the projection doubles as a stable
//! serialization fingerprint across processes and library files.

use crate::ir::Workload;
use crate::platform::loader::platform_to_json;
use crate::platform::Platform;
use crate::util::json::{Json, JsonObj};
use std::fmt;

/// 64-bit FNV-1a over a byte string.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn parse_hex16(s: &str) -> Option<u64> {
    if s.len() != 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

/// Copy a subset of fields from a JSON object, preserving canonical order.
fn project(v: &Json, keys: &[&str]) -> Json {
    let mut o = JsonObj::new();
    for &key in keys {
        if let Some(field) = v.get(key) {
            o.insert(key, field.clone());
        }
    }
    Json::Obj(o)
}

/// Content hash of a workload: kernel types, widths, shapes, and the coarse
/// group partition — kernel and group *names* are display labels and do not
/// participate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct WorkloadHash(pub u64);

impl WorkloadHash {
    pub fn of(workload: &Workload) -> WorkloadHash {
        let full = workload.to_json();
        let kernels: Vec<Json> = full
            .get("kernels")
            .and_then(|k| k.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(|kv| project(kv, &["type", "dw", "shape"]))
            .collect();
        let groups: Vec<Json> = full
            .get("groups")
            .and_then(|g| g.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(|gv| project(gv, &["start", "end"]))
            .collect();
        let mut o = JsonObj::new();
        o.insert("kernels", Json::Arr(kernels));
        o.insert("groups", Json::Arr(groups));
        WorkloadHash(fnv1a64(Json::Obj(o).to_compact().as_bytes()))
    }
}

impl fmt::Display for WorkloadHash {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

/// Content fingerprint of a platform: PE classes and physical constants,
/// V-F table, memories, constraints — platform and PE *names* do not
/// participate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PlatformFingerprint(pub u64);

impl PlatformFingerprint {
    pub fn of(platform: &Platform) -> PlatformFingerprint {
        let full = platform_to_json(platform);
        let mut o = JsonObj::new();
        for key in ["l2_bytes", "sleep_power_uw", "vf_switch_cycles", "active_base", "vf"] {
            if let Some(field) = full.get(key) {
                o.insert(key, field.clone());
            }
        }
        let pes: Vec<Json> = full
            .get("pes")
            .and_then(|p| p.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(|pv| project(pv, &["id", "class", "lm_bytes", "dma", "power"]))
            .collect();
        o.insert("pes", Json::Arr(pes));
        if let Some(cons) = full.get("constraints") {
            o.insert("constraints", cons.clone());
        }
        PlatformFingerprint(fnv1a64(Json::Obj(o).to_compact().as_bytes()))
    }
}

impl fmt::Display for PlatformFingerprint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

/// The library index key: one atlas per (platform, workload) content pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FleetKey {
    pub platform: PlatformFingerprint,
    pub workload: WorkloadHash,
}

impl FleetKey {
    pub fn of(platform: &Platform, workload: &Workload) -> FleetKey {
        FleetKey {
            platform: PlatformFingerprint::of(platform),
            workload: WorkloadHash::of(workload),
        }
    }

    /// Parse the `Display` form (`<platform16hex>-<workload16hex>`), which
    /// also names library entry files on disk.
    pub fn parse(s: &str) -> Option<FleetKey> {
        let (p, w) = s.split_once('-')?;
        Some(FleetKey {
            platform: PlatformFingerprint(parse_hex16(p)?),
            workload: WorkloadHash(parse_hex16(w)?),
        })
    }
}

impl fmt::Display for FleetKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}-{}", self.platform, self.workload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::tsd::{tsd_core, tsd_small, TsdParams};
    use crate::platform::heeptimize::heeptimize;
    use crate::platform::presets::heeptimize_hp;

    #[test]
    fn renaming_does_not_change_keys() {
        let mut p = heeptimize();
        let fp_a = PlatformFingerprint::of(&p);
        p.name = "rebadged-silicon".into();
        p.pes[0].name = "host".into();
        assert_eq!(PlatformFingerprint::of(&p), fp_a);

        let mut w = tsd_core(&TsdParams::default());
        let wh_a = WorkloadHash::of(&w);
        w.name = "tsd-export-v2".into();
        assert_eq!(WorkloadHash::of(&w), wh_a);
    }

    #[test]
    fn distinct_content_gets_distinct_keys() {
        assert_ne!(
            PlatformFingerprint::of(&heeptimize()),
            PlatformFingerprint::of(&heeptimize_hp())
        );
        assert_ne!(
            WorkloadHash::of(&tsd_core(&TsdParams::default())),
            WorkloadHash::of(&tsd_small())
        );
    }

    #[test]
    fn key_display_round_trips() {
        let key = FleetKey::of(&heeptimize(), &tsd_small());
        let text = key.to_string();
        assert_eq!(text.len(), 33);
        assert_eq!(FleetKey::parse(&text), Some(key));
        assert_eq!(FleetKey::parse("nonsense"), None);
        assert_eq!(FleetKey::parse("0123-4567"), None);
    }

    #[test]
    fn fingerprint_is_stable_across_calls() {
        assert_eq!(
            PlatformFingerprint::of(&heeptimize()),
            PlatformFingerprint::of(&heeptimize())
        );
        assert_eq!(
            WorkloadHash::of(&tsd_small()),
            WorkloadHash::of(&tsd_small())
        );
    }
}
