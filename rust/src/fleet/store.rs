//! The on-disk fleet library: a directory of entries plus an index manifest.
//!
//! Layout:
//!
//! ```text
//! fleet-lib/
//!   index.json                  # { version, epoch, entries: [meta…] }
//!   entries/<key>.json          # one FleetEntry per (platform, workload)
//! ```
//!
//! All writes are atomic at the file level (write to `*.tmp`, then rename),
//! so a crashed `fleet swap` leaves either the old or the new entry — never
//! a torn one. Loading skips entries whose content key no longer matches the
//! current presets (staleness, see [`crate::fleet::entry`]) with a warning,
//! so a library survives preset drift by serving what is still valid.

use super::entry::FleetEntry;
use super::key::FleetKey;
use super::registry::FleetRegistry;
use crate::util::json::{parse, Json, JsonObj};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Index manifest file name.
pub const INDEX_FILE: &str = "index.json";

/// Subdirectory holding entry files.
pub const ENTRY_DIR: &str = "entries";

const VERSION: u64 = 1;

/// Path of one entry file within a library directory.
pub fn entry_path(dir: &Path, entry: &FleetEntry) -> PathBuf {
    dir.join(ENTRY_DIR).join(format!("{}.json", entry.key))
}

fn atomic_write(path: &Path, contents: &str) -> Result<(), String> {
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, contents).map_err(|e| format!("write {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("rename {} -> {}: {e}", tmp.display(), path.display()))
}

fn entry_meta(entry: &FleetEntry) -> Json {
    let mut o = JsonObj::new();
    o.insert("key", entry.key.to_string());
    o.insert("platform_preset", entry.platform_preset.clone());
    o.insert("workload_preset", entry.workload_preset.clone());
    o.insert("file", format!("{ENTRY_DIR}/{}.json", entry.key));
    o.insert("knots", entry.atlas.len());
    o.insert("energy_knots", entry.energy.len());
    o.insert("floor_ms", entry.atlas.floor().as_ms());
    o.insert("energy_floor_uj", entry.energy.floor().as_uj());
    Json::Obj(o)
}

fn index_json(metas: Vec<Json>, epoch: u64) -> Json {
    let mut o = JsonObj::new();
    o.insert("version", VERSION);
    o.insert("epoch", epoch);
    o.insert("entries", Json::Arr(metas));
    Json::Obj(o)
}

/// Write one entry file atomically (no index update).
pub fn write_entry(dir: &Path, entry: &FleetEntry) -> Result<PathBuf, String> {
    let entries_dir = dir.join(ENTRY_DIR);
    std::fs::create_dir_all(&entries_dir)
        .map_err(|e| format!("create {}: {e}", entries_dir.display()))?;
    let path = entry_path(dir, entry);
    atomic_write(&path, &entry.to_json().to_pretty())?;
    Ok(path)
}

/// Persist a whole registry as a library directory.
pub fn save_library(dir: &Path, registry: &FleetRegistry) -> Result<(), String> {
    let mut metas = Vec::new();
    for resolved in registry.entries() {
        write_entry(dir, &resolved.entry)?;
        metas.push(entry_meta(&resolved.entry));
    }
    atomic_write(
        &dir.join(INDEX_FILE),
        &index_json(metas, registry.epoch()).to_pretty(),
    )
}

/// Load a library directory into a fresh registry. Entries that fail the
/// staleness check (or fail to parse) are skipped with a warning; the load
/// only errors when the index itself is unreadable.
pub fn load_library(dir: &Path) -> Result<FleetRegistry, String> {
    let index_path = dir.join(INDEX_FILE);
    let text = std::fs::read_to_string(&index_path)
        .map_err(|e| format!("read {}: {e}", index_path.display()))?;
    let index = parse(&text).map_err(|e| e.to_string())?;
    let version = index.req("version")?.as_u64().ok_or("version")?;
    if version != VERSION {
        return Err(format!("unsupported fleet library version {version}"));
    }
    let epoch = index.req("epoch")?.as_u64().ok_or("epoch")?;

    let registry = FleetRegistry::new();
    for meta in index.req("entries")?.as_arr().ok_or("entries")? {
        let file = meta.req("file")?.as_str().ok_or("file")?;
        let path = dir.join(file);
        let loaded = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))
            .and_then(|t| parse(&t).map_err(|e| e.to_string()))
            .and_then(|v| FleetEntry::from_json(&v));
        match loaded {
            Ok(entry) => {
                registry.publish(entry);
            }
            Err(e) => {
                crate::log_warn!("fleet library: skipping {}: {e}", path.display());
            }
        }
    }
    registry.advance_epoch_to(epoch);
    Ok(registry)
}

/// Atomically replace (or add) one entry in a persisted library and bump the
/// index epoch. Returns the new epoch. This is the on-disk counterpart of
/// [`FleetRegistry::publish`]: a running pool that loaded the library keeps
/// serving its in-memory entries until it republishes from disk.
pub fn swap_entry(dir: &Path, entry: &FleetEntry) -> Result<u64, String> {
    let index_path = dir.join(INDEX_FILE);
    let (mut metas, epoch) = if index_path.exists() {
        let text = std::fs::read_to_string(&index_path)
            .map_err(|e| format!("read {}: {e}", index_path.display()))?;
        let index = parse(&text).map_err(|e| e.to_string())?;
        let epoch = index.req("epoch")?.as_u64().ok_or("epoch")?;
        let metas: Vec<Json> = index
            .req("entries")?
            .as_arr()
            .ok_or("entries")?
            .to_vec();
        (metas, epoch)
    } else {
        (Vec::new(), 0)
    };

    write_entry(dir, entry)?;
    let key = entry.key.to_string();
    // Supersede by key *and* by preset pair: when a preset's content drifted
    // since the last build, the rebuilt entry lands under a new key, and the
    // old (now stale) row plus its entry file must not linger in the library.
    metas.retain(|m| {
        let same_key = m.get("key").and_then(|k| k.as_str()) == Some(key.as_str());
        let same_presets = m.get("platform_preset").and_then(|v| v.as_str())
            == Some(entry.platform_preset.as_str())
            && m.get("workload_preset").and_then(|v| v.as_str())
                == Some(entry.workload_preset.as_str());
        if same_presets && !same_key {
            if let Some(file) = m.get("file").and_then(|f| f.as_str()) {
                let _ = std::fs::remove_file(dir.join(file));
            }
        }
        !(same_key || same_presets)
    });
    metas.push(entry_meta(entry));
    let epoch = epoch + 1;
    atomic_write(&index_path, &index_json(metas, epoch).to_pretty())?;
    Ok(epoch)
}

/// Read just the index epoch — the cheap probe a reload watcher polls.
pub fn index_epoch(dir: &Path) -> Result<u64, String> {
    let index_path = dir.join(INDEX_FILE);
    let text = std::fs::read_to_string(&index_path)
        .map_err(|e| format!("read {}: {e}", index_path.display()))?;
    let index = parse(&text).map_err(|e| e.to_string())?;
    let epoch = index.req("epoch")?.as_u64().ok_or("epoch")?;
    Ok(epoch)
}

/// Re-read a library's `index.json` and republish new or rebuilt entries
/// into a *running* registry — the bridge between an on-disk [`swap_entry`]
/// and a live [`crate::fleet::pool::FleetPool`]. Entries are content-keyed,
/// so an index row whose key the registry already resolves is skipped
/// without touching its slot; unknown keys are parsed and published exactly
/// as a restart-time [`load_library`] would, while queued and executing
/// jobs keep the entry `Arc` they were admitted under either way. Finally
/// the registry epoch advances to the index epoch (monotone, so a stale
/// index can never roll a live registry back). Returns how many entries
/// were published.
pub fn reload_library_into(dir: &Path, registry: &FleetRegistry) -> Result<usize, String> {
    let index_path = dir.join(INDEX_FILE);
    let text = std::fs::read_to_string(&index_path)
        .map_err(|e| format!("read {}: {e}", index_path.display()))?;
    let index = parse(&text).map_err(|e| e.to_string())?;
    let version = index.req("version")?.as_u64().ok_or("version")?;
    if version != VERSION {
        return Err(format!("unsupported fleet library version {version}"));
    }
    let epoch = index.req("epoch")?.as_u64().ok_or("epoch")?;

    let mut published = 0;
    for meta in index.req("entries")?.as_arr().ok_or("entries")? {
        let key = meta.req("key")?.as_str().ok_or("key")?;
        // Content keys are immutable: a key the registry already resolves
        // is this exact entry, live — skip without re-reading its file.
        let known = match FleetKey::parse(key) {
            Some(k) => registry.resolve(&k).is_some(),
            None => false,
        };
        if known {
            continue;
        }
        let file = meta.req("file")?.as_str().ok_or("file")?;
        let path = dir.join(file);
        let loaded = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))
            .and_then(|t| parse(&t).map_err(|e| e.to_string()))
            .and_then(|v| FleetEntry::from_json(&v));
        match loaded {
            Ok(entry) => {
                registry.publish(entry);
                published += 1;
            }
            Err(e) => {
                crate::log_warn!("fleet reload: skipping {}: {e}", path.display());
            }
        }
    }
    registry.advance_epoch_to(epoch);
    Ok(published)
}

/// Handle for a running [`watch_library`] thread. Dropping it (or calling
/// [`LibraryWatcher::stop`]) signals the watcher and joins it.
pub struct LibraryWatcher {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl LibraryWatcher {
    /// Signal the watcher to stop and join its thread.
    pub fn stop(mut self) {
        self.shut_down();
    }

    fn shut_down(&mut self) {
        // ordering: relaxed stop flag — the watcher re-reads it at least
        // once per sleep chunk, and the join below is the real barrier.
        self.stop.store(true, Ordering::Relaxed);
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for LibraryWatcher {
    fn drop(&mut self) {
        self.shut_down();
    }
}

/// Sleep up to `total`, waking early when `stop` is raised. Chunked so a
/// long watch interval never delays shutdown by more than ~200 ms.
fn sleep_unless_stopped(stop: &AtomicBool, total: Duration) {
    let mut remaining = total;
    while !remaining.is_zero() {
        // ordering: relaxed stop flag, see `LibraryWatcher::shut_down`.
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let chunk = remaining.min(Duration::from_millis(200));
        std::thread::sleep(chunk);
        remaining = remaining.saturating_sub(chunk);
    }
}

/// Spawn a polling watcher bridging on-disk [`swap_entry`] writes into a
/// running registry: every `interval` it re-reads the index epoch and, when
/// the index has advanced past the registry, runs [`reload_library_into`].
/// Polling (not inotify) keeps it portable and dependency-free; the index
/// is written atomically, so a torn mid-write read is impossible. An
/// unreadable or stale index is logged and retried on the next tick — a
/// watcher never takes down serving.
pub fn watch_library(
    dir: &Path,
    registry: Arc<FleetRegistry>,
    interval: Duration,
) -> LibraryWatcher {
    let stop = Arc::new(AtomicBool::new(false));
    let flag = stop.clone();
    let dir = dir.to_path_buf();
    let interval = interval.max(Duration::from_millis(10));
    let handle = std::thread::Builder::new()
        .name("medea-fleet-watch".into())
        .spawn(move || {
            // ordering: relaxed stop flag, see `LibraryWatcher::shut_down`.
            while !flag.load(Ordering::Relaxed) {
                match index_epoch(&dir) {
                    Ok(epoch) if epoch > registry.epoch() => {
                        match reload_library_into(&dir, &registry) {
                            Ok(published) => {
                                crate::log_info!(
                                    "fleet watch: index epoch {epoch}, republished \
                                     {published} entr{}",
                                    if published == 1 { "y" } else { "ies" }
                                );
                            }
                            Err(e) => crate::log_warn!("fleet watch: reload failed: {e}"),
                        }
                    }
                    Ok(_) => {}
                    Err(e) => crate::log_warn!("fleet watch: {e}"),
                }
                sleep_unless_stopped(&flag, interval);
            }
        })
        .map_err(|e| crate::log_warn!("fleet watch: spawn failed: {e}"))
        .ok();
    LibraryWatcher { stop, handle }
}
