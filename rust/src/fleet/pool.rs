//! The fleet serving pool: one worker pool, many atlases.
//!
//! Where [`crate::serve::pool::ServePool`] serves a single frozen atlas,
//! this pool routes every request through a shared [`FleetRegistry`]: a
//! request arrives tagged with a platform preset and a workload preset plus
//! a [`Demand`] (deadline *or* energy cap), resolves its entry and schedule
//! in `O(log n)` at submit time, and carries the entry's `Arc` with the job.
//! That submit-time binding is what makes hot swaps safe: publishing a
//! rebuilt atlas changes what subsequent lookups resolve, while queued and
//! executing jobs keep the entry they were admitted under — nothing drains,
//! nothing is rejected.
//!
//! Dispatch, admission, and shutdown follow the serve pool: per-worker EDF
//! queues with typed shedding, [`crate::serve::pool::pick_shard`]'s
//! EDF-aware dispatch heuristic, cross-shard work stealing
//! ([`crate::serve::pool::StealConfig`]: idle workers lift compatible
//! groups — same entry, epoch, and resolved knot — from a backlogged
//! sibling's queue head), graceful drain on shutdown — and batched dequeue
//! ([`crate::serve::batch`]): jobs sharing one `(entry, resolved knot)`
//! identity coalesce into a single dispatch, deadline demands gated by the
//! sim-anchored batch makespan, energy demands by the dual per-member
//! budget-share check.

use super::entry::FleetEntry;
use super::key::FleetKey;
use super::registry::FleetRegistry;
use crate::eeg::synth::EegWindow;
use crate::manager::schedule::Schedule;
use crate::runtime::artifacts::ArtifactManifest;
use crate::runtime::client::Runtime;
use crate::runtime::infer::{Prediction, TsdInference};
use crate::serve::batch::{
    batch_energy_share, batch_makespan, batch_share, member_report, stub_predictions, BatchConfig,
    WindowAutotuner,
};
use crate::serve::metrics::ServeMetrics;
use crate::serve::pool::{
    deadline_us, head_laxity, pick_shard, pop_group, readiness_probe_over, trace_kernel_spans,
    ServeError, Shard, StealConfig, StealMesh,
};
use crate::serve::queue::{Admission, EdfQueue, Rejection};
use crate::sim::replay::{simulate, SimReport};
use crate::telemetry::ledger::{EnergyLedger, LedgerEntrySpec};
use crate::telemetry::trace::{TraceEventKind, TraceRing};
use crate::telemetry::{TelemetryConfig, TelemetryRegistry, WorkerShard};
use crate::util::error::{anyhow, Result};
use crate::util::units::{Energy, Time};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// What a request asks of its atlas entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Demand {
    /// Meet this deadline with minimal energy (deadline atlas).
    Deadline(Time),
    /// Stay within this active-energy cap, as fast as possible (energy
    /// atlas).
    EnergyBudget(Energy),
}

/// Pool sizing (atlases are prebuilt in the registry, so no sweep config).
#[derive(Debug, Clone)]
pub struct FleetPoolConfig {
    /// Worker thread count (≥ 1).
    pub workers: usize,
    /// Per-worker admission queue capacity.
    pub queue_capacity: usize,
    /// Directory holding the AOT artifacts (`manifest.json`); when absent
    /// or unloadable the pool serves schedule-only responses.
    pub artifact_dir: PathBuf,
    /// Batched-admission knobs (`max_batch == 1` is the solo legacy path).
    pub batch: BatchConfig,
    /// Cross-shard work-stealing knobs (enabled by default).
    pub steal: StealConfig,
    /// Telemetry knobs (`trace_events` sizes the dispatch-event ring; the
    /// metrics registry itself is always on — it *is* the metrics path).
    pub telemetry: TelemetryConfig,
}

impl Default for FleetPoolConfig {
    fn default() -> Self {
        FleetPoolConfig {
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .clamp(1, 4),
            queue_capacity: 256,
            artifact_dir: ArtifactManifest::default_dir(),
            batch: BatchConfig::default(),
            steal: StealConfig::default(),
            telemetry: TelemetryConfig::default(),
        }
    }
}

/// The response: functional prediction + simulated on-device execution, plus
/// the routing provenance (entry, epoch, covering knot).
#[derive(Debug)]
pub struct FleetOutcome {
    pub window_index: usize,
    pub prediction: Prediction,
    pub sim: SimReport,
    pub scheduler: String,
    /// Platform preset that served this request.
    pub platform: String,
    /// Workload preset that served this request.
    pub workload: String,
    /// Registry epoch of the entry this request was admitted under — stays
    /// the admission-time epoch across hot swaps.
    pub epoch: u64,
    pub demand: Demand,
    /// Deadline of the schedule actually executed (the covering knot's for
    /// deadline demands, the dual solve's converged deadline for energy
    /// demands).
    pub knot_deadline: Time,
    /// Covering budget knot (energy demands only).
    pub knot_budget: Option<Energy>,
    /// How many requests shared this dispatch (1 = solo). Batch members are
    /// charged amortized per-member active time/energy shares; demands and
    /// sleep windows are judged against the batch completion time.
    pub batch_size: usize,
    /// Submission-to-response latency, queue wait included.
    pub host_latency: Duration,
}

/// Handle for one in-flight request.
#[derive(Debug)]
pub struct FleetTicket {
    rx: mpsc::Receiver<std::result::Result<FleetOutcome, ServeError>>,
}

impl FleetTicket {
    /// Block until the worker responds.
    pub fn wait(self) -> std::result::Result<FleetOutcome, ServeError> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err(ServeError::Internal("worker dropped response".into())))
    }
}

struct Job {
    /// Pool-unique request id ([`TelemetryRegistry::next_request_id`]),
    /// threaded through every trace event this request produces.
    id: u64,
    window: EegWindow,
    schedule: Schedule,
    entry: Arc<FleetEntry>,
    epoch: u64,
    demand: Demand,
    knot_deadline: Time,
    knot_budget: Option<Energy>,
    /// Batch identity within the entry: jobs coalesce only when they carry
    /// the same resolved schedule — `(demand kind, knot coordinate bits)`.
    /// The dispatch key additionally includes the admission epoch, so jobs
    /// straddling a hot swap never coalesce: a rebuilt entry can reproduce
    /// a knot coordinate with a different schedule.
    batch_key: (u8, u64),
    /// Sim-validated solo active time of the resolved knot: the anchor of
    /// the batch-makespan check.
    unit_time: Time,
    /// Solo active energy of the resolved knot (sim-validated for energy
    /// knots): the anchor of the dual per-member budget-share check.
    unit_energy: Energy,
    submitted: Instant,
    reply: mpsc::Sender<std::result::Result<FleetOutcome, ServeError>>,
}

/// A running fleet pool. Dropping it shuts workers down (discarding
/// metrics); call [`FleetPool::shutdown`] to collect the aggregate instead.
pub struct FleetPool {
    registry: Arc<FleetRegistry>,
    shards: Vec<Arc<Shard<Job>>>,
    /// Steal-wake notifier shared with the workers: submit posts wakes to
    /// idle siblings through it when a shard's backlog crosses the
    /// threshold.
    mesh: Arc<StealMesh>,
    workers: Vec<JoinHandle<()>>,
    next: AtomicUsize,
    /// The live metrics registry: admission counts sheds here, workers
    /// record into their shards, and both [`FleetPool::live_metrics`] and
    /// [`FleetPool::shutdown`] read the same state.
    telemetry: Arc<TelemetryRegistry>,
    /// Dispatch-event ring; `None` unless `telemetry.trace_events > 0`.
    trace: Option<Arc<TraceRing>>,
}

impl FleetPool {
    /// Spawn workers over a prebuilt registry. The registry stays shared:
    /// publishing into it while the pool runs hot-swaps what subsequent
    /// requests resolve.
    pub fn start(registry: Arc<FleetRegistry>, config: FleetPoolConfig) -> Result<FleetPool> {
        let n = config.workers.max(1);
        let batch = config.batch.clone().sanitized();
        let steal = config.steal.clone();
        // The fleet pool serves *many* (platform, workload) entries through
        // one registry, so its telemetry labels are the fleet itself.
        let telemetry = Arc::new(TelemetryRegistry::new("fleet", "multi", n));
        let trace = (config.telemetry.trace_events > 0)
            .then(|| Arc::new(TraceRing::new(config.telemetry.trace_events)));
        // Energy attribution tables, one entry per registry entry at start
        // time. The knot table merges the deadline atlas's knot deadlines
        // with the energy atlas's converged schedule deadlines (the knot
        // identity an energy-demand dispatch carries), sorted and deduped
        // bitwise. Entries hot-swapped in later are counted unattributed
        // rather than resized — the tables stay fixed so the dispatch path
        // stays allocation-free.
        let specs: Vec<LedgerEntrySpec> = registry
            .entries()
            .iter()
            .map(|resolved| {
                let e = &resolved.entry;
                let mut knots: Vec<Time> =
                    e.atlas.knots().iter().map(|k| k.deadline).collect();
                knots.extend(e.energy.knots().iter().map(|k| k.schedule.deadline));
                knots.sort_by(|a, b| a.raw().total_cmp(&b.raw()));
                knots.dedup_by(|a, b| a.raw().to_bits() == b.raw().to_bits());
                let mut spec =
                    LedgerEntrySpec::new(&e.platform, e.workload_preset.clone(), knots);
                // Attribution keys on preset names (what dispatch carries),
                // not the platform's display name.
                spec.platform = e.platform_preset.clone();
                spec
            })
            .collect();
        let ledger = EnergyLedger::new(n, &specs);
        telemetry.install_ledger(ledger.clone());
        // Every shard exists before any worker spawns: workers see the full
        // sibling set, so stealing never races pool construction.
        let shards: Vec<Arc<Shard<Job>>> = (0..n)
            .map(|_| Arc::new(Shard::new(EdfQueue::new(config.queue_capacity.max(1)))))
            .collect();
        let mesh = Arc::new(StealMesh::new(n, &steal));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let handle = std::thread::Builder::new()
                .name(format!("medea-fleet-{i}"))
                .spawn({
                    let shards = shards.clone();
                    let mesh = mesh.clone();
                    let dir = config.artifact_dir.clone();
                    let batch = batch.clone();
                    let steal = steal.clone();
                    let tel = telemetry.worker(i);
                    let trace = trace.clone();
                    let ledger = ledger.clone();
                    move || {
                        worker_loop(
                            &shards,
                            i,
                            &dir,
                            &batch,
                            &steal,
                            &mesh,
                            &tel,
                            trace.as_deref(),
                            &ledger,
                        )
                    }
                })
                .map_err(|e| anyhow!("spawn fleet worker {i}: {e}"))?;
            workers.push(handle);
        }
        Ok(FleetPool {
            registry,
            shards,
            mesh,
            workers,
            next: AtomicUsize::new(0),
            telemetry,
            trace,
        })
    }

    pub fn registry(&self) -> &Arc<FleetRegistry> {
        &self.registry
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Route, resolve, and enqueue one request. The atlas lookup happens
    /// here — before admission — so infeasible demands and unknown targets
    /// shed with a typed [`Rejection`] and never occupy queue space.
    pub fn submit(
        &self,
        platform: &str,
        workload: &str,
        window: EegWindow,
        demand: Demand,
    ) -> std::result::Result<FleetTicket, Rejection> {
        // Id allocated before resolution so resolve-time sheds carry one
        // into the trace too.
        let id = self.telemetry.next_request_id();
        let Some(resolved) = self.registry.resolve_named(platform, workload) else {
            let reason = Rejection::UnknownEntry {
                platform: platform.to_string(),
                workload: workload.to_string(),
            };
            self.shed(0, id, &reason);
            return Err(reason);
        };
        let entry = resolved.entry;
        let (schedule, knot_deadline, knot_budget, batch_key, unit_time, unit_energy) =
            match demand {
                Demand::Deadline(deadline) => match entry.atlas.lookup(deadline) {
                    Ok(knot) => {
                        let mut schedule = knot.schedule.clone();
                        schedule.deadline = deadline;
                        (
                            schedule,
                            knot.deadline,
                            None,
                            (0u8, knot.deadline.raw().to_bits()),
                            knot.sim_time,
                            knot.schedule.active_energy(),
                        )
                    }
                    Err(miss) => {
                        let reason = Rejection::BelowFloor {
                            requested: miss.requested,
                            floor: miss.floor,
                        };
                        self.shed(0, id, &reason);
                        return Err(reason);
                    }
                },
                Demand::EnergyBudget(budget) => match entry.energy.lookup(budget) {
                    Ok(knot) => (
                        knot.schedule.clone(),
                        knot.schedule.deadline,
                        Some(knot.budget),
                        (1u8, knot.budget.raw().to_bits()),
                        knot.sim_time,
                        knot.sim_energy,
                    ),
                    Err(miss) => {
                        let reason = Rejection::BelowEnergyFloor {
                            requested: miss.requested,
                            floor: miss.floor,
                        };
                        self.shed(0, id, &reason);
                        return Err(reason);
                    }
                },
            };

        // ordering: round-robin ticket and depth hints are heuristics for
        // shard choice only — stale reads just pick a slightly busier
        // shard; the queue itself is protected by the shard mutex.
        let rr = self.next.fetch_add(1, Ordering::Relaxed);
        let depths = self.shards.iter().map(|s| s.depth.load(Ordering::Relaxed));
        let idx = pick_shard(depths, rr);
        let shard = &self.shards[idx];
        let (tx, rx) = mpsc::channel();
        // EDF priority: the schedule's effective deadline (energy demands
        // queue at the urgency their dual solve converged to).
        let priority = schedule.deadline;
        let job = Job {
            id,
            window,
            schedule,
            entry,
            epoch: resolved.epoch,
            demand,
            knot_deadline,
            knot_budget,
            batch_key,
            unit_time,
            unit_energy,
            submitted: Instant::now(),
            reply: tx,
        };
        // lint: allow(no-unwrap): a poisoned shard means a worker panicked
        // with the queue in an unknown state; crashing is the safe option.
        let mut st = shard.state.lock().expect("fleet shard lock poisoned");
        if st.stopping {
            drop(st);
            let reason = Rejection::ShuttingDown;
            self.shed(idx, id, &reason);
            return Err(reason);
        }
        let capacity = st.queue.capacity();
        match st.queue.push(priority, job) {
            Admission::Accepted => {
                let depth = st.queue.len();
                // ordering: relaxed depth hint, see the shard pick above.
                shard.depth.store(depth, Ordering::Relaxed);
                self.telemetry.worker(idx).set_queue_depth(depth);
                drop(st);
                shard.ring();
                self.mesh.wake_for_backlog(idx, depth, &self.shards);
                if let Some(ring) = &self.trace {
                    ring.record(TraceEventKind::Enqueue, idx as u32, id, deadline_us(priority));
                }
                Ok(FleetTicket { rx })
            }
            Admission::AcceptedShedding { evicted, .. } => {
                let depth = st.queue.len();
                // ordering: relaxed depth hint, see the shard pick above.
                shard.depth.store(depth, Ordering::Relaxed);
                self.telemetry.worker(idx).set_queue_depth(depth);
                let reason = Rejection::QueueFull { capacity };
                self.shed(idx, evicted.id, &reason);
                let _ = evicted.reply.send(Err(ServeError::Shed(reason)));
                drop(st);
                shard.ring();
                self.mesh.wake_for_backlog(idx, depth, &self.shards);
                if let Some(ring) = &self.trace {
                    ring.record(TraceEventKind::Enqueue, idx as u32, id, deadline_us(priority));
                }
                Ok(FleetTicket { rx })
            }
            Admission::Rejected { reason, .. } => {
                drop(st);
                self.shed(idx, id, &reason);
                Err(reason)
            }
        }
    }

    /// Count + trace one shed (`shard` is 0 for resolve-time sheds, which
    /// happen before a shard is picked).
    fn shed(&self, shard: usize, id: u64, reason: &Rejection) {
        self.telemetry.record_shed(reason);
        if let Some(ring) = &self.trace {
            ring.record(TraceEventKind::Shed, shard as u32, id, reason.code());
        }
    }

    /// Submit and block for the response.
    pub fn infer(
        &self,
        platform: &str,
        workload: &str,
        window: EegWindow,
        demand: Demand,
    ) -> std::result::Result<FleetOutcome, ServeError> {
        match self.submit(platform, workload, window, demand) {
            Ok(ticket) => ticket.wait(),
            Err(rejection) => Err(ServeError::Shed(rejection)),
        }
    }

    fn begin_stop(&self) {
        for shard in &self.shards {
            // lint: allow(no-unwrap): same poisoning rationale as `submit`.
            let mut st = shard.state.lock().expect("fleet shard lock poisoned");
            st.stopping = true;
            drop(st);
            // One waiter per gate (the shard's own worker), so a single
            // token wake reaches everyone affected.
            shard.ring();
        }
    }

    /// The live telemetry registry: what the Prometheus endpoint, the
    /// periodic reporter, and [`FleetPool::live_metrics`] all read.
    pub fn telemetry(&self) -> &Arc<TelemetryRegistry> {
        &self.telemetry
    }

    /// The dispatch-event trace ring, when `telemetry.trace_events > 0`.
    pub fn trace(&self) -> Option<&Arc<TraceRing>> {
        self.trace.as_ref()
    }

    /// A `/readyz` probe over this pool's shards: ready while no shard is
    /// stopping and total queued admissions sit below the 90 % saturation
    /// watermark (see `ServePool::readiness_probe`).
    pub fn readiness_probe(&self) -> crate::telemetry::ReadinessProbe {
        readiness_probe_over(&self.shards)
    }

    /// A [`ServeMetrics`] view of the pool *right now*, without shutting
    /// anything down — the same registry read [`FleetPool::shutdown`]
    /// performs, so live and final percentiles share one arithmetic.
    pub fn live_metrics(&self) -> ServeMetrics {
        ServeMetrics::from_registry(&self.telemetry)
    }

    /// Graceful shutdown: queues drain, workers exit, and the final
    /// aggregate is read from the telemetry registry.
    pub fn shutdown(mut self) -> ServeMetrics {
        self.begin_stop();
        for h in self.workers.drain(..) {
            // lint: allow(no-unwrap): a panicked worker already lost jobs;
            // surfacing the panic at shutdown is deliberate.
            h.join().expect("fleet worker panicked");
        }
        ServeMetrics::from_registry(&self.telemetry)
    }
}

impl Drop for FleetPool {
    fn drop(&mut self) {
        self.begin_stop();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    shards: &[Arc<Shard<Job>>],
    me: usize,
    artifact_dir: &std::path::Path,
    batch: &BatchConfig,
    steal: &StealConfig,
    mesh: &StealMesh,
    tel: &WorkerShard,
    trace: Option<&TraceRing>,
    ledger: &EnergyLedger,
) {
    // One PJRT runtime handle per worker, created on the worker thread.
    let mut runtime = match Runtime::new(artifact_dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            crate::log_warn!("PJRT runtime unavailable ({e}); serving schedule-only responses");
            None
        }
    };
    let infer = TsdInference::default();
    let amort = batch.amortization;

    // Same entry + same epoch + same resolved knot ⇒ one coalesced
    // dispatch. The kind tag keeps deadline- and energy-resolved schedules
    // apart even when knot coordinates collide bitwise; the epoch keeps
    // pre- and post-hot-swap jobs apart, since a rebuilt entry (same
    // content key, different sweep config) can reproduce a knot coordinate
    // with a different schedule. A thief runs this same key — including
    // the hot-swap-epoch batch identity — so stolen groups are exactly the
    // groups the victim's own worker would have formed.
    let key =
        |job: &Job| -> (FleetKey, u64, (u8, u64)) { (job.entry.key, job.epoch, job.batch_key) };
    let grow = |group: &[(Time, Job)], _cand_deadline: Time, cand: &Job| {
        let head = &group[0].1;
        let n = group.len() + 1;
        match head.demand {
            // Deadline members: the batch makespan must fit the *earliest*
            // member deadline (everyone else is laxer in EDF pop order).
            Demand::Deadline(_) => {
                batch_makespan(head.unit_time, n, amort).raw() <= group[0].0.raw()
            }
            // Energy members promise energy, not latency: the dual
            // EnergyAtlas check admits while the amortized per-member
            // share fits every member's requested cap (the share is
            // non-increasing in n, so existing members can only get
            // cheaper).
            Demand::EnergyBudget(_) => {
                let share = batch_energy_share(head.unit_energy, n, amort).raw();
                group
                    .iter()
                    .map(|(_, j)| j)
                    .chain(std::iter::once(cand))
                    .all(|j| match j.demand {
                        Demand::EnergyBudget(cap) => share <= cap.raw(),
                        Demand::Deadline(_) => false, // distinct batch_key kind
                    })
            }
        }
    };
    // Fill-window clamp: the queue priority is the schedule's effective
    // deadline (the dual solve's for energy demands), so the head's laxity
    // bounds how long a straggler wait may delay it.
    let slack = |deadline: Time, job: &Job| head_laxity(deadline, job.unit_time, job.submitted);
    let queued_for = |job: &Job| job.submitted.elapsed();

    // The reusable dispatch-group buffer: sized once for the largest legal
    // batch, so steady-state group formation allocates nothing.
    let mut group: Vec<(Time, Job)> = Vec::with_capacity(batch.max_batch.max(1));
    let mut tuner = WindowAutotuner::new(batch);
    loop {
        group.clear();
        let fill_window = tuner.effective();
        tel.set_batch_window(fill_window);
        let popped = pop_group(
            shards,
            me,
            batch,
            fill_window,
            steal,
            mesh,
            tel,
            &key,
            &grow,
            &slack,
            &queued_for,
            &mut group,
        );
        let Some(popped) = popped else { break };
        if group.is_empty() {
            continue;
        }
        tuner.observe(group.len());
        let exec_start = Instant::now();
        let head_id = group[0].1.id;
        let size = group.len() as u64;
        for (_, job) in &group {
            tel.record_queue_wait(job.submitted.elapsed());
        }
        {
            let (head_deadline, head) = &group[0];
            tel.record_head_laxity(head_laxity(*head_deadline, head.unit_time, head.submitted));
        }
        if popped.stolen {
            tel.record_steal(group.len());
            if let Some(ring) = trace {
                ring.record(TraceEventKind::Steal, me as u32, head_id, size);
            }
        }
        if let Some(ring) = trace {
            if group.len() > 1 {
                ring.record(TraceEventKind::BatchForm, me as u32, head_id, size);
            }
            ring.record(TraceEventKind::Dispatch, me as u32, head_id, size);
        }
        if group.len() == 1 {
            // Solo dispatch: the exact legacy path. `process` consumes the
            // job (the entry `Arc` and schedule ride in it) and hands the
            // reply channel back alongside the outcome. `swap_remove`
            // keeps the buffer's capacity for the next dispatch.
            let (_, job) = group.swap_remove(0);
            let (reply, outcome) =
                process(job, runtime.as_mut(), &infer, ledger, me, exec_start, trace);
            let met = matches!(&outcome, Ok(o) if o.sim.deadline_met);
            if let Ok(o) = &outcome {
                tel.record_batch(1);
                tel.record(
                    o.prediction.seizure,
                    o.sim.deadline_met,
                    o.sim.total_energy().raw(),
                    o.sim.active_time.raw(),
                    o.host_latency,
                );
            }
            if let Some(ring) = trace {
                ring.record(TraceEventKind::Retire, me as u32, head_id, u64::from(met));
            }
            let _ = reply.send(outcome);
        } else {
            process_batch(
                &mut group,
                runtime.as_mut(),
                &infer,
                batch,
                me,
                tel,
                trace,
                ledger,
                exec_start,
            );
        }
        tel.record_dispatch_time(exec_start.elapsed());
    }
}

/// Execute one coalesced dispatch for a fleet batch: one simulated run of
/// the shared schedule (under the head's entry — all members resolved the
/// same content key) and one amortized inference invocation, fanned back
/// out per member.
/// Deadline members get `deadline_met = makespan ≤ their deadline`; energy
/// members get `deadline_met = amortized share ≤ their cap` — each member is
/// judged against the demand it actually made.
/// Drains the caller's reusable group buffer (capacity is retained).
#[allow(clippy::too_many_arguments)]
fn process_batch(
    group: &mut Vec<(Time, Job)>,
    runtime: Option<&mut Runtime>,
    infer: &TsdInference,
    batch: &BatchConfig,
    me: usize,
    tel: &WorkerShard,
    trace: Option<&TraceRing>,
    ledger: &EnergyLedger,
    exec_start: Instant,
) {
    let n = group.len();
    let head = &group[0].1;
    let entry = &head.entry;
    let sim = simulate(&entry.workload, &entry.platform, &entry.model, &head.schedule);
    let share = batch_share(&sim, n, batch.amortization);
    let scheduler = head.schedule.scheduler.clone();

    let predictions: Vec<Prediction> = match runtime {
        Some(rt) => {
            let windows: Vec<&EegWindow> = group.iter().map(|(_, j)| &j.window).collect();
            match infer.infer_staged_batch(rt, &windows) {
                Ok(p) => p,
                Err(e) => {
                    let msg = e.to_string();
                    for (_, job) in group.drain(..) {
                        if let Some(ring) = trace {
                            ring.record(TraceEventKind::Retire, me as u32, job.id, 0);
                        }
                        let _ = job.reply.send(Err(ServeError::Internal(msg.clone())));
                    }
                    return;
                }
            }
        }
        None => stub_predictions(n),
    };

    // Only successful fan-outs count as dispatches (the error path above
    // returns early), keeping batched + solo == recorded requests.
    tel.record_batch(n);
    // Attribute the coalesced dispatch once, under the head's entry (all
    // members share it by batch key). The drift reference is the same
    // sim-anchored batch makespan that admitted the group.
    {
        let head = &group[0].1;
        match ledger.find_entry(&head.entry.platform_preset, &head.entry.workload_preset) {
            Some(idx) => {
                let expected = batch_makespan(head.unit_time, n, batch.amortization);
                let realized = exec_start.elapsed();
                ledger.record_dispatch(
                    me,
                    idx,
                    head.knot_deadline,
                    &head.schedule.decisions,
                    n as u64,
                    realized,
                    expected,
                );
                if let Some(ring) = trace {
                    trace_kernel_spans(ring, me, head.id, &head.schedule.decisions, realized);
                }
            }
            None => ledger.record_unattributed(),
        }
    }
    for ((_, job), prediction) in group.drain(..).zip(predictions) {
        // Each member is judged against the demand it actually made.
        let met = match job.demand {
            Demand::Deadline(d) => share.batch_time.raw() <= d.raw(),
            Demand::EnergyBudget(cap) => share.member_energy.raw() <= cap.raw(),
        };
        // Sleep re-derives against the member's own stamped deadline
        // (requested for deadline demands, the dual solve's for energy
        // demands).
        let member_sim = member_report(
            &sim,
            share,
            job.schedule.deadline,
            job.entry.platform.sleep_power,
            met,
        );
        tel.record(
            prediction.seizure,
            member_sim.deadline_met,
            member_sim.total_energy().raw(),
            member_sim.active_time.raw(),
            job.submitted.elapsed(),
        );
        if let Some(ring) = trace {
            let met = u64::from(member_sim.deadline_met);
            ring.record(TraceEventKind::Retire, me as u32, job.id, met);
        }
        let outcome = FleetOutcome {
            window_index: job.window.index,
            prediction,
            sim: member_sim,
            scheduler: scheduler.clone(),
            platform: job.entry.platform_preset.clone(),
            workload: job.entry.workload_preset.clone(),
            epoch: job.epoch,
            demand: job.demand,
            knot_deadline: job.knot_deadline,
            knot_budget: job.knot_budget,
            batch_size: n,
            host_latency: job.submitted.elapsed(),
        };
        let _ = job.reply.send(Ok(outcome));
    }
}

type Reply = mpsc::Sender<std::result::Result<FleetOutcome, ServeError>>;

#[allow(clippy::too_many_arguments)]
fn process(
    job: Job,
    runtime: Option<&mut Runtime>,
    infer: &TsdInference,
    ledger: &EnergyLedger,
    me: usize,
    exec_start: Instant,
    trace: Option<&TraceRing>,
) -> (Reply, std::result::Result<FleetOutcome, ServeError>) {
    let Job {
        id,
        window,
        schedule,
        entry,
        epoch,
        demand,
        knot_deadline,
        knot_budget,
        batch_key: _,
        unit_time,
        unit_energy: _,
        submitted,
        reply,
    } = job;
    let sim = simulate(&entry.workload, &entry.platform, &entry.model, &schedule);
    let prediction = match runtime {
        Some(rt) => match infer.infer_staged(rt, &window) {
            Ok(p) => p,
            Err(e) => return (reply, Err(ServeError::Internal(e.to_string()))),
        },
        None => Prediction {
            logits: vec![0.0, 0.0],
            class_idx: 0,
            seizure: false,
        },
    };
    // Attribute the successful dispatch. An entry published after pool
    // start has no preallocated tables and counts as unattributed instead.
    match ledger.find_entry(&entry.platform_preset, &entry.workload_preset) {
        Some(idx) => {
            let realized = exec_start.elapsed();
            ledger.record_dispatch(
                me,
                idx,
                knot_deadline,
                &schedule.decisions,
                1,
                realized,
                unit_time,
            );
            if let Some(ring) = trace {
                trace_kernel_spans(ring, me, id, &schedule.decisions, realized);
            }
        }
        None => ledger.record_unattributed(),
    }
    let outcome = FleetOutcome {
        window_index: window.index,
        prediction,
        sim,
        scheduler: schedule.scheduler.clone(),
        platform: entry.platform_preset.clone(),
        workload: entry.workload_preset.clone(),
        epoch,
        demand,
        knot_deadline,
        knot_budget,
        batch_size: 1,
        host_latency: submitted.elapsed(),
    };
    (reply, Ok(outcome))
}
