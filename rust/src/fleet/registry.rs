//! The epoch-versioned, atomically swappable atlas registry.
//!
//! The registry is the live heart of the fleet layer: a read-mostly map from
//! [`FleetKey`] to `Arc<FleetEntry>`, plus a (preset, workload) name alias
//! table for request routing. Publishing a rebuilt entry swaps the `Arc`
//! under a briefly held write lock and bumps a global epoch — readers that
//! already resolved an entry keep serving from their clone, so a hot swap
//! never drains or rejects in-flight requests; it only changes what
//! *subsequent* lookups see. Both maps are `BTreeMap`s, so a resolve is two
//! `O(log n)` walks with no hashing on the request path.

use super::entry::FleetEntry;
use super::key::FleetKey;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

struct Slot {
    epoch: u64,
    entry: Arc<FleetEntry>,
}

/// A successful resolve: the entry plus the epoch at which it was published
/// (serving layers stamp it on outcomes so swaps are observable).
#[derive(Debug, Clone)]
pub struct Resolved {
    pub entry: Arc<FleetEntry>,
    pub epoch: u64,
}

/// The versioned atlas library registry.
pub struct FleetRegistry {
    slots: RwLock<BTreeMap<FleetKey, Slot>>,
    /// `"platform/workload"` preset-name aliases → content key.
    names: RwLock<BTreeMap<String, FleetKey>>,
    /// Global publish counter; each publish gets the next epoch.
    epoch: AtomicU64,
}

fn alias(platform: &str, workload: &str) -> String {
    format!("{platform}/{workload}")
}

impl FleetRegistry {
    pub fn new() -> FleetRegistry {
        FleetRegistry {
            slots: RwLock::new(BTreeMap::new()),
            names: RwLock::new(BTreeMap::new()),
            epoch: AtomicU64::new(0),
        }
    }

    /// Insert or atomically replace the entry for its content key. Returns
    /// the epoch assigned to this publish. In-flight requests holding the
    /// previous `Arc` are unaffected.
    pub fn publish(&self, entry: FleetEntry) -> u64 {
        let key = entry.key;
        let name = alias(&entry.platform_preset, &entry.workload_preset);
        let entry = Arc::new(entry);
        // Epoch allocation happens under the slots write lock so that
        // concurrent publishes of the same key commit in epoch order — a
        // later epoch always denotes the build that actually won the slot.
        // Slot before alias: a name must never resolve to a missing slot.
        let epoch;
        {
            // lint: allow(no-unwrap): a poisoned registry lock means a
            // publisher panicked mid-commit; crashing is the safe option.
            let mut slots = self.slots.write().expect("fleet slot lock poisoned");
            // ordering: SeqCst so the bare `epoch()` read (taken without
            // the lock) observes allocations in the single global commit
            // order the write lock establishes for the slots themselves.
            epoch = self.epoch.fetch_add(1, Ordering::SeqCst) + 1;
            slots.insert(key, Slot { epoch, entry });
        }
        {
            // lint: allow(no-unwrap): same poisoning rationale as above.
            let mut names = self.names.write().expect("fleet name lock poisoned");
            names.insert(name, key);
        }
        epoch
    }

    /// Resolve by content key.
    pub fn resolve(&self, key: &FleetKey) -> Option<Resolved> {
        // lint: allow(no-unwrap): same poisoning rationale as `publish`.
        let slots = self.slots.read().expect("fleet slot lock poisoned");
        slots.get(key).map(|slot| Resolved {
            entry: slot.entry.clone(),
            epoch: slot.epoch,
        })
    }

    /// Resolve by (platform preset, workload preset) request tags.
    pub fn resolve_named(&self, platform: &str, workload: &str) -> Option<Resolved> {
        let key = {
            // lint: allow(no-unwrap): same poisoning rationale as `publish`.
            let names = self.names.read().expect("fleet name lock poisoned");
            *names.get(&alias(platform, workload))?
        };
        self.resolve(&key)
    }

    /// Keys currently published, in order.
    pub fn keys(&self) -> Vec<FleetKey> {
        // lint: allow(no-unwrap): same poisoning rationale as `publish`.
        let slots = self.slots.read().expect("fleet slot lock poisoned");
        slots.keys().copied().collect()
    }

    /// Snapshot of every published entry (arc clones, cheap).
    pub fn entries(&self) -> Vec<Resolved> {
        // lint: allow(no-unwrap): same poisoning rationale as `publish`.
        let slots = self.slots.read().expect("fleet slot lock poisoned");
        slots
            .values()
            .map(|slot| Resolved {
                entry: slot.entry.clone(),
                epoch: slot.epoch,
            })
            .collect()
    }

    pub fn len(&self) -> usize {
        // lint: allow(no-unwrap): same poisoning rationale as `publish`.
        self.slots.read().expect("fleet slot lock poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The epoch of the most recent publish (0 when nothing was published).
    pub fn epoch(&self) -> u64 {
        // ordering: SeqCst pairs with the allocation in `publish` — see
        // the comment there for the global-order contract.
        self.epoch.load(Ordering::SeqCst)
    }

    /// Advance the publish counter to at least `epoch` (used when loading a
    /// persisted library so future publishes continue its epoch sequence).
    pub fn advance_epoch_to(&self, epoch: u64) {
        // ordering: SeqCst to stay in the same total order as `publish`.
        self.epoch.fetch_max(epoch, Ordering::SeqCst);
    }
}

impl Default for FleetRegistry {
    fn default() -> Self {
        FleetRegistry::new()
    }
}
