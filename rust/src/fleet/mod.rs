//! The fleet layer: a multi-platform atlas **library** with live hot-swap
//! and energy-budget serving.
//!
//! MEDEA is a design-time manager, so every expensive multi-objective solve
//! can be staged before traffic arrives — but one
//! [`crate::serve::ScheduleAtlas`] covers exactly one (platform, workload)
//! pair. A heterogeneous device fleet needs many: this module owns them.
//!
//! * [`key`] — canonical content keys: [`key::PlatformFingerprint`] and
//!   [`key::WorkloadHash`] over name-stripped canonical JSON, so equivalent
//!   platform/network descriptions dedupe to one atlas.
//! * [`catalog`] — the named platform/workload presets entries are built
//!   from (and re-resolved against at load time).
//! * [`energy`] — the **energy-budget atlas**: the dual objective
//!   ([`crate::manager::medea::Medea::schedule_energy_budget`]) swept over a
//!   budget grid with simulator-validated knots, so a request may carry an
//!   energy cap instead of a deadline.
//! * [`entry`] — one library entry: both atlases plus the resolved platform,
//!   cycle model, and workload, keyed by content and staleness-checked on
//!   load.
//! * [`registry`] — the epoch-versioned [`registry::FleetRegistry`]:
//!   `Arc`-swap publishing rebuilt atlases into a running pool without
//!   draining it.
//! * [`store`] — the on-disk library (entry files + index manifest, all
//!   writes atomic via temp-file rename).
//! * [`pool`] — the [`pool::FleetPool`]: one sharded worker pool serving
//!   every published entry, requests tagged (platform preset, workload
//!   preset, deadline-or-energy [`pool::Demand`]), resolved in `O(log n)` at
//!   admission, and coalesced at dispatch time into batches per
//!   `(entry, resolved knot)` under [`crate::serve::batch::BatchConfig`].

// Serving hot path: a panicking `.unwrap()` here takes a whole pool worker
// down with it. Shed with a typed rejection or carry the error instead
// (`.expect` with an invariant message is allowed for real invariants).
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod catalog;
pub mod energy;
pub mod entry;
pub mod key;
pub mod pool;
pub mod registry;
pub mod store;

pub use energy::{BelowEnergyFloor, EnergyAtlas, EnergyAtlasConfig, EnergyKnot};
pub use entry::{FleetConfig, FleetEntry};
pub use key::{FleetKey, PlatformFingerprint, WorkloadHash};
pub use pool::{Demand, FleetOutcome, FleetPool, FleetPoolConfig, FleetTicket};
pub use registry::{FleetRegistry, Resolved};
pub use store::{
    index_epoch, load_library, reload_library_into, save_library, swap_entry, watch_library,
    LibraryWatcher,
};
