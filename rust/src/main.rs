//! `medea` — the command-line entry point.
//!
//! Subcommands regenerate every table/figure of the paper, run schedules,
//! characterize platforms, and serve the end-to-end inference demo.

use medea::baselines;
use medea::eeg::synth::{EegGenerator, SynthConfig};
use medea::exp::{self, ExpContext};
use medea::manager::medea::{Medea, MedeaFeatures, SolverKind};
use medea::platform::loader::{load_platform, save_platform};
use medea::report::{emit, Format};
use medea::runtime::artifacts::ArtifactManifest;
use medea::sim::replay::simulate;
use medea::util::cli::{App, Args, CmdSpec, Parsed};
use medea::util::units::Time;
use std::path::{Path, PathBuf};

fn app() -> App {
    App::new("medea", "MEDEA: design-time multi-objective manager for energy-efficient DNN inference on heterogeneous ULP platforms")
        .command(
            CmdSpec::new("schedule", "Generate a MEDEA schedule for the TSD workload")
                .opt_default("deadline-ms", "Application deadline in ms", "200")
                .opt_default("solver", "MCKP solver: dp|bb|lagrange|greedy", "dp")
                .opt("features", "Ablation: full|no-kerdvfs|no-kersched|no-adaptile")
                .opt("save", "Write the schedule JSON to this path")
                .flag("simulate", "Replay the schedule on the event simulator")
                .flag("verbose", "Print every per-kernel decision"),
        )
        .command(
            CmdSpec::new("baselines", "Run the four §4.4 baseline schedulers")
                .opt_default("deadline-ms", "Application deadline in ms", "200"),
        )
        .command(CmdSpec::new("platform", "Show platform tables (Table 2/3) or export the preset")
            .flag("table2", "Print Table 2 (V-F points)")
            .flag("table3", "Print Table 3 (area breakdown)")
            .opt("export", "Write the HEEPtimize platform JSON to this path")
            .opt("load", "Validate + summarize a platform JSON"))
        .command(
            CmdSpec::new("tables", "Reproduce paper tables")
                .flag("table2", "V-F points")
                .flag("table3", "Area breakdown")
                .flag("table4", "TSD modification cycle reductions")
                .flag("table5", "MEDEA end-to-end breakdown")
                .flag("table6", "Feature-ablation energies")
                .opt("out-dir", "Persist CSV/MD copies under this directory"),
        )
        .command(
            CmdSpec::new("fig5", "Reproduce Fig 5 (MEDEA vs baselines)")
                .opt("out-dir", "Persist CSV/MD copies under this directory"),
        )
        .command(
            CmdSpec::new("fig6", "Reproduce Fig 6 (decision snapshot)")
                .opt_default("start", "First kernel index", "2")
                .opt_default("len", "Number of kernels", "12")
                .flag("histogram", "Print the aggregate (PE, V-F) histogram")
                .opt("out-dir", "Persist CSV/MD copies under this directory"),
        )
        .command(
            CmdSpec::new("fig7", "Reproduce Fig 7 (CGRA/Carus crossover)")
                .opt("out-dir", "Persist CSV/MD copies under this directory"),
        )
        .command(
            CmdSpec::new("fig8", "Reproduce Fig 8 + Table 6 (feature ablations)")
                .opt("out-dir", "Persist CSV/MD copies under this directory"),
        )
        .command(
            CmdSpec::new("all", "Reproduce every table and figure")
                .opt_default("out-dir", "Persist CSV/MD copies under this directory", "results"),
        )
        .command(
            CmdSpec::new("sensitivity", "Sweep calibrated substrate constants (DMA bandwidth, NMC array energy, solver backend)")
                .opt("out-dir", "Persist CSV/MD copies under this directory"),
        )
        .command(
            CmdSpec::new("serve", "Serve synthetic EEG traffic through the atlas-backed worker pool")
                .opt_default("windows", "Number of EEG windows", "10")
                .opt_default("deadline-ms", "Per-window deadline in ms", "200")
                .opt("deadlines", "Comma-separated deadline mix in ms (cycled across windows; overrides --deadline-ms)")
                .opt_default("seed", "EEG generator seed", "42")
                .opt_default("workers", "Worker threads in the serving pool", "4")
                .opt_default("queue-cap", "Per-worker admission queue capacity", "256")
                .opt("atlas", "Schedule-atlas JSON path: loaded when present, else built and saved there")
                .opt("fleet-dir", "Fleet library directory: serve through the multi-platform FleetPool instead of the single-atlas pool")
                .opt_default("platform", "Platform preset tag for fleet routing", "heeptimize")
                .opt_default("workload", "Workload preset tag for fleet routing", "tsd-core")
                .opt("energy-budgets-uj", "Comma-separated energy caps in uJ (cycled; requests carry an energy budget instead of a deadline; fleet mode only)")
                .opt_default("max-batch", "Coalesce up to N compatible queued requests into one dispatch (1 = solo)", "8")
                .opt_default("batch-window-us", "Extra microseconds a worker waits for stragglers when the backlog cannot fill a batch (0 = opportunistic only)", "0")
                .flag("batch-window-auto", "Autotune each worker's effective fill window from observed batch occupancy (published as the medea_batch_window_seconds gauge)")
                .flag("no-steal", "Disable cross-shard work stealing (idle workers rescuing queued work from a stuck shard)")
                .opt_default("steal-poll-us", "Fallback heartbeat period in microseconds for idle workers; event wakeups deliver steals, this only bounds worst-case discovery", "5000")
                .opt_default("steal-wake-threshold", "Queue depth at which a submit wakes the longest-idle sibling worker", "2")
                .opt("fleet-watch-s", "Re-read the fleet library index every N seconds and republish on-disk swaps into the running pool (fleet mode only)")
                .opt("artifacts", "Artifacts directory (default: ./artifacts or $MEDEA_ARTIFACTS)")
                .opt("metrics-addr", "Expose live Prometheus metrics on this host:port (e.g. 127.0.0.1:9464); scrape with `medea scrape` or curl")
                .opt("metrics-out", "Write the final Prometheus exposition to this file before shutdown")
                .opt("trace-out", "Write a chrome://tracing JSON dump of dispatch events to this file before shutdown")
                .opt_default("trace-events", "Dispatch-event trace ring capacity (allocated when --trace-out or --postmortem-dir is set)", "65536")
                .opt_default("report-every-s", "Log a one-line telemetry rates summary every N seconds (0 = off)", "0")
                .flag("slo", "Enable the SLO burn-rate engine with default objectives (any --slo-* target or --postmortem-dir also enables it)")
                .opt("slo-deadline-hit", "Deadline hit-rate target in [0,1] (default 0.999)")
                .opt("slo-shed-ceiling", "Shed-rate ceiling in [0,1] (default 0.05)")
                .opt("slo-dispatch-p99-ms", "p99 dispatch-latency bound in ms (default 250)")
                .opt("slo-energy-uj", "Mean energy-per-request budget in uJ (default: unbounded)")
                .opt("slo-drift-ratio", "Atlas drift-ratio bound: worst-knot realized/modeled dispatch time before the atlas_drift objective burns (default: unbounded)")
                .opt("slo-fast-s", "Fast burn-rate window in seconds (default 5)")
                .opt("slo-slow-s", "Slow burn-rate window in seconds (default 60)")
                .opt("slo-warn-burn", "Burn rate at which an objective degrades to Warn (default 1)")
                .opt("slo-critical-burn", "Fast-window burn rate at which an objective degrades to Critical (default 2)")
                .opt_default("slo-every-s", "SLO evaluation period in seconds", "1")
                .opt("postmortem-dir", "Arm the flight recorder: write rate-limited post-mortem bundles here on Critical transitions and burn-rate spikes")
                .opt_default("postmortem-keep", "Oldest bundles beyond this count are pruned", "8")
                .opt_default("postmortem-min-interval-s", "Minimum seconds between bundles (a storm produces a handful, not thousands)", "30")
                .opt_default("synth-slowdown", "Drift-injection test hook: stretch every dispatch to N x its modeled time (0 = off; single-atlas mode only)", "0"),
        )
        .command(
            CmdSpec::new("scrape", "Fetch one Prometheus exposition from a running `serve --metrics-addr` endpoint")
                .opt_default("addr", "host:port of the metrics endpoint", "127.0.0.1:9464")
                .opt_default("timeout-ms", "Connect + read deadline per attempt, in ms", "5000")
                .opt_default("retries", "Retry this many times on failure (exponential backoff from 50 ms)", "0"),
        )
        .command(
            CmdSpec::new("health", "Probe /healthz, /readyz, and /slo on a running `serve --metrics-addr` endpoint")
                .positional("addr", "host:port of the metrics endpoint")
                .opt_default("timeout-ms", "Connect + read deadline per request, in ms", "2000"),
        )
        .command(
            CmdSpec::new("energy-report", "Print per-PE utilization/energy-share tables from the energy attribution ledger")
                .positional("source", "host:port of a live metrics endpoint, or a snapshot JSON path (registry snapshot, postmortem bundle, or bench output)")
                .opt_default("timeout-ms", "Connect + read deadline for a live scrape, in ms", "5000"),
        )
        .command(
            CmdSpec::new("atlas", "Precompute the schedule atlas and write it to disk")
                .opt_default("out", "Output JSON path", "atlas.json")
                .opt_default("relax", "Sweep bound as a multiple of the feasibility floor", "24")
                .opt_default("growth", "Geometric knot spacing (>1)", "1.15")
                .opt_default("max-knots", "Hard cap on knot count (truncation is logged)", "256")
                .flag("verbose", "Print every knot"),
        )
        .command(
            CmdSpec::new("fleet", "Build, inspect, or hot-swap a multi-platform atlas library")
                .positional("action", "build | inspect | swap")
                .opt_default("dir", "Library directory", "fleet-lib")
                .opt("platforms", "Comma-separated platform presets for `build` (default: all)")
                .opt("workloads", "Comma-separated workload presets for `build` (default: tsd-core,tsd-small)")
                .opt("platform", "Platform preset for `swap`")
                .opt("workload", "Workload preset for `swap`")
                .opt_default("relax", "Deadline sweep bound as a multiple of the feasibility floor", "24")
                .opt_default("growth", "Geometric deadline knot spacing (>1)", "1.15")
                .opt_default("max-knots", "Knot cap per deadline atlas", "256")
                .opt_default("energy-growth", "Geometric energy-budget knot spacing (>1)", "1.25")
                .opt_default("energy-knots", "Knot cap per energy atlas", "48")
                .flag("verbose", "Print every entry's knots"),
        )
        .command(
            CmdSpec::new("lint", "Run the self-hosted concurrency/determinism lint over Rust sources")
                .flag("json", "Emit machine-readable findings (stable key order) instead of text")
                .flag("rules", "List the rule catalog and exit")
                .variadic("paths", "Files or directories to lint (default: src)"),
        )
}

fn main() {
    logger_init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let app = app();
    match app.parse(&argv) {
        Ok(Parsed::Help(h)) => println!("{h}"),
        Ok(Parsed::Command(name, args)) => {
            if let Err(e) = dispatch(&name, &args) {
                eprintln!("error: {e}");
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    }
}

fn logger_init() {
    medea::util::log::init_from_env();
}

fn out_dir(args: &Args) -> Option<PathBuf> {
    args.get("out-dir").map(PathBuf::from)
}

fn dispatch(name: &str, args: &Args) -> Result<(), String> {
    match name {
        "schedule" => cmd_schedule(args),
        "baselines" => cmd_baselines(args),
        "platform" => cmd_platform(args),
        "tables" => cmd_tables(args),
        "fig5" => {
            let ctx = ExpContext::paper();
            emit(&exp::fig5::run(&ctx), "fig5", Format::Text, out_dir(args).as_deref());
            Ok(())
        }
        "fig6" => {
            let ctx = ExpContext::paper();
            let start = args.req_parse::<usize>("start").map_err(|e| e.to_string())?;
            let len = args.req_parse::<usize>("len").map_err(|e| e.to_string())?;
            emit(
                &exp::fig6::run(&ctx, start, len),
                "fig6",
                Format::Text,
                out_dir(args).as_deref(),
            );
            if args.flag("histogram") {
                emit(
                    &exp::fig6::histogram(&ctx),
                    "fig6_histogram",
                    Format::Text,
                    out_dir(args).as_deref(),
                );
            }
            Ok(())
        }
        "fig7" => {
            let ctx = ExpContext::paper();
            emit(&exp::fig7::run(&ctx), "fig7", Format::Text, out_dir(args).as_deref());
            Ok(())
        }
        "fig8" => {
            let ctx = ExpContext::paper();
            emit(&exp::fig8::table6(&ctx), "table6", Format::Text, out_dir(args).as_deref());
            emit(&exp::fig8::run(&ctx), "fig8", Format::Text, out_dir(args).as_deref());
            Ok(())
        }
        "sensitivity" => {
            let ctx = ExpContext::paper();
            emit(&exp::sensitivity::dma_sweep(&ctx), "sens_dma", Format::Text, out_dir(args).as_deref());
            emit(&exp::sensitivity::efixed_sweep(&ctx), "sens_efixed", Format::Text, out_dir(args).as_deref());
            emit(&exp::sensitivity::solver_sweep(&ctx), "sens_solver", Format::Text, out_dir(args).as_deref());
            Ok(())
        }
        "all" => cmd_all(args),
        "serve" => cmd_serve(args),
        "scrape" => cmd_scrape(args),
        "health" => cmd_health(args),
        "energy-report" => cmd_energy_report(args),
        "atlas" => cmd_atlas(args),
        "fleet" => cmd_fleet(args),
        "lint" => cmd_lint(args),
        other => Err(format!("unhandled command {other}")),
    }
}

fn parse_features(args: &Args) -> Result<MedeaFeatures, String> {
    Ok(match args.get("features") {
        None | Some("full") => MedeaFeatures::default(),
        Some("no-kerdvfs") => MedeaFeatures::without_kernel_dvfs(),
        Some("no-kersched") => MedeaFeatures::without_kernel_sched(),
        Some("no-adaptile") => MedeaFeatures::without_adaptive_tiling(),
        Some(other) => return Err(format!("unknown feature set `{other}`")),
    })
}

fn cmd_schedule(args: &Args) -> Result<(), String> {
    let ctx = ExpContext::paper();
    let deadline = Time::from_ms(args.req_parse::<f64>("deadline-ms").map_err(|e| e.to_string())?);
    let solver = SolverKind::from_name(args.get("solver").unwrap_or("dp"))
        .ok_or("unknown solver (dp|bb|lagrange|greedy)")?;
    let medea = Medea::new(&ctx.platform, &ctx.profiles, &ctx.model)
        .with_features(parse_features(args)?)
        .with_solver(solver);
    let schedule = medea
        .schedule(&ctx.workload, deadline)
        .map_err(|e| e.to_string())?;

    println!(
        "scheduler={} deadline={:.0} ms active={:.2} ms energy={:.0} uJ (E_t={:.0} uJ) switches={} optimal={}",
        schedule.scheduler,
        deadline.as_ms(),
        schedule.active_time().as_ms(),
        schedule.active_energy().as_uj(),
        schedule.total_energy(&ctx.platform).as_uj(),
        schedule.vf_switch_count(),
        schedule.optimal,
    );
    if args.flag("verbose") {
        for d in &schedule.decisions {
            println!(
                "  {:>3} {:<22} {:>6} {:>14} {:>3} {:>9.1} us {:>8.3} uJ",
                d.kernel,
                ctx.workload.kernels()[d.kernel].name,
                ctx.platform.pe(d.pe).name,
                ctx.platform.vf.get(d.vf_idx).label(),
                d.mode.name(),
                d.time.as_us(),
                d.energy.as_uj(),
            );
        }
    }
    if args.flag("simulate") {
        let r = simulate(&ctx.workload, &ctx.platform, &ctx.model, &schedule);
        println!(
            "sim: active={:.2} ms energy={:.0} uJ (E_t={:.0} uJ) events={} dma={:.2} ms pe_busy=[{}] deadline_met={}",
            r.active_time.as_ms(),
            r.active_energy.as_uj(),
            r.total_energy().as_uj(),
            r.events,
            r.dma_time.as_ms(),
            r.pe_busy
                .iter()
                .map(|t| format!("{:.1}ms", t.as_ms()))
                .collect::<Vec<_>>()
                .join(", "),
            r.deadline_met,
        );
    }
    if let Some(path) = args.get("save") {
        schedule.save(Path::new(path))?;
        println!("schedule written to {path}");
    }
    Ok(())
}

fn cmd_baselines(args: &Args) -> Result<(), String> {
    let ctx = ExpContext::paper();
    let deadline = Time::from_ms(args.req_parse::<f64>("deadline-ms").map_err(|e| e.to_string())?);
    let (w, p, pr, m) = (&ctx.workload, &ctx.platform, &ctx.profiles, &ctx.model);
    let schedules = vec![
        baselines::cpu_max_vf(w, p, pr, m, deadline).map_err(|e| e.to_string())?,
        baselines::static_accel_max_vf(w, p, pr, m, deadline).map_err(|e| e.to_string())?,
        baselines::static_accel_app_dvfs(w, p, pr, m, deadline).map_err(|e| e.to_string())?,
        baselines::coarse_grain_app_dvfs(w, p, pr, m, deadline).map_err(|e| e.to_string())?,
    ];
    for s in schedules {
        let r = simulate(w, p, m, &s);
        println!(
            "{:<22} active={:>7.2} ms  E_t={:>7.0} uJ  meets={}",
            s.scheduler,
            r.active_time.as_ms(),
            r.total_energy().as_uj(),
            r.deadline_met
        );
    }
    Ok(())
}

fn cmd_platform(args: &Args) -> Result<(), String> {
    let ctx = ExpContext::paper();
    let mut did_something = false;
    if args.flag("table2") {
        println!("{}", exp::tables::table2(&ctx).to_text());
        did_something = true;
    }
    if args.flag("table3") {
        println!("{}", exp::tables::table3(&ctx).to_text());
        did_something = true;
    }
    if let Some(path) = args.get("export") {
        save_platform(&ctx.platform, Path::new(path))?;
        println!("platform written to {path}");
        did_something = true;
    }
    if let Some(path) = args.get("load") {
        let p = load_platform(Path::new(path))?;
        println!(
            "loaded `{}`: {} PEs, {} V-F points, L2 {}, sleep {:.0} uW",
            p.name,
            p.pes.len(),
            p.vf.len(),
            p.l2,
            p.sleep_power.as_uw()
        );
        did_something = true;
    }
    if !did_something {
        println!("{}", exp::tables::table2(&ctx).to_text());
        println!("{}", exp::tables::table3(&ctx).to_text());
    }
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<(), String> {
    let ctx = ExpContext::paper();
    let dir = out_dir(args);
    let all = !(args.flag("table2")
        || args.flag("table3")
        || args.flag("table4")
        || args.flag("table5")
        || args.flag("table6"));
    if all || args.flag("table2") {
        emit(&exp::tables::table2(&ctx), "table2", Format::Text, dir.as_deref());
    }
    if all || args.flag("table3") {
        emit(&exp::tables::table3(&ctx), "table3", Format::Text, dir.as_deref());
    }
    if all || args.flag("table4") {
        emit(&exp::tables::table4(&ctx), "table4", Format::Text, dir.as_deref());
    }
    if all || args.flag("table5") {
        emit(&exp::tables::table5(&ctx), "table5", Format::Text, dir.as_deref());
    }
    if all || args.flag("table6") {
        emit(&exp::fig8::table6(&ctx), "table6", Format::Text, dir.as_deref());
    }
    Ok(())
}

fn cmd_all(args: &Args) -> Result<(), String> {
    let ctx = ExpContext::paper();
    let dir = out_dir(args);
    let d = dir.as_deref();
    emit(&exp::tables::table2(&ctx), "table2", Format::Text, d);
    emit(&exp::tables::table3(&ctx), "table3", Format::Text, d);
    emit(&exp::tables::table4(&ctx), "table4", Format::Text, d);
    emit(&exp::tables::table5(&ctx), "table5", Format::Text, d);
    emit(&exp::fig5::run(&ctx), "fig5", Format::Text, d);
    emit(&exp::fig6::run(&ctx, 2, 12), "fig6", Format::Text, d);
    emit(&exp::fig6::histogram(&ctx), "fig6_histogram", Format::Text, d);
    emit(&exp::fig7::run(&ctx), "fig7", Format::Text, d);
    emit(&exp::fig8::table6(&ctx), "table6", Format::Text, d);
    emit(&exp::fig8::run(&ctx), "fig8", Format::Text, d);
    Ok(())
}

/// Parse `--max-batch` / `--batch-window-us` / `--batch-window-auto` into a
/// [`BatchConfig`].
fn parse_batch(args: &Args) -> Result<medea::serve::BatchConfig, String> {
    let max_batch: usize = args.req_parse("max-batch").map_err(|e| e.to_string())?;
    let window_us: u64 = args.req_parse("batch-window-us").map_err(|e| e.to_string())?;
    if max_batch < 1 {
        return Err("--max-batch must be >= 1".into());
    }
    Ok(medea::serve::BatchConfig {
        max_batch,
        window: std::time::Duration::from_micros(window_us),
        auto: args.flag("batch-window-auto"),
        ..medea::serve::BatchConfig::default()
    })
}

/// Parse `--no-steal` / `--steal-poll-us` / `--steal-wake-threshold` into a
/// [`medea::serve::StealConfig`]. Degenerate values are rejected at the CLI
/// boundary with a typed error: a zero or sub-50 us heartbeat is a
/// busy-wait in disguise, a multi-second one defeats its watchdog role,
/// and a zero wake threshold would make every submit ring a sibling.
fn parse_steal(args: &Args) -> Result<medea::serve::StealConfig, String> {
    if args.flag("no-steal") {
        return Ok(medea::serve::StealConfig::disabled());
    }
    let poll_us: u64 = args.req_parse("steal-poll-us").map_err(|e| e.to_string())?;
    let wake_threshold: usize = args
        .req_parse("steal-wake-threshold")
        .map_err(|e| e.to_string())?;
    if !(50..=10_000_000).contains(&poll_us) {
        return Err(format!(
            "--steal-poll-us must be in [50, 10000000] us (a fallback heartbeat, \
             not a busy-wait or a stall): got {poll_us}"
        ));
    }
    if !(1..=4096).contains(&wake_threshold) {
        return Err(format!(
            "--steal-wake-threshold must be in [1, 4096]: got {wake_threshold}"
        ));
    }
    Ok(medea::serve::StealConfig {
        poll: std::time::Duration::from_micros(poll_us),
        wake_threshold,
        ..medea::serve::StealConfig::default()
    })
}

/// Observability options shared by `serve` and `serve --fleet-dir`.
struct TelemetryCli {
    metrics_addr: Option<String>,
    metrics_out: Option<PathBuf>,
    trace_out: Option<PathBuf>,
    trace_events: usize,
    report_every: Option<std::time::Duration>,
}

impl TelemetryCli {
    fn parse(args: &Args) -> Result<TelemetryCli, String> {
        let trace_events: usize = args.req_parse("trace-events").map_err(|e| e.to_string())?;
        let report_s: f64 = args.req_parse("report-every-s").map_err(|e| e.to_string())?;
        Ok(TelemetryCli {
            metrics_addr: args.get("metrics-addr").map(String::from),
            metrics_out: args.get("metrics-out").map(PathBuf::from),
            trace_out: args.get("trace-out").map(PathBuf::from),
            trace_events,
            report_every: (report_s > 0.0)
                .then(|| std::time::Duration::from_secs_f64(report_s)),
        })
    }

    /// Pool-side config: the trace ring is only allocated when something
    /// consumes it — a `--trace-out` dump or the flight recorder's bundles.
    fn pool_config(&self, slo: &SloCli) -> medea::telemetry::TelemetryConfig {
        let traced = self.trace_out.is_some() || slo.flight.is_some();
        medea::telemetry::TelemetryConfig {
            trace_events: if traced { self.trace_events } else { 0 },
        }
    }

    /// Start the HTTP responder (metrics + health surface) and the periodic
    /// reporter, when asked for. The returned guards keep both alive until
    /// dropped.
    fn attach(
        &self,
        registry: &std::sync::Arc<medea::telemetry::TelemetryRegistry>,
        slo: Option<std::sync::Arc<medea::telemetry::SloEngine>>,
        ready: medea::telemetry::ReadinessProbe,
    ) -> Result<
        (Option<medea::telemetry::MetricsServer>, Option<medea::telemetry::Reporter>),
        String,
    > {
        let server = match &self.metrics_addr {
            Some(addr) => {
                let server = medea::telemetry::MetricsServer::start_with(
                    addr,
                    registry.clone(),
                    slo.clone(),
                    Some(ready),
                )
                .map_err(|e| e.to_string())?;
                println!(
                    "metrics: serving http://{}/metrics (also /healthz, /readyz, /slo)",
                    server.addr()
                );
                Some(server)
            }
            None => None,
        };
        let reporter = self
            .report_every
            .map(|every| medea::telemetry::Reporter::start_with_slo(registry.clone(), every, slo));
        Ok((server, reporter))
    }

    /// Write the one-shot exposition and trace dumps (called just before
    /// pool shutdown, once all in-flight requests resolved).
    fn dump(
        &self,
        registry: &medea::telemetry::TelemetryRegistry,
        trace: Option<&medea::telemetry::TraceRing>,
    ) -> Result<(), String> {
        if let Some(path) = &self.metrics_out {
            let text = medea::telemetry::render_prometheus(&registry.snapshot());
            std::fs::write(path, text).map_err(|e| e.to_string())?;
            println!("metrics: exposition written to {}", path.display());
        }
        if let Some(path) = &self.trace_out {
            match trace {
                Some(ring) => {
                    std::fs::write(path, ring.to_chrome_json()).map_err(|e| e.to_string())?;
                    println!(
                        "trace: {} events written to {} (load in chrome://tracing)",
                        ring.events().len(),
                        path.display()
                    );
                }
                None => println!("trace: ring disabled (--trace-events 0), nothing written"),
            }
        }
        Ok(())
    }
}

/// SLO + flight-recorder options for `serve` (`--slo-*`, `--postmortem-*`).
struct SloCli {
    /// Set when `--slo`, any `--slo-*` target, or `--postmortem-dir` was
    /// given; otherwise the engine is not built at all.
    enabled: bool,
    spec: medea::telemetry::SloSpec,
    every: std::time::Duration,
    flight: Option<medea::telemetry::FlightConfig>,
}

/// Overlay an optional f64 CLI value onto a spec slot, recording that an
/// SLO option was given.
fn slo_opt(args: &Args, name: &str, slot: &mut f64, given: &mut bool) -> Result<(), String> {
    if let Some(v) = args.get_parse::<f64>(name).map_err(|e| e.to_string())? {
        *slot = v;
        *given = true;
    }
    Ok(())
}

impl SloCli {
    fn parse(args: &Args) -> Result<SloCli, String> {
        let mut spec = medea::telemetry::SloSpec::default();
        let mut given = args.flag("slo");
        slo_opt(args, "slo-deadline-hit", &mut spec.deadline_hit_target, &mut given)?;
        slo_opt(args, "slo-shed-ceiling", &mut spec.shed_ceiling, &mut given)?;
        slo_opt(args, "slo-energy-uj", &mut spec.energy_per_request_uj, &mut given)?;
        slo_opt(args, "slo-drift-ratio", &mut spec.drift_ratio_bound, &mut given)?;
        slo_opt(args, "slo-warn-burn", &mut spec.warn_burn, &mut given)?;
        slo_opt(args, "slo-critical-burn", &mut spec.critical_burn, &mut given)?;
        let mut p99_ms = spec.dispatch_p99_bound.as_secs_f64() * 1e3;
        let mut fast_s = spec.fast_window.as_secs_f64();
        let mut slow_s = spec.slow_window.as_secs_f64();
        slo_opt(args, "slo-dispatch-p99-ms", &mut p99_ms, &mut given)?;
        slo_opt(args, "slo-fast-s", &mut fast_s, &mut given)?;
        slo_opt(args, "slo-slow-s", &mut slow_s, &mut given)?;
        if !(p99_ms > 0.0 && fast_s > 0.0 && slow_s >= fast_s) {
            return Err(
                "--slo-dispatch-p99-ms and --slo-fast-s must be > 0, --slo-slow-s >= --slo-fast-s"
                    .into(),
            );
        }
        spec.dispatch_p99_bound = std::time::Duration::from_secs_f64(p99_ms / 1e3);
        spec.fast_window = std::time::Duration::from_secs_f64(fast_s);
        spec.slow_window = std::time::Duration::from_secs_f64(slow_s);

        let every_s: f64 = args.req_parse("slo-every-s").map_err(|e| e.to_string())?;
        if every_s.is_nan() || every_s <= 0.0 {
            return Err("--slo-every-s must be > 0".into());
        }
        let flight = match args.get("postmortem-dir") {
            Some(dir) => {
                given = true;
                let keep: usize = args.req_parse("postmortem-keep").map_err(|e| e.to_string())?;
                let min_s: f64 =
                    args.req_parse("postmortem-min-interval-s").map_err(|e| e.to_string())?;
                Some(medea::telemetry::FlightConfig {
                    dir: PathBuf::from(dir),
                    max_bundles: keep.max(1),
                    min_interval: std::time::Duration::from_secs_f64(min_s.max(0.0)),
                    ..medea::telemetry::FlightConfig::default()
                })
            }
            None => None,
        };
        Ok(SloCli {
            enabled: given,
            spec,
            every: std::time::Duration::from_secs_f64(every_s),
            flight,
        })
    }

    /// Build the engine (and its flight recorder) when any SLO option was
    /// given; `None` keeps the serve path SLO-free.
    fn engine(
        &self,
        registry: &std::sync::Arc<medea::telemetry::TelemetryRegistry>,
        trace: Option<&std::sync::Arc<medea::telemetry::TraceRing>>,
    ) -> Result<Option<std::sync::Arc<medea::telemetry::SloEngine>>, String> {
        if !self.enabled {
            return Ok(None);
        }
        let flight = match &self.flight {
            Some(cfg) => {
                let rec =
                    medea::telemetry::FlightRecorder::new(cfg.clone()).map_err(|e| e.to_string())?;
                println!(
                    "postmortems: armed at {} (keep {}, min interval {:?})",
                    cfg.dir.display(),
                    cfg.max_bundles,
                    cfg.min_interval
                );
                Some(std::sync::Arc::new(rec))
            }
            None => None,
        };
        let engine = medea::telemetry::SloEngine::new(
            self.spec.clone(),
            registry.clone(),
            trace.cloned(),
            flight,
        );
        // Seed a start-of-run baseline sample so the final evaluation in
        // `finish` diffs against pool start even when the run outpaces the
        // first ticker fire (a burst that sheds everything can finish in
        // well under one tick interval).
        engine.evaluate_now();
        Ok(Some(engine))
    }

    /// Final evaluation + recorder tally, printed just before shutdown (so
    /// an overloaded run always leaves a verdict and its bundles behind).
    fn finish(&self, engine: &Option<std::sync::Arc<medea::telemetry::SloEngine>>) {
        let Some(engine) = engine else { return };
        println!("{}", medea::telemetry::slo_line(&engine.evaluate_now()));
        if let Some(flight) = engine.flight() {
            println!(
                "postmortems: {} written, {} suppressed -> {}",
                flight.bundles_written(),
                flight.suppressed(),
                flight.dir().display()
            );
        }
    }
}

fn cmd_scrape(args: &Args) -> Result<(), String> {
    let addr = args.get("addr").unwrap_or("127.0.0.1:9464");
    let timeout_ms: u64 = args.req_parse("timeout-ms").map_err(|e| e.to_string())?;
    let retries: u32 = args.req_parse("retries").map_err(|e| e.to_string())?;
    let timeout = std::time::Duration::from_millis(timeout_ms.max(1));
    let body = medea::telemetry::scrape_with(addr, timeout, retries).map_err(|e| e.to_string())?;
    print!("{body}");
    Ok(())
}

fn cmd_health(args: &Args) -> Result<(), String> {
    let addr = args.positional(0).ok_or("health needs an <addr> (host:port)")?;
    let timeout_ms: u64 = args.req_parse("timeout-ms").map_err(|e| e.to_string())?;
    let timeout = std::time::Duration::from_millis(timeout_ms.max(1));
    let mut healthy = true;
    for path in ["/healthz", "/readyz"] {
        match medea::telemetry::http_get(addr, path, timeout) {
            Ok((code, body)) => {
                println!("{path}: {code} {}", body.trim());
                healthy &= code == 200;
            }
            Err(e) => {
                println!("{path}: {e}");
                healthy = false;
            }
        }
    }
    match medea::telemetry::http_get(addr, "/slo", timeout) {
        Ok((200, body)) => println!("/slo: 200\n{body}"),
        Ok((404, _)) => println!("/slo: 404 (no SLO engine attached)"),
        Ok((code, body)) => {
            println!("/slo: {code} {}", body.trim());
            healthy = false;
        }
        Err(e) => {
            println!("/slo: {e}");
            healthy = false;
        }
    }
    if healthy {
        Ok(())
    } else {
        Err(format!("`{addr}` is unhealthy"))
    }
}

/// `medea energy-report <source>` — print the energy attribution ledger as
/// per-PE utilization/energy-share tables. The source is either a live
/// `serve --metrics-addr` endpoint (the ledger families are re-ingested from
/// one Prometheus scrape) or a JSON file carrying a ledger snapshot: a
/// `--metrics-out`-style registry snapshot (`ledger` key), a flight-recorder
/// postmortem bundle (`registry.ledger`), or a bench output
/// (`telemetry.ledger`).
fn cmd_energy_report(args: &Args) -> Result<(), String> {
    use medea::telemetry::{ledger_from_prometheus, render_energy_report, LedgerSnapshot};
    let source = args
        .positional(0)
        .ok_or("energy-report needs a <source> (host:port or snapshot JSON path)")?;
    let snap = if Path::new(source).exists() {
        let text = std::fs::read_to_string(source).map_err(|e| e.to_string())?;
        let doc = medea::util::json::parse(&text).map_err(|e| e.to_string())?;
        let ledger = doc
            .get("ledger")
            .or_else(|| doc.get("registry").and_then(|r| r.get("ledger")))
            .or_else(|| doc.get("telemetry").and_then(|t| t.get("ledger")))
            .ok_or_else(|| {
                format!(
                    "{source}: no `ledger` section (expected a registry snapshot, \
                     postmortem bundle, or bench output)"
                )
            })?;
        LedgerSnapshot::from_json(ledger)?
    } else {
        let timeout_ms: u64 = args.req_parse("timeout-ms").map_err(|e| e.to_string())?;
        let timeout = std::time::Duration::from_millis(timeout_ms.max(1));
        let body =
            medea::telemetry::scrape_with(source, timeout, 0).map_err(|e| e.to_string())?;
        ledger_from_prometheus(&body)?
    };
    print!("{}", render_energy_report(&snap));
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<(), String> {
    use medea::analysis::{findings_to_json, lint_paths, rules};
    if args.flag("rules") {
        for r in &rules::ALL {
            println!("{:<18} {}  [{}]", r.id, r.summary, r.scope);
        }
        return Ok(());
    }
    let paths: Vec<PathBuf> = if args.positionals().is_empty() {
        vec![PathBuf::from("src")]
    } else {
        args.positionals().iter().map(PathBuf::from).collect()
    };
    let findings = lint_paths(&paths).map_err(|e| format!("lint: {e}"))?;
    if args.flag("json") {
        println!("{}", findings_to_json(&findings));
    } else {
        for f in &findings {
            println!("{}", f.display());
        }
    }
    if findings.is_empty() {
        if !args.flag("json") {
            println!("lint: clean");
        }
        Ok(())
    } else {
        Err(format!("{} lint finding(s)", findings.len()))
    }
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    use medea::serve::{PoolConfig, ScheduleAtlas, ServePool, Ticket};
    if args.get("fleet-dir").is_some() {
        return cmd_serve_fleet(args);
    }
    let windows: usize = args.req_parse("windows").map_err(|e| e.to_string())?;
    let default_deadline: f64 = args.req_parse("deadline-ms").map_err(|e| e.to_string())?;
    let deadlines_ms = args
        .get_f64_list("deadlines")
        .map_err(|e| e.to_string())?
        .unwrap_or_else(|| vec![default_deadline]);
    let seed: u64 = args.req_parse("seed").map_err(|e| e.to_string())?;
    let workers: usize = args.req_parse("workers").map_err(|e| e.to_string())?;
    let queue_cap: usize = args.req_parse("queue-cap").map_err(|e| e.to_string())?;
    let dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(ArtifactManifest::default_dir);

    let synth_slowdown: f64 = args.req_parse("synth-slowdown").map_err(|e| e.to_string())?;
    if !synth_slowdown.is_finite() || synth_slowdown < 0.0 {
        return Err(format!("--synth-slowdown must be a finite factor >= 0: got {synth_slowdown}"));
    }
    if synth_slowdown > 0.0 {
        println!("drift injection: stretching every dispatch to {synth_slowdown}x its modeled time");
    }

    let tel_cli = TelemetryCli::parse(args)?;
    let slo_cli = SloCli::parse(args)?;
    let config = PoolConfig {
        workers,
        queue_capacity: queue_cap,
        artifact_dir: dir,
        batch: parse_batch(args)?,
        steal: parse_steal(args)?,
        telemetry: tel_cli.pool_config(&slo_cli),
        synth_slowdown,
        ..PoolConfig::default()
    };
    let pool = match args.get("atlas").map(Path::new) {
        Some(path) if path.exists() => {
            let atlas = ScheduleAtlas::load(path)?;
            println!("atlas: loaded {} knots from {}", atlas.len(), path.display());
            ServePool::start_with_atlas(config, atlas).map_err(|e| e.to_string())?
        }
        other => {
            let pool = ServePool::start(config).map_err(|e| e.to_string())?;
            println!(
                "atlas: built {} knots, floor {:.1} ms",
                pool.atlas().len(),
                pool.floor().as_ms()
            );
            if let Some(path) = other {
                pool.atlas().save(path)?;
                println!("atlas: saved to {}", path.display());
            }
            pool
        }
    };
    let slo_engine = slo_cli.engine(pool.telemetry(), pool.trace())?;
    let _slo_ticker = slo_engine
        .as_ref()
        .map(|engine| medea::telemetry::SloTicker::start(engine.clone(), slo_cli.every));
    let (_metrics_server, _reporter) =
        tel_cli.attach(pool.telemetry(), slo_engine.clone(), pool.readiness_probe())?;

    // Burst-submit everything, then collect: exercises the EDF queues.
    let mut gen = EegGenerator::new(SynthConfig::default(), seed);
    let mut pending: Vec<(usize, bool, Option<Ticket>)> = Vec::with_capacity(windows);
    for i in 0..windows {
        let deadline = Time::from_ms(deadlines_ms[i % deadlines_ms.len()]);
        let window = gen.next_window();
        let truth = window.seizure;
        match pool.submit(window, deadline) {
            Ok(ticket) => pending.push((i, truth, Some(ticket))),
            Err(rejection) => {
                println!("window {i:>3}: {rejection}");
                pending.push((i, truth, None));
            }
        }
    }
    for (i, truth, ticket) in pending {
        let Some(ticket) = ticket else { continue };
        match ticket.wait() {
            Ok(out) => println!(
                "window {:>3}: pred={:<10} truth={:<10} logits=[{:+.3} {:+.3}] sim: {:.1} ms / {:.0} uJ (met={}) knot={:.0} ms host={:?}",
                out.window_index,
                if out.prediction.seizure { "seizure" } else { "background" },
                if truth { "seizure" } else { "background" },
                out.prediction.logits[0],
                out.prediction.logits[1],
                out.sim.active_time.as_ms(),
                out.sim.total_energy().as_uj(),
                out.sim.deadline_met,
                out.knot_deadline.as_ms(),
                out.host_latency,
            ),
            Err(e) => println!("window {i:>3}: {e}"),
        }
    }
    slo_cli.finish(&slo_engine);
    tel_cli.dump(pool.telemetry(), pool.trace().map(|r| r.as_ref()))?;
    let metrics = pool.shutdown();
    println!("---\n{}", metrics.summary());
    Ok(())
}

fn cmd_atlas(args: &Args) -> Result<(), String> {
    use medea::serve::{AtlasConfig, ScheduleAtlas};
    let out = PathBuf::from(args.get("out").unwrap_or("atlas.json"));
    let relax: f64 = args.req_parse("relax").map_err(|e| e.to_string())?;
    let growth: f64 = args.req_parse("growth").map_err(|e| e.to_string())?;
    let max_knots: usize = args.req_parse("max-knots").map_err(|e| e.to_string())?;
    if growth <= 1.0 {
        return Err("--growth must be > 1".into());
    }
    if relax <= 1.0 {
        return Err("--relax must be > 1".into());
    }
    if max_knots < 2 {
        return Err("--max-knots must be >= 2".into());
    }
    let ctx = ExpContext::paper();
    let cfg = AtlasConfig {
        relax_factor: relax,
        growth,
        max_knots,
        ..AtlasConfig::default()
    };
    let atlas = ScheduleAtlas::build(&ctx.medea(), &ctx.workload, &cfg).map_err(|e| e.to_string())?;
    println!(
        "atlas: {} knots, floor {:.1} ms, min makespan {:.1} ms",
        atlas.len(),
        atlas.floor().as_ms(),
        atlas.min_makespan.as_ms()
    );
    if args.flag("verbose") {
        for k in atlas.knots() {
            println!(
                "  knot {:>8.1} ms  active {:>7.2} ms  energy {:>8.1} uJ",
                k.deadline.as_ms(),
                k.schedule.active_time().as_ms(),
                k.schedule.active_energy().as_uj()
            );
        }
    }
    atlas.save(&out)?;
    println!("atlas written to {}", out.display());
    Ok(())
}

/// Serve through the multi-platform fleet pool (`serve --fleet-dir …`).
fn cmd_serve_fleet(args: &Args) -> Result<(), String> {
    use medea::fleet::{load_library, watch_library, Demand, FleetPool, FleetPoolConfig};
    use medea::util::units::Energy;
    use std::sync::Arc;

    let dir = PathBuf::from(args.get("fleet-dir").expect("checked by caller"));
    let windows: usize = args.req_parse("windows").map_err(|e| e.to_string())?;
    let default_deadline: f64 = args.req_parse("deadline-ms").map_err(|e| e.to_string())?;
    let deadlines_ms = args
        .get_f64_list("deadlines")
        .map_err(|e| e.to_string())?
        .unwrap_or_else(|| vec![default_deadline]);
    let budgets_uj = args.get_f64_list("energy-budgets-uj").map_err(|e| e.to_string())?;
    let seed: u64 = args.req_parse("seed").map_err(|e| e.to_string())?;
    let workers: usize = args.req_parse("workers").map_err(|e| e.to_string())?;
    let queue_cap: usize = args.req_parse("queue-cap").map_err(|e| e.to_string())?;
    let platform = args.get("platform").unwrap_or("heeptimize").to_string();
    let workload = args.get("workload").unwrap_or("tsd-core").to_string();
    let artifact_dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(ArtifactManifest::default_dir);

    let watch_s: Option<f64> = args.get_parse("fleet-watch-s").map_err(|e| e.to_string())?;
    if let Some(s) = watch_s {
        if !s.is_finite() || s <= 0.0 {
            return Err(format!("--fleet-watch-s must be a positive number of seconds: got {s}"));
        }
    }

    let synth_slowdown: f64 = args.req_parse("synth-slowdown").map_err(|e| e.to_string())?;
    if synth_slowdown != 0.0 {
        return Err("--synth-slowdown is a single-atlas serve hook; drop --fleet-dir to use it".into());
    }

    let registry = Arc::new(load_library(&dir)?);
    println!(
        "fleet: loaded {} entries (epoch {}) from {}",
        registry.len(),
        registry.epoch(),
        dir.display()
    );
    if registry.is_empty() {
        return Err("fleet library has no servable entries".into());
    }
    let tel_cli = TelemetryCli::parse(args)?;
    let slo_cli = SloCli::parse(args)?;
    let pool = FleetPool::start(
        registry.clone(),
        FleetPoolConfig {
            workers,
            queue_capacity: queue_cap,
            artifact_dir,
            batch: parse_batch(args)?,
            steal: parse_steal(args)?,
            telemetry: tel_cli.pool_config(&slo_cli),
        },
    )
    .map_err(|e| e.to_string())?;
    // The reload watcher bridges on-disk library swaps (`medea fleet swap`)
    // into the running registry; entries resolve on the next admission.
    let watcher = watch_s.map(|s| {
        println!("fleet: watching {} every {s} s for index swaps", dir.display());
        watch_library(&dir, registry.clone(), std::time::Duration::from_secs_f64(s))
    });
    let slo_engine = slo_cli.engine(pool.telemetry(), pool.trace())?;
    let _slo_ticker = slo_engine
        .as_ref()
        .map(|engine| medea::telemetry::SloTicker::start(engine.clone(), slo_cli.every));
    let (_metrics_server, _reporter) =
        tel_cli.attach(pool.telemetry(), slo_engine.clone(), pool.readiness_probe())?;

    let mut gen = EegGenerator::new(SynthConfig::default(), seed);
    let mut pending = Vec::with_capacity(windows);
    for i in 0..windows {
        let demand = match &budgets_uj {
            Some(budgets) => Demand::EnergyBudget(Energy::from_uj(budgets[i % budgets.len()])),
            None => Demand::Deadline(Time::from_ms(deadlines_ms[i % deadlines_ms.len()])),
        };
        match pool.submit(&platform, &workload, gen.next_window(), demand) {
            Ok(ticket) => pending.push((i, Some(ticket))),
            Err(rejection) => {
                println!("window {i:>3}: {rejection}");
                pending.push((i, None));
            }
        }
    }
    for (i, ticket) in pending {
        let Some(ticket) = ticket else { continue };
        match ticket.wait() {
            Ok(out) => {
                let demand = match out.demand {
                    Demand::Deadline(d) => format!("deadline {:.0} ms", d.as_ms()),
                    Demand::EnergyBudget(b) => format!("cap {:.0} uJ", b.as_uj()),
                };
                println!(
                    "window {:>3}: {}/{} epoch={} {} sim: {:.1} ms / {:.0} uJ (met={}) host={:?}",
                    out.window_index,
                    out.platform,
                    out.workload,
                    out.epoch,
                    demand,
                    out.sim.active_time.as_ms(),
                    out.sim.total_energy().as_uj(),
                    out.sim.deadline_met,
                    out.host_latency,
                );
            }
            Err(e) => println!("window {i:>3}: {e}"),
        }
    }
    slo_cli.finish(&slo_engine);
    tel_cli.dump(pool.telemetry(), pool.trace().map(|r| r.as_ref()))?;
    if let Some(w) = watcher {
        w.stop();
    }
    let metrics = pool.shutdown();
    println!("---\n{}", metrics.summary());
    Ok(())
}

fn cmd_fleet(args: &Args) -> Result<(), String> {
    use medea::fleet::catalog::{PLATFORM_PRESETS, WORKLOAD_PRESETS};
    use medea::fleet::{load_library, save_library, swap_entry, FleetEntry, FleetRegistry};
    use medea::serve::AtlasConfig;

    let action = args
        .positional(0)
        .ok_or("fleet needs an action: build | inspect | swap")?;
    let dir = PathBuf::from(args.get("dir").unwrap_or("fleet-lib"));

    let relax: f64 = args.req_parse("relax").map_err(|e| e.to_string())?;
    let growth: f64 = args.req_parse("growth").map_err(|e| e.to_string())?;
    let max_knots: usize = args.req_parse("max-knots").map_err(|e| e.to_string())?;
    let energy_growth: f64 = args.req_parse("energy-growth").map_err(|e| e.to_string())?;
    let energy_knots: usize = args.req_parse("energy-knots").map_err(|e| e.to_string())?;
    if growth <= 1.0 || energy_growth <= 1.0 {
        return Err("--growth and --energy-growth must be > 1".into());
    }
    if max_knots < 2 || energy_knots < 2 {
        return Err("--max-knots and --energy-knots must be >= 2".into());
    }
    let cfg = medea::fleet::FleetConfig {
        atlas: AtlasConfig {
            relax_factor: relax,
            growth,
            max_knots,
            ..AtlasConfig::default()
        },
        energy: medea::fleet::EnergyAtlasConfig {
            growth: energy_growth,
            max_knots: energy_knots,
            ..medea::fleet::EnergyAtlasConfig::default()
        },
    };

    let list = |opt: Option<&str>, default: &[&str]| -> Vec<String> {
        match opt {
            Some(raw) => raw.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    };

    match action {
        "build" => {
            let platforms = list(args.get("platforms"), &PLATFORM_PRESETS);
            let workloads = list(args.get("workloads"), &["tsd-core", "tsd-small"]);
            let registry = FleetRegistry::new();
            for p in &platforms {
                for w in &workloads {
                    let entry = FleetEntry::build(p, w, &cfg)?;
                    println!(
                        "built {p}/{w}: key {} | {} deadline knots (floor {:.1} ms) | {} energy knots (floor {:.1} uJ)",
                        entry.key,
                        entry.atlas.len(),
                        entry.atlas.floor().as_ms(),
                        entry.energy.len(),
                        entry.energy.floor().as_uj(),
                    );
                    registry.publish(entry);
                }
            }
            save_library(&dir, &registry)?;
            println!(
                "fleet library: {} entries written to {} (epoch {})",
                registry.len(),
                dir.display(),
                registry.epoch()
            );
            Ok(())
        }
        "inspect" => {
            let registry = load_library(&dir)?;
            println!(
                "fleet library at {}: {} entries, epoch {}",
                dir.display(),
                registry.len(),
                registry.epoch()
            );
            for resolved in registry.entries() {
                let e = &resolved.entry;
                println!(
                    "  {} {:>14}/{:<10} {:>3} knots (floor {:>7.1} ms)  {:>3} energy knots (floor {:>8.1} uJ)",
                    e.key,
                    e.platform_preset,
                    e.workload_preset,
                    e.atlas.len(),
                    e.atlas.floor().as_ms(),
                    e.energy.len(),
                    e.energy.floor().as_uj(),
                );
                if args.flag("verbose") {
                    for k in e.atlas.knots() {
                        println!(
                            "      deadline {:>8.1} ms  energy {:>8.1} uJ",
                            k.deadline.as_ms(),
                            k.schedule.active_energy().as_uj()
                        );
                    }
                    for k in e.energy.knots() {
                        println!(
                            "      budget   {:>8.1} uJ  sim time {:>7.2} ms",
                            k.budget.as_uj(),
                            k.sim_time.as_ms()
                        );
                    }
                }
            }
            Ok(())
        }
        "swap" => {
            let platform = args.get("platform").ok_or("swap needs --platform")?;
            let workload = args.get("workload").ok_or("swap needs --workload")?;
            let entry = FleetEntry::build(platform, workload, &cfg)?;
            let knots = entry.atlas.len();
            let energy_knots = entry.energy.len();
            let key = entry.key;
            let epoch = swap_entry(&dir, &entry)?;
            println!(
                "swapped {platform}/{workload} (key {key}): {knots} deadline + {energy_knots} energy knots, library now at epoch {epoch}"
            );
            println!("(a pool serving this library picks the new entry up on its next reload/publish; in-process pools swap live via FleetRegistry::publish)");
            Ok(())
        }
        other => Err(format!(
            "unknown fleet action `{other}` (expected build | inspect | swap); \
             available platforms: {}; workloads: {}",
            PLATFORM_PRESETS.join(", "),
            WORKLOAD_PRESETS.join(", ")
        )),
    }
}
