//! The online serving subsystem: design-time optimization, table-lookup
//! request path.
//!
//! MEDEA (§3.3) is a *design-time* manager: the energy-optimal schedule for
//! a deadline depends only on the platform characterization, never on the
//! request. This module exploits that to serve production traffic without a
//! single solver invocation on the hot path:
//!
//! * [`atlas`] — the **schedule atlas**: a startup sweep over the feasible
//!   deadline range (geometric grid + energy-Pareto refinement) precomputes
//!   one MEDEA schedule per knot; requests resolve by `O(log n)` binary
//!   search to the tightest covering knot. Serializable via
//!   [`crate::util::json`] so it can be built once and shipped.
//! * [`queue`] — deadline-aware admission control: a bounded EDF priority
//!   queue that sheds infeasible (below the atlas floor) and overflow
//!   requests with a typed [`queue::Rejection`] instead of a scheduling
//!   error, and pops EDF-contiguous compatible groups
//!   ([`queue::EdfQueue::pop_compatible`]) for batched dispatch.
//! * [`batch`] — batched admission: queued requests resolving to the same
//!   atlas knot coalesce into one dispatch under a sim-anchored sublinear
//!   makespan model ([`batch::BatchConfig`]), deadline-monotone by
//!   construction.
//! * [`pool`] — the sharded worker pool: N threads, one PJRT runtime handle
//!   each, sharing the atlas behind an `Arc`, EDF-aware dispatch
//!   (round-robin while shard backlogs balance, least-backlogged shard when
//!   they skew), batch-aware dequeue, cross-shard work stealing (idle
//!   workers lift EDF-contiguous groups from a backlogged sibling's queue
//!   head, [`pool::StealConfig`]), bounded per-worker schedule LRUs,
//!   graceful draining shutdown.
//! * [`metrics`] — cross-worker aggregation (p50/p99 host latency, energy,
//!   per-batch-size dispatch histograms, deadline-miss and shed counts)
//!   merged from per-worker [`crate::coordinator::Metrics`].
//!
//! The legacy [`crate::coordinator::Coordinator`] is a thin single-worker
//! compatibility wrapper over [`pool::ServePool`]. Serving *many* (platform,
//! workload) pairs from one process — with live atlas hot-swap and
//! energy-budget demands — is the [`crate::fleet`] layer, built on the same
//! queue and metrics primitives.

// Serving hot path: a panicking `.unwrap()` here takes a whole pool worker
// down with it. Shed with a typed rejection or carry the error instead
// (`.expect` with an invariant message is allowed for real invariants).
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod atlas;
pub mod batch;
pub mod metrics;
pub mod pool;
pub mod queue;

pub use atlas::{AtlasConfig, AtlasKnot, BelowFloor, ScheduleAtlas};
pub use batch::BatchConfig;
pub use metrics::ServeMetrics;
pub use pool::{InferenceOutcome, PoolConfig, ServeError, ServePool, StealConfig, Ticket};
pub use queue::{Admission, EdfQueue, Rejection};
