//! The sharded serving pool.
//!
//! `N` worker threads share one [`ScheduleAtlas`] behind an `Arc`; each
//! worker owns its *own* PJRT runtime handle (PJRT clients are not shared
//! across threads), a bounded LRU of deadline-stamped schedules, and a
//! per-worker [`crate::coordinator::Metrics`]. Requests are dispatched
//! round-robin to per-worker EDF admission queues; infeasible or overflow
//! requests are shed with a typed [`Rejection`] at submit time, never as a
//! solver error. At dequeue time workers pop EDF-contiguous groups of
//! requests resolving to the same atlas knot and execute each group as one
//! dispatch ([`crate::serve::batch`]); dispatch routing itself stays
//! EDF-aware ([`pick_shard`]), and idle workers steal EDF-contiguous
//! groups from backlogged sibling shards ([`StealConfig`]) so a worker
//! stuck mid-dispatch cannot strand urgent queued work. The dequeue core
//! is event-driven: idle workers park on a per-shard gate and are woken by
//! submits or by a backlogged victim's steal wake ([`StealMesh`]) — the
//! steal poll survives only as a lazy fallback heartbeat. Shutdown is
//! graceful: queues drain, then workers exit and their metrics are merged
//! into a [`ServeMetrics`].

use crate::eeg::synth::EegWindow;
use crate::ir::tsd::{tsd_core, TsdParams};
use crate::ir::Workload;
use crate::manager::medea::Medea;
use crate::manager::schedule::{Decision, Schedule};
use crate::platform::heeptimize::heeptimize;
use crate::platform::Platform;
use crate::profile::characterize;
use crate::runtime::artifacts::ArtifactManifest;
use crate::runtime::client::Runtime;
use crate::runtime::infer::{Prediction, TsdInference};
use crate::serve::atlas::{AtlasConfig, ScheduleAtlas};
use crate::serve::batch::{
    batch_makespan, batch_share, member_report, stub_predictions, BatchConfig, WindowAutotuner,
};
use crate::serve::metrics::ServeMetrics;
use crate::serve::queue::{Admission, EdfQueue, Rejection};
use crate::sim::replay::{simulate, SimReport};
use crate::telemetry::ledger::{EnergyLedger, LedgerEntrySpec};
use crate::telemetry::trace::{TraceEventKind, TraceRing};
use crate::telemetry::{TelemetryConfig, TelemetryRegistry, WorkerShard};
use crate::timing::cycle_model::CycleModel;
use crate::util::error::{anyhow, bail, Result};
use crate::util::lru::LruCache;
use crate::util::units::Time;
use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Pool sizing and atlas parameters.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Worker thread count (≥ 1).
    pub workers: usize,
    /// Per-worker admission queue capacity.
    pub queue_capacity: usize,
    /// Per-worker LRU capacity for deadline-stamped schedules.
    pub schedule_cache: usize,
    /// Directory holding the AOT artifacts (`manifest.json`); when absent
    /// or unloadable the pool serves schedule-only responses.
    pub artifact_dir: PathBuf,
    pub atlas: AtlasConfig,
    /// Batched-admission knobs (`max_batch == 1` is the solo legacy path).
    pub batch: BatchConfig,
    /// Cross-shard work-stealing knobs (enabled by default).
    pub steal: StealConfig,
    /// Telemetry knobs (`trace_events` sizes the dispatch-event ring; the
    /// metrics registry itself is always on — it *is* the metrics path).
    pub telemetry: TelemetryConfig,
    /// Drift-injection test hook (`serve --synth-slowdown`): when > 0,
    /// every dispatch is stretched (by sleeping, never under a lock) to
    /// this multiple of its atlas-modeled time, so the realized-vs-modeled
    /// drift ratio converges to the factor and the atlas drift detector can
    /// be exercised without a genuinely slow backend. `0.0` disables.
    pub synth_slowdown: f64,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .clamp(1, 4),
            queue_capacity: 256,
            schedule_cache: 64,
            artifact_dir: ArtifactManifest::default_dir(),
            atlas: AtlasConfig::default(),
            batch: BatchConfig::default(),
            steal: StealConfig::default(),
            telemetry: TelemetryConfig::default(),
            synth_slowdown: 0.0,
        }
    }
}

/// Cross-shard work-stealing knobs, shared by [`ServePool`] and
/// [`crate::fleet::pool::FleetPool`].
///
/// Dispatch routing ([`pick_shard`]) balances queue *depths* at submit
/// time, but cannot help once a shard's worker is stuck mid-dispatch with
/// urgent work queued behind it: queued jobs sit idle while sibling workers
/// starve. Stealing closes that hole at dequeue time — an idle worker scans
/// sibling depth mirrors and lifts an EDF-contiguous compatible group from
/// the most-backlogged victim's queue head (the tightest-deadline work the
/// victim cannot get to), so stealing strictly improves EDF adherence and
/// never reorders a victim's remaining queue.
#[derive(Debug, Clone)]
pub struct StealConfig {
    /// `false` pins every job to the shard it was dispatched to (the
    /// pre-stealing behavior; `serve --no-steal`).
    pub enabled: bool,
    /// Fallback heartbeat: the longest an idle worker sleeps before
    /// re-sampling sibling depth mirrors even without a wake. Steal latency
    /// is bounded by the event-driven wakeup ([`StealMesh`]), not this
    /// interval — the heartbeat only covers a wake suppressed by dedup or a
    /// victim that never crossed [`StealConfig::wake_threshold`], so it can
    /// be lazy. Only idle workers pay it; busy workers never poll.
    pub poll: Duration,
    /// Victim backlog depth at or above which a submit posts a wake to the
    /// longest-idle sibling. `1` wakes a thief for every queued job;
    /// larger values let the victim's own worker absorb small backlogs
    /// without wakeup traffic.
    pub wake_threshold: usize,
}

impl Default for StealConfig {
    fn default() -> Self {
        StealConfig {
            enabled: true,
            poll: Duration::from_millis(5),
            wake_threshold: 2,
        }
    }
}

impl StealConfig {
    /// The no-stealing configuration (jobs stay on their dispatch shard).
    pub fn disabled() -> StealConfig {
        StealConfig {
            enabled: false,
            ..StealConfig::default()
        }
    }
}

/// The response: functional prediction + simulated on-device execution.
#[derive(Debug)]
pub struct InferenceOutcome {
    pub window_index: usize,
    pub prediction: Prediction,
    pub sim: SimReport,
    pub scheduler: String,
    /// Deadline of the atlas knot that served this request (≤ the requested
    /// deadline; the gap is the lookup's energy pessimism window).
    pub knot_deadline: Time,
    /// How many requests shared this dispatch (1 = solo). Batch members are
    /// charged amortized per-member active time/energy shares; deadlines
    /// and sleep windows are judged against the batch completion time.
    pub batch_size: usize,
    /// Submission-to-response latency, queue wait included.
    pub host_latency: Duration,
}

/// Serving failure modes surfaced to a waiting client.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// The request was shed by admission control (typed, expected under
    /// overload or infeasible deadlines).
    Shed(Rejection),
    /// Unexpected worker-side failure (runtime execution error, …).
    Internal(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Shed(r) => write!(f, "{r}"),
            ServeError::Internal(msg) => write!(f, "internal serving error: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Handle for one in-flight request.
#[derive(Debug)]
pub struct Ticket {
    rx: mpsc::Receiver<std::result::Result<InferenceOutcome, ServeError>>,
}

impl Ticket {
    /// Block until the worker responds.
    pub fn wait(self) -> std::result::Result<InferenceOutcome, ServeError> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err(ServeError::Internal("worker dropped response".into())))
    }
}

struct Job {
    /// Pool-unique request id ([`TelemetryRegistry::next_request_id`]),
    /// threaded through every trace event this request produces.
    id: u64,
    window: EegWindow,
    deadline: Time,
    /// Resolved knot identity (deadline bits), stamped at submit — the
    /// atlas is fixed for the pool's lifetime, so submit-time resolution is
    /// definitive and dispatch never re-searches it. `u64::MAX` marks a
    /// below-floor request (the queue sheds those; the sentinel never
    /// batches because `grow` refuses it).
    knot_bits: u64,
    /// The resolved knot's sim-validated solo active time: the anchor of
    /// the batch-makespan admission check.
    unit_time: Time,
    submitted: Instant,
    reply: mpsc::Sender<std::result::Result<InferenceOutcome, ServeError>>,
}

/// Per-shard admission state. Generic over the job type so the fleet pool
/// reuses the same shard + batched-dequeue machinery.
pub(crate) struct ShardState<J> {
    pub(crate) queue: EdfQueue<J>,
    pub(crate) stopping: bool,
}

/// One shard: the admission half (`state`, taken by submitters and by the
/// dequeue predicates) is split from the dispatch half (`gate` + `cv`, the
/// only things a parked worker holds). Submitters therefore never contend
/// with a sleeping worker's condvar re-acquisition, and the worker's park
/// path never holds the queue lock.
pub(crate) struct Shard<J> {
    /// Admission half: the EDF queue and the stopping flag. Held only for
    /// push/pop/peek — never across a wait.
    pub(crate) state: Mutex<ShardState<J>>,
    /// Dispatch half: the wake token. `true` means "something changed since
    /// you last looked" (new job, stop, or a steal wake); set under this
    /// mutex *before* notifying, so a wake posted between a worker's queue
    /// check and its park is never lost.
    gate: Mutex<bool>,
    cv: Condvar,
    /// Queue depth mirror, readable without taking the shard lock: the
    /// dispatcher samples every shard's backlog on each submit.
    pub(crate) depth: AtomicUsize,
}

impl<J> Shard<J> {
    pub(crate) fn new(queue: EdfQueue<J>) -> Shard<J> {
        Shard {
            state: Mutex::new(ShardState {
                queue,
                stopping: false,
            }),
            gate: Mutex::new(false),
            cv: Condvar::new(),
            depth: AtomicUsize::new(0),
        }
    }

    /// Park on the dispatch gate until [`Shard::ring`] posts a wake token
    /// or `timeout` elapses (`None` parks indefinitely). Consumes the
    /// token; returns whether one was present — `false` is a heartbeat
    /// expiry (a spurious condvar wake with no token reads the same way).
    /// Only the shard's owning worker parks here, so there is exactly one
    /// waiter per gate and `notify_one` cannot miss anyone.
    pub(crate) fn park(&self, timeout: Option<Duration>) -> bool {
        // lint: allow(no-unwrap): a poisoned gate means a worker panicked
        // mid-wake; crashing is the safe option.
        let mut token = self.gate.lock().expect("gate lock poisoned");
        if !*token {
            token = match timeout {
                Some(d) => {
                    // lint: allow(no-unwrap): same poisoning rationale.
                    self.cv.wait_timeout(token, d).expect("gate lock poisoned").0
                }
                // lint: allow(no-unwrap): same poisoning rationale.
                None => self.cv.wait(token).expect("gate lock poisoned"),
            };
        }
        let woke = *token;
        *token = false;
        woke
    }

    /// Post a wake token and wake the parked owner. The token is set under
    /// the gate mutex *before* the notify, which closes the check-vs-park
    /// race: a worker between its queue check and [`Shard::park`] finds the
    /// token instead of sleeping through the wake.
    pub(crate) fn ring(&self) {
        // lint: allow(no-unwrap): same poisoning rationale as `park`.
        let mut token = self.gate.lock().expect("gate lock poisoned");
        *token = true;
        drop(token);
        self.cv.notify_one();
    }
}

/// Event-driven steal notifier: one slot per worker.
///
/// A submitter whose shard backlog crosses [`StealConfig::wake_threshold`]
/// posts a wake to the longest-idle sibling instead of leaving that sibling
/// to discover the backlog on its fallback-heartbeat poll, so steal latency
/// is bounded by a wakeup, not by [`StealConfig::poll`]. The wake itself is
/// delivered through the thief's shard gate ([`Shard::ring`]), which is
/// lossless; the atomics here are the *targeting* heuristic (who is idle,
/// since when) and the latency anchor (when the wake was posted).
pub(crate) struct StealMesh {
    start: Instant,
    /// Per-worker idle stamp: `0` while the worker is active, otherwise the
    /// `idle_seq` ticket taken when it went idle — a smaller ticket means
    /// idle longer, so victims wake the thief with the smallest stamp.
    idle_since: Vec<AtomicU64>,
    /// Per-worker pending wake: `0` when none, otherwise nanoseconds since
    /// `start` at post time. Doubles as the dedup token (one outstanding
    /// wake per thief) and the wakeup-latency anchor.
    wake_ns: Vec<AtomicU64>,
    idle_seq: AtomicU64,
    /// Backlog depth at-or-above which a submit wakes a thief; `0` means
    /// wakes are off entirely (stealing disabled, or nobody to wake).
    threshold: usize,
}

impl StealMesh {
    pub(crate) fn new(workers: usize, steal: &StealConfig) -> StealMesh {
        let threshold = if steal.enabled && workers > 1 {
            steal.wake_threshold.max(1)
        } else {
            0
        };
        StealMesh {
            start: Instant::now(),
            idle_since: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            wake_ns: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            idle_seq: AtomicU64::new(0),
            threshold,
        }
    }

    /// Monotonic nanoseconds since mesh construction, clamped away from the
    /// `0` sentinel so a posted stamp is always distinguishable from "no
    /// wake pending".
    fn now_ns(&self) -> u64 {
        u64::try_from(self.start.elapsed().as_nanos())
            .unwrap_or(u64::MAX)
            .max(1)
    }

    /// Stamp this worker idle (about to park with an empty queue).
    pub(crate) fn mark_idle(&self, me: usize) {
        // ordering: the idle stamps are a victim-side targeting heuristic
        // like the depth mirrors — a stale rank only mis-picks which thief
        // to wake. The wake handoff itself rides the gate mutex, so no
        // publication protocol is needed here.
        let ticket = self.idle_seq.fetch_add(1, Ordering::Relaxed) + 1;
        self.idle_since[me].store(ticket, Ordering::Relaxed);
    }

    /// Clear this worker's idle stamp (found work, or gave up parking).
    pub(crate) fn mark_active(&self, me: usize) {
        // ordering: relaxed targeting stamp, see `mark_idle`.
        self.idle_since[me].store(0, Ordering::Relaxed);
    }

    /// Called by a submitter after pushing onto `victim`'s queue left it
    /// `depth` deep: if the backlog crossed the wake threshold, post a wake
    /// to the longest-idle sibling and ring its gate. Deduplicated — a
    /// thief with a wake already pending is not re-notified, so a burst of
    /// submits costs one wakeup, not one per job.
    pub(crate) fn wake_for_backlog<J>(&self, victim: usize, depth: usize, shards: &[Arc<Shard<J>>]) {
        if self.threshold == 0 || depth < self.threshold {
            return;
        }
        let mut best = u64::MAX;
        let mut thief = usize::MAX;
        for (i, slot) in self.idle_since.iter().enumerate() {
            if i == victim {
                continue;
            }
            // ordering: relaxed targeting scan, see `mark_idle`.
            let ticket = slot.load(Ordering::Relaxed);
            if ticket != 0 && ticket < best {
                best = ticket;
                thief = i;
            }
        }
        if thief == usize::MAX {
            return;
        }
        let posted = self.now_ns();
        let claimed = self.wake_ns[thief]
            // ordering: success Release pairs with the Acquire swap in
            // `consume_wake` so the thief's latency read sees the stamp
            // that was actually posted; failure means a wake is already
            // pending (dedup) and the observed value goes unused.
            .compare_exchange(0, posted, Ordering::Release, Ordering::Relaxed);
        if claimed.is_ok() {
            shards[thief].ring();
        }
    }

    /// Consume a pending wake addressed to this worker, returning how long
    /// it sat between the victim posting it and the thief waking.
    pub(crate) fn consume_wake(&self, me: usize) -> Option<Duration> {
        // ordering: Acquire pairs with the posting CAS's Release in
        // `wake_for_backlog`, see there.
        let posted = self.wake_ns[me].swap(0, Ordering::Acquire);
        (posted != 0).then(|| Duration::from_nanos(self.now_ns().saturating_sub(posted)))
    }
}

/// One dequeued dispatch group's provenance. The jobs themselves land in
/// the caller-owned buffer passed to [`pop_group`] — steady-state dispatch
/// reuses that buffer and allocates nothing.
pub(crate) struct Popped {
    /// `true` when the group was lifted from a sibling shard's queue.
    pub(crate) stolen: bool,
}

/// Block until work is available on `shards[me]` — or, when stealing is
/// enabled and the own queue is empty, on a backlogged sibling — then pop
/// an EDF-contiguous compatible group under `key`/`grow` (see
/// [`EdfQueue::pop_compatible_into`]) into the caller-owned `out` buffer.
/// The buffer is reused across dispatches, so steady-state group formation
/// performs no heap allocation.
///
/// Honors the batch fill window with one *precise* timed wait: when the
/// backlog cannot fill a batch, the wait deadline is derived once from the
/// head's remaining laxity clamped to `fill_window` (`slack`: a configured
/// window must never consume the slack the head needs to still dispatch in
/// time) and re-armed only when the head's identity
/// ([`EdfQueue::head_seq`]) changes — a straggler joining the group or a
/// spurious wake re-parks to the *same* absolute instant instead of
/// recomputing (or worse, restarting) the window. Returns `None` when the
/// own shard is stopping and drained.
///
/// Idle workers park on their shard gate and are woken event-driven: by a
/// submit to their own shard ([`Shard::ring`]) or by a backlogged victim's
/// steal wake ([`StealMesh::wake_for_backlog`]). `steal.poll` survives only
/// as the fallback heartbeat bounding how long a suppressed wake can
/// strand queued work.
///
/// Steals never wait: the victim's queued work is stranded (its worker is
/// stuck mid-dispatch), so the thief lifts whatever compatible prefix
/// exists right now. A victim head still inside its configured fill window
/// (`queued_for(head) < batch.window`) is *not* stranded — its worker may
/// be deliberately holding it for stragglers — so thieves skip it until it
/// has aged past the window; the age rule is raceless (derived from the
/// job itself, not from worker state). Pops — own or stolen — happen under
/// the owning shard's admission lock, so no job can be dispatched twice;
/// the thief never holds two shard locks at once, so stealing cannot
/// deadlock against submit, shutdown, or a symmetric thief.
#[allow(clippy::too_many_arguments)]
pub(crate) fn pop_group<J, K: PartialEq>(
    shards: &[Arc<Shard<J>>],
    me: usize,
    batch: &BatchConfig,
    fill_window: Duration,
    steal: &StealConfig,
    mesh: &StealMesh,
    tel: &WorkerShard,
    key: &impl Fn(&J) -> K,
    grow: &impl Fn(&[(Time, J)], Time, &J) -> bool,
    slack: &impl Fn(Time, &J) -> Duration,
    queued_for: &impl Fn(&J) -> Duration,
    out: &mut Vec<(Time, J)>,
) -> Option<Popped> {
    let shard = &shards[me];
    let can_steal = steal.enabled && shards.len() > 1;
    // The armed fill wait: `(head_seq, wake_at)`. Re-derived only when the
    // head changes; an unchanged head re-parks to the same absolute
    // instant, so wakeups mid-fill cost one peek, not a recomputation.
    let mut armed: Option<(u64, Instant)> = None;
    loop {
        // lint: allow(no-unwrap): a poisoned shard means a worker panicked
        // with the queue in an unknown state; crashing is the safe option.
        let mut st = shard.state.lock().expect("shard lock poisoned");
        if !st.queue.is_empty() {
            // A queue that can never hold `max_batch` entries must not
            // make every dispatch burn the whole window waiting for a
            // fill that cannot happen.
            let fill_target = batch.max_batch.min(st.queue.capacity().max(1));
            if batch.max_batch > 1
                && !fill_window.is_zero()
                && !st.stopping
                && st.queue.len() < fill_target
            {
                let now = Instant::now();
                let head_seq = st.queue.head_seq();
                let wake_at = match armed {
                    Some((seq, at)) if head_seq == Some(seq) => at,
                    _ => {
                        // New head (or first sight of this backlog): derive
                        // its one wait deadline — the fill window clamped
                        // to the head's remaining laxity.
                        let head_slack = match st.queue.peek() {
                            Some((deadline, job)) => slack(deadline, job),
                            None => Duration::ZERO,
                        };
                        let at = now + fill_window.min(head_slack);
                        armed = Some((head_seq.unwrap_or(0), at));
                        at
                    }
                };
                let remaining = wake_at.saturating_duration_since(now);
                if !remaining.is_zero() {
                    drop(st);
                    // Parked on the gate: a straggler submit rings it, and
                    // the loop re-checks fill/head either way.
                    shard.park(Some(remaining));
                    continue;
                }
            }
            armed = None;
            let popped = st.queue.pop_compatible_into(batch.max_batch, key, grow, out);
            // ordering: the depth mirror is a lock-free steal heuristic;
            // stale values only misrank victims, the steal itself re-reads
            // the queue under the victim's lock.
            shard.depth.store(st.queue.len(), Ordering::Relaxed);
            tel.set_queue_depth(st.queue.len());
            debug_assert!(popped > 0, "non-empty queue must pop at least the head");
            return Some(Popped { stolen: false });
        }
        if st.stopping {
            return None;
        }
        drop(st);
        armed = None;
        mesh.mark_idle(me);
        if can_steal && try_steal(shards, me, batch, key, grow, queued_for, out) {
            mesh.mark_active(me);
            if let Some(latency) = mesh.consume_wake(me) {
                tel.record_wakeup(latency);
            }
            return Some(Popped { stolen: true });
        }
        // Park event-driven; `steal.poll` is only the fallback heartbeat
        // bounding how long a suppressed steal wake can strand sibling
        // work. Without stealing there is nothing to heartbeat for.
        let woke = shard.park(can_steal.then_some(steal.poll));
        mesh.mark_active(me);
        if woke {
            if let Some(latency) = mesh.consume_wake(me) {
                tel.record_wakeup(latency);
            }
        } else {
            tel.record_spurious_wakeup();
        }
    }
}

/// Scan sibling depth mirrors (no locks) and lift an EDF-contiguous
/// compatible group from the head of the most-backlogged victim's queue
/// into `out`, under the victim's lock and the caller's own `key`/`grow`
/// predicates — a stolen group is admissible exactly when the victim's own
/// worker would have formed it. Victims are tried in descending-backlog
/// order until one yields work; returns whether anything was lifted.
#[allow(clippy::too_many_arguments)]
fn try_steal<J, K: PartialEq>(
    shards: &[Arc<Shard<J>>],
    me: usize,
    batch: &BatchConfig,
    key: &impl Fn(&J) -> K,
    grow: &impl Fn(&[(Time, J)], Time, &J) -> bool,
    queued_for: &impl Fn(&J) -> Duration,
    out: &mut Vec<(Time, J)>,
) -> bool {
    let mut victims: Vec<(usize, usize)> = shards
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != me)
        // ordering: depth mirrors are victim-ranking heuristics; the
        // actual steal re-reads the queue under the victim's lock below.
        .map(|(i, s)| (s.depth.load(Ordering::Relaxed), i))
        .filter(|&(depth, _)| depth > 0)
        .collect();
    victims.sort_unstable_by(|a, b| b.0.cmp(&a.0));
    for (_, v) in victims {
        let victim = &shards[v];
        // lint: allow(no-unwrap): same poisoning rationale as `pop_group`.
        let mut st = victim.state.lock().expect("shard lock poisoned");
        // A head still inside the configured fill window is being held for
        // stragglers on purpose, not stranded: its own worker (or a later
        // thief) will dispatch it once the window has been paid. Stealing
        // it early would dispatch a partial batch and silently defeat
        // `--batch-window-us` amortization whenever any sibling idles.
        // Age is a property of the job itself, so this rule has no race
        // with the victim's worker entering or leaving its fill wait. The
        // *configured* window governs here even when the victim autotunes
        // its effective window shorter: erring lazy never steals a group
        // the victim still wants.
        if batch.max_batch > 1 && !batch.window.is_zero() {
            if let Some((_, head)) = st.queue.peek() {
                if queued_for(head) < batch.window {
                    continue;
                }
            }
        }
        let popped = st.queue.pop_compatible_into(batch.max_batch, key, grow, out);
        // ordering: relaxed depth mirror refresh, see the victim scan.
        victim.depth.store(st.queue.len(), Ordering::Relaxed);
        drop(st);
        if popped > 0 {
            return true;
        }
    }
    false
}

/// Remaining wall-clock laxity of a queue head: its deadline minus the
/// resolved knot's sim-anchored unit time (the on-device work it still has
/// ahead of it), minus the time it has already spent queued. The batch fill
/// window is clamped to this, so a configured `--batch-window-us` can never
/// consume the slack a tight-deadline head needs to still dispatch in time.
pub(crate) fn head_laxity(deadline: Time, unit_time: Time, submitted: Instant) -> Duration {
    let laxity = deadline.raw() - unit_time.raw();
    // Non-finite deadlines (admissible in principle) must not poison the
    // Duration conversion; an hour bounds any sane fill wait anyway.
    let laxity = if laxity.is_finite() {
        laxity.clamp(0.0, 3600.0)
    } else {
        3600.0
    };
    Duration::from_secs_f64(laxity).saturating_sub(submitted.elapsed())
}

/// Backlog skew (max − min queue depth) beyond which dispatch abandons
/// round-robin for the least-backlogged shard.
pub(crate) const DISPATCH_SKEW_THRESHOLD: usize = 2;

/// EDF-aware dispatch: plain round-robin while shard backlogs are balanced
/// (it preserves submission-order fairness and costs one atomic), but when
/// depths skew — deadline-heavy bursts landing on one shard, a worker stuck
/// on a slow request — pick the least-backlogged shard instead. Under EDF
/// queues, backlog is the work queued ahead of the new request, so the
/// least-backlogged shard is where it keeps the most laxity; this is the
/// small-heuristic alternative to full cross-shard work stealing.
pub(crate) fn pick_shard(depths: impl Iterator<Item = usize>, round_robin: usize) -> usize {
    let mut n = 0;
    let mut min_i = 0;
    let mut min_d = usize::MAX;
    let mut max_d = 0;
    for (i, d) in depths.enumerate() {
        n += 1;
        if d < min_d {
            min_d = d;
            min_i = i;
        }
        if d > max_d {
            max_d = d;
        }
    }
    if max_d.saturating_sub(min_d) >= DISPATCH_SKEW_THRESHOLD {
        min_i
    } else {
        round_robin % n.max(1)
    }
}

/// Design-time state shared read-only by every worker.
struct ServeContext {
    platform: Platform,
    model: CycleModel,
    workload: Workload,
}

/// A running pool. Dropping it shuts workers down (discarding metrics);
/// call [`ServePool::shutdown`] to collect the aggregate instead.
pub struct ServePool {
    shards: Vec<Arc<Shard<Job>>>,
    /// Steal-wake notifier shared with the workers: submit posts wakes to
    /// idle siblings through it when a shard's backlog crosses the
    /// threshold.
    mesh: Arc<StealMesh>,
    workers: Vec<JoinHandle<()>>,
    next: AtomicUsize,
    atlas: Arc<ScheduleAtlas>,
    /// The live metrics registry: admission counts sheds here, workers
    /// record into their shards, and both [`ServePool::live_metrics`] and
    /// [`ServePool::shutdown`] read the same state.
    telemetry: Arc<TelemetryRegistry>,
    /// Dispatch-event ring; `None` unless `telemetry.trace_events > 0`.
    trace: Option<Arc<TraceRing>>,
}

impl ServePool {
    /// Build the design-time state, sweep the atlas, and spawn the workers.
    pub fn start(config: PoolConfig) -> Result<ServePool> {
        let platform = heeptimize();
        let model = CycleModel::heeptimize();
        let profiles = characterize(&platform, &model);
        let workload = tsd_core(&TsdParams::default());
        let medea = Medea::new(&platform, &profiles, &model);
        let atlas = ScheduleAtlas::build(&medea, &workload, &config.atlas)
            .map_err(|e| anyhow!("atlas build failed: {e}"))?;
        Self::start_with_atlas(config, atlas)
    }

    /// Spawn workers over a prebuilt (e.g. loaded-from-disk) atlas.
    pub fn start_with_atlas(config: PoolConfig, atlas: ScheduleAtlas) -> Result<ServePool> {
        let workload = tsd_core(&TsdParams::default());
        if atlas.workload != workload.name {
            bail!(
                "atlas was built for workload `{}`, this pool serves `{}`",
                atlas.workload,
                workload.name
            );
        }
        if atlas.is_empty() {
            bail!("atlas has no knots");
        }
        let ctx = Arc::new(ServeContext {
            platform: heeptimize(),
            model: CycleModel::heeptimize(),
            workload,
        });
        let atlas = Arc::new(atlas);
        let floor = atlas.floor();
        let batch = config.batch.clone().sanitized();
        let steal = config.steal.clone();

        let n = config.workers.max(1);
        let telemetry = Arc::new(TelemetryRegistry::new(
            ctx.platform.name.clone(),
            ctx.workload.name.clone(),
            n,
        ));
        let trace = (config.telemetry.trace_events > 0)
            .then(|| Arc::new(TraceRing::new(config.telemetry.trace_events)));
        // The energy attribution ledger is sized once from the atlas (one
        // entry, one knot row per atlas knot) before any worker spawns, so
        // the dispatch hot path touches only preallocated atomic tables.
        let ledger = EnergyLedger::new(
            n,
            &[LedgerEntrySpec::new(
                &ctx.platform,
                ctx.workload.name.clone(),
                atlas.knots().iter().map(|k| k.deadline).collect(),
            )],
        );
        telemetry.install_ledger(ledger.clone());
        // Every shard exists before any worker spawns: workers see the full
        // sibling set, so stealing never races pool construction.
        let shards: Vec<Arc<Shard<Job>>> = (0..n)
            .map(|_| {
                Arc::new(Shard::new(
                    EdfQueue::new(config.queue_capacity.max(1)).with_floor(floor),
                ))
            })
            .collect();
        let mesh = Arc::new(StealMesh::new(n, &steal));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let handle = std::thread::Builder::new()
                .name(format!("medea-serve-{i}"))
                .spawn({
                    let shards = shards.clone();
                    let mesh = mesh.clone();
                    let ctx = ctx.clone();
                    let atlas = atlas.clone();
                    let dir = config.artifact_dir.clone();
                    let cache = config.schedule_cache.max(1);
                    let batch = batch.clone();
                    let steal = steal.clone();
                    let tel = telemetry.worker(i);
                    let trace = trace.clone();
                    let ledger = ledger.clone();
                    let synth_slowdown = config.synth_slowdown;
                    move || {
                        worker_loop(
                            &shards,
                            i,
                            &ctx,
                            &atlas,
                            &dir,
                            cache,
                            &batch,
                            &steal,
                            &mesh,
                            &tel,
                            trace.as_deref(),
                            &ledger,
                            synth_slowdown,
                        )
                    }
                })
                .map_err(|e| anyhow!("spawn serve worker {i}: {e}"))?;
            workers.push(handle);
        }

        Ok(ServePool {
            shards,
            mesh,
            workers,
            next: AtomicUsize::new(0),
            atlas,
            telemetry,
            trace,
        })
    }

    pub fn atlas(&self) -> &ScheduleAtlas {
        &self.atlas
    }

    /// The tightest deadline admission control will accept.
    pub fn floor(&self) -> Time {
        self.atlas.floor()
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Dispatch into a worker's EDF queue ([`pick_shard`]: round-robin while
    /// backlogs are balanced, least-backlogged shard when they skew).
    /// Returns a [`Ticket`] on admission, or the typed shed reason.
    pub fn submit(
        &self,
        window: EegWindow,
        deadline: Time,
    ) -> std::result::Result<Ticket, Rejection> {
        // ordering: round-robin ticket and depth hints are heuristics for
        // shard choice only — stale reads just pick a slightly busier
        // shard; the queue itself is protected by the shard mutex.
        let rr = self.next.fetch_add(1, Ordering::Relaxed);
        let depths = self.shards.iter().map(|s| s.depth.load(Ordering::Relaxed));
        self.submit_pinned(pick_shard(depths, rr), window, deadline)
    }

    /// Submit pinned to one shard, bypassing [`pick_shard`] routing: a
    /// load-skew injection hook for benches and tests (deterministically
    /// loading one shard while its siblings idle is exactly the scenario
    /// work stealing exists for). Not a serving API.
    #[doc(hidden)]
    pub fn submit_pinned(
        &self,
        shard: usize,
        window: EegWindow,
        deadline: Time,
    ) -> std::result::Result<Ticket, Rejection> {
        let idx = shard % self.shards.len();
        let shard = &self.shards[idx];
        let id = self.telemetry.next_request_id();
        let (tx, rx) = mpsc::channel();
        let (knot_bits, unit_time) = match self.atlas.lookup(deadline) {
            Ok(knot) => (knot.deadline.raw().to_bits(), knot.sim_time),
            Err(_) => (u64::MAX, Time::ZERO),
        };
        let job = Job {
            id,
            window,
            deadline,
            knot_bits,
            unit_time,
            submitted: Instant::now(),
            reply: tx,
        };
        // lint: allow(no-unwrap): same poisoning rationale as `pop_group`.
        let mut st = shard.state.lock().expect("shard lock poisoned");
        if st.stopping {
            drop(st);
            let reason = Rejection::ShuttingDown;
            self.telemetry.record_shed(&reason);
            self.trace_shed(idx, id, &reason);
            return Err(reason);
        }
        let capacity = st.queue.capacity();
        match st.queue.push(deadline, job) {
            Admission::Accepted => {
                let depth = st.queue.len();
                // ordering: relaxed depth hint, see `submit`.
                shard.depth.store(depth, Ordering::Relaxed);
                self.telemetry.worker(idx).set_queue_depth(depth);
                drop(st);
                shard.ring();
                self.mesh.wake_for_backlog(idx, depth, &self.shards);
                if let Some(ring) = &self.trace {
                    ring.record(TraceEventKind::Enqueue, idx as u32, id, deadline_us(deadline));
                }
                Ok(Ticket { rx })
            }
            Admission::AcceptedShedding { evicted, .. } => {
                let depth = st.queue.len();
                // ordering: relaxed depth hint, see `submit`.
                shard.depth.store(depth, Ordering::Relaxed);
                self.telemetry.worker(idx).set_queue_depth(depth);
                let reason = Rejection::QueueFull { capacity };
                self.telemetry.record_shed(&reason);
                self.trace_shed(idx, evicted.id, &reason);
                let _ = evicted.reply.send(Err(ServeError::Shed(reason)));
                drop(st);
                shard.ring();
                self.mesh.wake_for_backlog(idx, depth, &self.shards);
                if let Some(ring) = &self.trace {
                    ring.record(TraceEventKind::Enqueue, idx as u32, id, deadline_us(deadline));
                }
                Ok(Ticket { rx })
            }
            Admission::Rejected { reason, .. } => {
                drop(st);
                self.telemetry.record_shed(&reason);
                self.trace_shed(idx, id, &reason);
                Err(reason)
            }
        }
    }

    fn trace_shed(&self, shard: usize, id: u64, reason: &Rejection) {
        if let Some(ring) = &self.trace {
            ring.record(TraceEventKind::Shed, shard as u32, id, reason.code());
        }
    }

    /// Submit and block for the response.
    pub fn infer(
        &self,
        window: EegWindow,
        deadline: Time,
    ) -> std::result::Result<InferenceOutcome, ServeError> {
        match self.submit(window, deadline) {
            Ok(ticket) => ticket.wait(),
            Err(rejection) => Err(ServeError::Shed(rejection)),
        }
    }

    fn begin_stop(&self) {
        for shard in &self.shards {
            // lint: allow(no-unwrap): same poisoning rationale as
            // `pop_group`.
            let mut st = shard.state.lock().expect("shard lock poisoned");
            st.stopping = true;
            drop(st);
            // One waiter per gate (the shard's own worker), so a single
            // token wake reaches everyone affected.
            shard.ring();
        }
    }

    /// The live telemetry registry: what the Prometheus endpoint, the
    /// periodic reporter, and [`ServePool::live_metrics`] all read.
    pub fn telemetry(&self) -> &Arc<TelemetryRegistry> {
        &self.telemetry
    }

    /// The dispatch-event trace ring, when `telemetry.trace_events > 0`.
    pub fn trace(&self) -> Option<&Arc<TraceRing>> {
        self.trace.as_ref()
    }

    /// A `/readyz` probe over this pool's shards: ready while no shard is
    /// stopping and total queued admissions sit below a 90 % saturation
    /// watermark of total capacity. The probe holds only shard handles
    /// (one brief shard lock each to answer), so it stays valid for the
    /// pool's lifetime.
    pub fn readiness_probe(&self) -> crate::telemetry::ReadinessProbe {
        readiness_probe_over(&self.shards)
    }

    /// A [`ServeMetrics`] view of the pool *right now*, without shutting
    /// anything down — the same registry read [`ServePool::shutdown`]
    /// performs, so live and final percentiles share one arithmetic.
    pub fn live_metrics(&self) -> ServeMetrics {
        ServeMetrics::from_registry(&self.telemetry)
    }

    /// Graceful shutdown: queues drain, workers exit, and the final
    /// aggregate is read from the telemetry registry.
    pub fn shutdown(mut self) -> ServeMetrics {
        self.begin_stop();
        for h in self.workers.drain(..) {
            // lint: allow(no-unwrap): a panicked worker already lost jobs;
            // surfacing the panic at shutdown is deliberate.
            h.join().expect("serve worker panicked");
        }
        ServeMetrics::from_registry(&self.telemetry)
    }
}

/// Requested deadline in whole microseconds (saturating) — the `arg` of an
/// [`TraceEventKind::Enqueue`] event.
pub(crate) fn deadline_us(deadline: Time) -> u64 {
    (deadline.raw() * 1e6) as u64
}

/// Drift-injection hook ([`PoolConfig::synth_slowdown`]): sleep off the
/// remainder until the dispatch has taken `factor ×` its modeled time.
/// Called strictly after the dispatch work, with no locks held, so it
/// stretches realized wall time without perturbing queueing or stealing.
pub(crate) fn stretch_dispatch(exec_start: Instant, factor: f64, expected: Time) {
    if factor <= 0.0 || !expected.raw().is_finite() || expected.raw() <= 0.0 {
        return;
    }
    // An hour bounds any sane injection; also guards the f64→Duration cast.
    let target = Duration::from_secs_f64((factor * expected.raw()).min(3600.0));
    let elapsed = exec_start.elapsed();
    if elapsed < target {
        std::thread::sleep(target - elapsed);
    }
}

/// Emit one [`TraceEventKind::KernelSpan`] per schedule decision, laying
/// the kernels out back-to-back over the dispatch's realized wall time:
/// each kernel's modeled duration is scaled by `realized / Σ modeled`, so
/// the chrome-trace per-PE Gantt spans exactly the observed dispatch window
/// while preserving the schedule's relative kernel proportions.
pub(crate) fn trace_kernel_spans(
    ring: &TraceRing,
    worker: usize,
    req: u64,
    decisions: &[Decision],
    realized: Duration,
) {
    let total: f64 = decisions.iter().map(|d| d.time.raw()).sum();
    if !total.is_finite() || total <= 0.0 {
        return;
    }
    let realized_ns = u64::try_from(realized.as_nanos()).unwrap_or(u64::MAX);
    let scale = realized_ns as f64 / total;
    let base = ring.now_ns().saturating_sub(realized_ns);
    let mut cum = 0.0f64;
    for d in decisions {
        let start = base.saturating_add((cum * scale) as u64);
        let dur = (d.time.raw() * scale) as u64;
        ring.record_kernel_span(worker as u32, req, d.kernel, d.pe.0, d.vf_idx, start, dur);
        cum += d.time.raw();
    }
}

/// Shared `/readyz` arithmetic for both pools: unready when any shard is
/// stopping or total depth reaches `max(1, 90 % of total capacity)` — the
/// watermark leaves headroom so a scheduler can stop routing *before* the
/// pool starts shedding.
pub(crate) fn readiness_probe_over<J: Send + 'static>(
    shards: &[Arc<Shard<J>>],
) -> crate::telemetry::ReadinessProbe {
    let shards: Vec<Arc<Shard<J>>> = shards.to_vec();
    Arc::new(move || {
        let mut depth = 0usize;
        let mut cap = 0usize;
        for shard in &shards {
            // lint: allow(no-unwrap): same poisoning rationale as
            // `pop_group`.
            let st = shard.state.lock().expect("shard lock poisoned");
            if st.stopping {
                return crate::telemetry::Readiness::unready("pool stopping");
            }
            cap += st.queue.capacity();
            drop(st);
            // ordering: relaxed depth hint; readiness is advisory and a
            // slightly stale total is fine.
            depth += shard.depth.load(Ordering::Relaxed);
        }
        let watermark = (cap * 9 / 10).max(1);
        if depth < watermark {
            crate::telemetry::Readiness::ready(format!("queue {depth}/{cap}"))
        } else {
            crate::telemetry::Readiness::unready(format!(
                "queue {depth}/{cap} at watermark {watermark}"
            ))
        }
    })
}

impl Drop for ServePool {
    fn drop(&mut self) {
        self.begin_stop();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    shards: &[Arc<Shard<Job>>],
    me: usize,
    ctx: &ServeContext,
    atlas: &ScheduleAtlas,
    artifact_dir: &std::path::Path,
    cache_capacity: usize,
    batch: &BatchConfig,
    steal: &StealConfig,
    mesh: &StealMesh,
    tel: &WorkerShard,
    trace: Option<&TraceRing>,
    ledger: &EnergyLedger,
    synth_slowdown: f64,
) {
    // One PJRT runtime handle per worker, created on the worker thread.
    let mut runtime = match Runtime::new(artifact_dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            crate::log_warn!("PJRT runtime unavailable ({e}); serving schedule-only responses");
            None
        }
    };
    let infer = TsdInference::default();
    // Deadline-stamped schedules, bounded (the pre-atlas coordinator kept
    // an unbounded BTreeMap here).
    let mut schedules: LruCache<u64, (Schedule, Time)> = LruCache::new(cache_capacity);
    let amort = batch.amortization;

    // Same resolved knot (stamped at submit) ⇒ same schedule ⇒ one
    // dispatch; no atlas search on the dequeue path.
    let key = |job: &Job| job.knot_bits;
    // Admit the candidate only while the sim-anchored batch makespan fits
    // the *earliest* member deadline; EDF pop order makes everyone else
    // laxer, so this bounds every member.
    let grow = |group: &[(Time, Job)], _cand_deadline: Time, _cand: &Job| {
        let head = &group[0].1;
        head.knot_bits != u64::MAX
            && batch_makespan(head.unit_time, group.len() + 1, amort).raw() <= group[0].0.raw()
    };
    let slack = |deadline: Time, job: &Job| head_laxity(deadline, job.unit_time, job.submitted);
    let queued_for = |job: &Job| job.submitted.elapsed();

    // The reusable dispatch-group buffer: sized once for the largest legal
    // batch, so steady-state group formation allocates nothing.
    let mut group: Vec<(Time, Job)> = Vec::with_capacity(batch.max_batch.max(1));
    let mut tuner = WindowAutotuner::new(batch);
    loop {
        group.clear();
        let fill_window = tuner.effective();
        tel.set_batch_window(fill_window);
        let popped = pop_group(
            shards,
            me,
            batch,
            fill_window,
            steal,
            mesh,
            tel,
            &key,
            &grow,
            &slack,
            &queued_for,
            &mut group,
        );
        let Some(popped) = popped else { break };
        if group.is_empty() {
            continue;
        }
        tuner.observe(group.len());
        let exec_start = Instant::now();
        let head_id = group[0].1.id;
        let size = group.len() as u64;
        for (_, job) in &group {
            tel.record_queue_wait(job.submitted.elapsed());
        }
        {
            let (head_deadline, head) = &group[0];
            tel.record_head_laxity(head_laxity(*head_deadline, head.unit_time, head.submitted));
        }
        if popped.stolen {
            tel.record_steal(group.len());
            if let Some(ring) = trace {
                ring.record(TraceEventKind::Steal, me as u32, head_id, size);
            }
        }
        if let Some(ring) = trace {
            if group.len() > 1 {
                ring.record(TraceEventKind::BatchForm, me as u32, head_id, size);
            }
            ring.record(TraceEventKind::Dispatch, me as u32, head_id, size);
        }
        if group.len() == 1 {
            // Solo dispatch: the exact legacy path (per-member deadline
            // stamping + LRU-cached schedules). `swap_remove` keeps the
            // buffer's capacity for the next dispatch.
            let (_, job) = group.swap_remove(0);
            let outcome = process(&job, ctx, atlas, &mut schedules, runtime.as_mut(), &infer);
            let met = matches!(&outcome, Ok(o) if o.sim.deadline_met);
            if let Ok(o) = &outcome {
                tel.record_batch(1);
                tel.record(
                    o.prediction.seizure,
                    o.sim.deadline_met,
                    o.sim.total_energy().raw(),
                    o.sim.active_time.raw(),
                    o.host_latency,
                );
                stretch_dispatch(exec_start, synth_slowdown, job.unit_time);
                // The solo cache was populated by `process` on success, so
                // this lookup is a hit; the knot's solo sim time stamped at
                // submit (`unit_time`) is the drift reference.
                if let Some((schedule, knot_deadline)) =
                    schedules.get(&job.deadline.raw().to_bits())
                {
                    let realized = exec_start.elapsed();
                    ledger.record_dispatch(
                        me,
                        0,
                        *knot_deadline,
                        &schedule.decisions,
                        1,
                        realized,
                        job.unit_time,
                    );
                    if let Some(ring) = trace {
                        trace_kernel_spans(ring, me, job.id, &schedule.decisions, realized);
                    }
                }
            }
            if let Some(ring) = trace {
                ring.record(TraceEventKind::Retire, me as u32, job.id, u64::from(met));
            }
            let _ = job.reply.send(outcome);
        } else {
            process_batch(
                &mut group,
                ctx,
                atlas,
                runtime.as_mut(),
                &infer,
                batch,
                me,
                tel,
                trace,
                ledger,
                exec_start,
                synth_slowdown,
            );
        }
        tel.record_dispatch_time(exec_start.elapsed());
    }
}

/// Execute one coalesced dispatch: a single simulated on-device run and a
/// single amortized inference invocation, fanned back out to every member.
/// Per-member accounting ([`member_report`]): amortized active time/energy
/// shares (sums stay equal to the batch totals), deadlines and sleep judged
/// against the batch *completion* time — all derived from the one fresh
/// event-level replay, mirroring how the atlas knots were validated.
/// Drains the caller's reusable group buffer (capacity is retained).
#[allow(clippy::too_many_arguments)]
fn process_batch(
    group: &mut Vec<(Time, Job)>,
    ctx: &ServeContext,
    atlas: &ScheduleAtlas,
    runtime: Option<&mut Runtime>,
    infer: &TsdInference,
    batch: &BatchConfig,
    me: usize,
    tel: &WorkerShard,
    trace: Option<&TraceRing>,
    ledger: &EnergyLedger,
    exec_start: Instant,
    synth_slowdown: f64,
) {
    let n = group.len();
    let head_deadline = group[0].0;
    let knot = match atlas.lookup(head_deadline) {
        Ok(k) => k,
        Err(miss) => {
            // Admission floor-checked every member; this only races atlas
            // swaps. Shed the whole group with the typed reason.
            let reason = Rejection::BelowFloor {
                requested: miss.requested,
                floor: miss.floor,
            };
            for (_, job) in group.drain(..) {
                if let Some(ring) = trace {
                    ring.record(TraceEventKind::Shed, me as u32, job.id, reason.code());
                }
                let _ = job.reply.send(Err(ServeError::Shed(reason.clone())));
            }
            return;
        }
    };
    let mut schedule = knot.schedule.clone();
    schedule.deadline = head_deadline;
    let sim = simulate(&ctx.workload, &ctx.platform, &ctx.model, &schedule);
    let share = batch_share(&sim, n, batch.amortization);

    let predictions: Vec<Prediction> = match runtime {
        Some(rt) => {
            let windows: Vec<&EegWindow> = group.iter().map(|(_, j)| &j.window).collect();
            match infer.infer_staged_batch(rt, &windows) {
                Ok(p) => p,
                Err(e) => {
                    let msg = e.to_string();
                    for (_, job) in group.drain(..) {
                        if let Some(ring) = trace {
                            ring.record(TraceEventKind::Retire, me as u32, job.id, 0);
                        }
                        let _ = job.reply.send(Err(ServeError::Internal(msg.clone())));
                    }
                    return;
                }
            }
        }
        None => stub_predictions(n),
    };

    // Only successful fan-outs count as dispatches (the shed/error paths
    // above return early), keeping batched + solo == recorded requests.
    tel.record_batch(n);
    // Attribute the whole coalesced dispatch once: per-kernel cells scale
    // by the member count, the knot counter and drift EWMA do not. The
    // drift reference is the same sim-anchored batch makespan admission
    // used to admit the group.
    let expected = batch_makespan(knot.sim_time, n, batch.amortization);
    stretch_dispatch(exec_start, synth_slowdown, expected);
    let realized = exec_start.elapsed();
    ledger.record_dispatch(
        me,
        0,
        knot.deadline,
        &schedule.decisions,
        n as u64,
        realized,
        expected,
    );
    if let Some(ring) = trace {
        trace_kernel_spans(ring, me, group[0].1.id, &schedule.decisions, realized);
    }
    for ((deadline, job), prediction) in group.drain(..).zip(predictions) {
        // Guaranteed by batch admission; recomputed rather than assumed so
        // the deadline-monotone property tests observe the real outcome.
        let met = share.batch_time.raw() <= deadline.raw();
        let member_sim = member_report(&sim, share, deadline, ctx.platform.sleep_power, met);
        tel.record(
            prediction.seizure,
            member_sim.deadline_met,
            member_sim.total_energy().raw(),
            member_sim.active_time.raw(),
            job.submitted.elapsed(),
        );
        if let Some(ring) = trace {
            let met = u64::from(member_sim.deadline_met);
            ring.record(TraceEventKind::Retire, me as u32, job.id, met);
        }
        let outcome = InferenceOutcome {
            window_index: job.window.index,
            prediction,
            sim: member_sim,
            scheduler: schedule.scheduler.clone(),
            knot_deadline: knot.deadline,
            batch_size: n,
            host_latency: job.submitted.elapsed(),
        };
        let _ = job.reply.send(Ok(outcome));
    }
}

fn process(
    job: &Job,
    ctx: &ServeContext,
    atlas: &ScheduleAtlas,
    schedules: &mut LruCache<u64, (Schedule, Time)>,
    runtime: Option<&mut Runtime>,
    infer: &TsdInference,
) -> std::result::Result<InferenceOutcome, ServeError> {
    // O(log n) atlas resolution, LRU keyed by the exact deadline bits on
    // top. (Rounding to whole microseconds aliased distinct deadlines to
    // one slot, serving a schedule stamped with the *first* requester's
    // deadline — and collapsed every sub-microsecond deadline to one key.)
    let key = job.deadline.raw().to_bits();
    if !schedules.contains(&key) {
        let knot = atlas.lookup(job.deadline).map_err(|miss| {
            // Admission already floor-checked; this only races atlas swaps.
            ServeError::Shed(Rejection::BelowFloor {
                requested: miss.requested,
                floor: miss.floor,
            })
        })?;
        let mut schedule = knot.schedule.clone();
        schedule.deadline = job.deadline;
        schedules.insert(key, (schedule, knot.deadline));
    }
    // lint: allow(no-unwrap): the branch above inserts the key when absent.
    let (schedule, knot_deadline) = schedules.get(&key).expect("just inserted");
    let knot_deadline = *knot_deadline;

    let sim = simulate(&ctx.workload, &ctx.platform, &ctx.model, schedule);
    let prediction = match runtime {
        Some(rt) => infer
            .infer_staged(rt, &job.window)
            .map_err(|e| ServeError::Internal(e.to_string()))?,
        None => Prediction {
            logits: vec![0.0, 0.0],
            class_idx: 0,
            seizure: false,
        },
    };

    Ok(InferenceOutcome {
        window_index: job.window.index,
        prediction,
        sim,
        scheduler: schedule.scheduler.clone(),
        knot_deadline,
        batch_size: 1,
        host_latency: job.submitted.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eeg::synth::{EegGenerator, SynthConfig};

    fn test_config() -> PoolConfig {
        PoolConfig {
            workers: 2,
            queue_capacity: 64,
            schedule_cache: 8,
            // Nonexistent on purpose: exercises the schedule-only path.
            artifact_dir: PathBuf::from("/nonexistent-artifacts"),
            atlas: AtlasConfig {
                relax_factor: 8.0,
                growth: 1.5,
                refine_rel_energy: 0.05,
                max_knots: 32,
                ..AtlasConfig::default()
            },
            // Spread, not a full literal: future PoolConfig knobs must not
            // break the test build again.
            ..PoolConfig::default()
        }
    }

    #[test]
    fn pool_serves_schedule_only_end_to_end() {
        let pool = ServePool::start(test_config()).unwrap();
        assert_eq!(pool.worker_count(), 2);
        let mut gen = EegGenerator::new(SynthConfig::default(), 7);
        let mut tickets = Vec::new();
        for i in 0..16 {
            let deadline = Time::from_ms(if i % 2 == 0 { 200.0 } else { 1000.0 });
            tickets.push(pool.submit(gen.next_window(), deadline).unwrap());
        }
        for (i, t) in tickets.into_iter().enumerate() {
            let out = t.wait().unwrap();
            assert_eq!(out.window_index, i);
            assert!(out.sim.deadline_met, "window {i}");
            assert_eq!(out.scheduler, "medea");
            assert!(out.knot_deadline.raw() <= Time::from_ms(1000.0).raw() + 1e-12);
            assert_eq!(out.prediction.logits.len(), 2);
        }
        let m = pool.shutdown();
        assert_eq!(m.workers, 2);
        assert_eq!(m.aggregate.requests, 16);
        // Dispatch is round-robin while backlogs stay balanced, but workers
        // drain concurrently with the submit burst, so only the total is
        // deterministic.
        assert_eq!(m.per_worker_requests.iter().sum::<u64>(), 16);
        assert_eq!(m.aggregate.deadline_misses, 0);
        assert_eq!(m.total_shed(), 0);
    }

    #[test]
    fn below_floor_is_shed_at_submit_with_typed_rejection() {
        let pool = ServePool::start(test_config()).unwrap();
        let floor = pool.floor();
        let mut gen = EegGenerator::new(SynthConfig::default(), 8);
        let err = pool.submit(gen.next_window(), floor * 0.5).unwrap_err();
        match err {
            Rejection::BelowFloor { requested, floor: f } => {
                assert!((requested.raw() - floor.raw() * 0.5).abs() < 1e-15);
                assert_eq!(f.raw(), floor.raw());
            }
            other => panic!("expected BelowFloor, got {other:?}"),
        }
        // A feasible request still goes through afterwards.
        let out = pool.infer(gen.next_window(), floor * 4.0).unwrap();
        assert!(out.sim.deadline_met);
        let m = pool.shutdown();
        assert_eq!(m.shed_below_floor, 1);
        assert_eq!(m.aggregate.requests, 1);
    }

    #[test]
    fn dispatch_is_round_robin_until_backlogs_skew() {
        let pick = |depths: &[usize], rr| pick_shard(depths.iter().copied(), rr);
        // Balanced: the round-robin counter decides.
        assert_eq!(pick(&[0, 0, 0], 0), 0);
        assert_eq!(pick(&[0, 0, 0], 4), 1);
        assert_eq!(pick(&[3, 3, 4], 2), 2); // skew 1 < threshold
        // Skewed: the least-backlogged shard wins regardless of the counter.
        assert_eq!(pick(&[5, 0, 5], 0), 1);
        assert_eq!(pick(&[2, 7, 4], 1), 0);
        // Ties on minimum depth resolve to the first such shard.
        assert_eq!(pick(&[9, 0, 0], 2), 1);
    }

    #[test]
    fn backlogged_same_knot_requests_coalesce_into_batches() {
        // One worker + a burst of identical lax deadlines: the backlog that
        // builds while the worker simulates must coalesce, and every member
        // still meets its deadline with the amortized per-member charge.
        let pool = ServePool::start(PoolConfig {
            workers: 1,
            batch: BatchConfig {
                max_batch: 8,
                ..BatchConfig::default()
            },
            ..test_config()
        })
        .unwrap();
        // Far beyond the sweep ceiling (hi ≤ relax_factor × floor), so the
        // batch makespan check structurally admits full batches of the
        // energy-minimal knot: sim_time·scale(8) ≤ 8·floor·6.95 < deadline.
        let lax = pool.floor() * 64.0;
        let mut gen = EegGenerator::new(SynthConfig::default(), 21);
        let tickets: Vec<Ticket> = (0..64)
            .map(|_| pool.submit(gen.next_window(), lax).unwrap())
            .collect();
        let mut max_seen = 0;
        for t in tickets {
            let out = t.wait().unwrap();
            assert!(out.sim.deadline_met);
            assert!(out.batch_size >= 1 && out.batch_size <= 8);
            max_seen = max_seen.max(out.batch_size);
        }
        let m = pool.shutdown();
        assert_eq!(m.aggregate.requests, 64);
        assert_eq!(m.aggregate.deadline_misses, 0);
        assert_eq!(m.batched_requests() + m.solo_requests(), 64);
        // The dispatch histogram accounts for every request exactly once.
        let hist_requests: u64 = m
            .batch_histogram()
            .iter()
            .enumerate()
            .map(|(i, &n)| (i as u64 + 1) * n)
            .sum();
        assert_eq!(hist_requests, 64);
        // The burst outpaces a single worker simulating every dispatch, so
        // at least one multi-request batch must have formed.
        assert!(
            max_seen >= 2,
            "expected at least one coalesced dispatch, got only solos"
        );
    }

    #[test]
    fn solo_batch_config_is_the_legacy_path() {
        let pool = ServePool::start(PoolConfig {
            batch: BatchConfig::solo(),
            ..test_config()
        })
        .unwrap();
        let mut gen = EegGenerator::new(SynthConfig::default(), 22);
        let tickets: Vec<Ticket> = (0..8)
            .map(|_| pool.submit(gen.next_window(), Time::from_ms(400.0)).unwrap())
            .collect();
        for t in tickets {
            let out = t.wait().unwrap();
            assert_eq!(out.batch_size, 1);
            assert!(out.sim.deadline_met);
        }
        let m = pool.shutdown();
        assert_eq!(m.batched_requests(), 0);
        assert_eq!(m.solo_requests(), 8);
    }

    #[test]
    fn shutdown_drains_queued_work() {
        let pool = ServePool::start(test_config()).unwrap();
        let mut gen = EegGenerator::new(SynthConfig::default(), 9);
        let tickets: Vec<Ticket> = (0..8)
            .map(|_| pool.submit(gen.next_window(), Time::from_ms(500.0)).unwrap())
            .collect();
        // Shut down immediately: queued jobs must still be answered.
        let m = pool.shutdown();
        assert_eq!(m.aggregate.requests, 8);
        for t in tickets {
            assert!(t.wait().is_ok());
        }
    }

    #[test]
    fn head_laxity_bounds_the_fill_wait() {
        let now = Instant::now();
        // 100 ms deadline, 40 ms of on-device work left: ~60 ms of slack.
        let lax = head_laxity(Time::from_ms(100.0), Time::from_ms(40.0), now);
        assert!(lax <= Duration::from_millis(60));
        assert!(lax >= Duration::from_millis(40), "{lax:?}");
        // No slack (or garbage) never goes negative / panics.
        assert_eq!(
            head_laxity(Time::from_ms(10.0), Time::from_ms(40.0), now),
            Duration::ZERO
        );
        assert!(head_laxity(Time(f64::INFINITY), Time::ZERO, now) > Duration::from_secs(60));
        // Queue wait already consumed is subtracted.
        let lax = head_laxity(Time::from_ms(100.0), Time::from_ms(99.9), now);
        assert!(lax <= Duration::from_micros(100));
    }

    #[test]
    fn fill_window_is_clamped_to_head_laxity() {
        // Regression (deadline hole): a long --batch-window-us must not
        // consume a tight head's entire laxity before dispatch. One request
        // can never fill an 8-batch, so pre-clamp the worker sat out the
        // whole 2 s window before dispatching.
        let pool = ServePool::start(PoolConfig {
            workers: 1,
            batch: BatchConfig {
                max_batch: 8,
                window: Duration::from_secs(2),
                ..BatchConfig::default()
            },
            ..test_config()
        })
        .unwrap();
        let deadline = pool.floor() * 1.1;
        let mut gen = EegGenerator::new(SynthConfig::default(), 23);
        let start = Instant::now();
        let out = pool.infer(gen.next_window(), deadline).unwrap();
        let elapsed = start.elapsed();
        assert!(out.sim.deadline_met);
        // The head's laxity is deadline − sim_time, a few ms at 1.1× the
        // floor — orders of magnitude under the configured 2 s window.
        assert!(
            elapsed < Duration::from_secs(1),
            "fill window ignored head laxity: dispatch took {elapsed:?}"
        );
        pool.shutdown();
    }

    #[test]
    fn solo_cache_distinguishes_nearby_deadlines() {
        // Regression (cache-key collision): rounding the LRU key to whole
        // microseconds aliased distinct deadlines, serving a schedule
        // stamped with the *first* requester's deadline.
        let pool = ServePool::start(PoolConfig {
            workers: 1,
            batch: BatchConfig::solo(),
            ..test_config()
        })
        .unwrap();
        let mut gen = EegGenerator::new(SynthConfig::default(), 24);
        let d1 = Time::from_ms(200.0);
        let d2 = Time(d1.raw() + 3e-7); // +0.3 µs: same µs-rounded key
        let out1 = pool.infer(gen.next_window(), d1).unwrap();
        let out2 = pool.infer(gen.next_window(), d2).unwrap();
        // Same covering knot ⇒ identical active time; the sleep window is
        // re-derived from the *stamped* deadline, so it must differ by
        // exactly the deadline gap.
        assert_eq!(out1.knot_deadline.raw(), out2.knot_deadline.raw());
        assert!((out1.sim.active_time.raw() - out2.sim.active_time.raw()).abs() < 1e-15);
        let gap = out2.sim.sleep_time.raw() - out1.sim.sleep_time.raw();
        assert!(
            (gap - 3e-7).abs() < 1e-12,
            "second request served a schedule stamped with the first's deadline (sleep gap {gap:e})"
        );
        pool.shutdown();
    }

    #[test]
    fn idle_workers_steal_from_a_backlogged_sibling() {
        // Everything lands on shard 0 while worker 1 idles: exactly the
        // stuck-shard scenario stealing exists for. Worker 0 alone needs
        // many multi-ms dispatches to drain 64 jobs; shard 0's backlog
        // posts steal wakes to idle worker 1 (with the heartbeat poll as
        // fallback), so it must lift at least one group.
        let pool = ServePool::start(test_config()).unwrap();
        let floor = pool.floor();
        let mut gen = EegGenerator::new(SynthConfig::default(), 25);
        let tickets: Vec<Ticket> = (0..64)
            .map(|i| {
                let deadline = floor * if i % 2 == 0 { 4.0 } else { 6.0 };
                pool.submit_pinned(0, gen.next_window(), deadline).unwrap()
            })
            .collect();
        for t in tickets {
            assert!(t.wait().unwrap().sim.deadline_met);
        }
        let m = pool.shutdown();
        assert_eq!(m.aggregate.requests, 64);
        assert_eq!(m.aggregate.deadline_misses, 0);
        assert!(
            m.aggregate.steals >= 1,
            "idle sibling never stole from the loaded shard: {}",
            m.summary()
        );
        assert!(m.aggregate.stolen_requests >= m.aggregate.steals);
    }

    #[test]
    fn thieves_leave_fill_window_victims_alone() {
        // A victim mid-fill-window is waiting for stragglers, not stuck:
        // an idle sibling must not lift the partially-filled group, or a
        // configured --batch-window-us silently stops amortizing whenever
        // any worker idles. Four slow trickled submissions must still
        // coalesce into one batch of 4 with zero steals.
        let pool = ServePool::start(PoolConfig {
            workers: 2,
            batch: BatchConfig {
                max_batch: 4,
                window: Duration::from_millis(300),
                ..BatchConfig::default()
            },
            steal: StealConfig {
                poll: Duration::from_millis(50),
                ..StealConfig::default()
            },
            ..test_config()
        })
        .unwrap();
        let lax = pool.floor() * 64.0;
        let mut gen = EegGenerator::new(SynthConfig::default(), 27);
        let mut tickets = Vec::new();
        for _ in 0..4 {
            tickets.push(pool.submit_pinned(0, gen.next_window(), lax).unwrap());
            std::thread::sleep(Duration::from_millis(20));
        }
        for t in tickets {
            let out = t.wait().unwrap();
            assert_eq!(
                out.batch_size, 4,
                "fill window was cut short mid-fill (stolen or dispatched early)"
            );
            assert!(out.sim.deadline_met);
        }
        let m = pool.shutdown();
        assert_eq!(m.aggregate.steals, 0, "{}", m.summary());
    }

    #[test]
    fn no_steal_pins_jobs_to_their_shard() {
        let pool = ServePool::start(PoolConfig {
            steal: StealConfig::disabled(),
            ..test_config()
        })
        .unwrap();
        let floor = pool.floor();
        let mut gen = EegGenerator::new(SynthConfig::default(), 26);
        let tickets: Vec<Ticket> = (0..16)
            .map(|_| pool.submit_pinned(0, gen.next_window(), floor * 4.0).unwrap())
            .collect();
        for t in tickets {
            assert!(t.wait().is_ok());
        }
        let m = pool.shutdown();
        assert_eq!(m.aggregate.requests, 16);
        assert_eq!(m.aggregate.steals, 0);
        assert_eq!(m.aggregate.stolen_requests, 0);
        // With stealing disabled every pinned job is served by its own
        // shard's worker.
        assert_eq!(m.per_worker_requests, vec![16, 0]);
    }

    #[test]
    fn dispatches_feed_the_energy_ledger_and_kernel_spans() {
        let pool = ServePool::start(PoolConfig {
            workers: 1,
            telemetry: TelemetryConfig { trace_events: 1024 },
            ..test_config()
        })
        .unwrap();
        let deadline = Time::from_ms(400.0);
        let kernels = pool.atlas().lookup(deadline).unwrap().schedule.decisions.len();
        assert!(kernels > 0);
        let mut gen = EegGenerator::new(SynthConfig::default(), 31);
        for _ in 0..4 {
            assert!(pool.infer(gen.next_window(), deadline).is_ok());
        }
        let snap = pool.telemetry().snapshot();
        let ledger = snap.ledger.as_ref().expect("serve pool installs a ledger");
        assert_eq!(ledger.unattributed, 0);
        let e = &ledger.entries[0];
        assert_eq!(e.knot_dispatches.iter().sum::<u64>(), 4);
        assert!(e.pe_busy_ns.iter().sum::<u64>() > 0);
        assert!(e.pe_energy_nj.iter().sum::<u64>() > 0);
        // Every dispatch emitted one span per schedule decision.
        let spans = pool
            .trace()
            .expect("trace ring enabled")
            .events()
            .iter()
            .filter(|e| e.kind == TraceEventKind::KernelSpan)
            .count();
        assert_eq!(spans, 4 * kernels);
        pool.shutdown();
    }

    #[test]
    fn synth_slowdown_inflates_the_drift_ratio() {
        // Stretch every dispatch to 2× its modeled time: the realized/
        // modeled EWMA must sit at ≥ 2× (the sleep guarantees the realized
        // wall time, so the ratio is bounded below, not just approximate).
        let pool = ServePool::start(PoolConfig {
            workers: 1,
            synth_slowdown: 2.0,
            ..test_config()
        })
        .unwrap();
        let deadline = pool.floor() * 1.05;
        let mut gen = EegGenerator::new(SynthConfig::default(), 32);
        for _ in 0..2 {
            assert!(pool.infer(gen.next_window(), deadline).is_ok());
        }
        let snap = pool.telemetry().snapshot();
        let drift = snap.drift_ratio();
        assert!(drift >= 2.0, "stretched dispatches must read ≥ 2×, got {drift}");
        pool.shutdown();
    }

    fn mesh_shards(n: usize) -> Vec<Arc<Shard<u32>>> {
        (0..n).map(|_| Arc::new(Shard::new(EdfQueue::new(4)))).collect()
    }

    #[test]
    fn gate_park_consumes_a_posted_token() {
        let shard = Arc::new(Shard::new(EdfQueue::<u32>::new(4)));
        // A pre-posted token is consumed without sleeping.
        shard.ring();
        assert!(shard.park(Some(Duration::ZERO)));
        // Consumed: the next zero-timeout park is a heartbeat expiry.
        assert!(!shard.park(Some(Duration::ZERO)));
        // Ringing twice coalesces into one token.
        shard.ring();
        shard.ring();
        assert!(shard.park(Some(Duration::ZERO)));
        assert!(!shard.park(Some(Duration::ZERO)));
    }

    #[test]
    fn steal_mesh_targets_the_longest_idle_thief() {
        let shards = mesh_shards(3);
        let mesh = StealMesh::new(3, &StealConfig::default());
        mesh.mark_idle(1); // idle first ⇒ longest idle
        mesh.mark_idle(2);
        mesh.wake_for_backlog(0, 2, &shards);
        assert!(mesh.consume_wake(1).is_some(), "longest-idle thief not picked");
        assert!(mesh.consume_wake(2).is_none());
        // The wake rang thief 1's gate (and nobody else's).
        assert!(shards[1].park(Some(Duration::ZERO)));
        assert!(!shards[2].park(Some(Duration::ZERO)));
        // Once worker 1 is active again, the wake goes to the next thief.
        mesh.mark_active(1);
        mesh.wake_for_backlog(0, 2, &shards);
        assert!(mesh.consume_wake(2).is_some());
    }

    #[test]
    fn steal_mesh_dedups_pending_wakes() {
        let shards = mesh_shards(2);
        let mesh = StealMesh::new(2, &StealConfig::default());
        mesh.mark_idle(1);
        mesh.wake_for_backlog(0, 2, &shards);
        mesh.wake_for_backlog(0, 3, &shards);
        // Two backlogged submits, one outstanding wake and one gate token.
        assert!(shards[1].park(Some(Duration::ZERO)));
        assert!(!shards[1].park(Some(Duration::ZERO)));
        assert!(mesh.consume_wake(1).is_some());
        assert!(mesh.consume_wake(1).is_none());
    }

    #[test]
    fn steal_mesh_honors_threshold_and_disabled() {
        let shards = mesh_shards(2);
        // Below the wake threshold: the victim's own worker absorbs it.
        let mesh = StealMesh::new(2, &StealConfig::default());
        mesh.mark_idle(1);
        mesh.wake_for_backlog(0, 1, &shards);
        assert!(mesh.consume_wake(1).is_none());
        // Stealing disabled: no wakes no matter the depth.
        let mesh = StealMesh::new(2, &StealConfig::disabled());
        mesh.mark_idle(1);
        mesh.wake_for_backlog(0, 100, &shards);
        assert!(mesh.consume_wake(1).is_none());
        // A lone worker has nobody to wake.
        let mesh = StealMesh::new(1, &StealConfig::default());
        mesh.wake_for_backlog(0, 100, &shards);
        assert!(mesh.consume_wake(0).is_none());
    }
}
