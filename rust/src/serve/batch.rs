//! Batched admission: coalesce compatible queued requests into one dispatch.
//!
//! MEDEA amortizes per-invocation overhead at *design time* (one solve per
//! atlas knot, zero on the request path); this module amortizes it at
//! *dispatch time*. When several admitted requests resolve to the same atlas
//! knot — the common case under heavy traffic, where a handful of knots
//! serve the whole deadline mix — they execute as one dispatch: a single
//! event-level replay of the shared schedule, and a single amortized
//! inference invocation ([`crate::runtime::client::Runtime::run_f32_batch`];
//! a true stacked `[n, …]` PJRT execute — `run_f32_stacked` — additionally
//! needs the artifact exported batch-shaped, an open ROADMAP item). The
//! makespan model below prices the *on-device* side of that coalescing: the
//! per-invocation wakeup/dispatch/DMA-priming overhead the simulator grounds.
//!
//! The makespan model is anchored on each knot's **sim-validated** solo
//! active time `t₁` (recorded when the knot passed event-level replay at
//! build time): a batch of `n` compatible windows completes in
//!
//! ```text
//! makespan(n) = t₁ · scale(n)        scale(n) = 1 + a·(n − 1)
//! ```
//!
//! where `a ∈ (0, 1]` is the calibrated marginal-cost (amortization) factor:
//! the fraction of a solo invocation that is true per-window work, the rest
//! being dispatch/setup recovered by batching. `a = 1` degenerates to solo
//! cost (batching buys nothing, but also never risks anything); smaller `a`
//! models more recoverable overhead.
//!
//! **Deadline monotonicity** (the safety property the admission check and
//! the property tests pin): a batch is only formed when `makespan(n)` fits
//! the *earliest* member deadline. Members pop in EDF order, so every other
//! member is laxer, and `scale(1) = 1` means a batch of one is exactly the
//! solo path — batching can never violate a deadline the solo path would
//! have met.
//!
//! **Energy duality**: total batch active energy scales like the makespan
//! (same power envelope, shorter aggregate runtime), so the per-member share
//! `E₁ · scale(n) / n` is non-increasing in `n`. Energy-budget members admit
//! a new member only when the share still fits every member's requested cap
//! — the dual [`crate::fleet::energy::EnergyAtlas`] check.

use crate::runtime::infer::Prediction;
use crate::sim::replay::SimReport;
use crate::util::units::{Energy, Power, Time};
use std::time::Duration;

/// Batch-admission knobs shared by [`crate::serve::pool::ServePool`] and
/// [`crate::fleet::pool::FleetPool`].
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Largest number of requests coalesced into one dispatch; `1` disables
    /// batching (the exact legacy solo path).
    pub max_batch: usize,
    /// How long a worker waits for stragglers when the backlog cannot fill
    /// a batch. `0` dispatches whatever is already queued (opportunistic
    /// batching only — no added latency).
    pub window: Duration,
    /// Marginal per-member cost fraction `a` in `(0, 1]` of the sublinear
    /// makespan model `t₁·(1 + a·(n−1))`.
    pub amortization: f64,
    /// Autotune the *effective* fill window per worker between `0` and
    /// [`BatchConfig::window`] from the observed batch fill ratio (see
    /// [`WindowAutotuner`]): starved batches stretch the wait toward
    /// `window`, bursts dispatch immediately. `false` uses `window` as-is.
    pub auto: bool,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            max_batch: 8,
            window: Duration::ZERO,
            // ~15 % of a solo invocation modeled as fixed wakeup/dispatch/
            // DMA-priming overhead recovered by coalescing.
            amortization: 0.85,
            auto: false,
        }
    }
}

impl BatchConfig {
    /// The solo-dispatch configuration (exact legacy behavior).
    pub fn solo() -> BatchConfig {
        BatchConfig {
            max_batch: 1,
            ..BatchConfig::default()
        }
    }

    /// Clamp into the ranges the makespan model is valid for.
    pub fn sanitized(mut self) -> BatchConfig {
        self.max_batch = self.max_batch.max(1);
        if !(self.amortization > 0.0 && self.amortization <= 1.0) {
            self.amortization = 1.0; // NaN/out-of-range ⇒ no amortization claimed
        }
        self
    }
}

/// `scale(n) = 1 + a·(n − 1)`: batch makespan as a multiple of the solo
/// sim-validated time. `scale(1) = 1` exactly, so batch admission with
/// `n = 1` is the solo feasibility check.
pub fn batch_scale(n: usize, amortization: f64) -> f64 {
    1.0 + amortization * (n.saturating_sub(1)) as f64
}

/// Batch makespan from a sim-validated solo time anchor:
/// `unit_time · scale(n)`. The single source of truth for every admission
/// check ([`crate::serve::atlas::AtlasKnot::batch_makespan`], the pools'
/// grow predicates, and [`batch_share`] all delegate here).
pub fn batch_makespan(unit_time: Time, n: usize, amortization: f64) -> Time {
    Time(unit_time.raw() * batch_scale(n, amortization))
}

/// Amortized per-member active-energy share from a solo energy anchor:
/// `unit_energy · scale(n) / n`, non-increasing in `n`. The single source
/// of truth for the dual budget check
/// ([`crate::fleet::energy::EnergyKnot::batch_energy_per_member`] and the
/// fleet pool's grow predicate delegate here).
pub fn batch_energy_share(unit_energy: Energy, n: usize, amortization: f64) -> Energy {
    let n = n.max(1);
    Energy(unit_energy.raw() * batch_scale(n, amortization) / n as f64)
}

/// Adapts the effective batch fill window to the observed arrival rate.
///
/// One per worker (plain state, no sharing): each dispatch reports its group
/// size via [`WindowAutotuner::observe`], which folds the fill ratio
/// `group / max_batch` into an EWMA. The effective window is
/// `window · (1 − fill)`:
///
/// * **starved** (solo dispatches, fill → 0) — stretch the wait toward the
///   configured `--batch-window-us` ceiling, buying stragglers time to
///   coalesce;
/// * **burst** (full batches, fill → 1) — the backlog fills batches by
///   itself, so dispatch immediately and spend nothing on waiting.
///
/// With `auto` off (or a zero ceiling) this is a constant: exactly the
/// configured window, no state consulted.
#[derive(Debug, Clone)]
pub struct WindowAutotuner {
    max: Duration,
    target: f64,
    fill: f64,
    auto: bool,
}

/// EWMA gain per dispatch: ~12 dispatches to move 95 % of the way to a new
/// steady state — fast enough to catch a burst, slow enough not to flap on
/// one odd group.
const AUTOTUNE_GAIN: f64 = 0.25;

impl WindowAutotuner {
    pub fn new(batch: &BatchConfig) -> WindowAutotuner {
        WindowAutotuner {
            max: batch.window,
            target: batch.max_batch.max(1) as f64,
            fill: 0.0,
            auto: batch.auto,
        }
    }

    /// Fold one dispatched group size into the fill EWMA.
    pub fn observe(&mut self, group_len: usize) {
        if !self.auto {
            return;
        }
        let ratio = (group_len as f64 / self.target).clamp(0.0, 1.0);
        self.fill += AUTOTUNE_GAIN * (ratio - self.fill);
    }

    /// The fill window the next dispatch episode should wait for.
    pub fn effective(&self) -> Duration {
        if !self.auto {
            return self.max;
        }
        self.max.mul_f64((1.0 - self.fill).clamp(0.0, 1.0))
    }
}

/// Per-member accounting for one coalesced dispatch, derived from a single
/// fresh event-level replay of the shared schedule. Shared by the serve and
/// fleet pools so the amortization math cannot drift between them.
#[derive(Debug, Clone, Copy)]
pub(crate) struct BatchShare {
    /// Completion time of every member (the batch makespan): what deadline
    /// checks and sleep windows are judged against.
    pub(crate) batch_time: Time,
    /// Amortized active-time charge per member (`batch_time / n`). Member
    /// shares sum to the true batch device time, so aggregated
    /// `sim_active_s` stays honest under batching — mirroring the energy
    /// share.
    pub(crate) member_time: Time,
    /// Amortized active-energy charge per member.
    pub(crate) member_energy: Energy,
}

pub(crate) fn batch_share(sim: &SimReport, n: usize, amortization: f64) -> BatchShare {
    let n = n.max(1);
    let batch_time = batch_makespan(sim.active_time, n, amortization);
    BatchShare {
        batch_time,
        member_time: Time(batch_time.raw() / n as f64),
        member_energy: batch_energy_share(sim.active_energy, n, amortization),
    }
}

/// Clone the shared replay into one member's report: the amortized
/// active-time and active-energy *shares* (so per-request aggregates sum to
/// the true batch totals), with the sleep window re-derived against
/// `sleep_deadline` from the batch *completion* time (the device sleeps
/// only once the whole batch finishes), mirroring the simulator's
/// `sleep = max(0, deadline − active)` accounting.
pub(crate) fn member_report(
    sim: &SimReport,
    share: BatchShare,
    sleep_deadline: Time,
    sleep_power: Power,
    deadline_met: bool,
) -> SimReport {
    let mut r = sim.clone();
    r.active_time = share.member_time;
    r.active_energy = share.member_energy;
    r.sleep_time = Time((sleep_deadline.raw() - share.batch_time.raw()).max(0.0));
    r.sleep_energy = sleep_power * r.sleep_time;
    r.deadline_met = deadline_met;
    r
}

/// Placeholder predictions for schedule-only serving (no PJRT runtime).
pub(crate) fn stub_predictions(n: usize) -> Vec<Prediction> {
    (0..n)
        .map(|_| Prediction {
            logits: vec![0.0, 0.0],
            class_idx: 0,
            seizure: false,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_anchors_at_solo() {
        assert_eq!(batch_scale(1, 0.85), 1.0);
        assert_eq!(batch_scale(0, 0.85), 1.0); // degenerate, clamped
        assert!((batch_scale(8, 1.0) - 8.0).abs() < 1e-12);
        assert!((batch_scale(8, 0.5) - 4.5).abs() < 1e-12);
    }

    #[test]
    fn scale_is_monotone_and_sublinear() {
        for &a in &[0.1, 0.5, 0.85, 1.0] {
            for n in 1..32usize {
                let s_n = batch_scale(n, a);
                let s_next = batch_scale(n + 1, a);
                assert!(s_next > s_n, "scale must grow with batch size");
                // Sublinear: per-member cost never exceeds solo cost.
                assert!(s_next / (n + 1) as f64 <= 1.0 + 1e-12);
                // Per-member cost is non-increasing in n (energy-share
                // monotonicity the fleet's dual budget check relies on).
                assert!(s_next / (n + 1) as f64 <= s_n / n as f64 + 1e-12);
            }
        }
    }

    #[test]
    fn sanitize_clamps_nonsense() {
        let c = BatchConfig {
            max_batch: 0,
            amortization: f64::NAN,
            ..BatchConfig::default()
        }
        .sanitized();
        assert_eq!(c.max_batch, 1);
        assert_eq!(c.amortization, 1.0);
        let c = BatchConfig {
            amortization: -3.0,
            ..BatchConfig::default()
        }
        .sanitized();
        assert_eq!(c.amortization, 1.0);
        assert_eq!(BatchConfig::solo().max_batch, 1);
    }

    fn tuned(window_us: u64, auto: bool) -> WindowAutotuner {
        WindowAutotuner::new(&BatchConfig {
            window: Duration::from_micros(window_us),
            auto,
            ..BatchConfig::default()
        })
    }

    #[test]
    fn autotuner_disabled_is_the_static_window() {
        let mut t = tuned(500, false);
        assert_eq!(t.effective(), Duration::from_micros(500));
        for _ in 0..100 {
            t.observe(8); // full batches would normally shrink the window
        }
        assert_eq!(t.effective(), Duration::from_micros(500));
    }

    #[test]
    fn autotuner_starts_stretched_and_stays_there_when_starved() {
        let mut t = tuned(500, true);
        // Nothing observed yet ⇒ assume starved, wait the full window.
        assert_eq!(t.effective(), Duration::from_micros(500));
        for _ in 0..50 {
            t.observe(1); // solo dispatches: starved
        }
        // Solo against max_batch 8 keeps fill low: ≥ 80 % of the ceiling.
        assert!(t.effective() >= Duration::from_micros(400), "{:?}", t.effective());
    }

    #[test]
    fn autotuner_collapses_under_burst_and_recovers() {
        let mut t = tuned(500, true);
        let mut prev = t.effective();
        for _ in 0..30 {
            t.observe(8); // full batches: burst
            let now = t.effective();
            assert!(now <= prev, "window must shrink monotonically under burst");
            prev = now;
        }
        assert!(prev <= Duration::from_micros(5), "{prev:?}");
        // Arrival rate drops again: the window stretches back out.
        for _ in 0..30 {
            t.observe(1);
        }
        assert!(t.effective() >= Duration::from_micros(300), "{:?}", t.effective());
    }

    #[test]
    fn autotuner_zero_ceiling_never_waits() {
        let mut t = tuned(0, true);
        t.observe(1);
        assert_eq!(t.effective(), Duration::ZERO);
    }
}
