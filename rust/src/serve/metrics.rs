//! Cross-worker serving metrics.
//!
//! Workers record into the pool's live [`TelemetryRegistry`];
//! [`ServeMetrics::from_registry`] derives this aggregated view from a
//! registry snapshot — the *same* read whether taken mid-run
//! (`ServePool::live_metrics`, the Prometheus endpoint) or at shutdown, so
//! live and final numbers can never drift apart. The admission-side shed
//! counters ride on the registry too (shed requests never reach a worker).

use crate::coordinator::Metrics;
use crate::telemetry::TelemetryRegistry;
use crate::util::json::{Json, JsonObj};
use std::time::Duration;

/// Aggregated view over a pool run.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Number of workers that contributed.
    pub workers: usize,
    /// Per-worker request counts (diagnostic for dispatch balance).
    pub per_worker_requests: Vec<u64>,
    /// All worker metrics merged.
    pub aggregate: Metrics,
    /// Requests shed because the deadline (or energy budget) was below the
    /// corresponding atlas floor.
    pub shed_below_floor: u64,
    /// Requests shed because the admission queue was full.
    pub shed_queue_full: u64,
    /// Requests shed because no atlas was published for the requested
    /// (platform, workload) pair — fleet routing only, 0 elsewhere.
    pub shed_unknown_entry: u64,
}

impl ServeMetrics {
    /// Merge per-worker metrics with the pool's shed counters.
    pub fn aggregate(
        per_worker: Vec<Metrics>,
        shed_below_floor: u64,
        shed_queue_full: u64,
    ) -> ServeMetrics {
        let mut agg = Metrics::default();
        let mut per_worker_requests = Vec::with_capacity(per_worker.len());
        for m in &per_worker {
            per_worker_requests.push(m.requests);
            agg.merge(m);
        }
        ServeMetrics {
            workers: per_worker.len(),
            per_worker_requests,
            aggregate: agg,
            shed_below_floor,
            shed_queue_full,
            shed_unknown_entry: 0,
        }
    }

    /// Attach the fleet router's unknown-entry shed count.
    pub fn with_unknown_entries(mut self, shed_unknown_entry: u64) -> ServeMetrics {
        self.shed_unknown_entry = shed_unknown_entry;
        self
    }

    /// Derive the aggregated view from a live telemetry registry — the one
    /// code path behind both `live_metrics()` and shutdown.
    pub fn from_registry(registry: &TelemetryRegistry) -> ServeMetrics {
        let snap = registry.snapshot();
        let per_worker: Vec<Metrics> = snap.workers.iter().map(|w| w.to_metrics()).collect();
        ServeMetrics::aggregate(per_worker, snap.shed_below_floor, snap.shed_queue_full)
            .with_unknown_entries(snap.shed_unknown_entry)
    }

    pub fn total_shed(&self) -> u64 {
        self.shed_below_floor + self.shed_queue_full + self.shed_unknown_entry
    }

    /// Dispatch-size histogram merged across workers (`[i]` = dispatches of
    /// `i + 1` coalesced requests).
    pub fn batch_histogram(&self) -> &[u64] {
        &self.aggregate.batch_hist
    }

    /// Requests that rode a multi-request dispatch.
    pub fn batched_requests(&self) -> u64 {
        self.aggregate.batched_requests()
    }

    /// Requests dispatched solo.
    pub fn solo_requests(&self) -> u64 {
        self.aggregate.solo_requests()
    }

    /// Steal events across all workers (dispatch groups lifted from a
    /// sibling shard's queue by an otherwise idle worker).
    pub fn steals(&self) -> u64 {
        self.aggregate.steals
    }

    /// Requests served through stolen dispatches.
    pub fn stolen_requests(&self) -> u64 {
        self.aggregate.stolen_requests
    }

    pub fn p50(&self) -> Duration {
        self.aggregate.host_latency_p50()
    }

    pub fn p99(&self) -> Duration {
        self.aggregate.host_latency_p99()
    }

    pub fn summary(&self) -> String {
        format!(
            "workers={} requests={} [{}] batched={} solo={} steals={} (stolen_reqs={}) misses={} shed={} (floor={} full={} unknown={}) energy={:.1} uJ p50={:?} p99={:?}",
            self.workers,
            self.aggregate.requests,
            self.per_worker_requests
                .iter()
                .map(|n| n.to_string())
                .collect::<Vec<_>>()
                .join("/"),
            self.batched_requests(),
            self.solo_requests(),
            self.steals(),
            self.stolen_requests(),
            self.aggregate.deadline_misses,
            self.total_shed(),
            self.shed_below_floor,
            self.shed_queue_full,
            self.shed_unknown_entry,
            self.aggregate.sim_energy_j * 1e6,
            self.p50(),
            self.p99(),
        )
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("workers", self.workers);
        o.insert("requests", self.aggregate.requests);
        o.insert(
            "per_worker_requests",
            Json::Arr(self.per_worker_requests.iter().map(|&n| Json::from(n)).collect()),
        );
        o.insert("deadline_misses", self.aggregate.deadline_misses);
        o.insert("batched_requests", self.batched_requests());
        o.insert("solo_requests", self.solo_requests());
        o.insert(
            "batch_hist",
            Json::Arr(self.batch_histogram().iter().map(|&n| Json::from(n)).collect()),
        );
        o.insert("steals", self.steals());
        o.insert("stolen_requests", self.stolen_requests());
        o.insert("shed_below_floor", self.shed_below_floor);
        o.insert("shed_queue_full", self.shed_queue_full);
        o.insert("shed_unknown_entry", self.shed_unknown_entry);
        o.insert("sim_energy_uj", self.aggregate.sim_energy_j * 1e6);
        o.insert("sim_active_ms", self.aggregate.sim_active_s * 1e3);
        o.insert("host_p50_us", self.p50().as_secs_f64() * 1e6);
        o.insert("host_p99_us", self.p99().as_secs_f64() * 1e6);
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_across_workers() {
        let mut w0 = Metrics::default();
        w0.record(false, true, 100e-6, 0.01, Duration::from_millis(1));
        w0.record(true, true, 100e-6, 0.01, Duration::from_millis(3));
        let mut w1 = Metrics::default();
        w1.record(false, false, 200e-6, 0.02, Duration::from_millis(9));
        let m = ServeMetrics::aggregate(vec![w0, w1], 4, 2);
        assert_eq!(m.workers, 2);
        assert_eq!(m.aggregate.requests, 3);
        assert_eq!(m.per_worker_requests, vec![2, 1]);
        assert_eq!(m.aggregate.deadline_misses, 1);
        assert_eq!(m.total_shed(), 6);
        assert!(m.p99() >= m.p50());
        let s = m.summary();
        assert!(s.contains("workers=2") && s.contains("shed=6"), "{s}");
        let j = m.to_json();
        assert_eq!(j.get("requests").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("shed_below_floor").unwrap().as_u64(), Some(4));
        let m = m.with_unknown_entries(3);
        assert_eq!(m.total_shed(), 9);
        assert!(m.summary().contains("unknown=3"));
        assert_eq!(m.to_json().get("shed_unknown_entry").unwrap().as_u64(), Some(3));
    }

    #[test]
    fn percentiles_hold_on_degenerate_windows() {
        // Empty window: both percentiles are zero (p99 ≥ p50 trivially).
        let m = ServeMetrics::aggregate(vec![Metrics::default()], 0, 0);
        assert_eq!(m.p50(), Duration::ZERO);
        assert_eq!(m.p99(), Duration::ZERO);
        assert!(m.p99() >= m.p50());
        // One sample: every percentile is that sample.
        let mut w = Metrics::default();
        w.record(false, true, 0.0, 0.0, Duration::from_millis(7));
        let m = ServeMetrics::aggregate(vec![w], 0, 0);
        assert_eq!(m.p50(), Duration::from_millis(7));
        assert_eq!(m.p99(), Duration::from_millis(7));
        assert!(m.p99() >= m.p50());
    }

    #[test]
    fn batch_counters_surface_in_summary_and_json() {
        let mut w0 = Metrics::default();
        for _ in 0..4 {
            w0.record(false, true, 1e-6, 0.01, Duration::from_millis(1));
        }
        w0.record_batch(4); // one dispatch of 4
        w0.record_steal(4); // ... which was stolen from a sibling shard
        let mut w1 = Metrics::default();
        w1.record(false, true, 1e-6, 0.01, Duration::from_millis(1));
        w1.record_batch(1); // one solo dispatch
        let m = ServeMetrics::aggregate(vec![w0, w1], 0, 0);
        assert_eq!(m.batched_requests(), 4);
        assert_eq!(m.solo_requests(), 1);
        assert_eq!(m.steals(), 1);
        assert_eq!(m.stolen_requests(), 4);
        assert_eq!(m.batch_histogram(), &[1, 0, 0, 1]);
        let s = m.summary();
        assert!(s.contains("batched=4") && s.contains("solo=1"), "{s}");
        assert!(s.contains("steals=1") && s.contains("stolen_reqs=4"), "{s}");
        let j = m.to_json();
        assert_eq!(j.get("batched_requests").unwrap().as_u64(), Some(4));
        assert_eq!(j.get("solo_requests").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("steals").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("stolen_requests").unwrap().as_u64(), Some(4));
        assert_eq!(j.get("batch_hist").unwrap().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn from_registry_mirrors_shard_recordings() {
        use crate::serve::queue::Rejection;
        use crate::telemetry::TelemetryRegistry;
        let reg = TelemetryRegistry::new("heeptimize", "tsd-core", 2);
        reg.worker(0).record(false, true, 100e-6, 0.01, Duration::from_millis(2));
        reg.worker(0).record_batch(1);
        reg.worker(1).record(false, false, 200e-6, 0.02, Duration::from_millis(6));
        reg.worker(1).record_batch(1);
        reg.record_shed(&Rejection::QueueFull { capacity: 4 });
        reg.record_shed(&Rejection::UnknownEntry { platform: "x".into(), workload: "y".into() });
        let m = ServeMetrics::from_registry(&reg);
        assert_eq!(m.workers, 2);
        assert_eq!(m.aggregate.requests, 2);
        assert_eq!(m.per_worker_requests, vec![1, 1]);
        assert_eq!(m.aggregate.deadline_misses, 1);
        assert_eq!(m.shed_queue_full, 1);
        assert_eq!(m.shed_unknown_entry, 1);
        assert_eq!(m.total_shed(), 2);
        assert_eq!(m.p99(), Duration::from_millis(6));
    }

    /// Golden shape test: the exported JSON keys (and their order) are load
    /// bearing for `BENCH_*.json` consumers — renames must be deliberate.
    #[test]
    fn json_shape_is_pinned() {
        let mut w = Metrics::default();
        w.record(false, true, 100e-6, 0.01, Duration::from_millis(1));
        w.record_batch(1);
        w.record_steal(1);
        let m = ServeMetrics::aggregate(vec![w], 2, 3).with_unknown_entries(1);
        let j = m.to_json();
        let obj = j.as_obj().expect("object");
        let keys: Vec<String> = obj.iter().map(|(k, _)| k.clone()).collect();
        let expected = [
            "workers",
            "requests",
            "per_worker_requests",
            "deadline_misses",
            "batched_requests",
            "solo_requests",
            "batch_hist",
            "steals",
            "stolen_requests",
            "shed_below_floor",
            "shed_queue_full",
            "shed_unknown_entry",
            "sim_energy_uj",
            "sim_active_ms",
            "host_p50_us",
            "host_p99_us",
        ];
        assert_eq!(keys, expected.map(String::from).to_vec());
        // Arrays stay arrays, scalars stay numeric.
        for (k, v) in obj.iter() {
            match k.as_str() {
                "per_worker_requests" | "batch_hist" => {
                    assert!(v.as_arr().is_some(), "{k} should be an array")
                }
                _ => assert!(v.as_f64().is_some(), "{k} should be numeric"),
            }
        }
        assert_eq!(j.get("shed_below_floor").and_then(|v| v.as_u64()), Some(2));
        assert_eq!(j.get("shed_queue_full").and_then(|v| v.as_u64()), Some(3));
        assert_eq!(j.get("steals").and_then(|v| v.as_u64()), Some(1));
        assert_eq!(
            j.get("per_worker_requests").and_then(|v| v.as_arr()).map(|a| a.len()),
            Some(1)
        );
    }
}
