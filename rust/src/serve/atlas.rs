//! The schedule atlas: every MEDEA solve moved to startup.
//!
//! MEDEA is a design-time manager — the energy-optimal configuration vector
//! for a deadline `T_d` does not depend on anything known only at request
//! time. The atlas exploits that: at startup it sweeps deadlines from the
//! feasibility floor up to a relaxed bound, solves the MCKP once per sweep
//! knot, and keeps the resulting schedules sorted by deadline. A request for
//! any deadline then resolves with an `O(log n)` binary search to the
//! *tightest precomputed schedule that still meets it* — no DP solve on the
//! request path, ever.
//!
//! The sweep is a geometric grid (constant relative spacing, so the relative
//! energy pessimism of snapping a deadline down to a knot is bounded by the
//! growth factor) refined where the energy Pareto front curves: adjacent
//! knots whose optimal energies differ by more than a threshold get a
//! midpoint knot, recursively, until the front is flat or the knot budget is
//! exhausted. Past the point where the energy-minimal schedule is reached,
//! knots are deduplicated — the last knot serves every laxer deadline.
//!
//! Atlases serialize through [`crate::util::json`] so they can be built once
//! at design time and shipped next to the model artifacts.

use crate::ir::Workload;
use crate::manager::medea::{Medea, ScheduleError};
use crate::manager::schedule::Schedule;
use crate::sim::replay::simulate;
use crate::util::json::{parse, Json, JsonObj};
use crate::util::units::Time;
use std::fmt;

/// Sweep parameters for [`ScheduleAtlas::build`].
#[derive(Debug, Clone)]
pub struct AtlasConfig {
    /// Upper sweep bound as a multiple of the feasibility floor. The energy
    /// front flattens once every kernel runs at the lowest V-F, so a modest
    /// factor covers the whole useful range.
    pub relax_factor: f64,
    /// Geometric grid growth between adjacent knots (> 1). Also bounds the
    /// worst-case relative deadline-tightening a lookup can incur.
    pub growth: f64,
    /// Refine between adjacent knots whose energies differ relatively by
    /// more than this; `0` disables refinement.
    pub refine_rel_energy: f64,
    /// Hard cap on the number of knots (refinement stops there).
    pub max_knots: usize,
    /// Fraction of each knot deadline actually given to the solver, so the
    /// event-level replay (which does not always grant the estimator's
    /// optimistic LM-residency chaining) still lands inside the deadline.
    /// Mirrors `ExpContext::SIM_MARGIN`.
    pub margin: f64,
}

impl Default for AtlasConfig {
    fn default() -> Self {
        AtlasConfig {
            relax_factor: 24.0,
            growth: 1.15,
            refine_rel_energy: 0.02,
            max_knots: 256,
            margin: 0.97,
        }
    }
}

/// One precomputed point: the energy-optimal schedule for `deadline`,
/// validated against the event-level simulator at build time.
#[derive(Debug, Clone)]
pub struct AtlasKnot {
    pub deadline: Time,
    /// The deadline actually handed to the solver (margin folded in, then
    /// tightened further if the simulator overshot). Kept so independent
    /// solvers can re-derive the same optimization problem.
    pub solve_deadline: Time,
    /// The event-level simulator's measured active time for this schedule,
    /// recorded when the knot passed validation (≤ `deadline` by
    /// construction). Anchors the batch-makespan model — atlases saved
    /// before this field existed load with the conservative `deadline`.
    pub sim_time: Time,
    pub schedule: Schedule,
}

impl AtlasKnot {
    /// Sim-anchored batch makespan: executing `n` compatible windows as one
    /// dispatch completes in `sim_time · batch_scale(n, amortization)`
    /// ([`crate::serve::batch`]). `n = 1` is exactly the sim-validated solo
    /// active time, so any deadline the solo path meets, a batch of one
    /// meets too (deadline monotonicity).
    pub fn batch_makespan(&self, n: usize, amortization: f64) -> Time {
        crate::serve::batch::batch_makespan(self.sim_time, n, amortization)
    }
}

/// Typed lookup failure: the request is below the atlas's feasibility floor.
/// This is an *admission* outcome, not a solver error — serving layers shed
/// such requests instead of attempting a doomed solve.
#[derive(Debug, Clone, PartialEq)]
pub struct BelowFloor {
    pub requested: Time,
    pub floor: Time,
}

impl fmt::Display for BelowFloor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "deadline {:.2} ms below the atlas feasibility floor {:.2} ms",
            self.requested.as_ms(),
            self.floor.as_ms()
        )
    }
}

impl std::error::Error for BelowFloor {}

/// A deadline-indexed library of precomputed MEDEA schedules.
#[derive(Debug, Clone)]
pub struct ScheduleAtlas {
    /// Workload the schedules were generated for (checked on load).
    pub workload: String,
    /// Estimator-level minimum makespan (pre-margin), kept for diagnostics.
    pub min_makespan: Time,
    /// Knots in strictly ascending deadline order.
    knots: Vec<AtlasKnot>,
}

impl ScheduleAtlas {
    /// Sweep `medea` over the feasible deadline range and precompute one
    /// schedule per knot.
    pub fn build(
        medea: &Medea<'_>,
        workload: &Workload,
        cfg: &AtlasConfig,
    ) -> Result<ScheduleAtlas, ScheduleError> {
        assert!(cfg.growth > 1.0, "atlas growth must be > 1");
        assert!(cfg.relax_factor > 1.0, "atlas relax_factor must be > 1");
        assert!(cfg.margin > 0.0 && cfg.margin <= 1.0, "atlas margin in (0, 1]");
        assert!(cfg.max_knots >= 2, "atlas max_knots must be >= 2");

        let t_min = medea.min_makespan(workload)?;
        let t_max = medea.max_makespan(workload)?;
        // Nominal first knot: the margin plus 1 % slack for the DP's
        // per-item round-up (≤ #kernels / resolution of the deadline). The
        // *actual* floor is wherever the first sim-validated knot lands.
        let nominal_floor = Time(t_min.raw() * 1.01 / cfg.margin);
        // Past the slowest single-choice makespan extra slack cannot change
        // the optimum, so the sweep stops at whichever bound is tighter.
        let flat_hi = (t_max.raw() / cfg.margin).max(nominal_floor.raw() * cfg.growth);
        let hi = Time((nominal_floor.raw() * cfg.relax_factor).min(flat_hi));

        // Geometric grid, then solve + sim-validate every point. Points too
        // tight to validate are skipped; the first that validates defines
        // the atlas floor.
        let mut grid = Vec::new();
        let mut d = nominal_floor;
        while d.raw() < hi.raw() {
            grid.push(d);
            d = d * cfg.growth;
        }
        grid.push(hi);
        if grid.len() > cfg.max_knots {
            // Never truncate silently: the caller chose a cap that cannot
            // even hold the base grid, so lookups between the last kept
            // knot and `hi` will snap further down than `growth` implies.
            crate::log_warn!(
                "atlas knot cap {} below the {}-point base grid: truncating \
                 (deadlines above {:.1} ms collapse onto the final knot)",
                cfg.max_knots,
                grid.len(),
                grid[cfg.max_knots - 2].as_ms()
            );
            grid.truncate(cfg.max_knots - 1);
            grid.push(hi);
        }

        let mut knots: Vec<AtlasKnot> = Vec::with_capacity(grid.len());
        let mut last_invalid: Option<Time> = None;
        for d in grid {
            match Self::solve_knot(medea, workload, d, cfg.margin)? {
                Some(knot) => knots.push(knot),
                None if knots.is_empty() => last_invalid = Some(d),
                // A mid-sweep validation failure (laxer than an already
                // validated knot) cannot happen with a deadline-monotone
                // solver; skip defensively if it ever does.
                None => {}
            }
        }
        if knots.is_empty() {
            return Err(ScheduleError::Infeasible {
                min_ms: t_min.as_ms(),
                deadline_ms: hi.as_ms(),
            });
        }
        // Tighten the floor: bisect between the tightest deadline known to
        // fail validation and the first knot that passed. Even when the
        // first grid point validated immediately, the true (sim-validated)
        // feasibility boundary can sit below it — and nothing at or below
        // the estimator's minimum makespan can ever validate, so `t_min`
        // bounds the search from below.
        {
            let mut bad = last_invalid.unwrap_or(t_min);
            let mut good = knots[0].deadline;
            for _ in 0..5 {
                if good.raw() / bad.raw() < 1.005 || knots.len() >= cfg.max_knots {
                    break;
                }
                let mid = Time((bad.raw() * good.raw()).sqrt());
                match Self::solve_knot(medea, workload, mid, cfg.margin)? {
                    Some(knot) => {
                        good = knot.deadline;
                        knots.insert(0, knot);
                    }
                    None => bad = mid,
                }
            }
            knots.sort_by(|a, b| a.deadline.raw().total_cmp(&b.deadline.raw()));
        }

        // Energy-Pareto refinement: split intervals where the front still
        // curves. Work left to right so inserted knots are re-examined.
        if cfg.refine_rel_energy > 0.0 {
            let mut i = 0;
            while i + 1 < knots.len() && knots.len() < cfg.max_knots {
                let e_lo = knots[i].schedule.active_energy().raw();
                let e_hi = knots[i + 1].schedule.active_energy().raw();
                let rel = (e_lo - e_hi).abs() / e_lo.max(e_hi).max(f64::MIN_POSITIVE);
                let d_lo = knots[i].deadline.raw();
                let d_hi = knots[i + 1].deadline.raw();
                // Stop splitting once intervals are narrow: below 1 %
                // spacing the DP's quantization dominates any gain.
                if rel > cfg.refine_rel_energy && d_hi / d_lo > 1.01 {
                    let mid = Time((d_lo * d_hi).sqrt());
                    match Self::solve_knot(medea, workload, mid, cfg.margin)? {
                        Some(knot) => knots.insert(i + 1, knot),
                        None => i += 1,
                    }
                } else {
                    i += 1;
                }
            }
            // Never cap silently: report the worst interval the knot budget
            // left unrefined, so operators know to raise `max_knots` (or
            // accept the extra energy pessimism between those knots).
            if knots.len() >= cfg.max_knots {
                let worst = knots
                    .windows(2)
                    .map(|w| {
                        let e_lo = w[0].schedule.active_energy().raw();
                        let e_hi = w[1].schedule.active_energy().raw();
                        let rel = (e_lo - e_hi).abs() / e_lo.max(e_hi).max(f64::MIN_POSITIVE);
                        let splittable = w[1].deadline.raw() / w[0].deadline.raw() > 1.01;
                        if splittable { rel } else { 0.0 }
                    })
                    .fold(0.0, f64::max);
                if worst > cfg.refine_rel_energy {
                    crate::log_warn!(
                        "atlas knot cap {} reached: Pareto refinement truncated with a \
                         {:.1} % relative energy gap still unrefined",
                        cfg.max_knots,
                        worst * 100.0
                    );
                }
            }
        }

        // Dedup the flat tail: once the energy-minimal schedule is reached,
        // one knot suffices (it serves every laxer deadline). Keep a knot
        // only when it improves on the previous kept knot's energy.
        let mut kept: Vec<AtlasKnot> = Vec::with_capacity(knots.len());
        for knot in knots {
            let improves = kept
                .last()
                .map(|prev| {
                    knot.schedule.active_energy().raw()
                        < prev.schedule.active_energy().raw() * (1.0 - 1e-9)
                })
                .unwrap_or(true);
            if improves {
                kept.push(knot);
            }
        }

        Ok(ScheduleAtlas {
            workload: workload.name.clone(),
            min_makespan: t_min,
            knots: kept,
        })
    }

    /// Solve for one knot and validate it on the event-level simulator.
    /// The sim does not always grant the estimator's optimistic
    /// LM-residency chaining, so when the replayed makespan overshoots the
    /// knot deadline the solve is retried with a proportionally tighter
    /// target. Returns `Ok(None)` when no sim-valid schedule exists at this
    /// deadline (it is below the *true* feasibility floor).
    fn solve_knot(
        medea: &Medea<'_>,
        workload: &Workload,
        deadline: Time,
        margin: f64,
    ) -> Result<Option<AtlasKnot>, ScheduleError> {
        let mut target = deadline * margin;
        for _ in 0..4 {
            let mut schedule = match medea.schedule(workload, target) {
                Ok(s) => s,
                Err(ScheduleError::Infeasible { .. }) => return Ok(None),
                Err(e) => return Err(e),
            };
            schedule.deadline = deadline;
            let sim = simulate(workload, medea.platform, medea.model, &schedule);
            if sim.active_time.raw() <= deadline.raw() {
                return Ok(Some(AtlasKnot {
                    deadline,
                    solve_deadline: target,
                    sim_time: sim.active_time,
                    schedule,
                }));
            }
            // Shrink the solve target by the observed overshoot (plus a
            // hair) and retry.
            target = Time(target.raw() * deadline.raw() / sim.active_time.raw() * 0.998);
        }
        Ok(None)
    }

    /// The tightest deadline this atlas can serve. Requests below it are
    /// infeasible and should be shed at admission.
    pub fn floor(&self) -> Time {
        self.knots[0].deadline
    }

    pub fn len(&self) -> usize {
        self.knots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.knots.is_empty()
    }

    pub fn knots(&self) -> &[AtlasKnot] {
        &self.knots
    }

    /// `O(log n)` lookup: the highest knot whose deadline is ≤ `deadline` —
    /// i.e. the lowest-energy precomputed schedule that still meets it
    /// (knot energy is non-increasing in knot deadline by construction).
    ///
    /// The returned knot's exact `deadline` bit pattern is also the knot's
    /// identity downstream: the pool stamps it on dispatch groups and the
    /// energy ledger ([`crate::telemetry::ledger`]) keys its per-knot
    /// dispatch and drift tables on it, so the atlas must stay frozen for
    /// the ledger tables sized from it to stay attributable.
    pub fn lookup(&self, deadline: Time) -> Result<&AtlasKnot, BelowFloor> {
        let idx = self
            .knots
            .partition_point(|k| k.deadline.raw() <= deadline.raw());
        if idx == 0 {
            return Err(BelowFloor {
                requested: deadline,
                floor: self.floor(),
            });
        }
        Ok(&self.knots[idx - 1])
    }

    /// Like [`ScheduleAtlas::lookup`], but clones the schedule and stamps
    /// the *requested* deadline on it, so downstream sleep-energy and
    /// deadline-met accounting use what the caller asked for.
    pub fn resolve(&self, deadline: Time) -> Result<Schedule, BelowFloor> {
        let knot = self.lookup(deadline)?;
        let mut schedule = knot.schedule.clone();
        schedule.deadline = deadline;
        Ok(schedule)
    }

    // ---- JSON ----------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("workload", self.workload.clone());
        o.insert("min_makespan_ms", self.min_makespan.as_ms());
        let knots: Vec<Json> = self
            .knots
            .iter()
            .map(|k| {
                let mut kj = JsonObj::new();
                kj.insert("deadline_ms", k.deadline.as_ms());
                kj.insert("solve_deadline_ms", k.solve_deadline.as_ms());
                kj.insert("sim_time_ms", k.sim_time.as_ms());
                kj.insert("schedule", k.schedule.to_json());
                Json::Obj(kj)
            })
            .collect();
        o.insert("knots", Json::Arr(knots));
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<ScheduleAtlas, String> {
        let workload = v.req("workload")?.as_str().ok_or("workload")?.to_string();
        let min_makespan =
            Time::from_ms(v.req("min_makespan_ms")?.as_f64().ok_or("min_makespan_ms")?);
        let mut knots = Vec::new();
        for kv in v.req("knots")?.as_arr().ok_or("knots")? {
            let deadline = Time::from_ms(kv.req("deadline_ms")?.as_f64().ok_or("deadline_ms")?);
            let solve_deadline = Time::from_ms(
                kv.req("solve_deadline_ms")?
                    .as_f64()
                    .ok_or("solve_deadline_ms")?,
            );
            // Atlases serialized before the batch model default to the knot
            // deadline: a conservative (sim-validated upper bound) anchor.
            let sim_time = kv
                .get("sim_time_ms")
                .and_then(|v| v.as_f64())
                .map(Time::from_ms)
                .unwrap_or(deadline);
            let schedule = Schedule::from_json(kv.req("schedule")?)?;
            knots.push(AtlasKnot {
                deadline,
                solve_deadline,
                sim_time,
                schedule,
            });
        }
        if knots.is_empty() {
            return Err("atlas has no knots".to_string());
        }
        for w in knots.windows(2) {
            if w[1].deadline.raw() <= w[0].deadline.raw() {
                return Err("atlas knots not in ascending deadline order".to_string());
            }
        }
        Ok(ScheduleAtlas {
            workload,
            min_makespan,
            knots,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<(), String> {
        std::fs::write(path, self.to_json().to_pretty()).map_err(|e| e.to_string())
    }

    pub fn load(path: &std::path::Path) -> Result<ScheduleAtlas, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        ScheduleAtlas::from_json(&parse(&text).map_err(|e| e.to_string())?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::ExpContext;

    fn small_cfg() -> AtlasConfig {
        // Coarse grid to keep unit tests fast; integration tests use the
        // default config.
        AtlasConfig {
            relax_factor: 8.0,
            growth: 1.5,
            refine_rel_energy: 0.05,
            max_knots: 32,
            ..AtlasConfig::default()
        }
    }

    #[test]
    fn builds_sorted_deduped_knots() {
        let ctx = ExpContext::paper();
        let medea = ctx.medea();
        let atlas = ScheduleAtlas::build(&medea, &ctx.workload, &small_cfg()).unwrap();
        assert!(!atlas.is_empty());
        assert_eq!(atlas.workload, ctx.workload.name);
        for w in atlas.knots().windows(2) {
            assert!(w[1].deadline.raw() > w[0].deadline.raw());
            // Energy strictly improves along kept knots.
            assert!(
                w[1].schedule.active_energy().raw() < w[0].schedule.active_energy().raw(),
                "non-improving knot survived dedup"
            );
        }
    }

    #[test]
    fn lookup_picks_tightest_covering_knot() {
        let ctx = ExpContext::paper();
        let atlas = ScheduleAtlas::build(&ctx.medea(), &ctx.workload, &small_cfg()).unwrap();
        assert!(atlas.len() >= 2, "degenerate atlas: {} knots", atlas.len());
        let i = atlas.len() / 2 - 1;
        // Exactly on a knot → that knot.
        let k_lo = &atlas.knots()[i];
        let hit = atlas.lookup(k_lo.deadline).unwrap();
        assert!((hit.deadline.raw() - k_lo.deadline.raw()).abs() < 1e-15);
        // Between knots → the lower one.
        let k_hi = &atlas.knots()[i + 1];
        let mid = Time(0.5 * (k_lo.deadline.raw() + k_hi.deadline.raw()));
        let hit = atlas.lookup(mid).unwrap();
        assert!((hit.deadline.raw() - k_lo.deadline.raw()).abs() < 1e-15);
        // Beyond the last knot → the last (energy-minimal) knot.
        let last = atlas.knots().last().unwrap();
        let hit = atlas.lookup(last.deadline * 100.0).unwrap();
        assert!((hit.deadline.raw() - last.deadline.raw()).abs() < 1e-15);
    }

    #[test]
    fn below_floor_is_typed() {
        let ctx = ExpContext::paper();
        let atlas = ScheduleAtlas::build(&ctx.medea(), &ctx.workload, &small_cfg()).unwrap();
        let bad = atlas.floor() * 0.5;
        let err = atlas.lookup(bad).unwrap_err();
        assert_eq!(err.floor.raw(), atlas.floor().raw());
        assert!((err.requested.raw() - bad.raw()).abs() < 1e-15);
        assert!(err.to_string().contains("feasibility floor"));
    }

    #[test]
    fn resolve_stamps_requested_deadline() {
        let ctx = ExpContext::paper();
        let atlas = ScheduleAtlas::build(&ctx.medea(), &ctx.workload, &small_cfg()).unwrap();
        let req = atlas.floor() * 3.7;
        let s = atlas.resolve(req).unwrap();
        assert!((s.deadline.raw() - req.raw()).abs() < 1e-15);
        assert!(s.meets_deadline());
    }

    #[test]
    fn batch_makespan_is_sim_anchored() {
        let ctx = ExpContext::paper();
        let atlas = ScheduleAtlas::build(&ctx.medea(), &ctx.workload, &small_cfg()).unwrap();
        for k in atlas.knots() {
            // The anchor is the validated solo time, within the deadline.
            assert!(k.sim_time.raw() > 0.0);
            assert!(k.sim_time.raw() <= k.deadline.raw() + 1e-15);
            assert!((k.batch_makespan(1, 0.85).raw() - k.sim_time.raw()).abs() < 1e-15);
            // Monotone in batch size, sublinear per member.
            for n in 1..8usize {
                let m_n = k.batch_makespan(n, 0.85);
                let m_next = k.batch_makespan(n + 1, 0.85);
                assert!(m_next.raw() > m_n.raw());
                assert!(m_next.raw() / (n + 1) as f64 <= k.sim_time.raw() + 1e-15);
            }
        }
    }

    #[test]
    fn json_round_trip() {
        let ctx = ExpContext::paper();
        let atlas = ScheduleAtlas::build(&ctx.medea(), &ctx.workload, &small_cfg()).unwrap();
        let text = atlas.to_json().to_pretty();
        let back = ScheduleAtlas::from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(back.len(), atlas.len());
        for (a, b) in atlas.knots().iter().zip(back.knots()) {
            assert!((a.sim_time.raw() - b.sim_time.raw()).abs() < 1e-12);
        }
        assert_eq!(back.workload, atlas.workload);
        let d = atlas.floor() * 2.0;
        let a = atlas.resolve(d).unwrap();
        let b = back.resolve(d).unwrap();
        assert!((a.active_energy().raw() - b.active_energy().raw()).abs() < 1e-15);
        assert_eq!(a.decisions.len(), b.decisions.len());
    }

    #[test]
    fn knot_cap_is_a_hard_invariant() {
        // An aggressive refinement threshold under a tiny cap: the build
        // must truncate (with a warning) rather than exceed the cap.
        let ctx = ExpContext::paper();
        let atlas = ScheduleAtlas::build(
            &ctx.medea(),
            &ctx.workload,
            &AtlasConfig {
                refine_rel_energy: 1e-4,
                max_knots: 6,
                ..small_cfg()
            },
        )
        .unwrap();
        assert!(atlas.len() <= 6, "cap exceeded: {} knots", atlas.len());
        assert!(!atlas.is_empty());
    }

    #[test]
    fn refinement_adds_knots_where_front_curves() {
        let ctx = ExpContext::paper();
        let medea = ctx.medea();
        let coarse = ScheduleAtlas::build(
            &medea,
            &ctx.workload,
            &AtlasConfig {
                refine_rel_energy: 0.0,
                ..small_cfg()
            },
        )
        .unwrap();
        let refined = ScheduleAtlas::build(&medea, &ctx.workload, &small_cfg()).unwrap();
        assert!(
            refined.len() > coarse.len(),
            "refinement added no knots ({} vs {})",
            refined.len(),
            coarse.len()
        );
    }
}
