//! Deadline-aware admission control: a bounded EDF priority queue.
//!
//! Two shedding rules run at admission, both returning a typed
//! [`Rejection`] instead of letting an infeasible request reach the solver
//! or an overloaded worker:
//!
//! * **Feasibility floor** — requests whose deadline is below the atlas's
//!   floor can never be scheduled; they are rejected immediately.
//! * **Capacity** — when the queue is full, the entry with the *latest*
//!   deadline (the one with the most slack, least harmed by waiting and, by
//!   EDF order, served last anyway) is shed; that may be the incoming
//!   request itself.
//!
//! Admitted entries pop in earliest-deadline-first order, FIFO among equal
//! deadlines.

use crate::util::units::{Energy, Time};
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::fmt;

/// Why a request was shed at admission.
#[derive(Debug, Clone, PartialEq)]
pub enum Rejection {
    /// The deadline is below the atlas's sim-validated feasibility floor:
    /// no precomputed schedule meets it, and nothing below the estimator's
    /// minimum makespan ever could on this platform.
    BelowFloor { requested: Time, floor: Time },
    /// The energy cap is below the atlas's sim-validated energy floor: even
    /// the unconstrained energy-minimal schedule exceeds it.
    BelowEnergyFloor { requested: Energy, floor: Energy },
    /// No atlas is published for the requested (platform, workload) pair.
    UnknownEntry { platform: String, workload: String },
    /// The queue is at capacity and this request had the most slack.
    QueueFull { capacity: usize },
    /// The pool is shutting down.
    ShuttingDown,
}

impl Rejection {
    /// Stable snake_case label for metrics (`shed_reason`) and logs.
    pub fn label(&self) -> &'static str {
        match self {
            Rejection::BelowFloor { .. } => "below_floor",
            Rejection::BelowEnergyFloor { .. } => "below_energy_floor",
            Rejection::UnknownEntry { .. } => "unknown_entry",
            Rejection::QueueFull { .. } => "queue_full",
            Rejection::ShuttingDown => "shutting_down",
        }
    }

    /// Compact numeric code carried in trace-ring shed events (decoded by
    /// [`crate::telemetry::trace::shed_reason_name`]).
    pub fn code(&self) -> u64 {
        match self {
            Rejection::BelowFloor { .. } => 0,
            Rejection::BelowEnergyFloor { .. } => 1,
            Rejection::UnknownEntry { .. } => 2,
            Rejection::QueueFull { .. } => 3,
            Rejection::ShuttingDown => 4,
        }
    }
}

impl fmt::Display for Rejection {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Rejection::BelowFloor { requested, floor } => write!(
                f,
                "shed: deadline {:.2} ms below feasibility floor {:.2} ms",
                requested.as_ms(),
                floor.as_ms()
            ),
            Rejection::BelowEnergyFloor { requested, floor } => write!(
                f,
                "shed: energy budget {:.1} uJ below energy floor {:.1} uJ",
                requested.as_uj(),
                floor.as_uj()
            ),
            Rejection::UnknownEntry { platform, workload } => {
                write!(f, "shed: no atlas for platform `{platform}` workload `{workload}`")
            }
            Rejection::QueueFull { capacity } => {
                write!(f, "shed: queue full (capacity {capacity})")
            }
            Rejection::ShuttingDown => write!(f, "shed: pool shutting down"),
        }
    }
}

impl std::error::Error for Rejection {}

/// Outcome of [`EdfQueue::push`].
#[derive(Debug)]
pub enum Admission<T> {
    /// Admitted; nothing was displaced.
    Accepted,
    /// Admitted by shedding the queued entry with the latest deadline.
    AcceptedShedding { evicted: T, evicted_deadline: Time },
    /// The request itself was shed; ownership returns to the caller.
    Rejected { item: T, reason: Rejection },
}

struct Entry<T> {
    deadline: Time,
    /// Admission sequence number: FIFO tie-break among equal deadlines.
    seq: u64,
    item: T,
}

// BinaryHeap is a max-heap; order entries so the earliest deadline (then
// the earliest admission) is the maximum.
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .deadline
            .raw()
            .total_cmp(&self.deadline.raw())
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl<T> PartialOrd for Entry<T> {
    // lint: allow(no-partial-cmp): canonical PartialOrd delegating to the
    // total `Ord` above (which uses total_cmp); never NaN-lossy.
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl<T> Eq for Entry<T> {}

/// A bounded earliest-deadline-first queue with an optional feasibility
/// floor.
pub struct EdfQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    capacity: usize,
    floor: Option<Time>,
    seq: u64,
}

impl<T> EdfQueue<T> {
    /// A queue with capacity 0 admits nothing: every push is rejected with
    /// [`Rejection::QueueFull`] (useful as a drain/bypass sentinel).
    pub fn new(capacity: usize) -> EdfQueue<T> {
        EdfQueue {
            heap: BinaryHeap::with_capacity(capacity + 1),
            capacity,
            floor: None,
            seq: 0,
        }
    }

    /// Shed pushes whose deadline is below `floor`.
    pub fn with_floor(mut self, floor: Time) -> EdfQueue<T> {
        self.floor = Some(floor);
        self
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Admit `item` under EDF shedding rules.
    pub fn push(&mut self, deadline: Time, item: T) -> Admission<T> {
        if let Some(floor) = self.floor {
            if deadline.raw() < floor.raw() {
                return Admission::Rejected {
                    item,
                    reason: Rejection::BelowFloor {
                        requested: deadline,
                        floor,
                    },
                };
            }
        }
        if self.heap.len() >= self.capacity {
            // Shed the latest-deadline entry — possibly the incoming one.
            // O(n) scan; admission-queue capacities are small.
            let latest_queued = self
                .heap
                .iter()
                .map(|e| e.deadline.raw())
                .fold(f64::NEG_INFINITY, f64::max);
            if deadline.raw() >= latest_queued {
                return Admission::Rejected {
                    item,
                    reason: Rejection::QueueFull {
                        capacity: self.capacity,
                    },
                };
            }
            let mut entries = std::mem::take(&mut self.heap).into_vec();
            let drop_pos = entries
                .iter()
                .enumerate()
                .max_by(|(_, a), (_, b)| {
                    a.deadline
                        .raw()
                        .total_cmp(&b.deadline.raw())
                        // Among equal latest deadlines, shed the youngest
                        // (latest-admitted) to preserve FIFO fairness.
                        .then_with(|| a.seq.cmp(&b.seq))
                })
                .map(|(i, _)| i)
                // lint: allow(no-unwrap): the enclosing branch only runs
                // when the queue is full, so `entries` is non-empty.
                .expect("full queue has entries");
            let evicted = entries.swap_remove(drop_pos);
            self.heap = BinaryHeap::from(entries);
            self.push_unchecked(deadline, item);
            return Admission::AcceptedShedding {
                evicted: evicted.item,
                evicted_deadline: evicted.deadline,
            };
        }
        self.push_unchecked(deadline, item);
        Admission::Accepted
    }

    fn push_unchecked(&mut self, deadline: Time, item: T) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { deadline, seq, item });
    }

    /// Remove and return the earliest-deadline entry.
    pub fn pop(&mut self) -> Option<(Time, T)> {
        self.heap.pop().map(|e| (e.deadline, e.item))
    }

    /// Pop the earliest-deadline entry plus up to `max − 1` more entries
    /// forming an EDF-contiguous compatible group.
    ///
    /// The group is a strict *prefix* of EDF order — candidates are examined
    /// in pop order and the scan stops at the first incompatibility — so
    /// batching never reorders the queue: a request is dispatched in the
    /// same batch as, or earlier than, it would have popped solo, and the
    /// group's first member carries the group's earliest deadline.
    ///
    /// A candidate joins when both hold:
    /// * `key(candidate) == key(head)` — same batchable work (e.g. same
    ///   resolved atlas knot for the same fleet entry);
    /// * `grow(&group, candidate_deadline, &candidate)` — the caller's
    ///   feasibility check (batch makespan fits every member, energy shares
    ///   fit every cap, …) accepts extending the group by this candidate.
    ///
    /// Returns an empty vector when the queue is empty; `max` is clamped to
    /// at least 1. With `max == 1` this is exactly [`EdfQueue::pop`] (the
    /// key/grow closures are never called).
    pub fn pop_compatible<K: PartialEq>(
        &mut self,
        max: usize,
        key: impl Fn(&T) -> K,
        grow: impl Fn(&[(Time, T)], Time, &T) -> bool,
    ) -> Vec<(Time, T)> {
        let mut group = Vec::with_capacity(max.max(1).min(self.len()));
        self.pop_compatible_into(max, key, grow, &mut group);
        group
    }

    /// [`EdfQueue::pop_compatible`] into a caller-owned buffer: the group is
    /// appended to `out` (which the caller clears between dispatches), so a
    /// worker loop that reuses one pre-sized buffer forms groups without any
    /// heap allocation in steady state. Returns the number of entries
    /// appended.
    pub fn pop_compatible_into<K: PartialEq>(
        &mut self,
        max: usize,
        key: impl Fn(&T) -> K,
        grow: impl Fn(&[(Time, T)], Time, &T) -> bool,
        out: &mut Vec<(Time, T)>,
    ) -> usize {
        let Some(head) = self.pop() else {
            return 0;
        };
        let max = max.max(1);
        let base = out.len();
        // Hoisted: the head is fixed, and `key` may be arbitrarily
        // expensive for some callers. Skipped entirely when no candidate
        // could ever join (max 1 or nothing left queued).
        let head_key = (max > 1 && !self.heap.is_empty()).then(|| key(&head.1));
        out.push(head);
        while out.len() - base < max {
            let Some(next) = self.heap.peek() else { break };
            if Some(key(&next.item)) != head_key {
                break;
            }
            if !grow(&out[base..], next.deadline, &next.item) {
                break;
            }
            // lint: allow(no-unwrap): peek above returned Some and the
            // heap is not touched in between.
            let e = self.heap.pop().expect("peeked entry exists");
            out.push((e.deadline, e.item));
        }
        out.len() - base
    }

    /// Deadline of the entry that would pop next.
    pub fn peek_deadline(&self) -> Option<Time> {
        self.heap.peek().map(|e| e.deadline)
    }

    /// Identity of the entry that would pop next: its admission sequence
    /// number, unique per queue. Dispatch layers use this to re-arm a timed
    /// fill wait only when the head actually changes (a later admission can
    /// preempt the head; an unchanged head's wake instant stays fixed).
    pub fn head_seq(&self) -> Option<u64> {
        self.heap.peek().map(|e| e.seq)
    }

    /// The entry that would pop next, without removing it. Lets dispatch
    /// layers inspect the head's payload (e.g. its sim-anchored unit time)
    /// to bound how long a batch fill window may delay it.
    pub fn peek(&self) -> Option<(Time, &T)> {
        self.heap.peek().map(|e| (e.deadline, &e.item))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: f64) -> Time {
        Time::from_ms(v)
    }

    #[test]
    fn pops_in_edf_order() {
        let mut q: EdfQueue<&str> = EdfQueue::new(8);
        assert!(matches!(q.push(ms(200.0), "b"), Admission::Accepted));
        assert!(matches!(q.push(ms(50.0), "a"), Admission::Accepted));
        assert!(matches!(q.push(ms(1000.0), "c"), Admission::Accepted));
        assert_eq!(q.peek_deadline(), Some(ms(50.0)));
        assert_eq!(q.peek(), Some((ms(50.0), &"a")));
        assert_eq!(q.pop().unwrap().1, "a");
        assert_eq!(q.pop().unwrap().1, "b");
        assert_eq!(q.pop().unwrap().1, "c");
        assert!(q.pop().is_none());
    }

    #[test]
    fn equal_deadlines_are_fifo() {
        let mut q: EdfQueue<u32> = EdfQueue::new(8);
        for i in 0..5 {
            q.push(ms(100.0), i);
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop().map(|(_, v)| v)).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn floor_rejection_is_typed() {
        let mut q: EdfQueue<&str> = EdfQueue::new(4).with_floor(ms(30.0));
        match q.push(ms(10.0), "x") {
            Admission::Rejected { item, reason } => {
                assert_eq!(item, "x");
                assert_eq!(
                    reason,
                    Rejection::BelowFloor {
                        requested: ms(10.0),
                        floor: ms(30.0)
                    }
                );
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        assert!(matches!(q.push(ms(30.0), "ok"), Admission::Accepted));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn overflow_sheds_latest_deadline() {
        let mut q: EdfQueue<&str> = EdfQueue::new(2);
        q.push(ms(100.0), "a");
        q.push(ms(500.0), "slack");
        // Tighter than everything queued: evicts the slackest entry.
        match q.push(ms(50.0), "urgent") {
            Admission::AcceptedShedding {
                evicted,
                evicted_deadline,
            } => {
                assert_eq!(evicted, "slack");
                assert_eq!(evicted_deadline, ms(500.0));
            }
            other => panic!("expected eviction, got {other:?}"),
        }
        assert_eq!(q.len(), 2);
        // Slacker than everything queued: the incoming one is shed.
        match q.push(ms(900.0), "late") {
            Admission::Rejected { item, reason } => {
                assert_eq!(item, "late");
                assert_eq!(reason, Rejection::QueueFull { capacity: 2 });
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        // EDF order among survivors holds.
        assert_eq!(q.pop().unwrap().1, "urgent");
        assert_eq!(q.pop().unwrap().1, "a");
    }

    #[test]
    fn capacity_zero_rejects_everything() {
        let mut q: EdfQueue<&str> = EdfQueue::new(0);
        match q.push(ms(50.0), "x") {
            Admission::Rejected { item, reason } => {
                assert_eq!(item, "x");
                assert_eq!(reason, Rejection::QueueFull { capacity: 0 });
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        assert!(q.is_empty());
        assert!(q.pop().is_none());
        // The floor check still runs first.
        let mut q: EdfQueue<&str> = EdfQueue::new(0).with_floor(ms(30.0));
        assert!(matches!(
            q.push(ms(10.0), "y"),
            Admission::Rejected {
                reason: Rejection::BelowFloor { .. },
                ..
            }
        ));
    }

    #[test]
    fn capacity_one_swaps_only_for_tighter_deadlines() {
        let mut q: EdfQueue<&str> = EdfQueue::new(1);
        assert!(matches!(q.push(ms(100.0), "a"), Admission::Accepted));
        // Equal deadline: the incoming request is the youngest, so it sheds.
        match q.push(ms(100.0), "dup") {
            Admission::Rejected { item, reason } => {
                assert_eq!(item, "dup");
                assert_eq!(reason, Rejection::QueueFull { capacity: 1 });
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        // Slacker: also sheds.
        assert!(matches!(q.push(ms(101.0), "late"), Admission::Rejected { .. }));
        // Tighter: evicts the sole occupant.
        match q.push(ms(99.0), "tight") {
            Admission::AcceptedShedding {
                evicted,
                evicted_deadline,
            } => {
                assert_eq!(evicted, "a");
                assert_eq!(evicted_deadline, ms(100.0));
            }
            other => panic!("expected eviction, got {other:?}"),
        }
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop().unwrap().1, "tight");
    }

    #[test]
    fn overflow_among_duplicate_deadlines_sheds_the_youngest() {
        let mut q: EdfQueue<u32> = EdfQueue::new(3);
        q.push(ms(200.0), 0);
        q.push(ms(200.0), 1);
        q.push(ms(200.0), 2);
        // Tighter incoming: among the equal-latest entries, the youngest
        // admission (2) is shed, preserving FIFO fairness for the rest.
        match q.push(ms(50.0), 99) {
            Admission::AcceptedShedding {
                evicted,
                evicted_deadline,
            } => {
                assert_eq!(evicted, 2);
                assert_eq!(evicted_deadline, ms(200.0));
            }
            other => panic!("expected eviction, got {other:?}"),
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop().map(|(_, v)| v)).collect();
        assert_eq!(order, vec![99, 0, 1]);
    }

    // ---- pop_compatible -------------------------------------------------

    /// Key by the item's first character (a stand-in for "same atlas knot /
    /// fleet entry"); grow while the candidate deadline stays within
    /// `laxity × head deadline`.
    fn pop_group<'q>(
        q: &mut EdfQueue<&'q str>,
        max: usize,
        laxity: f64,
    ) -> Vec<(Time, &'q str)> {
        q.pop_compatible(
            max,
            |item| item.as_bytes()[0],
            move |group, d, _| d.raw() <= group[0].0.raw() * laxity,
        )
    }

    #[test]
    fn pop_compatible_empty_queue_returns_empty() {
        let mut q: EdfQueue<&str> = EdfQueue::new(4);
        assert!(pop_group(&mut q, 8, 10.0).is_empty());
        assert!(q.is_empty());
    }

    #[test]
    fn pop_compatible_singleton_never_calls_closures() {
        let mut q: EdfQueue<&str> = EdfQueue::new(4);
        q.push(ms(100.0), "a1");
        let group = q.pop_compatible(
            8,
            |_: &&str| -> u8 { panic!("key must not run on a singleton") },
            |_, _, _| panic!("grow must not run on a singleton"),
        );
        assert_eq!(group.len(), 1);
        assert_eq!(group[0].1, "a1");
        assert!(q.is_empty());
        // max == 1 pops exactly the head even with a full queue.
        q.push(ms(50.0), "a2");
        q.push(ms(60.0), "a3");
        let group = q.pop_compatible(
            1,
            |_: &&str| -> u8 { panic!("key must not run at max=1") },
            |_, _, _| panic!("grow must not run at max=1"),
        );
        assert_eq!(group, vec![(ms(50.0), "a2")]);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn pop_compatible_groups_same_key_in_edf_order() {
        let mut q: EdfQueue<&str> = EdfQueue::new(8);
        q.push(ms(300.0), "a3");
        q.push(ms(100.0), "a1");
        q.push(ms(200.0), "a2");
        let group = pop_group(&mut q, 8, 10.0);
        assert_eq!(
            group,
            vec![(ms(100.0), "a1"), (ms(200.0), "a2"), (ms(300.0), "a3")]
        );
        assert!(q.is_empty());
    }

    #[test]
    fn pop_compatible_mixed_entries_stop_at_the_boundary() {
        let mut q: EdfQueue<&str> = EdfQueue::new(8);
        q.push(ms(100.0), "a1");
        q.push(ms(110.0), "a2");
        q.push(ms(120.0), "b1"); // different entry: blocks the prefix
        q.push(ms(130.0), "a4"); // same entry, but queued behind b1
        let group = pop_group(&mut q, 8, 10.0);
        assert_eq!(group, vec![(ms(100.0), "a1"), (ms(110.0), "a2")]);
        // EDF order among the survivors is untouched.
        assert_eq!(q.pop().unwrap().1, "b1");
        assert_eq!(q.pop().unwrap().1, "a4");
    }

    #[test]
    fn pop_compatible_respects_laxity_boundary_and_max() {
        let mut q: EdfQueue<&str> = EdfQueue::new(8);
        q.push(ms(100.0), "a1");
        q.push(ms(150.0), "a2");
        q.push(ms(199.9), "a3");
        q.push(ms(200.1), "a4"); // just past 2× the head deadline
        let group = pop_group(&mut q, 8, 2.0);
        assert_eq!(group.len(), 3, "{group:?}");
        assert_eq!(q.len(), 1);
        // The rejected candidate still pops normally afterwards.
        assert_eq!(q.pop().unwrap().1, "a4");

        // `max` caps the group even when everything is compatible.
        for item in ["a1", "a2", "a3", "a4", "a5"] {
            q.push(ms(100.0), item);
        }
        let group = pop_group(&mut q, 2, 10.0);
        assert_eq!(group.len(), 2);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn pop_compatible_into_appends_and_reuses_the_buffer() {
        let mut q: EdfQueue<&str> = EdfQueue::new(8);
        q.push(ms(300.0), "a3");
        q.push(ms(100.0), "a1");
        q.push(ms(200.0), "a2");
        let mut buf: Vec<(Time, &str)> = Vec::with_capacity(8);
        let n = q.pop_compatible_into(
            8,
            |item| item.as_bytes()[0],
            |group, d, _| d.raw() <= group[0].0.raw() * 10.0,
            &mut buf,
        );
        assert_eq!(n, 3);
        assert_eq!(
            buf,
            vec![(ms(100.0), "a1"), (ms(200.0), "a2"), (ms(300.0), "a3")]
        );
        // Reuse after clear: no entries from the previous group leak in,
        // and the capacity is retained (steady-state allocation-free).
        let cap = buf.capacity();
        buf.clear();
        q.push(ms(50.0), "b1");
        let n = q.pop_compatible_into(
            8,
            |item| item.as_bytes()[0],
            |_, _, _| true,
            &mut buf,
        );
        assert_eq!(n, 1);
        assert_eq!(buf, vec![(ms(50.0), "b1")]);
        assert_eq!(buf.capacity(), cap);
        // Empty queue appends nothing.
        buf.clear();
        assert_eq!(
            q.pop_compatible_into(8, |item| item.as_bytes()[0], |_, _, _| true, &mut buf),
            0
        );
        assert!(buf.is_empty());
    }

    #[test]
    fn head_seq_tracks_the_popping_entry() {
        let mut q: EdfQueue<&str> = EdfQueue::new(8);
        assert_eq!(q.head_seq(), None);
        q.push(ms(200.0), "slow");
        let slow = q.head_seq().expect("non-empty");
        // A tighter admission preempts the head: the identity changes.
        q.push(ms(50.0), "urgent");
        let urgent = q.head_seq().expect("non-empty");
        assert_ne!(slow, urgent);
        // A slacker admission leaves the head untouched.
        q.push(ms(500.0), "lax");
        assert_eq!(q.head_seq(), Some(urgent));
        q.pop();
        assert_eq!(q.head_seq(), Some(slow));
    }

    #[test]
    fn rejection_messages_render() {
        let r = Rejection::BelowFloor {
            requested: ms(5.0),
            floor: ms(31.0),
        };
        assert!(r.to_string().contains("feasibility floor"));
        assert!(Rejection::QueueFull { capacity: 7 }.to_string().contains("7"));
        assert!(Rejection::ShuttingDown.to_string().contains("shutting down"));
        let e = Rejection::BelowEnergyFloor {
            requested: crate::util::units::Energy::from_uj(10.0),
            floor: crate::util::units::Energy::from_uj(25.0),
        };
        assert!(e.to_string().contains("energy floor"));
        let u = Rejection::UnknownEntry {
            platform: "soc-x".into(),
            workload: "net-y".into(),
        };
        assert!(u.to_string().contains("soc-x") && u.to_string().contains("net-y"));
    }

    #[test]
    fn rejection_labels_match_trace_codes() {
        let variants = [
            Rejection::BelowFloor { requested: ms(1.0), floor: ms(2.0) },
            Rejection::BelowEnergyFloor {
                requested: crate::util::units::Energy::from_uj(1.0),
                floor: crate::util::units::Energy::from_uj(2.0),
            },
            Rejection::UnknownEntry { platform: "p".into(), workload: "w".into() },
            Rejection::QueueFull { capacity: 1 },
            Rejection::ShuttingDown,
        ];
        for r in &variants {
            // The trace ring stores the code; decoding it must round-trip
            // back to the metrics label.
            assert_eq!(crate::telemetry::trace::shed_reason_name(r.code()), r.label());
        }
    }
}
