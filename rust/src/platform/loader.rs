//! JSON serialization of [`Platform`] descriptions.
//!
//! Lets users define custom HULPs (see `examples/custom_platform.rs`) and
//! ship characterized platforms alongside profiles.

use super::constraints::{OpConstraint, OpConstraints};
use super::pe::{DmaSpec, Pe, PeClass, PeId, PePower};
use super::vf::{VfPoint, VfTable};
use super::Platform;
use crate::ir::{DataWidth, KernelType};
use crate::util::json::{parse, Json, JsonObj};
use crate::util::units::{Bytes, Power, Voltage};
use std::collections::BTreeMap;

pub fn platform_to_json(p: &Platform) -> Json {
    let mut o = JsonObj::new();
    o.insert("name", p.name.clone());
    o.insert("l2_bytes", p.l2.raw());
    o.insert("sleep_power_uw", p.sleep_power.as_uw());
    o.insert("vf_switch_cycles", p.vf_switch_cycles);
    o.insert("active_base", power_to_json(&p.active_base));

    let vf: Vec<Json> = p
        .vf
        .points()
        .iter()
        .map(|pt| {
            let mut v = JsonObj::new();
            v.insert("volts", pt.v.raw());
            v.insert("mhz", pt.f.as_mhz());
            Json::Obj(v)
        })
        .collect();
    o.insert("vf", Json::Arr(vf));

    let pes: Vec<Json> = p.pes.iter().map(pe_to_json).collect();
    o.insert("pes", Json::Arr(pes));

    let cons: Vec<Json> = p
        .constraints
        .iter()
        .map(|(pe, ty, c)| {
            let mut v = JsonObj::new();
            v.insert("pe", pe.0);
            v.insert("type", ty.name());
            match c.max_dim {
                Some(d) => v.insert("max_dim", d),
                None => v.insert("max_dim", Json::Null),
            }
            v.insert(
                "widths",
                Json::Arr(c.widths.iter().map(|w| Json::from(w.name())).collect()),
            );
            Json::Obj(v)
        })
        .collect();
    o.insert("constraints", Json::Arr(cons));
    Json::Obj(o)
}

fn power_to_json(pw: &PePower) -> Json {
    let mut o = JsonObj::new();
    o.insert("p_stat_ref_uw", pw.p_stat_ref.as_uw());
    o.insert("v_ref", pw.v_ref.raw());
    o.insert("leak_exp", pw.leak_exp);
    o.insert("c_eff_pf", pw.c_eff * 1e12);
    o.insert("e_fixed_pj", pw.e_fixed * 1e12);
    let mut act = JsonObj::new();
    for (ty, a) in &pw.activity {
        act.insert(ty.name(), *a);
    }
    o.insert("activity", Json::Obj(act));
    Json::Obj(o)
}

fn power_from_json(v: &Json) -> Result<PePower, String> {
    let mut activity = BTreeMap::new();
    if let Some(act) = v.get("activity").and_then(|a| a.as_obj()) {
        for (k, av) in act.iter() {
            let ty = KernelType::from_name(k).ok_or("activity type unknown")?;
            activity.insert(ty, av.as_f64().ok_or("activity value")?);
        }
    }
    Ok(PePower {
        p_stat_ref: Power::from_uw(v.req("p_stat_ref_uw")?.as_f64().ok_or("p_stat_ref_uw")?),
        v_ref: Voltage(v.req("v_ref")?.as_f64().ok_or("v_ref")?),
        leak_exp: v.req("leak_exp")?.as_f64().ok_or("leak_exp")?,
        c_eff: v.req("c_eff_pf")?.as_f64().ok_or("c_eff_pf")? * 1e-12,
        e_fixed: v.req("e_fixed_pj")?.as_f64().ok_or("e_fixed_pj")? * 1e-12,
        activity,
    })
}

fn pe_to_json(pe: &Pe) -> Json {
    let mut o = JsonObj::new();
    o.insert("id", pe.id.0);
    o.insert("name", pe.name.clone());
    o.insert("class", pe.class.name());
    match pe.lm {
        Some(b) => o.insert("lm_bytes", b.raw()),
        None => o.insert("lm_bytes", Json::Null),
    }
    match pe.dma {
        Some(d) => {
            let mut dj = JsonObj::new();
            dj.insert("bytes_per_cycle", d.bytes_per_cycle);
            dj.insert("setup_cycles", d.setup_cycles);
            o.insert("dma", Json::Obj(dj));
        }
        None => o.insert("dma", Json::Null),
    }
    o.insert("power", power_to_json(&pe.power));
    Json::Obj(o)
}

pub fn platform_from_json(v: &Json) -> Result<Platform, String> {
    let name = v.req("name")?.as_str().ok_or("name")?.to_string();
    let l2 = Bytes(v.req("l2_bytes")?.as_u64().ok_or("l2_bytes")?);
    let sleep_power = Power::from_uw(v.req("sleep_power_uw")?.as_f64().ok_or("sleep_power_uw")?);
    let vf_switch_cycles = v.req("vf_switch_cycles")?.as_u64().ok_or("vf_switch_cycles")?;

    let mut points = Vec::new();
    for pt in v.req("vf")?.as_arr().ok_or("vf")? {
        points.push(VfPoint::new(
            pt.req("volts")?.as_f64().ok_or("volts")?,
            pt.req("mhz")?.as_f64().ok_or("mhz")?,
        ));
    }
    let vf = VfTable::new(points);

    let mut pes = Vec::new();
    for pv in v.req("pes")?.as_arr().ok_or("pes")? {
        pes.push(pe_from_json(pv)?);
    }

    let mut constraints = OpConstraints::new();
    for cv in v.req("constraints")?.as_arr().ok_or("constraints")? {
        let pe = PeId(cv.req("pe")?.as_usize().ok_or("constraint.pe")?);
        let ty = KernelType::from_name(cv.req("type")?.as_str().ok_or("constraint.type")?)
            .ok_or("constraint.type unknown")?;
        let max_dim = match cv.req("max_dim")? {
            Json::Null => None,
            other => Some(other.as_u64().ok_or("constraint.max_dim")?),
        };
        let mut widths = Vec::new();
        for wv in cv.req("widths")?.as_arr().ok_or("constraint.widths")? {
            widths.push(
                DataWidth::from_name(wv.as_str().ok_or("width")?).ok_or("width unknown")?,
            );
        }
        constraints.allow(pe, ty, OpConstraint { max_dim, widths });
    }

    let active_base = power_from_json(v.req("active_base")?)?;
    let p = Platform {
        name,
        pes,
        vf,
        l2,
        sleep_power,
        constraints,
        vf_switch_cycles,
        active_base,
    };
    p.validate()?;
    Ok(p)
}

fn pe_from_json(v: &Json) -> Result<Pe, String> {
    let id = PeId(v.req("id")?.as_usize().ok_or("pe.id")?);
    let name = v.req("name")?.as_str().ok_or("pe.name")?.to_string();
    let class = PeClass::from_name(v.req("class")?.as_str().ok_or("pe.class")?)
        .ok_or("pe.class unknown")?;
    let lm = match v.req("lm_bytes")? {
        Json::Null => None,
        other => Some(Bytes(other.as_u64().ok_or("pe.lm_bytes")?)),
    };
    let dma = match v.req("dma")? {
        Json::Null => None,
        d => Some(DmaSpec {
            bytes_per_cycle: d.req("bytes_per_cycle")?.as_f64().ok_or("dma.bpc")?,
            setup_cycles: d.req("setup_cycles")?.as_u64().ok_or("dma.setup")?,
        }),
    };
    Ok(Pe {
        id,
        name,
        class,
        lm,
        dma,
        power: power_from_json(v.req("power")?)?,
    })
}

/// Load a platform from a JSON file.
pub fn load_platform(path: &std::path::Path) -> Result<Platform, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path:?}: {e}"))?;
    let v = parse(&text).map_err(|e| e.to_string())?;
    platform_from_json(&v)
}

/// Save a platform to a JSON file.
pub fn save_platform(p: &Platform, path: &std::path::Path) -> Result<(), String> {
    std::fs::write(path, platform_to_json(p).to_pretty()).map_err(|e| format!("write {path:?}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::heeptimize::heeptimize;

    #[test]
    fn heeptimize_round_trips() {
        let p = heeptimize();
        let j = platform_to_json(&p);
        let back = platform_from_json(&parse(&j.to_pretty()).unwrap()).unwrap();
        assert_eq!(back.name, p.name);
        assert_eq!(back.pes.len(), p.pes.len());
        assert_eq!(back.l2, p.l2);
        assert_eq!(back.vf.points(), p.vf.points());
        assert_eq!(back.vf_switch_cycles, p.vf_switch_cycles);
        // Constraint count preserved.
        assert_eq!(back.constraints.iter().count(), p.constraints.iter().count());
        // Power constants preserved.
        for (a, b) in back.pes.iter().zip(&p.pes) {
            assert!((a.power.c_eff - b.power.c_eff).abs() < 1e-18);
            assert_eq!(a.power.activity, b.power.activity);
            assert_eq!(a.dma, b.dma);
        }
    }

    #[test]
    fn invalid_platform_rejected() {
        let p = heeptimize();
        let mut j = platform_to_json(&p);
        // Drop the CPU: validation must fail (exactly one CPU required).
        if let Json::Obj(ref mut o) = j {
            let pes = o.get("pes").unwrap().as_arr().unwrap().to_vec();
            o.insert("pes", Json::Arr(pes[1..].to_vec()));
        }
        assert!(platform_from_json(&j).is_err());
    }

    #[test]
    fn file_round_trip() {
        let p = heeptimize();
        let dir = std::env::temp_dir().join("medea_test_loader");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("platform.json");
        save_platform(&p, &path).unwrap();
        let back = load_platform(&path).unwrap();
        assert_eq!(back.name, "heeptimize");
    }
}
