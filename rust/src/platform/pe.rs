//! Processing elements and their physical (power, DMA, memory) description.

use crate::ir::KernelType;
use crate::util::units::{Bytes, Freq, Power, Voltage};
use std::collections::BTreeMap;
use std::fmt;

/// Index of a PE within its platform (dense, equals position in `pes`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PeId(pub usize);

impl fmt::Display for PeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "pe{}", self.0)
    }
}

/// Microarchitectural family of a PE — the timing and power models key off
/// this (plus per-PE constants).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PeClass {
    /// In-order RV32IMC host core (CV32E40P-like).
    RiscvCpu,
    /// 4×4 coarse-grained reconfigurable array (OpenEdgeCGRA-like).
    Cgra,
    /// Near-memory-computing vector unit over an SRAM VRF (Carus-like).
    Nmc,
}

impl PeClass {
    pub fn name(self) -> &'static str {
        match self {
            PeClass::RiscvCpu => "riscv-cpu",
            PeClass::Cgra => "cgra",
            PeClass::Nmc => "nmc",
        }
    }

    pub fn from_name(s: &str) -> Option<PeClass> {
        match s {
            "riscv-cpu" => Some(PeClass::RiscvCpu),
            "cgra" => Some(PeClass::Cgra),
            "nmc" => Some(PeClass::Nmc),
            _ => None,
        }
    }
}

/// DMA path between the shared L2 and this PE's local memory.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DmaSpec {
    /// Aggregate transfer width, bytes per cycle (ports × port width).
    pub bytes_per_cycle: f64,
    /// Fixed per-transfer programming/arbitration cost in cycles.
    pub setup_cycles: u64,
}

/// Physical power description of one PE, used by the ASIC-flow stand-in.
///
/// `P(v, f) = P_stat(v) + a_τ · (C_eff · v² + e_fixed) · f` with
/// `P_stat(v) = p_stat_ref · (v / v_ref)^leak_exp` — leakage grows
/// super-linearly with supply voltage (DIBL); switching power follows the
/// classic `C·V²·f` law scaled by a per-kernel-type activity factor `a_τ`,
/// plus an optional voltage-independent per-cycle energy `e_fixed` (used to
/// model SRAM-array access energy on internally biased rails, the key to the
/// NMC's flat power/voltage profile — paper Fig 7).
#[derive(Debug, Clone)]
pub struct PePower {
    /// Static (leakage) power at `v_ref`.
    pub p_stat_ref: Power,
    /// Reference voltage for `p_stat_ref`.
    pub v_ref: Voltage,
    /// Leakage voltage exponent (logic ≈ 2.5–3, SRAM-dominant ≈ 2).
    pub leak_exp: f64,
    /// Effective switching capacitance in farads (per-cycle energy = C·V²).
    pub c_eff: f64,
    /// Voltage-independent per-cycle energy in joules (0 for pure logic).
    pub e_fixed: f64,
    /// Per-kernel-type activity factor (defaults to 1.0).
    pub activity: BTreeMap<KernelType, f64>,
}

impl PePower {
    /// Static power at voltage `v`.
    pub fn p_stat(&self, v: Voltage) -> Power {
        Power(self.p_stat_ref.raw() * (v.raw() / self.v_ref.raw()).powf(self.leak_exp))
    }

    /// Dynamic power for kernel type `ty` at `(v, f)`.
    pub fn p_dyn(&self, ty: KernelType, v: Voltage, f: Freq) -> Power {
        let a = self.activity.get(&ty).copied().unwrap_or(1.0);
        Power(a * (self.c_eff * v.raw() * v.raw() + self.e_fixed) * f.raw())
    }

    /// Total active power for kernel type `ty` at `(v, f)`.
    pub fn p_total(&self, ty: KernelType, v: Voltage, f: Freq) -> Power {
        self.p_stat(v) + self.p_dyn(ty, v, f)
    }
}

/// One processing element.
#[derive(Debug, Clone)]
pub struct Pe {
    pub id: PeId,
    pub name: String,
    pub class: PeClass,
    /// Private local memory capacity `C_LM` (None: operates out of L2
    /// directly, like the host CPU).
    pub lm: Option<Bytes>,
    /// DMA path L2 ↔ LM (None when `lm` is None).
    pub dma: Option<DmaSpec>,
    /// Physical power description.
    pub power: PePower,
}

impl Pe {
    /// Local-memory capacity; PEs without an LM report the shared L2 size
    /// passed by the caller.
    pub fn lm_capacity(&self, l2: Bytes) -> Bytes {
        self.lm.unwrap_or(l2)
    }

    pub fn has_lm(&self) -> bool {
        self.lm.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn power() -> PePower {
        PePower {
            p_stat_ref: Power::from_uw(100.0),
            v_ref: Voltage(0.8),
            leak_exp: 3.0,
            c_eff: 20e-12,
            e_fixed: 0.0,
            activity: BTreeMap::new(),
        }
    }

    #[test]
    fn e_fixed_adds_flat_per_cycle_energy() {
        let mut p = power();
        p.e_fixed = 4e-12;
        let pd = p.p_dyn(KernelType::MatMul, Voltage(0.5), Freq::from_mhz(100.0));
        // (20e-12·0.25 + 4e-12) · 100e6 = 0.9 mW
        assert!((pd.as_mw() - 0.9).abs() < 1e-9);
    }

    #[test]
    fn static_power_scales_with_voltage() {
        let p = power();
        let at_ref = p.p_stat(Voltage(0.8));
        assert!((at_ref.as_uw() - 100.0).abs() < 1e-9);
        let at_half = p.p_stat(Voltage(0.4));
        assert!((at_half.as_uw() - 100.0 * 0.125).abs() < 1e-9);
    }

    #[test]
    fn dynamic_power_cv2f() {
        let p = power();
        let pd = p.p_dyn(KernelType::MatMul, Voltage(0.5), Freq::from_mhz(100.0));
        // 20e-12 * 0.25 * 100e6 = 0.5 mW
        assert!((pd.as_mw() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn activity_factor_applies() {
        let mut p = power();
        p.activity.insert(KernelType::Add, 0.5);
        let mm = p.p_dyn(KernelType::MatMul, Voltage(0.8), Freq::from_mhz(100.0));
        let add = p.p_dyn(KernelType::Add, Voltage(0.8), Freq::from_mhz(100.0));
        assert!((add.raw() / mm.raw() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn pe_class_round_trip() {
        for c in [PeClass::RiscvCpu, PeClass::Cgra, PeClass::Nmc] {
            assert_eq!(PeClass::from_name(c.name()), Some(c));
        }
    }
}
