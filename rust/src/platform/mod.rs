//! Heterogeneous ULP platform descriptions (§3.1.2).
//!
//! A [`Platform`] bundles the PE set `P`, the V-F operating points `S_vf`,
//! the memory hierarchy (`C_LM`, shared L2), the kernel-PE operational
//! constraints `Λ_op`, and the physical power description used by the
//! characterization stand-ins. [`heeptimize`] provides the paper's
//! evaluation platform as a preset.

pub mod constraints;
pub mod heeptimize;
pub mod loader;
pub mod pe;
pub mod presets;
pub mod vf;

pub use constraints::{OpConstraint, OpConstraints};
pub use pe::{DmaSpec, Pe, PeClass, PeId, PePower};
pub use vf::{VfPoint, VfTable};

use crate::util::units::{Bytes, Power};

/// A complete heterogeneous ULP platform description.
#[derive(Debug, Clone)]
pub struct Platform {
    pub name: String,
    pub pes: Vec<Pe>,
    pub vf: VfTable,
    /// Shared L2 capacity (intermediate tier between flash and PE LMs).
    pub l2: Bytes,
    /// Global idle/deep-sleep power `P_slp`.
    pub sleep_power: Power,
    /// Kernel-PE operational constraints `Λ_op`.
    pub constraints: OpConstraints,
    /// Cycles a PE stalls when the platform switches V-F (regulator settle),
    /// charged at the *new* operating point by the timing model.
    pub vf_switch_cycles: u64,
    /// Whole-SoC "active base" power (bus fabric, L2, DMA engines, host
    /// standby) drawn whenever the platform is awake, on top of the running
    /// PE's own power. Characterized kernel power profiles `S_P` include it,
    /// matching the paper's system-level post-synthesis measurements.
    pub active_base: PePower,
}

impl Platform {
    pub fn pe(&self, id: PeId) -> &Pe {
        &self.pes[id.0]
    }

    pub fn pe_by_name(&self, name: &str) -> Option<&Pe> {
        self.pes.iter().find(|p| p.name == name)
    }

    pub fn pe_ids(&self) -> impl Iterator<Item = PeId> + '_ {
        (0..self.pes.len()).map(PeId)
    }

    /// The CPU PE (exactly one per platform by convention).
    pub fn cpu(&self) -> &Pe {
        self.pes
            .iter()
            .find(|p| p.class == PeClass::RiscvCpu)
            .expect("platform has no CPU")
    }

    /// Accelerator PEs (non-CPU).
    pub fn accelerators(&self) -> impl Iterator<Item = &Pe> {
        self.pes.iter().filter(|p| p.class != PeClass::RiscvCpu)
    }

    /// Structural validation: ids are dense, exactly one CPU, V-F table
    /// non-empty and monotone, constraints reference valid PEs.
    pub fn validate(&self) -> Result<(), String> {
        for (i, pe) in self.pes.iter().enumerate() {
            if pe.id.0 != i {
                return Err(format!("pe `{}` id {} != index {i}", pe.name, pe.id.0));
            }
        }
        let cpus = self
            .pes
            .iter()
            .filter(|p| p.class == PeClass::RiscvCpu)
            .count();
        if cpus != 1 {
            return Err(format!("expected exactly 1 CPU, found {cpus}"));
        }
        self.vf.validate()?;
        self.constraints.validate(self.pes.len())?;
        if self.sleep_power.raw() < 0.0 {
            return Err("negative sleep power".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::heeptimize::heeptimize;

    #[test]
    fn preset_validates() {
        let p = heeptimize();
        p.validate().unwrap();
        assert_eq!(p.pes.len(), 3);
        assert_eq!(p.accelerators().count(), 2);
        assert_eq!(p.cpu().name, "cpu");
    }
}
