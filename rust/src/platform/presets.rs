//! Additional named platform presets beyond the paper's HEEPtimize.
//!
//! A device *fleet* rarely ships one SoC revision: the serving layer
//! ([`crate::fleet`]) routes requests by platform preset, so each preset here
//! is a complete, validated [`Platform`] with its own characterization
//! fingerprint. [`heeptimize_hp`] is a scaled-up derivative of the paper's
//! evaluation platform — the kind of next-revision part a deployment would
//! run side by side with the original silicon.

use super::constraints::{OpConstraint, OpConstraints};
use super::heeptimize::{heeptimize, CARUS, CGRA, CPU};
use super::pe::DmaSpec;
use super::vf::{VfPoint, VfTable};
use super::Platform;
use crate::ir::DataWidth::{Int16, Int32, Int8};
use crate::ir::KernelType;
use crate::util::units::{Bytes, Power};

/// HEEPtimize-HP: a hypothetical higher-performance spin of the paper's
/// platform. Same PE set and power models, but:
///
/// * one extra V-F point (1.00 V @ 800 MHz) extending the top of the range,
/// * 128 KiB local memories and a 256 KiB L2 (double the originals),
/// * a burst-capable DMA (2.6 B/cycle, 80-cycle setup) instead of the
///   single-beat OBI channel,
/// * relaxed operational constraints (larger maximum dimensions).
///
/// Structurally different from [`heeptimize`] in every fingerprinted field,
/// so the fleet layer treats it as a distinct platform.
pub fn heeptimize_hp() -> Platform {
    let mut p = heeptimize();
    p.name = "heeptimize-hp".into();

    let mut points: Vec<VfPoint> = p.vf.points().to_vec();
    points.push(VfPoint::new(1.00, 800.0));
    p.vf = VfTable::new(points);

    p.l2 = Bytes::from_kib(256);
    p.sleep_power = Power::from_uw(158.0); // larger SRAM macros leak more

    for pe in &mut p.pes {
        if pe.lm.is_some() {
            pe.lm = Some(Bytes::from_kib(128));
        }
        if pe.dma.is_some() {
            pe.dma = Some(DmaSpec {
                bytes_per_cycle: 2.6,
                setup_cycles: 80,
            });
        }
    }

    let mut constraints = OpConstraints::new();
    constraints.allow_all(CPU);
    let fixed = [Int8, Int16, Int32];
    for ty in [
        KernelType::MatMul,
        KernelType::Conv2d,
        KernelType::Add,
        KernelType::Norm,
        KernelType::Scale,
        KernelType::Transpose,
    ] {
        constraints.allow(CGRA, ty, OpConstraint::with_max_dim(2048).widths(&fixed));
        constraints.allow(CARUS, ty, OpConstraint::with_max_dim(1024).widths(&fixed));
    }
    p.constraints = constraints;
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hp_preset_validates() {
        let p = heeptimize_hp();
        p.validate().unwrap();
        assert_eq!(p.name, "heeptimize-hp");
        assert_eq!(p.vf.len(), 5);
        assert_eq!(p.l2, Bytes::from_kib(256));
    }

    #[test]
    fn hp_differs_from_base_structurally() {
        let base = heeptimize();
        let hp = heeptimize_hp();
        assert_ne!(base.vf.len(), hp.vf.len());
        assert_ne!(base.l2, hp.l2);
        assert_ne!(
            base.pes[CGRA.0].dma.unwrap().bytes_per_cycle,
            hp.pes[CGRA.0].dma.unwrap().bytes_per_cycle
        );
    }

    #[test]
    fn hp_tops_out_faster() {
        let hp = heeptimize_hp();
        assert!(hp.vf.max().f.as_mhz() > 690.0 + 1.0);
        assert_eq!(hp.vf.min().label(), "0.50V@122MHz");
    }
}
