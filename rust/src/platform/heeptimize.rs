//! The HEEPtimize evaluation platform (§4.1) as a calibrated preset.
//!
//! HEEPtimize = X-HEEP host (CV32E40P RISC-V) + OpenEdgeCGRA + Carus NMC,
//! 64 KiB LM per accelerator, 128 KiB shared L2, four V-F operating points
//! (GF 22 nm FDX characterization — paper Table 2), `P_slp` = 129 µW.
//!
//! The power constants below are the ASIC-flow stand-in. They are chosen to
//! reproduce the *published behaviours*, not re-measured silicon:
//!
//! * Table 2 V-F points verbatim; sleep power 129 µW (Table 5 caption).
//! * The CGRA is logic-dominant: almost all its power is `C·V²·f` switching,
//!   so its power collapses at low voltage (leakage exponent ≈ 3, tiny
//!   static floor).
//! * Carus is SRAM-dominant: a large VRF leakage floor (flatter voltage
//!   exponent ≈ 1.8) plus a per-cycle array-access energy component that
//!   scales weakly with supply (`e_fixed`), so its power falls more slowly
//!   at low voltage. Together these reproduce the paper's Fig 7 crossover:
//!   the CGRA/Carus power ratio drops at low V-F, flipping which accelerator
//!   is the energy-efficient choice for matmul below ≈0.6 V.
//! * Area numbers (Table 3) are carried verbatim for reporting.

use super::constraints::{OpConstraint, OpConstraints};
use super::pe::{DmaSpec, Pe, PeClass, PeId, PePower};
use super::vf::{VfPoint, VfTable};
use super::Platform;
use crate::ir::KernelType;
use crate::util::units::{Bytes, Power, Voltage};
use std::collections::BTreeMap;

/// Paper Table 2: maximum operating frequency per voltage (GF 22 nm FDX).
pub const VF_POINTS: [(f64, f64); 4] = [(0.50, 122.0), (0.65, 347.0), (0.80, 578.0), (0.90, 690.0)];

/// Paper Table 5 caption: global idle/deep-sleep power.
pub const SLEEP_POWER_UW: f64 = 129.0;

/// Paper Table 3: post-synthesis area breakdown (mm², GF 22 nm FDX, SSG).
pub const AREA_BREAKDOWN: [(&str, f64); 7] = [
    ("CPU Subsystem", 0.021),
    ("Carus (NMC, incl. 64 KiB VRF)", 0.110),
    ("OpenEdgeCGRA (Logic)", 0.085),
    ("CGRA Local Memory (64 KiB)", 0.091),
    ("L2 Cache (128 KiB)", 0.181),
    ("Instruction Memory (64 KiB)", 0.091),
    ("Peripherals", 0.053),
];

/// PE indices in the preset (stable, used across examples/tests).
pub const CPU: PeId = PeId(0);
pub const CGRA: PeId = PeId(1);
pub const CARUS: PeId = PeId(2);

fn active_base_power() -> PePower {
    // Bus fabric + L2 + DMA + host standby while any kernel executes:
    // dominated by clock-tree and L2 switching, so it scales with V²f.
    PePower {
        p_stat_ref: Power::from_uw(270.0),
        v_ref: Voltage(0.8),
        leak_exp: 2.2,
        c_eff: 24.0e-12,
        e_fixed: 0.0,
        activity: BTreeMap::new(),
    }
}

fn cpu_power() -> PePower {
    // CV32E40P-class core, ~16 µW/MHz dynamic at 0.9 V.
    let mut activity = BTreeMap::new();
    // Control-heavy kernels toggle less of the datapath.
    activity.insert(KernelType::Transpose, 0.7);
    activity.insert(KernelType::ClassConcat, 0.6);
    activity.insert(KernelType::Add, 0.8);
    activity.insert(KernelType::Scale, 0.8);
    PePower {
        p_stat_ref: Power::from_uw(94.0),
        v_ref: Voltage(0.8),
        leak_exp: 2.8,
        c_eff: 34.0e-12,
        e_fixed: 0.0,
        activity,
    }
}

fn cgra_power() -> PePower {
    // 16 reconfigurable cells; switching-dominated. 4 pJ/cycle at 0.5 V,
    // 13 pJ/cycle at 0.9 V. Negligible static floor.
    let mut activity = BTreeMap::new();
    activity.insert(KernelType::Add, 0.75);
    activity.insert(KernelType::Scale, 0.75);
    activity.insert(KernelType::Transpose, 0.65);
    activity.insert(KernelType::Norm, 0.9);
    PePower {
        p_stat_ref: Power::from_uw(100.0),
        v_ref: Voltage(0.8),
        leak_exp: 3.0,
        c_eff: 27.0e-12,
        e_fixed: 0.0,
        activity,
    }
}

fn carus_power() -> PePower {
    // NMC vector unit over a 64 KiB SRAM VRF: a large leakage floor with a
    // flat voltage exponent, plus array-access energy (`e_fixed`) that does
    // not scale with the logic supply.
    let mut activity = BTreeMap::new();
    activity.insert(KernelType::Add, 0.8);
    activity.insert(KernelType::Scale, 0.8);
    activity.insert(KernelType::Transpose, 0.7);
    activity.insert(KernelType::Norm, 0.95);
    PePower {
        p_stat_ref: Power::from_uw(850.0),
        v_ref: Voltage(0.8),
        leak_exp: 1.5,
        c_eff: 13.6e-12,
        e_fixed: CARUS_EFIXED,
        activity,
    }
}

/// Voltage-independent per-cycle energy of the Carus SRAM array (J/cycle).
pub const CARUS_EFIXED: f64 = 12.0e-12;

/// Build the HEEPtimize platform preset.
pub fn heeptimize() -> Platform {
    let pes = vec![
        Pe {
            id: CPU,
            name: "cpu".into(),
            class: PeClass::RiscvCpu,
            lm: None, // host operates out of the shared L2
            dma: None,
            power: cpu_power(),
        },
        Pe {
            id: CGRA,
            name: "cgra".into(),
            class: PeClass::Cgra,
            lm: Some(Bytes::from_kib(64)),
            // The CGRA's four master ports serve the RCs during compute;
            // L2->LM staging goes through the single 32-bit system DMA
            // channel (OBI single-beat transfers, no bursts: ~2.5 cycles
            // per word), like Carus.
            dma: Some(DmaSpec {
                bytes_per_cycle: 1.3,
                setup_cycles: 120,
            }),
            power: cgra_power(),
        },
        Pe {
            id: CARUS,
            name: "carus".into(),
            class: PeClass::Nmc,
            lm: Some(Bytes::from_kib(64)), // the VRF
            // Single 32-bit slave port; the host DMA pushes data in with
            // the same single-beat OBI handshake.
            dma: Some(DmaSpec {
                bytes_per_cycle: 1.3,
                setup_cycles: 120,
            }),
            power: carus_power(),
        },
    ];

    let mut constraints = OpConstraints::new();
    // Host CPU runs everything (reference implementations, f32 included).
    constraints.allow_all(CPU);

    use crate::ir::DataWidth::{Int16, Int32, Int8};
    let fixed = [Int8, Int16, Int32];

    // OpenEdgeCGRA: arithmetically intensive integer kernels; column-PC
    // addressing bounds the largest dimension.
    for ty in [
        KernelType::MatMul,
        KernelType::Conv2d,
        KernelType::Add,
        KernelType::Norm,
        KernelType::Scale,
        KernelType::Transpose,
    ] {
        constraints.allow(CGRA, ty, OpConstraint::with_max_dim(1024).widths(&fixed));
    }

    // Carus NMC: vector kernels on 8/16/32-bit fixed point; vector-register
    // geometry bounds a single dimension at 512.
    for ty in [
        KernelType::MatMul,
        KernelType::Conv2d,
        KernelType::Add,
        KernelType::Norm,
        KernelType::Scale,
        KernelType::Transpose,
    ] {
        constraints.allow(CARUS, ty, OpConstraint::with_max_dim(512).widths(&fixed));
    }
    // Softmax, GeLU, FFT-magnitude, class-concat: host-only (the paper's
    // §4.1.1: nonlinear/floating-point ops are offloaded to the CPU).

    Platform {
        name: "heeptimize".into(),
        pes,
        vf: VfTable::new(VF_POINTS.iter().map(|&(v, f)| VfPoint::new(v, f)).collect()),
        l2: Bytes::from_kib(128),
        sleep_power: Power::from_uw(SLEEP_POWER_UW),
        constraints,
        vf_switch_cycles: 220, // sub-µs regulator settle (Raven-style PMU)
        active_base: active_base_power(),
    }
}

/// Total die area of the preset (mm²), for Table 3.
pub fn total_area_mm2() -> f64 {
    AREA_BREAKDOWN.iter().map(|(_, a)| a).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::Freq;

    #[test]
    fn table2_vf_points() {
        let p = heeptimize();
        assert_eq!(p.vf.len(), 4);
        assert_eq!(p.vf.min().label(), "0.50V@122MHz");
        assert_eq!(p.vf.max().label(), "0.90V@690MHz");
    }

    #[test]
    fn lambda_op_cpu_only_kernels() {
        let p = heeptimize();
        use crate::ir::DataWidth;
        for ty in [KernelType::Softmax, KernelType::Gelu, KernelType::FftMag] {
            assert!(p.constraints.supports(CPU, ty, DataWidth::Float32));
            assert!(!p.constraints.supports(CGRA, ty, DataWidth::Int8));
            assert!(!p.constraints.supports(CARUS, ty, DataWidth::Int8));
        }
    }

    #[test]
    fn accelerators_reject_float() {
        let p = heeptimize();
        use crate::ir::DataWidth;
        assert!(!p
            .constraints
            .supports(CGRA, KernelType::MatMul, DataWidth::Float32));
        assert!(p
            .constraints
            .supports(CARUS, KernelType::MatMul, DataWidth::Int16));
    }

    #[test]
    fn power_ratio_falls_at_low_voltage() {
        // The Fig 7 precondition: CGRA/Carus power ratio must decrease
        // significantly when moving from the highest to the lowest V-F point.
        let p = heeptimize();
        let lo = p.vf.min();
        let hi = p.vf.max();
        let ratio = |vf: VfPoint| {
            let cgra = p.pe(CGRA).power.p_total(KernelType::MatMul, vf.v, vf.f);
            let carus = p.pe(CARUS).power.p_total(KernelType::MatMul, vf.v, vf.f);
            cgra.raw() / carus.raw()
        };
        let r_lo = ratio(lo);
        let r_hi = ratio(hi);
        assert!(
            r_lo < 0.75 * r_hi,
            "power ratio must fall at low V: lo={r_lo:.3} hi={r_hi:.3}"
        );
    }

    #[test]
    fn sleep_power_anchor() {
        let p = heeptimize();
        assert!((p.sleep_power.as_uw() - 129.0).abs() < 1e-9);
    }

    #[test]
    fn area_totals_paper_value() {
        // Paper Table 3 reports ≈0.632 mm².
        assert!((total_area_mm2() - 0.632).abs() < 0.001);
    }

    #[test]
    fn vf_switch_is_submicrosecond_at_all_points() {
        let p = heeptimize();
        for pt in p.vf.points() {
            let t = crate::util::units::Cycles(p.vf_switch_cycles).at(pt.f);
            assert!(t.as_us() < 2.0, "switch at {} took {}", pt.label(), t);
        }
        let _ = Freq::from_mhz(122.0);
    }
}
