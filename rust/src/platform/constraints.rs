//! Kernel-PE operational constraints `Λ_op` (Eq. 5).
//!
//! Each PE may (a) not support a kernel type at all, (b) restrict operand
//! data widths, or (c) bound the largest dimension it can address (e.g.
//! Carus vector length, CGRA column addressing). MEDEA consults these when
//! enumerating valid configurations and when tiling.

use crate::ir::{DataWidth, KernelType};
use crate::platform::pe::PeId;
use std::collections::BTreeMap;

/// Constraint `λ_{p_j, τ_i}` for one (PE, kernel-type) pair.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OpConstraint {
    /// Largest single dimension the PE can address for this kernel type
    /// (None: unbounded — only LM capacity limits the tile).
    pub max_dim: Option<u64>,
    /// Supported operand data widths (empty means all widths).
    pub widths: Vec<DataWidth>,
}

impl OpConstraint {
    pub fn unbounded() -> OpConstraint {
        OpConstraint {
            max_dim: None,
            widths: Vec::new(),
        }
    }

    pub fn with_max_dim(max_dim: u64) -> OpConstraint {
        OpConstraint {
            max_dim: Some(max_dim),
            widths: Vec::new(),
        }
    }

    pub fn widths(mut self, widths: &[DataWidth]) -> OpConstraint {
        self.widths = widths.to_vec();
        self
    }

    pub fn allows_width(&self, dw: DataWidth) -> bool {
        self.widths.is_empty() || self.widths.contains(&dw)
    }
}

/// The full constraint set `Λ_op`: `(p_j, τ_i) → λ`.
///
/// A missing entry means *the PE does not support the kernel type* — support
/// must be declared explicitly, mirroring how accelerator kernel libraries
/// enumerate what they implement.
#[derive(Debug, Clone, Default)]
pub struct OpConstraints {
    map: BTreeMap<(usize, KernelType), OpConstraint>,
}

impl OpConstraints {
    pub fn new() -> OpConstraints {
        OpConstraints::default()
    }

    pub fn allow(&mut self, pe: PeId, ty: KernelType, c: OpConstraint) {
        self.map.insert((pe.0, ty), c);
    }

    /// Allow every kernel type on `pe` (used for the host CPU).
    pub fn allow_all(&mut self, pe: PeId) {
        for ty in KernelType::ALL {
            self.allow(pe, ty, OpConstraint::unbounded());
        }
    }

    /// The constraint for `(pe, ty)`; None means unsupported.
    pub fn get(&self, pe: PeId, ty: KernelType) -> Option<&OpConstraint> {
        self.map.get(&(pe.0, ty))
    }

    /// Is `(pe, ty, dw)` executable at all (ignoring size/tiling)?
    pub fn supports(&self, pe: PeId, ty: KernelType, dw: DataWidth) -> bool {
        self.get(pe, ty).is_some_and(|c| c.allows_width(dw))
    }

    /// Kernel types supported on `pe`.
    pub fn supported_types(&self, pe: PeId) -> Vec<KernelType> {
        KernelType::ALL
            .into_iter()
            .filter(|ty| self.map.contains_key(&(pe.0, *ty)))
            .collect()
    }

    pub fn validate(&self, n_pes: usize) -> Result<(), String> {
        for ((pe, ty), c) in &self.map {
            if *pe >= n_pes {
                return Err(format!("constraint for nonexistent pe{pe} / {ty}"));
            }
            if let Some(0) = c.max_dim {
                return Err(format!("zero max_dim for pe{pe} / {ty}"));
            }
        }
        Ok(())
    }

    pub fn iter(&self) -> impl Iterator<Item = (PeId, KernelType, &OpConstraint)> {
        self.map.iter().map(|((pe, ty), c)| (PeId(*pe), *ty, c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_entry_means_unsupported() {
        let mut c = OpConstraints::new();
        c.allow(PeId(1), KernelType::MatMul, OpConstraint::with_max_dim(256));
        assert!(c.supports(PeId(1), KernelType::MatMul, DataWidth::Int8));
        assert!(!c.supports(PeId(1), KernelType::Softmax, DataWidth::Int8));
        assert!(!c.supports(PeId(0), KernelType::MatMul, DataWidth::Int8));
    }

    #[test]
    fn width_restrictions() {
        let mut c = OpConstraints::new();
        c.allow(
            PeId(0),
            KernelType::MatMul,
            OpConstraint::unbounded().widths(&[DataWidth::Int8, DataWidth::Int16]),
        );
        assert!(c.supports(PeId(0), KernelType::MatMul, DataWidth::Int8));
        assert!(!c.supports(PeId(0), KernelType::MatMul, DataWidth::Float32));
    }

    #[test]
    fn allow_all_covers_everything() {
        let mut c = OpConstraints::new();
        c.allow_all(PeId(0));
        for ty in KernelType::ALL {
            assert!(c.supports(PeId(0), ty, DataWidth::Float32));
        }
        assert_eq!(c.supported_types(PeId(0)).len(), KernelType::ALL.len());
    }

    #[test]
    fn validation() {
        let mut c = OpConstraints::new();
        c.allow(PeId(5), KernelType::Add, OpConstraint::unbounded());
        assert!(c.validate(3).is_err());
        assert!(c.validate(6).is_ok());
    }
}
