//! Voltage-frequency operating points `S_vf` (Eq. 3).
//!
//! Consistent with the paper (and [33]), the platform always runs at the
//! maximum supported frequency for each voltage: `f_l = F_max(v_l)`.

use crate::util::units::{Freq, Voltage};

/// One `(v_l, f_l)` operating point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VfPoint {
    pub v: Voltage,
    pub f: Freq,
}

impl VfPoint {
    pub fn new(volts: f64, mhz: f64) -> VfPoint {
        VfPoint {
            v: Voltage(volts),
            f: Freq::from_mhz(mhz),
        }
    }

    /// Label like `0.65V@347MHz`.
    pub fn label(&self) -> String {
        format!("{:.2}V@{:.0}MHz", self.v.raw(), self.f.as_mhz())
    }
}

/// The ordered set of operating points (ascending voltage).
#[derive(Debug, Clone, PartialEq)]
pub struct VfTable {
    points: Vec<VfPoint>,
}

impl VfTable {
    pub fn new(points: Vec<VfPoint>) -> VfTable {
        let t = VfTable { points };
        t.validate().expect("invalid V-F table");
        t
    }

    pub fn points(&self) -> &[VfPoint] {
        &self.points
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Index of a point (by exact voltage match).
    pub fn index_of(&self, v: Voltage) -> Option<usize> {
        self.points.iter().position(|p| p.v == v)
    }

    pub fn get(&self, idx: usize) -> VfPoint {
        self.points[idx]
    }

    /// Lowest operating point (minimum voltage).
    pub fn min(&self) -> VfPoint {
        self.points[0]
    }

    /// Highest operating point (maximum voltage/frequency).
    pub fn max(&self) -> VfPoint {
        *self.points.last().unwrap()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.points.is_empty() {
            return Err("empty V-F table".into());
        }
        for w in self.points.windows(2) {
            if w[1].v.raw() <= w[0].v.raw() {
                return Err(format!(
                    "V-F table voltages not strictly increasing: {} then {}",
                    w[0].label(),
                    w[1].label()
                ));
            }
            if w[1].f.raw() <= w[0].f.raw() {
                return Err(format!(
                    "V-F table frequencies not strictly increasing: {} then {}",
                    w[0].label(),
                    w[1].label()
                ));
            }
        }
        for p in &self.points {
            if p.v.raw() <= 0.0 || p.f.raw() <= 0.0 {
                return Err(format!("non-positive V-F point {}", p.label()));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table2() -> VfTable {
        VfTable::new(vec![
            VfPoint::new(0.50, 122.0),
            VfPoint::new(0.65, 347.0),
            VfPoint::new(0.80, 578.0),
            VfPoint::new(0.90, 690.0),
        ])
    }

    #[test]
    fn accessors() {
        let t = table2();
        assert_eq!(t.len(), 4);
        assert_eq!(t.min().label(), "0.50V@122MHz");
        assert_eq!(t.max().label(), "0.90V@690MHz");
        assert_eq!(t.index_of(Voltage(0.65)), Some(1));
        assert_eq!(t.index_of(Voltage(0.7)), None);
    }

    #[test]
    #[should_panic(expected = "invalid V-F table")]
    fn rejects_non_monotone() {
        VfTable::new(vec![VfPoint::new(0.8, 578.0), VfPoint::new(0.5, 122.0)]);
    }

    #[test]
    fn rejects_empty() {
        assert!(VfTable {
            points: vec![]
        }
        .validate()
        .is_err());
    }
}
