//! Typed physical quantities.
//!
//! The manager mixes cycle counts, frequencies, voltages, times, energies and
//! powers; mixing them up silently is the classic failure mode of an energy
//! model. Each quantity gets a newtype over `f64` (or `u64` for cycles) with
//! only the physically meaningful operations defined.

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub, SubAssign};

macro_rules! f64_newtype {
    ($(#[$meta:meta])* $name:ident, $unit:expr) => {
        $(#[$meta])*
        #[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
        pub struct $name(pub f64);

        impl $name {
            pub const ZERO: $name = $name(0.0);
            #[inline]
            pub fn raw(self) -> f64 {
                self.0
            }
            #[inline]
            pub fn abs(self) -> $name {
                $name(self.0.abs())
            }
            #[inline]
            pub fn min(self, other: $name) -> $name {
                $name(self.0.min(other.0))
            }
            #[inline]
            pub fn max(self, other: $name) -> $name {
                $name(self.0.max(other.0))
            }
            #[inline]
            pub fn is_finite(self) -> bool {
                self.0.is_finite()
            }
        }

        impl Add for $name {
            type Output = $name;
            #[inline]
            fn add(self, rhs: $name) -> $name {
                $name(self.0 + rhs.0)
            }
        }
        impl Sub for $name {
            type Output = $name;
            #[inline]
            fn sub(self, rhs: $name) -> $name {
                $name(self.0 - rhs.0)
            }
        }
        impl AddAssign for $name {
            #[inline]
            fn add_assign(&mut self, rhs: $name) {
                self.0 += rhs.0;
            }
        }
        impl SubAssign for $name {
            #[inline]
            fn sub_assign(&mut self, rhs: $name) {
                self.0 -= rhs.0;
            }
        }
        impl Neg for $name {
            type Output = $name;
            #[inline]
            fn neg(self) -> $name {
                $name(-self.0)
            }
        }
        impl Mul<f64> for $name {
            type Output = $name;
            #[inline]
            fn mul(self, rhs: f64) -> $name {
                $name(self.0 * rhs)
            }
        }
        impl Div<f64> for $name {
            type Output = $name;
            #[inline]
            fn div(self, rhs: f64) -> $name {
                $name(self.0 / rhs)
            }
        }
        impl Div<$name> for $name {
            type Output = f64;
            #[inline]
            fn div(self, rhs: $name) -> f64 {
                self.0 / rhs.0
            }
        }
        impl Sum for $name {
            fn sum<I: Iterator<Item = $name>>(iter: I) -> $name {
                $name(iter.map(|v| v.0).sum())
            }
        }
        impl fmt::Display for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "{} {}", self.0, $unit)
            }
        }
    };
}

f64_newtype!(
    /// A time span in seconds.
    Time,
    "s"
);
f64_newtype!(
    /// An energy in joules.
    Energy,
    "J"
);
f64_newtype!(
    /// A power in watts.
    Power,
    "W"
);
f64_newtype!(
    /// A frequency in hertz.
    Freq,
    "Hz"
);
f64_newtype!(
    /// A supply voltage in volts.
    Voltage,
    "V"
);

impl Time {
    #[inline]
    pub fn from_ms(ms: f64) -> Time {
        Time(ms * 1e-3)
    }
    #[inline]
    pub fn from_us(us: f64) -> Time {
        Time(us * 1e-6)
    }
    #[inline]
    pub fn as_ms(self) -> f64 {
        self.0 * 1e3
    }
    #[inline]
    pub fn as_us(self) -> f64 {
        self.0 * 1e6
    }
}

impl Energy {
    #[inline]
    pub fn from_uj(uj: f64) -> Energy {
        Energy(uj * 1e-6)
    }
    #[inline]
    pub fn as_uj(self) -> f64 {
        self.0 * 1e6
    }
    #[inline]
    pub fn as_mj(self) -> f64 {
        self.0 * 1e3
    }
}

impl Power {
    #[inline]
    pub fn from_uw(uw: f64) -> Power {
        Power(uw * 1e-6)
    }
    #[inline]
    pub fn from_mw(mw: f64) -> Power {
        Power(mw * 1e-3)
    }
    #[inline]
    pub fn as_uw(self) -> f64 {
        self.0 * 1e6
    }
    #[inline]
    pub fn as_mw(self) -> f64 {
        self.0 * 1e3
    }
}

impl Freq {
    #[inline]
    pub fn from_mhz(mhz: f64) -> Freq {
        Freq(mhz * 1e6)
    }
    #[inline]
    pub fn as_mhz(self) -> f64 {
        self.0 * 1e-6
    }
}

/// `P × t = E`
impl Mul<Time> for Power {
    type Output = Energy;
    #[inline]
    fn mul(self, rhs: Time) -> Energy {
        Energy(self.0 * rhs.0)
    }
}
/// `t × P = E`
impl Mul<Power> for Time {
    type Output = Energy;
    #[inline]
    fn mul(self, rhs: Power) -> Energy {
        Energy(self.0 * rhs.0)
    }
}
/// `E / t = P`
impl Div<Time> for Energy {
    type Output = Power;
    #[inline]
    fn div(self, rhs: Time) -> Power {
        Power(self.0 / rhs.0)
    }
}
/// `E / P = t`
impl Div<Power> for Energy {
    type Output = Time;
    #[inline]
    fn div(self, rhs: Power) -> Time {
        Time(self.0 / rhs.0)
    }
}

/// A cycle count. Kept integral: the characterization harness reports exact
/// simulated cycle counts, mirroring FPGA performance counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Cycles(pub u64);

impl Cycles {
    pub const ZERO: Cycles = Cycles(0);

    #[inline]
    pub fn raw(self) -> u64 {
        self.0
    }

    /// Wall-clock time of this many cycles at frequency `f`.
    #[inline]
    pub fn at(self, f: Freq) -> Time {
        Time(self.0 as f64 / f.0)
    }

    #[inline]
    pub fn saturating_sub(self, rhs: Cycles) -> Cycles {
        Cycles(self.0.saturating_sub(rhs.0))
    }
}

impl Add for Cycles {
    type Output = Cycles;
    #[inline]
    fn add(self, rhs: Cycles) -> Cycles {
        Cycles(self.0 + rhs.0)
    }
}
impl AddAssign for Cycles {
    #[inline]
    fn add_assign(&mut self, rhs: Cycles) {
        self.0 += rhs.0;
    }
}
impl Sub for Cycles {
    type Output = Cycles;
    #[inline]
    fn sub(self, rhs: Cycles) -> Cycles {
        Cycles(self.0 - rhs.0)
    }
}
impl Mul<u64> for Cycles {
    type Output = Cycles;
    #[inline]
    fn mul(self, rhs: u64) -> Cycles {
        Cycles(self.0 * rhs)
    }
}
impl Sum for Cycles {
    fn sum<I: Iterator<Item = Cycles>>(iter: I) -> Cycles {
        Cycles(iter.map(|c| c.0).sum())
    }
}
impl fmt::Display for Cycles {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} cyc", self.0)
    }
}

/// A memory size in bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Bytes(pub u64);

impl Bytes {
    pub const ZERO: Bytes = Bytes(0);

    #[inline]
    pub fn from_kib(kib: u64) -> Bytes {
        Bytes(kib * 1024)
    }
    #[inline]
    pub fn raw(self) -> u64 {
        self.0
    }
    #[inline]
    pub fn as_kib(self) -> f64 {
        self.0 as f64 / 1024.0
    }
    #[inline]
    pub fn min(self, other: Bytes) -> Bytes {
        Bytes(self.0.min(other.0))
    }
    #[inline]
    pub fn saturating_sub(self, rhs: Bytes) -> Bytes {
        Bytes(self.0.saturating_sub(rhs.0))
    }
}

impl Add for Bytes {
    type Output = Bytes;
    #[inline]
    fn add(self, rhs: Bytes) -> Bytes {
        Bytes(self.0 + rhs.0)
    }
}
impl AddAssign for Bytes {
    #[inline]
    fn add_assign(&mut self, rhs: Bytes) {
        self.0 += rhs.0;
    }
}
impl Mul<u64> for Bytes {
    type Output = Bytes;
    #[inline]
    fn mul(self, rhs: u64) -> Bytes {
        Bytes(self.0 * rhs)
    }
}
impl Sum for Bytes {
    fn sum<I: Iterator<Item = Bytes>>(iter: I) -> Bytes {
        Bytes(iter.map(|b| b.0).sum())
    }
}
impl fmt::Display for Bytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 % 1024 == 0 && self.0 > 0 {
            write!(f, "{} KiB", self.0 / 1024)
        } else {
            write!(f, "{} B", self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_time_energy_algebra() {
        let p = Power::from_mw(2.0);
        let t = Time::from_ms(50.0);
        let e = p * t;
        assert!((e.as_uj() - 100.0).abs() < 1e-9);
        let p2 = e / t;
        assert!((p2.as_mw() - 2.0).abs() < 1e-12);
        let t2 = e / p;
        assert!((t2.as_ms() - 50.0).abs() < 1e-12);
    }

    #[test]
    fn cycles_at_frequency() {
        let c = Cycles(122_000_000);
        let t = c.at(Freq::from_mhz(122.0));
        assert!((t.raw() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn bytes_display_and_conv() {
        assert_eq!(Bytes::from_kib(64).to_string(), "64 KiB");
        assert_eq!(Bytes(100).to_string(), "100 B");
        assert_eq!(Bytes::from_kib(128).raw(), 131072);
    }

    #[test]
    fn unit_conversions() {
        assert!((Time::from_us(1500.0).as_ms() - 1.5).abs() < 1e-12);
        assert!((Energy::from_uj(946.0).as_mj() - 0.946).abs() < 1e-12);
        assert!((Freq::from_mhz(690.0).raw() - 690e6).abs() < 1.0);
        assert!((Power::from_uw(129.0).as_mw() - 0.129).abs() < 1e-12);
    }

    #[test]
    fn sums_and_ordering() {
        let total: Cycles = [Cycles(1), Cycles(2), Cycles(3)].into_iter().sum();
        assert_eq!(total, Cycles(6));
        let e: Energy = [Energy(1.0), Energy(0.5)].into_iter().sum();
        assert!((e.raw() - 1.5).abs() < 1e-12);
        assert!(Time(1.0) < Time(2.0));
        assert_eq!(Time(3.0).min(Time(2.0)), Time(2.0));
    }

    #[test]
    fn saturating_ops() {
        assert_eq!(Cycles(5).saturating_sub(Cycles(9)), Cycles::ZERO);
        assert_eq!(Bytes(5).saturating_sub(Bytes(9)), Bytes::ZERO);
    }
}
