//! A small, complete JSON codec.
//!
//! Used for platform descriptions, characterization profiles (`S_c`, `S_P`),
//! schedules, and experiment outputs. The vendored crate set has no `serde`,
//! so this module implements RFC 8259 parsing and emission directly. Object
//! key order is preserved (insertion order) so emitted profiles diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Objects keep insertion order via a parallel key vector.
    Obj(JsonObj),
}

/// An insertion-ordered JSON object.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JsonObj {
    keys: Vec<String>,
    map: BTreeMap<String, Json>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: impl Into<Json>) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.keys.push(key.clone());
        }
        self.map.insert(key, value.into());
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn contains_key(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.keys.iter().map(move |k| (k, &self.map[k]))
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}
impl From<JsonObj> for Json {
    fn from(o: JsonObj) -> Json {
        Json::Obj(o)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(v: &[T]) -> Json {
        Json::Arr(v.iter().cloned().map(Into::into).collect())
    }
}

/// Error produced by [`parse`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj[key]` access that threads `Option`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field access with a contextual error message.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing field `{key}`"))
    }

    // ---- emission --------------------------------------------------------

    /// Compact single-line rendering.
    pub fn to_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_string(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(obj) => {
                if obj.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in obj.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_string(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n.fract() == 0.0 && n.abs() < 1e15 {
            fmt::Write::write_fmt(out, format_args!("{}", n as i64)).unwrap();
        } else {
            // Shortest round-trippable representation rust gives us.
            fmt::Write::write_fmt(out, format_args!("{n}")).unwrap();
        }
    } else {
        // JSON has no NaN/Inf; emit null like most encoders.
        out.push_str("null");
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32)).unwrap()
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Trailing whitespace is allowed; trailing garbage is
/// an error.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.into(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected byte `{}`", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal, expected `{lit}`")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(obj));
                }
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Handle surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("invalid \\u escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 character.
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8 in string"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("invalid number `{text}`")))
    }
}

/// Build a [`JsonObj`] inline: `obj! { "a" => 1u64, "b" => "x" }`.
#[macro_export]
macro_rules! json_obj {
    ( $( $k:expr => $v:expr ),* $(,)? ) => {{
        let mut o = $crate::util::json::JsonObj::new();
        $( o.insert($k, $v); )*
        $crate::util::json::Json::Obj(o)
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "d"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("d"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes_round_trip() {
        let original = Json::Str("line\nquote\"back\\slash\ttab\u{1}".into());
        let emitted = original.to_compact();
        assert_eq!(parse(&emitted).unwrap(), original);
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn object_preserves_insertion_order() {
        let v = parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.clone()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn pretty_round_trips() {
        let v = json_obj! {
            "name" => "heeptimize",
            "pes" => Json::Arr(vec![Json::from("cpu"), Json::from("cgra")]),
            "lm_kib" => 64u64,
            "ok" => true,
        };
        let text = v.to_pretty();
        assert_eq!(parse(&text).unwrap(), v);
        assert!(text.contains("\"lm_kib\": 64"));
    }

    #[test]
    fn numbers_round_trip() {
        for n in [0.0, -0.5, 1e-9, 123456789.25, 129e-6, 3.5e15] {
            let text = Json::Num(n).to_compact();
            assert_eq!(parse(&text).unwrap().as_f64().unwrap(), n, "{text}");
        }
    }

    #[test]
    fn typed_accessors() {
        let v = parse(r#"{"n": 3, "f": 1.5}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("f").unwrap().as_u64(), None);
        assert!(v.req("missing").is_err());
        assert!(v.req("n").is_ok());
    }
}
