//! A mini benchmark harness (criterion stand-in).
//!
//! `cargo bench` runs each bench binary (declared `harness = false` in
//! `Cargo.toml`); those binaries use [`Bencher`] for warmup + timed iterations
//! and print a uniform `name  mean ± σ  (iters)` report alongside the
//! reproduced paper table/figure data.

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::Running;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
    pub iters: u64,
}

impl Measurement {
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12} ± {:>10}  (min {:>10}, max {:>10}, n={})",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.stddev),
            fmt_dur(self.min),
            fmt_dur(self.max),
            self.iters
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with warmup and adaptive iteration count.
pub struct Bencher {
    /// Target total measurement time per benchmark.
    pub measure_time: Duration,
    /// Warmup time before measuring.
    pub warmup_time: Duration,
    /// Upper bound on timed iterations.
    pub max_iters: u64,
    results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // Honor quick runs: MEDEA_BENCH_FAST=1 trims times for CI smoke.
        let fast = std::env::var("MEDEA_BENCH_FAST").is_ok();
        Self {
            measure_time: if fast {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(1)
            },
            warmup_time: if fast {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(300)
            },
            max_iters: 10_000,
            results: Vec::new(),
        }
    }

    /// Time `f`, which must return a value (passed through `black_box`).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        // Warmup: also estimates per-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup_time {
            black_box(f());
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;
        let iters = ((self.measure_time.as_secs_f64() / per_iter.max(1e-9)) as u64)
            .clamp(1, self.max_iters);

        let mut stats = Running::new();
        for _ in 0..iters {
            let t0 = Instant::now();
            black_box(f());
            stats.push(t0.elapsed().as_secs_f64());
        }
        let m = Measurement {
            name: name.to_string(),
            mean: Duration::from_secs_f64(stats.mean()),
            stddev: Duration::from_secs_f64(stats.stddev()),
            min: Duration::from_secs_f64(stats.min()),
            max: Duration::from_secs_f64(stats.max()),
            iters,
        };
        println!("{}", m.report_line());
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Print the closing summary (called at the end of each bench binary).
    pub fn finish(&self, bench_name: &str) {
        println!(
            "\n[{bench_name}] {} benchmark(s) complete",
            self.results.len()
        );
    }
}

/// Write a machine-readable bench report to `path`, attaching an optional
/// telemetry registry snapshot (see `telemetry::RegistrySnapshot::to_json`)
/// under a top-level `"telemetry"` key so bench artifacts carry the same
/// counters and histograms a live scrape would — including the energy
/// attribution ledger (`telemetry.ledger`), which makes the artifact a valid
/// input to `medea energy-report`.
pub fn write_bench_json(
    path: &str,
    mut result: Json,
    telemetry: Option<Json>,
) -> std::io::Result<()> {
    if let (Json::Obj(obj), Some(snapshot)) = (&mut result, telemetry) {
        obj.insert("telemetry", snapshot);
    }
    std::fs::write(path, result.to_pretty())?;
    println!("\nwrote {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("MEDEA_BENCH_FAST", "1");
        let mut b = Bencher::new();
        b.measure_time = Duration::from_millis(20);
        b.warmup_time = Duration::from_millis(5);
        let m = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(m.iters >= 1);
        assert!(m.mean.as_nanos() > 0);
    }

    #[test]
    fn bench_json_attaches_telemetry_key() {
        let dir = std::env::temp_dir().join("medea_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.json");
        let path = path.to_str().unwrap();

        let result = crate::json_obj! { "reqs_per_sec" => 123.0 };
        let snap = crate::json_obj! { "requests" => 7u64 };
        write_bench_json(path, result, Some(snap)).unwrap();

        let parsed = crate::util::json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(parsed.get("reqs_per_sec").unwrap().as_f64(), Some(123.0));
        assert_eq!(
            parsed.get("telemetry").unwrap().get("requests").unwrap().as_u64(),
            Some(7)
        );

        // Without a snapshot the payload passes through untouched.
        write_bench_json(path, crate::json_obj! { "a" => 1u64 }, None).unwrap();
        let parsed = crate::util::json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert!(parsed.get("telemetry").is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.50 ms");
        assert!(fmt_dur(Duration::from_secs(2)).ends_with(" s"));
    }
}
