//! Deterministic pseudo-random number generation.
//!
//! Used by the synthetic EEG generator, the property-test helpers, and the
//! workload fuzzers. No `rand` crate is available offline, so this implements
//! SplitMix64 (seeding) and xoshiro256** (bulk generation) — both public
//! domain algorithms by Blackman & Vigna.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: fast, high-quality, deterministic PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> uniform double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[0, n)` using Lemire rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_below(items.len())]
    }

    /// Standard normal via Box–Muller (uses two uniforms, returns one value).
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.usize_below(i + 1);
            items.swap(i, j);
        }
    }
}

/// A minimal property-test driver: runs `body` over `cases` pseudo-random
/// cases derived from `seed`; on failure, reports the failing case index so
/// it can be replayed deterministically.
pub fn check_cases(seed: u64, cases: usize, mut body: impl FnMut(&mut Rng, usize)) {
    for case in 0..cases {
        // Derive an independent stream per case so failures replay in isolation.
        let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        body(&mut rng, case);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(7);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::new(11);
        let n = 100_000;
        let samples: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn range_u64_inclusive_bounds() {
        let mut rng = Rng::new(9);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let x = rng.range_u64(3, 6);
            assert!((3..=6).contains(&x));
            saw_lo |= x == 3;
            saw_hi |= x == 6;
        }
        assert!(saw_lo && saw_hi);
    }
}
