//! A minimal leveled stderr logger (stand-in for the `log` crate facade).
//!
//! The library logs rarely — runtime-unavailable warnings, worker lifecycle
//! notes — so a static atomic level plus `eprintln!` covers everything the
//! `log` crate was used for, without the external dependency.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ordered from quietest to chattiest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Off => "OFF",
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);

/// Set the maximum emitted level.
pub fn set_max_level(level: Level) {
    // ordering: the level is a single self-contained u8 — readers that
    // race with a change may emit (or skip) one message at the old level,
    // which is harmless, so no release/acquire pairing is needed.
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether a message at `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    // ordering: relaxed read of the standalone level, see `set_max_level`.
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Initialize the level from `$MEDEA_LOG` (error|warn|info|debug|trace|off);
/// defaults to `warn`.
pub fn init_from_env() {
    let level = match std::env::var("MEDEA_LOG").as_deref() {
        Ok("off") => Level::Off,
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Warn,
    };
    set_max_level(level);
}

/// Render milliseconds since the Unix epoch as `YYYY-MM-DDTHH:MM:SS.mmmZ`.
///
/// Uses the days-to-civil-date algorithm (era/400-year cycles) so no calendar
/// dependency is needed; valid for any date the serving layer will ever emit.
pub fn format_utc_ms(unix_ms: u64) -> String {
    let secs = unix_ms / 1000;
    let millis = unix_ms % 1000;
    let days = (secs / 86_400) as i64;
    let rem = secs % 86_400;
    let (hh, mm, ss) = (rem / 3600, (rem / 60) % 60, rem % 60);

    // Howard Hinnant's civil_from_days: shift the epoch to 0000-03-01 so each
    // 400-year era is a fixed 146097 days and leap handling becomes division.
    let z = days + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097); // day-of-era [0, 146096]
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365; // [0, 399]
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100); // [0, 365]
    let mp = (5 * doy + 2) / 153; // March-based month [0, 11]
    let day = doy - (153 * mp + 2) / 5 + 1; // [1, 31]
    let month = if mp < 10 { mp + 3 } else { mp - 9 };
    let year = yoe + era * 400 + i64::from(month <= 2);

    format!("{year:04}-{month:02}-{day:02}T{hh:02}:{mm:02}:{ss:02}.{millis:03}Z")
}

fn now_utc() -> String {
    let unix_ms = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0);
    format_utc_ms(unix_ms)
}

/// Emit one record (used by the macros; prefer those at call sites).
pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{} {}] {}", now_utc(), level.name(), args);
    }
}

/// Log at WARN.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Warn, format_args!($($arg)*))
    };
}

/// Log at INFO.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Info, format_args!($($arg)*))
    };
}

/// Log at DEBUG.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Debug, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_gates_emission() {
        set_max_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_max_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_max_level(Level::Off);
        assert!(!enabled(Level::Error));
        // Restore the default so other tests see the usual behavior.
        set_max_level(Level::Warn);
    }

    #[test]
    fn names_render() {
        assert_eq!(Level::Warn.name(), "WARN");
        assert_eq!(Level::Trace.name(), "TRACE");
    }

    #[test]
    fn utc_formatting_matches_known_instants() {
        // Pinned against `datetime.datetime.fromtimestamp(ms/1000, tz=utc)`.
        assert_eq!(format_utc_ms(0), "1970-01-01T00:00:00.000Z");
        // Leap day in a century year that *is* a leap year (divisible by 400).
        assert_eq!(format_utc_ms(951_867_296_789), "2000-02-29T23:34:56.789Z");
        assert_eq!(format_utc_ms(1_754_653_000_123), "2025-08-08T11:36:40.123Z");
        // Century year that is *not* a leap year: 2100-01-01 boundary.
        assert_eq!(format_utc_ms(4_102_444_800_000), "2100-01-01T00:00:00.000Z");
    }

    #[test]
    fn now_utc_is_well_formed() {
        let ts = now_utc();
        assert_eq!(ts.len(), 24, "unexpected timestamp {ts}");
        assert!(ts.ends_with('Z'));
        assert_eq!(&ts[4..5], "-");
        assert_eq!(&ts[10..11], "T");
    }
}
