//! A minimal leveled stderr logger (stand-in for the `log` crate facade).
//!
//! The library logs rarely — runtime-unavailable warnings, worker lifecycle
//! notes — so a static atomic level plus `eprintln!` covers everything the
//! `log` crate was used for, without the external dependency.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ordered from quietest to chattiest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Off => "OFF",
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);

/// Set the maximum emitted level.
pub fn set_max_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether a message at `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Initialize the level from `$MEDEA_LOG` (error|warn|info|debug|trace|off);
/// defaults to `warn`.
pub fn init_from_env() {
    let level = match std::env::var("MEDEA_LOG").as_deref() {
        Ok("off") => Level::Off,
        Ok("error") => Level::Error,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Warn,
    };
    set_max_level(level);
}

/// Emit one record (used by the macros; prefer those at call sites).
pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{}] {}", level.name(), args);
    }
}

/// Log at WARN.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Warn, format_args!($($arg)*))
    };
}

/// Log at INFO.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Info, format_args!($($arg)*))
    };
}

/// Log at DEBUG.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Debug, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_gates_emission() {
        set_max_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_max_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_max_level(Level::Off);
        assert!(!enabled(Level::Error));
        // Restore the default so other tests see the usual behavior.
        set_max_level(Level::Warn);
    }

    #[test]
    fn names_render() {
        assert_eq!(Level::Warn.name(), "WARN");
        assert_eq!(Level::Trace.name(), "TRACE");
    }
}
