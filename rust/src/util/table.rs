//! Aligned-text, markdown, and CSV table rendering for experiment output.
//!
//! Every experiment driver (`exp/`) renders its paper table/figure data
//! through this module so `medea figN` output is uniform and diffable.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple table builder.
#[derive(Debug, Clone)]
pub struct Table {
    title: Option<String>,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            title: None,
            aligns: headers.iter().map(|_| Align::Right).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn with_title(mut self, title: impl Into<String>) -> Self {
        self.title = Some(title.into());
        self
    }

    /// First column left-aligned (typical "label + numbers" layout).
    pub fn label_first(mut self) -> Self {
        if !self.aligns.is_empty() {
            self.aligns[0] = Align::Left;
        }
        self
    }

    pub fn set_align(&mut self, col: usize, align: Align) {
        self.aligns[col] = align;
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                w[i] = w[i].max(cell.chars().count());
            }
        }
        w
    }

    fn pad(cell: &str, width: usize, align: Align) -> String {
        let len = cell.chars().count();
        let pad = " ".repeat(width - len);
        match align {
            Align::Left => format!("{cell}{pad}"),
            Align::Right => format!("{pad}{cell}"),
        }
    }

    /// Fixed-width plain-text rendering.
    pub fn to_text(&self) -> String {
        let widths = self.widths();
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let render_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| Self::pad(c, widths[i], self.aligns[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&render_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&render_row(row));
            out.push('\n');
        }
        out
    }

    /// GitHub-flavored markdown rendering.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(&format!("**{t}**\n\n"));
        }
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        let seps: Vec<&str> = self
            .aligns
            .iter()
            .map(|a| match a {
                Align::Left => ":---",
                Align::Right => "---:",
            })
            .collect();
        out.push_str(&format!("| {} |\n", seps.join(" | ")));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    /// CSV rendering (quotes cells containing separators).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with `digits` decimals, trimming to a fixed width feel.
pub fn fnum(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

/// Format a percentage (already in percent units).
pub fn fpct(x: f64) -> String {
    format!("{x:.1} %")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new(&["Scheduler", "Energy (uJ)", "Time (ms)"]).label_first();
        t.row(vec!["MEDEA".into(), "946".into(), "50.0".into()]);
        t.row(vec!["CoarseGrain".into(), "1100".into(), "49.8".into()]);
        t
    }

    #[test]
    fn text_is_aligned() {
        let text = sample().to_text();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        // All rows render to the same width.
        assert_eq!(lines[0].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
        assert!(lines[0].starts_with("Scheduler"));
        assert!(lines[2].starts_with("MEDEA"));
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.starts_with("| Scheduler |"));
        assert!(md.contains("| :--- | ---: | ---: |"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\",\"q\"\"z\""));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn num_formatting() {
        assert_eq!(fnum(3.14159, 2), "3.14");
        assert_eq!(fpct(31.34), "31.3 %");
    }
}
