//! A minimal declarative command-line parser.
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options and
//! positional arguments, with generated `--help` text. Stands in for `clap`,
//! which is not available in the offline vendor set.

use std::collections::BTreeMap;
use std::fmt;

/// Specification of one option/flag.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Specification of a (sub)command.
#[derive(Debug, Clone, Default)]
pub struct CmdSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    pub positionals: Vec<(&'static str, &'static str)>,
    /// A trailing repeatable positional (`medea lint [paths…]`): extra
    /// positionals beyond the declared ones are collected instead of
    /// rejected.
    pub variadic: Option<(&'static str, &'static str)>,
}

impl CmdSpec {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            opts: Vec::new(),
            positionals: Vec::new(),
        }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default: None,
        });
        self
    }

    pub fn opt_default(
        mut self,
        name: &'static str,
        help: &'static str,
        default: &'static str,
    ) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default: Some(default),
        });
        self
    }

    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    /// Accept any number of trailing positionals under one name.
    pub fn variadic(mut self, name: &'static str, help: &'static str) -> Self {
        self.variadic = Some((name, help));
        self
    }

    fn find(&self, name: &str) -> Option<&OptSpec> {
        self.opts.iter().find(|o| o.name == name)
    }

    /// Render help text for this command.
    pub fn help(&self, prog: &str) -> String {
        let mut s = format!("{}\n\nUsage: {} {}", self.about, prog, self.name);
        if !self.opts.is_empty() {
            s.push_str(" [OPTIONS]");
        }
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        if let Some((p, _)) = self.variadic {
            s.push_str(&format!(" [{p}…]"));
        }
        s.push('\n');
        if !self.positionals.is_empty() || self.variadic.is_some() {
            s.push_str("\nArguments:\n");
            for (p, h) in &self.positionals {
                s.push_str(&format!("  <{p}>  {h}\n"));
            }
            if let Some((p, h)) = self.variadic {
                s.push_str(&format!("  [{p}…]  {h}\n"));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\nOptions:\n");
            for o in &self.opts {
                let arg = if o.takes_value {
                    format!("--{} <VALUE>", o.name)
                } else {
                    format!("--{}", o.name)
                };
                let default = o
                    .default
                    .map(|d| format!(" [default: {d}]"))
                    .unwrap_or_default();
                s.push_str(&format!("  {arg:<26} {}{default}\n", o.help));
            }
        }
        s
    }
}

/// Parsed arguments for one command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError>
    where
        T::Err: fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(raw) => raw.parse::<T>().map(Some).map_err(|e| CliError {
                msg: format!("invalid value for --{name}: {e}"),
            }),
        }
    }

    /// Parse a required (possibly defaulted) option.
    pub fn req_parse<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError>
    where
        T::Err: fmt::Display,
    {
        self.get_parse(name)?.ok_or_else(|| CliError {
            msg: format!("missing required option --{name}"),
        })
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(|s| s.as_str())
    }

    /// Every positional in order (declared ones first, then the variadic
    /// tail).
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Parse a comma-separated list of f64 (e.g. `--deadlines 50,200,1000`).
    pub fn get_f64_list(&self, name: &str) -> Result<Option<Vec<f64>>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(raw) => raw
                .split(',')
                .map(|p| {
                    p.trim().parse::<f64>().map_err(|e| CliError {
                        msg: format!("invalid list item in --{name}: {e}"),
                    })
                })
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }
}

/// CLI parse error.
#[derive(Debug, Clone)]
pub struct CliError {
    pub msg: String,
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for CliError {}

/// Outcome of parsing the full command line.
#[derive(Debug)]
pub enum Parsed {
    /// A command matched; its name and parsed args.
    Command(String, Args),
    /// `--help`/`help` requested; the rendered help text.
    Help(String),
}

/// The top-level application spec.
pub struct App {
    pub prog: &'static str,
    pub about: &'static str,
    pub commands: Vec<CmdSpec>,
}

impl App {
    pub fn new(prog: &'static str, about: &'static str) -> Self {
        Self {
            prog,
            about,
            commands: Vec::new(),
        }
    }

    pub fn command(mut self, cmd: CmdSpec) -> Self {
        self.commands.push(cmd);
        self
    }

    pub fn overview(&self) -> String {
        let mut s = format!("{}\n\nUsage: {} <COMMAND> [OPTIONS]\n\nCommands:\n", self.about, self.prog);
        for c in &self.commands {
            s.push_str(&format!("  {:<14} {}\n", c.name, c.about));
        }
        s.push_str(&format!(
            "\nRun `{} <COMMAND> --help` for command options.\n",
            self.prog
        ));
        s
    }

    /// Parse an argv (without the program name).
    pub fn parse(&self, argv: &[String]) -> Result<Parsed, CliError> {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
            return Ok(Parsed::Help(self.overview()));
        }
        let cmd_name = &argv[0];
        let spec = self
            .commands
            .iter()
            .find(|c| c.name == *cmd_name)
            .ok_or_else(|| CliError {
                msg: format!("unknown command `{cmd_name}`; see --help"),
            })?;

        let mut args = Args::default();
        for o in &spec.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }

        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Ok(Parsed::Help(spec.help(self.prog)));
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let opt = spec.find(name).ok_or_else(|| CliError {
                    msg: format!("unknown option --{name} for `{cmd_name}`"),
                })?;
                if opt.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError {
                                    msg: format!("option --{name} expects a value"),
                                })?
                        }
                    };
                    args.values.insert(name.to_string(), val);
                } else {
                    if inline_val.is_some() {
                        return Err(CliError {
                            msg: format!("flag --{name} does not take a value"),
                        });
                    }
                    args.flags.push(name.to_string());
                }
            } else {
                args.positionals.push(tok.clone());
            }
            i += 1;
        }

        if spec.variadic.is_none() && args.positionals.len() > spec.positionals.len() {
            return Err(CliError {
                msg: format!(
                    "too many positional arguments for `{cmd_name}` (expected {})",
                    spec.positionals.len()
                ),
            });
        }
        Ok(Parsed::Command(cmd_name.clone(), args))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("medea", "MEDEA manager").command(
            CmdSpec::new("schedule", "Generate a schedule")
                .opt_default("deadline-ms", "Application deadline", "200")
                .opt("solver", "MCKP solver to use")
                .flag("verbose", "Chatty output")
                .positional("workload", "Workload file"),
        )
    }

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_options_flags_positionals() {
        let parsed = app()
            .parse(&sv(&["schedule", "--deadline-ms", "50", "--verbose", "tsd.json"]))
            .unwrap();
        match parsed {
            Parsed::Command(name, args) => {
                assert_eq!(name, "schedule");
                assert_eq!(args.req_parse::<f64>("deadline-ms").unwrap(), 50.0);
                assert!(args.flag("verbose"));
                assert_eq!(args.positional(0), Some("tsd.json"));
                assert_eq!(args.get("solver"), None);
            }
            _ => panic!("expected command"),
        }
    }

    #[test]
    fn defaults_apply() {
        let Parsed::Command(_, args) = app().parse(&sv(&["schedule"])).unwrap() else {
            panic!()
        };
        assert_eq!(args.req_parse::<f64>("deadline-ms").unwrap(), 200.0);
    }

    #[test]
    fn equals_syntax() {
        let Parsed::Command(_, args) = app()
            .parse(&sv(&["schedule", "--deadline-ms=1000"]))
            .unwrap()
        else {
            panic!()
        };
        assert_eq!(args.req_parse::<f64>("deadline-ms").unwrap(), 1000.0);
    }

    #[test]
    fn errors_are_reported() {
        assert!(app().parse(&sv(&["bogus"])).is_err());
        assert!(app().parse(&sv(&["schedule", "--nope"])).is_err());
        assert!(app().parse(&sv(&["schedule", "--solver"])).is_err());
        assert!(app()
            .parse(&sv(&["schedule", "a.json", "extra.json"]))
            .is_err());
    }

    #[test]
    fn help_paths() {
        assert!(matches!(app().parse(&sv(&["--help"])), Ok(Parsed::Help(_))));
        assert!(matches!(
            app().parse(&sv(&["schedule", "--help"])),
            Ok(Parsed::Help(_))
        ));
        let Parsed::Help(h) = app().parse(&sv(&[])).unwrap() else {
            panic!()
        };
        assert!(h.contains("schedule"));
    }

    #[test]
    fn variadic_collects_trailing_positionals() {
        let app = App::new("medea", "m").command(
            CmdSpec::new("lint", "Lint")
                .flag("json", "JSON output")
                .variadic("paths", "Files or directories"),
        );
        let Parsed::Command(_, args) = app
            .parse(&sv(&["lint", "--json", "src", "tests", "benches"]))
            .unwrap()
        else {
            panic!()
        };
        assert!(args.flag("json"));
        let got: Vec<&str> = args.positionals().iter().map(|s| s.as_str()).collect();
        assert_eq!(got, vec!["src", "tests", "benches"]);
        // Zero trailing positionals is fine too.
        let Parsed::Command(_, args) = app.parse(&sv(&["lint"])).unwrap() else {
            panic!()
        };
        assert!(args.positionals().is_empty());
        // Help renders the variadic argument.
        let Parsed::Help(h) = app.parse(&sv(&["lint", "--help"])).unwrap() else {
            panic!()
        };
        assert!(h.contains("[paths…]"));
    }

    #[test]
    fn f64_list_parsing() {
        let Parsed::Command(_, args) = App::new("x", "y")
            .command(CmdSpec::new("s", "s").opt("deadlines", "list"))
            .parse(&sv(&["s", "--deadlines", "50, 200,1000"]))
            .unwrap()
        else {
            panic!()
        };
        assert_eq!(
            args.get_f64_list("deadlines").unwrap().unwrap(),
            vec![50.0, 200.0, 1000.0]
        );
    }
}
