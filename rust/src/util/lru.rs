//! A small bounded LRU cache.
//!
//! Used by the serving layers to bound per-worker schedule caches (the
//! original coordinator kept an unbounded `BTreeMap` keyed by deadline, which
//! grows without limit under diverse-deadline traffic). Recency is tracked in
//! a `VecDeque` of keys; with the small capacities used here (≤ a few
//! hundred) the O(len) touch on hit is cheaper than a linked-map would be.

use std::collections::{HashMap, VecDeque};
use std::hash::Hash;

/// A bounded map evicting the least-recently-used entry on overflow.
#[derive(Debug)]
pub struct LruCache<K: Eq + Hash + Clone, V> {
    map: HashMap<K, V>,
    /// Keys from least- to most-recently used.
    order: VecDeque<K>,
    capacity: usize,
}

impl<K: Eq + Hash + Clone, V> LruCache<K, V> {
    /// `capacity` must be ≥ 1.
    pub fn new(capacity: usize) -> LruCache<K, V> {
        assert!(capacity >= 1, "LruCache capacity must be >= 1");
        LruCache {
            map: HashMap::with_capacity(capacity),
            order: VecDeque::with_capacity(capacity),
            capacity,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn contains(&self, key: &K) -> bool {
        self.map.contains_key(key)
    }

    fn touch(&mut self, key: &K) {
        if let Some(pos) = self.order.iter().position(|k| k == key) {
            let k = self.order.remove(pos).unwrap();
            self.order.push_back(k);
        }
    }

    /// Fetch and mark as most-recently used.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        if self.map.contains_key(key) {
            self.touch(key);
            self.map.get(key)
        } else {
            None
        }
    }

    /// Insert (or replace), evicting the least-recently-used entry when the
    /// cache is full. Returns the evicted `(key, value)`, if any.
    pub fn insert(&mut self, key: K, value: V) -> Option<(K, V)> {
        if self.map.contains_key(&key) {
            self.touch(&key);
            self.map.insert(key, value);
            return None;
        }
        let evicted = if self.map.len() >= self.capacity {
            self.order.pop_front().map(|old| {
                let v = self.map.remove(&old).expect("order/map out of sync");
                (old, v)
            })
        } else {
            None
        };
        self.order.push_back(key.clone());
        self.map.insert(key, value);
        evicted
    }

    /// Fetch, or insert the value produced by `make` (marking it MRU).
    pub fn get_or_insert_with(&mut self, key: K, make: impl FnOnce() -> V) -> &V {
        if !self.map.contains_key(&key) {
            let v = make();
            self.insert(key.clone(), v);
        } else {
            self.touch(&key);
        }
        self.map.get(&key).expect("just inserted")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_recently_used() {
        let mut c: LruCache<u64, &str> = LruCache::new(2);
        assert!(c.insert(1, "a").is_none());
        assert!(c.insert(2, "b").is_none());
        // Touch 1 so 2 becomes LRU.
        assert_eq!(c.get(&1), Some(&"a"));
        let evicted = c.insert(3, "c").unwrap();
        assert_eq!(evicted, (2, "b"));
        assert!(c.contains(&1) && c.contains(&3) && !c.contains(&2));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn replace_does_not_evict() {
        let mut c: LruCache<u64, u64> = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        assert!(c.insert(1, 11).is_none());
        assert_eq!(c.get(&1), Some(&11));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn get_or_insert_with_runs_once() {
        let mut c: LruCache<u64, u64> = LruCache::new(4);
        let mut calls = 0;
        for _ in 0..3 {
            c.get_or_insert_with(7, || {
                calls += 1;
                42
            });
        }
        assert_eq!(calls, 1);
        assert_eq!(c.get(&7), Some(&42));
    }

    #[test]
    fn eviction_follows_full_recency_order() {
        // Interleave inserts, hits, and replacements, then drain by
        // overflowing: evictions must come out exactly in recency order.
        let mut c: LruCache<u64, u64> = LruCache::new(4);
        for k in [1, 2, 3, 4] {
            c.insert(k, k * 10);
        }
        assert_eq!(c.get(&2), Some(&20)); // order now 1, 3, 4, 2
        c.insert(3, 33); // replace touches: order now 1, 4, 2, 3
        c.get_or_insert_with(1, || unreachable!()); // order now 4, 2, 3, 1
        let mut evicted = Vec::new();
        for k in [100, 101, 102, 103] {
            evicted.push(c.insert(k, 0).unwrap().0);
        }
        assert_eq!(evicted, vec![4, 2, 3, 1]);
    }

    #[test]
    fn capacity_one_always_holds_the_latest() {
        let mut c: LruCache<u64, &str> = LruCache::new(1);
        assert!(c.insert(1, "a").is_none());
        assert_eq!(c.insert(2, "b"), Some((1, "a")));
        assert_eq!(c.insert(3, "c"), Some((2, "b")));
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&3), Some(&"c"));
        assert!(c.get(&1).is_none());
        // Replacing the sole entry evicts nothing.
        assert!(c.insert(3, "c2").is_none());
        assert_eq!(c.get(&3), Some(&"c2"));
    }

    #[test]
    fn get_miss_does_not_disturb_order() {
        let mut c: LruCache<u64, u64> = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        assert!(c.get(&99).is_none());
        // 1 is still the LRU entry.
        assert_eq!(c.insert(3, 30).unwrap(), (1, 10));
    }

    #[test]
    fn stays_bounded_under_churn() {
        let mut c: LruCache<u64, u64> = LruCache::new(8);
        for i in 0..1000 {
            c.insert(i, i);
        }
        assert_eq!(c.len(), 8);
        // The eight most recent keys survive.
        for i in 992..1000 {
            assert!(c.contains(&i), "{i}");
        }
    }
}
