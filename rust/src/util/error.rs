//! A minimal `anyhow` stand-in: a string-backed dynamic error with context
//! chaining, plus the `anyhow!`/`bail!` macros re-exported for call-site
//! compatibility. The offline vendor set has no `anyhow`, and the library's
//! fallible host-side paths (PJRT runtime, serving) only ever need a
//! human-readable message chain.

use std::fmt;

/// A dynamic error: the original message plus outer context frames.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }

    /// Wrap with an outer context frame (`context: inner`).
    pub fn wrap(self, context: impl fmt::Display) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error { msg }
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error { msg: e.to_string() }
    }
}

/// Crate-wide result alias (mirrors `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context chaining for results and options (mirrors `anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string (mirrors `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return with a formatted [`Error`] (mirrors `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

// Allow `use crate::util::error::{anyhow, bail}` like the real crate.
pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::result::Result<u32, std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn context_chains_messages() {
        let e = io_fail().context("loading manifest").unwrap_err();
        assert_eq!(e.to_string(), "loading manifest: gone");
        let e = io_fail()
            .with_context(|| format!("artifact `{}`", "tsd_core"))
            .unwrap_err();
        assert!(e.to_string().starts_with("artifact `tsd_core`:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(7).context("unused").unwrap(), 7);
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
        fn inner() -> Result<()> {
            bail!("boom {}", "now")
        }
        assert_eq!(inner().unwrap_err().to_string(), "boom now");
    }

    #[test]
    fn wrap_adds_outer_frame() {
        let e = Error::msg("inner").wrap("outer");
        assert_eq!(e.to_string(), "outer: inner");
    }
}
