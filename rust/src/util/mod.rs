//! Zero-dependency substrates used across the library.
//!
//! The execution environment vendors only the `xla` crate family, so the
//! usual ecosystem crates (serde, clap, criterion, rand, …) are rebuilt here
//! as small, tested modules:
//!
//! * [`units`] — typed physical quantities (cycles, Hz, V, s, J, W, bytes).
//! * [`json`] — a complete JSON parser/emitter for profiles and platforms.
//! * [`cli`] — a minimal declarative command-line parser.
//! * [`rng`] — deterministic SplitMix64/xoshiro256** RNG + sampling helpers.
//! * [`stats`] — running statistics and percentile summaries.
//! * [`table`] — aligned-text / markdown / CSV table rendering.
//! * [`bench`] — a mini-criterion: warmup, timed iterations, mean ± σ.
//! * [`error`] — string-backed dynamic error + context chaining (anyhow-ish).
//! * [`log`] — leveled stderr logging behind `$MEDEA_LOG`.
//! * [`lru`] — a bounded least-recently-used cache.

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod log;
pub mod lru;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;
