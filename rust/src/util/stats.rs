//! Running statistics and summaries for benchmarks and the simulator.

/// Welford running mean/variance plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Fold another accumulator into this one (Chan et al.'s parallel
    /// mean/variance combination) — used to merge per-worker metrics.
    pub fn merge(&mut self, other: &Running) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let total = n1 + n2;
        self.mean += delta * n2 / total;
        self.m2 += other.m2 + delta * delta * n1 * n2 / total;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile over a sample (linear interpolation, like numpy's default).
/// `q` in `[0, 100]`. Sorts a copy; fine for bench-sized samples.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    assert!(!samples.is_empty(), "percentile of empty sample");
    assert!((0.0..=100.0).contains(&q));
    let mut v = samples.to_vec();
    // total_cmp: a NaN sample (poisoned latency) sorts last instead of
    // panicking mid-aggregation on a serving hot path.
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = q / 100.0 * (v.len() - 1) as f64;
    // Clamp both neighbours into bounds: for a 1-element sample every
    // percentile is that element, and floating-point rank can otherwise
    // round `ceil` one past the end at q = 100.
    let lo = (rank.floor() as usize).min(v.len() - 1);
    let hi = (rank.ceil() as usize).min(v.len() - 1);
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Geometric mean (all inputs must be positive).
pub fn geomean(samples: &[f64]) -> f64 {
    assert!(!samples.is_empty());
    let log_sum: f64 = samples
        .iter()
        .map(|x| {
            assert!(*x > 0.0, "geomean needs positive values");
            x.ln()
        })
        .sum();
    (log_sum / samples.len() as f64).exp()
}

/// Relative difference `|a-b| / max(|a|,|b|)`, 0 when both are 0.
pub fn rel_diff(a: f64, b: f64) -> f64 {
    let denom = a.abs().max(b.abs());
    if denom == 0.0 {
        0.0
    } else {
        (a - b).abs() / denom
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct_computation() {
        let data = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut r = Running::new();
        for x in data {
            r.push(x);
        }
        assert_eq!(r.count(), 8);
        assert!((r.mean() - 5.0).abs() < 1e-12);
        // Sample stddev of this classic dataset = sqrt(32/7).
        assert!((r.stddev() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn merge_matches_single_pass() {
        let data = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut whole = Running::new();
        for x in data {
            whole.push(x);
        }
        let mut a = Running::new();
        let mut b = Running::new();
        for x in &data[..3] {
            a.push(*x);
        }
        for x in &data[3..] {
            b.push(*x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.stddev() - whole.stddev()).abs() < 1e-12);
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
        // Merging an empty accumulator is a no-op in both directions.
        let empty = Running::new();
        let before = a.clone();
        a.merge(&empty);
        assert!((a.mean() - before.mean()).abs() < 1e-15);
        let mut fresh = Running::new();
        fresh.merge(&before);
        assert_eq!(fresh.count(), before.count());
    }

    #[test]
    fn percentiles() {
        let data = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&data, 0.0), 1.0);
        assert_eq!(percentile(&data, 100.0), 4.0);
        assert!((percentile(&data, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_of_single_sample_is_that_sample() {
        // Nearest-rank edge: every percentile of a 1-element set is the
        // element — p99 in particular must never index out of bounds.
        for q in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&[7.5], q), 7.5);
        }
        // Two samples: q=99 interpolates inside the range, q=100 is exact.
        let two = [1.0, 3.0];
        assert!(percentile(&two, 99.0) <= 3.0);
        assert!(percentile(&two, 99.0) >= percentile(&two, 50.0));
        assert_eq!(percentile(&two, 100.0), 3.0);
    }

    #[test]
    fn percentile_is_monotone_in_q() {
        let data = [5.0, 1.0, 4.0, 2.0, 8.0, 3.0];
        let mut prev = f64::NEG_INFINITY;
        for q in 0..=100 {
            let p = percentile(&data, q as f64);
            assert!(p >= prev, "percentile must be monotone: p({q}) = {p} < {prev}");
            prev = p;
        }
    }

    #[test]
    fn percentile_tolerates_nan_samples() {
        // total_cmp sinks NaNs to the end: low percentiles stay finite
        // instead of the sort panicking.
        let data = [2.0, f64::NAN, 1.0];
        assert_eq!(percentile(&data, 0.0), 1.0);
        assert_eq!(percentile(&data, 50.0), 2.0);
    }

    #[test]
    fn geomean_simple() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn rel_diff_cases() {
        assert_eq!(rel_diff(0.0, 0.0), 0.0);
        assert!((rel_diff(1.0, 1.1) - 0.1 / 1.1).abs() < 1e-12);
    }
}
