//! The legacy inference service, now a thin compatibility wrapper.
//!
//! Historically this module owned a worker thread that ran a full MCKP DP
//! solve for every distinct deadline and cached the results in an
//! *unbounded* `BTreeMap`. Both problems are gone: [`Coordinator`] now
//! wraps a single-worker [`ServePool`], so every deadline resolves against
//! the precomputed [`crate::serve::ScheduleAtlas`] in `O(log n)` and the
//! per-worker schedule cache is a bounded LRU. The public API is unchanged;
//! new code should use [`ServePool`] directly for multi-worker serving and
//! typed shed rejections.

use crate::eeg::synth::EegWindow;
use crate::serve::atlas::AtlasConfig;
use crate::serve::pool::{PoolConfig, ServePool};
use crate::util::error::{anyhow, Result};
use crate::util::units::Time;
use std::path::Path;

pub use crate::serve::pool::InferenceOutcome;

use super::metrics::Metrics;

/// One inference request: a window and its timing constraint.
pub struct Request {
    pub window: EegWindow,
    pub deadline: Time,
}

/// A running coordinator: a single-worker [`ServePool`].
pub struct Coordinator {
    pool: ServePool,
}

impl Coordinator {
    /// Spawn the worker. `artifact_dir` must contain the AOT artifacts (a
    /// missing or unloadable manifest degrades to schedule-only responses,
    /// as before).
    pub fn start(artifact_dir: &Path) -> Result<Coordinator> {
        let config = PoolConfig {
            workers: 1,
            artifact_dir: artifact_dir.to_path_buf(),
            // The wrapper is the compatibility path: a coarser sweep keeps
            // startup latency close to the old lazy coordinator (which
            // solved nothing up front) while still eliminating per-request
            // solves. Production callers use [`ServePool`] directly with
            // the default sweep, or load a prebuilt atlas.
            atlas: AtlasConfig {
                growth: 1.3,
                refine_rel_energy: 0.03,
                ..AtlasConfig::default()
            },
            ..PoolConfig::default()
        };
        Ok(Coordinator {
            pool: ServePool::start(config)?,
        })
    }

    /// Submit a request; blocks until the worker responds. Shed requests
    /// (deadline below the atlas feasibility floor) surface as errors here
    /// for backward compatibility — [`ServePool::submit`] exposes them as
    /// typed [`crate::serve::Rejection`]s instead.
    pub fn infer(&self, req: Request) -> Result<InferenceOutcome> {
        self.pool
            .infer(req.window, req.deadline)
            .map_err(|e| anyhow!("{e}"))
    }

    /// Stop the worker and collect final metrics.
    pub fn shutdown(self) -> Metrics {
        self.pool.shutdown().aggregate
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eeg::synth::{EegGenerator, SynthConfig};
    use crate::runtime::artifacts::ArtifactManifest;
    use crate::runtime::client::Runtime;

    #[test]
    fn serves_schedule_only_without_artifacts() {
        // No manifest required: the wrapper must degrade to schedule-only
        // responses, with every deadline resolved from the atlas.
        let coord = Coordinator::start(Path::new("/nonexistent-artifacts")).unwrap();
        let mut gen = EegGenerator::new(SynthConfig::default(), 3);
        for i in 0..6 {
            let deadline = Time::from_ms(match i % 3 {
                0 => 120.0,
                1 => 200.0,
                _ => 1000.0,
            });
            let out = coord
                .infer(Request {
                    window: gen.next_window(),
                    deadline,
                })
                .unwrap();
            assert_eq!(out.window_index, i);
            assert!(out.sim.deadline_met, "window {i}");
            assert_eq!(out.scheduler, "medea");
            assert_eq!(out.prediction.logits.len(), 2);
        }
        let metrics = coord.shutdown();
        assert_eq!(metrics.requests, 6);
        assert_eq!(metrics.deadline_misses, 0);
    }

    #[test]
    fn infeasible_deadline_errors_cleanly() {
        let coord = Coordinator::start(Path::new("/nonexistent-artifacts")).unwrap();
        let mut gen = EegGenerator::new(SynthConfig::default(), 4);
        let err = coord
            .infer(Request {
                window: gen.next_window(),
                deadline: Time::from_ms(1.0),
            })
            .unwrap_err();
        assert!(err.to_string().contains("feasibility floor"), "{err}");
        coord.shutdown();
    }

    #[test]
    fn diverse_deadlines_stay_bounded() {
        // The historic failure mode: unbounded per-deadline cache growth.
        // 50 distinct deadlines churn through the bounded LRU; everything
        // must still be served correctly.
        let coord = Coordinator::start(Path::new("/nonexistent-artifacts")).unwrap();
        let mut gen = EegGenerator::new(SynthConfig::default(), 5);
        for i in 0..50 {
            let deadline = Time::from_ms(100.0 + 13.7 * i as f64);
            let out = coord
                .infer(Request {
                    window: gen.next_window(),
                    deadline,
                })
                .unwrap();
            assert!(out.sim.deadline_met, "deadline #{i}");
        }
        let metrics = coord.shutdown();
        assert_eq!(metrics.requests, 50);
        assert_eq!(metrics.deadline_misses, 0);
    }

    #[test]
    fn serves_requests_end_to_end() {
        if !Runtime::available() {
            eprintln!("skipping: PJRT backend not built (stub; build with --cfg medea_pjrt)");
            return;
        }
        let dir = ArtifactManifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let coord = Coordinator::start(&dir).unwrap();
        let mut gen = EegGenerator::new(SynthConfig::default(), 21);
        for i in 0..4 {
            let deadline = Time::from_ms(if i % 2 == 0 { 200.0 } else { 1000.0 });
            let out = coord
                .infer(Request {
                    window: gen.next_window(),
                    deadline,
                })
                .unwrap();
            assert_eq!(out.window_index, i);
            assert!(out.sim.deadline_met, "window {i}");
            assert_eq!(out.prediction.logits.len(), 2);
            assert_eq!(out.scheduler, "medea");
        }
        let metrics = coord.shutdown();
        assert_eq!(metrics.requests, 4);
        assert_eq!(metrics.deadline_misses, 0);
    }
}
