//! The threaded inference service.

use super::metrics::Metrics;
use crate::eeg::synth::EegWindow;
use crate::ir::tsd::{tsd_core, TsdParams};
use crate::ir::Workload;
use crate::manager::medea::{Medea, MedeaFeatures, SolverKind};
use crate::manager::schedule::Schedule;
use crate::platform::Platform;
use crate::profile::{characterize, Profiles};
use crate::runtime::client::Runtime;
use crate::runtime::infer::{Prediction, TsdInference};
use crate::sim::replay::{simulate, SimReport};
use crate::timing::cycle_model::CycleModel;
use crate::util::units::Time;
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Instant;

/// One inference request: a window and its timing constraint.
pub struct Request {
    pub window: EegWindow,
    pub deadline: Time,
}

/// The response: functional prediction + simulated on-device execution.
#[derive(Debug)]
pub struct InferenceOutcome {
    pub window_index: usize,
    pub prediction: Prediction,
    pub sim: SimReport,
    pub scheduler: String,
    pub host_latency: std::time::Duration,
}

enum Message {
    Infer(Request, mpsc::Sender<Result<InferenceOutcome>>),
    Shutdown,
}

/// A running coordinator: one worker thread owning the PJRT runtime and the
/// schedule cache (one MEDEA schedule per distinct deadline).
pub struct Coordinator {
    tx: mpsc::Sender<Message>,
    worker: Option<JoinHandle<Metrics>>,
}

impl Coordinator {
    /// Spawn the worker. `artifact_dir` must contain the AOT artifacts.
    pub fn start(artifact_dir: &Path) -> Result<Coordinator> {
        // Build the design-time state up front (it is Send; the PJRT
        // runtime is created inside the worker thread).
        let platform = crate::platform::heeptimize::heeptimize();
        let model = CycleModel::heeptimize();
        let profiles = characterize(&platform, &model);
        let workload = tsd_core(&TsdParams::default());
        let dir = artifact_dir.to_path_buf();

        let (tx, rx) = mpsc::channel::<Message>();
        let worker = std::thread::Builder::new()
            .name("medea-coordinator".into())
            .spawn(move || worker_loop(rx, &dir, platform, model, profiles, workload))
            .expect("spawn coordinator worker");
        Ok(Coordinator {
            tx,
            worker: Some(worker),
        })
    }

    /// Submit a request; blocks until the worker responds.
    pub fn infer(&self, req: Request) -> Result<InferenceOutcome> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Message::Infer(req, rtx))
            .map_err(|_| anyhow::anyhow!("coordinator is down"))?;
        rrx.recv().map_err(|_| anyhow::anyhow!("worker dropped response"))?
    }

    /// Stop the worker and collect final metrics.
    pub fn shutdown(mut self) -> Metrics {
        let _ = self.tx.send(Message::Shutdown);
        self.worker
            .take()
            .map(|h| h.join().expect("worker panicked"))
            .unwrap_or_default()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Message::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    rx: mpsc::Receiver<Message>,
    artifact_dir: &Path,
    platform: Platform,
    model: CycleModel,
    profiles: Profiles,
    workload: Workload,
) -> Metrics {
    let mut metrics = Metrics::default();
    let mut runtime = match Runtime::new(artifact_dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            log::warn!("PJRT runtime unavailable ({e}); serving schedule-only responses");
            None
        }
    };
    let infer = TsdInference::default();
    // Schedule cache keyed by deadline in microseconds.
    let mut schedules: BTreeMap<u64, Schedule> = BTreeMap::new();

    while let Ok(msg) = rx.recv() {
        match msg {
            Message::Shutdown => break,
            Message::Infer(req, reply) => {
                let t0 = Instant::now();
                let outcome = serve(
                    &req,
                    &platform,
                    &model,
                    &profiles,
                    &workload,
                    &mut schedules,
                    runtime.as_mut(),
                    &infer,
                    t0,
                );
                if let Ok(o) = &outcome {
                    metrics.record(
                        o.prediction.seizure,
                        o.sim.deadline_met,
                        o.sim.total_energy().raw(),
                        o.sim.active_time.raw(),
                        o.host_latency,
                    );
                }
                let _ = reply.send(outcome);
            }
        }
    }
    metrics
}

#[allow(clippy::too_many_arguments)]
fn serve(
    req: &Request,
    platform: &Platform,
    model: &CycleModel,
    profiles: &Profiles,
    workload: &Workload,
    schedules: &mut BTreeMap<u64, Schedule>,
    runtime: Option<&mut Runtime>,
    infer: &TsdInference,
    t0: Instant,
) -> Result<InferenceOutcome> {
    let key = (req.deadline.as_us().round() as u64).max(1);
    if !schedules.contains_key(&key) {
        // Schedule against a small margin (3 %) so the event-level replay
        // (which does not grant the estimator's optimistic LM-residency
        // chaining when the chain breaks) still lands inside the deadline.
        let mut schedule = Medea::new(platform, profiles, model)
            .with_features(MedeaFeatures::default())
            .with_solver(SolverKind::Dp)
            .schedule(workload, req.deadline * 0.97)
            .map_err(|e| anyhow::anyhow!("scheduling failed: {e}"))?;
        schedule.deadline = req.deadline;
        schedules.insert(key, schedule);
    }
    let schedule = &schedules[&key];
    let sim = simulate(workload, platform, model, schedule);

    let prediction = match runtime {
        Some(rt) => infer.infer_staged(rt, &req.window)?,
        None => Prediction {
            logits: vec![0.0, 0.0],
            class_idx: 0,
            seizure: false,
        },
    };

    Ok(InferenceOutcome {
        window_index: req.window.index,
        prediction,
        sim,
        scheduler: schedule.scheduler.clone(),
        host_latency: t0.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eeg::synth::{EegGenerator, SynthConfig};
    use crate::runtime::artifacts::ArtifactManifest;

    #[test]
    fn serves_requests_end_to_end() {
        let dir = ArtifactManifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let coord = Coordinator::start(&dir).unwrap();
        let mut gen = EegGenerator::new(SynthConfig::default(), 21);
        for i in 0..4 {
            let deadline = Time::from_ms(if i % 2 == 0 { 200.0 } else { 1000.0 });
            let out = coord
                .infer(Request {
                    window: gen.next_window(),
                    deadline,
                })
                .unwrap();
            assert_eq!(out.window_index, i);
            assert!(out.sim.deadline_met, "window {i}");
            assert_eq!(out.prediction.logits.len(), 2);
            assert_eq!(out.scheduler, "medea");
        }
        let metrics = coord.shutdown();
        assert_eq!(metrics.requests, 4);
        assert_eq!(metrics.deadline_misses, 0);
    }

    #[test]
    fn schedule_cache_survives_many_requests() {
        let dir = ArtifactManifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let coord = Coordinator::start(&dir).unwrap();
        let mut gen = EegGenerator::new(SynthConfig::default(), 5);
        let mut first_latency = None;
        let mut later = Vec::new();
        for i in 0..6 {
            let out = coord
                .infer(Request {
                    window: gen.next_window(),
                    deadline: Time::from_ms(200.0),
                })
                .unwrap();
            if i == 0 {
                first_latency = Some(out.host_latency);
            } else {
                later.push(out.host_latency);
            }
        }
        // After the first request the schedule + executable are cached, so
        // later requests must be significantly faster.
        let first = first_latency.unwrap();
        let avg_later: f64 =
            later.iter().map(|d| d.as_secs_f64()).sum::<f64>() / later.len() as f64;
        assert!(
            avg_later < first.as_secs_f64(),
            "no caching effect: first {first:?}, later avg {avg_later}"
        );
        coord.shutdown();
    }
}
