//! Service metrics: counters + host-side latency distribution.
//!
//! The latency distribution is a [`crate::telemetry::HistData`] — the same
//! fixed-bucket log-linear histogram the live telemetry registry records
//! into — so percentiles computed here (shutdown aggregate) and percentiles
//! computed from a live scrape are identical by construction: same buckets,
//! same arithmetic, and histogram merge is exact (unlike the sample
//! reservoir this replaced, which made merged percentiles depend on worker
//! order and sampling luck).

use crate::telemetry::hist::HistData;
use std::time::Duration;

/// Aggregated service metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub seizures_detected: u64,
    pub deadline_misses: u64,
    /// Simulated on-device energy across all served windows (J).
    pub sim_energy_j: f64,
    /// Simulated on-device active time across all served windows (s).
    pub sim_active_s: f64,
    /// Dispatch-batch size histogram: `batch_hist[i]` counts dispatches of
    /// `i + 1` coalesced requests (solo dispatches land in `batch_hist[0]`).
    pub batch_hist: Vec<u64>,
    /// Steal events: dispatches whose group was lifted from a sibling
    /// shard's queue by this (otherwise idle) worker.
    pub steals: u64,
    /// Requests served through stolen dispatches (each steal event
    /// contributes its group size).
    pub stolen_requests: u64,
    /// Host-latency distribution (ns). `pub(crate)` so the telemetry
    /// registry can rebuild a `Metrics` from a worker-shard snapshot.
    pub(crate) host: HistData,
}

impl Metrics {
    pub fn record(&mut self, seizure: bool, deadline_met: bool, energy_j: f64, active_s: f64, host: Duration) {
        self.requests += 1;
        if seizure {
            self.seizures_detected += 1;
        }
        if !deadline_met {
            self.deadline_misses += 1;
        }
        self.sim_energy_j += energy_j;
        self.sim_active_s += active_s;
        self.host.record(u64::try_from(host.as_nanos()).unwrap_or(u64::MAX));
    }

    /// Record one dispatch of `size` coalesced requests (1 = solo).
    pub fn record_batch(&mut self, size: usize) {
        let size = size.max(1);
        if self.batch_hist.len() < size {
            self.batch_hist.resize(size, 0);
        }
        self.batch_hist[size - 1] += 1;
    }

    /// Record one steal event of `size` coalesced requests (1 = solo).
    pub fn record_steal(&mut self, size: usize) {
        self.steals += 1;
        self.stolen_requests += size.max(1) as u64;
    }

    /// Requests served through a multi-request dispatch (batch size ≥ 2).
    pub fn batched_requests(&self) -> u64 {
        self.batch_hist
            .iter()
            .enumerate()
            .skip(1)
            .map(|(i, &n)| (i as u64 + 1) * n)
            .sum()
    }

    /// Requests served through a solo dispatch.
    pub fn solo_requests(&self) -> u64 {
        self.batch_hist.first().copied().unwrap_or(0)
    }

    /// Fold another worker's metrics into this one (used by the serve
    /// pool's cross-worker aggregation). Every field — counters and the
    /// latency histogram — merges exactly, so aggregation order never
    /// changes a percentile.
    pub fn merge(&mut self, other: &Metrics) {
        self.requests += other.requests;
        self.seizures_detected += other.seizures_detected;
        self.deadline_misses += other.deadline_misses;
        self.sim_energy_j += other.sim_energy_j;
        self.sim_active_s += other.sim_active_s;
        if self.batch_hist.len() < other.batch_hist.len() {
            self.batch_hist.resize(other.batch_hist.len(), 0);
        }
        for (slot, &n) in self.batch_hist.iter_mut().zip(&other.batch_hist) {
            *slot += n;
        }
        self.steals += other.steals;
        self.stolen_requests += other.stolen_requests;
        self.host.merge(&other.host);
    }

    pub fn host_latency_mean(&self) -> Duration {
        Duration::from_nanos(self.host.mean().round() as u64)
    }

    /// Host-latency percentile (`q` in `[0, 100]`); zero when empty. Bucket
    /// resolution is ≤ ~6% relative; p0/p100 and single-sample
    /// distributions are exact (see [`HistData::percentile`]).
    pub fn host_latency_percentile(&self, q: f64) -> Duration {
        Duration::from_nanos(self.host.percentile(q))
    }

    pub fn host_latency_p50(&self) -> Duration {
        self.host_latency_percentile(50.0)
    }

    pub fn host_latency_p95(&self) -> Duration {
        self.host_latency_percentile(95.0)
    }

    pub fn host_latency_p99(&self) -> Duration {
        self.host_latency_percentile(99.0)
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} seizures={} misses={} sim_energy={:.1} uJ sim_active={:.1} ms host_mean={:?} host_p95={:?}",
            self.requests,
            self.seizures_detected,
            self.deadline_misses,
            self.sim_energy_j * 1e6,
            self.sim_active_s * 1e3,
            self.host_latency_mean(),
            self.host_latency_p95(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = Metrics::default();
        m.record(true, true, 500e-6, 0.05, Duration::from_millis(2));
        m.record(false, false, 400e-6, 0.20, Duration::from_millis(4));
        assert_eq!(m.requests, 2);
        assert_eq!(m.seizures_detected, 1);
        assert_eq!(m.deadline_misses, 1);
        assert!((m.sim_energy_j - 900e-6).abs() < 1e-12);
        assert!(m.host_latency_mean() >= Duration::from_millis(2));
        let s = m.summary();
        assert!(s.contains("requests=2"));
    }

    #[test]
    fn merge_aggregates_workers() {
        let mut a = Metrics::default();
        a.record(true, true, 500e-6, 0.05, Duration::from_millis(2));
        let mut b = Metrics::default();
        b.record(false, false, 400e-6, 0.20, Duration::from_millis(4));
        b.record(false, true, 100e-6, 0.10, Duration::from_millis(6));
        a.merge(&b);
        assert_eq!(a.requests, 3);
        assert_eq!(a.seizures_detected, 1);
        assert_eq!(a.deadline_misses, 1);
        assert!((a.sim_energy_j - 1000e-6).abs() < 1e-12);
        // Percentiles span both workers' samples.
        assert_eq!(a.host_latency_percentile(0.0), Duration::from_millis(2));
        assert_eq!(a.host_latency_percentile(100.0), Duration::from_millis(6));
        assert!(a.host_latency_p50() >= Duration::from_millis(2));
        assert!(a.host_latency_p99() <= Duration::from_millis(6));
        // Merging into an empty accumulator works too.
        let mut fresh = Metrics::default();
        fresh.merge(&a);
        assert_eq!(fresh.requests, 3);
    }

    #[test]
    fn batch_histogram_counts_and_merges() {
        let mut a = Metrics::default();
        a.record_batch(1);
        a.record_batch(4);
        a.record_batch(4);
        assert_eq!(a.batch_hist, vec![1, 0, 0, 2]);
        assert_eq!(a.solo_requests(), 1);
        assert_eq!(a.batched_requests(), 8);
        let mut b = Metrics::default();
        b.record_batch(2);
        b.record_batch(6);
        b.record_steal(6);
        a.merge(&b);
        assert_eq!(a.batch_hist, vec![1, 1, 0, 2, 0, 1]);
        assert_eq!(a.steals, 1);
        assert_eq!(a.stolen_requests, 6);
        assert_eq!(a.batched_requests(), 8 + 2 + 6);
        // Merging the longer histogram into the shorter also works.
        let mut c = Metrics::default();
        c.record_batch(1);
        c.merge(&a);
        assert_eq!(c.batch_hist, vec![2, 1, 0, 2, 0, 1]);
        assert_eq!(c.solo_requests(), 2);
    }

    #[test]
    fn latency_histogram_stays_bounded_and_in_range() {
        let mut m = Metrics::default();
        for i in 0..12_288u64 {
            m.record(false, true, 0.0, 0.0, Duration::from_micros(100 + (i % 50)));
        }
        assert_eq!(m.requests, 12_288);
        // Percentiles land inside the observed sample range (the histogram
        // is fixed-size: no per-request memory growth to check).
        let p99 = m.host_latency_p99();
        assert!(p99 >= Duration::from_micros(99) && p99 <= Duration::from_micros(150), "{p99:?}");
        let p50 = m.host_latency_p50();
        assert!(p50 >= Duration::from_micros(100) && p50 <= Duration::from_micros(150));
        // Mean stays exact (streaming sum, not sampled).
        assert!(m.host_latency_mean() >= Duration::from_micros(100));
    }

    #[test]
    fn merge_order_never_changes_percentiles() {
        // The reservoir this replaced was order- and luck-sensitive; the
        // histogram must not be.
        let mut ab = Metrics::default();
        let mut ba = Metrics::default();
        let (mut a, mut b) = (Metrics::default(), Metrics::default());
        for i in 0..5_000u64 {
            let d = Duration::from_micros(50 + i % 400);
            if i % 3 == 0 {
                a.record(false, true, 0.0, 0.0, d);
            } else {
                b.record(false, true, 0.0, 0.0, d);
            }
        }
        ab.merge(&a);
        ab.merge(&b);
        ba.merge(&b);
        ba.merge(&a);
        for q in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(ab.host_latency_percentile(q), ba.host_latency_percentile(q), "q={q}");
        }
    }
}
