//! Service metrics: counters + host-side latency distribution.

use crate::util::stats::{percentile, Running};
use std::time::Duration;

/// Aggregated service metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub seizures_detected: u64,
    pub deadline_misses: u64,
    /// Simulated on-device energy across all served windows (J).
    pub sim_energy_j: f64,
    /// Simulated on-device active time across all served windows (s).
    pub sim_active_s: f64,
    host_latency: Running,
    latencies: Vec<f64>,
}

impl Metrics {
    pub fn record(&mut self, seizure: bool, deadline_met: bool, energy_j: f64, active_s: f64, host: Duration) {
        self.requests += 1;
        if seizure {
            self.seizures_detected += 1;
        }
        if !deadline_met {
            self.deadline_misses += 1;
        }
        self.sim_energy_j += energy_j;
        self.sim_active_s += active_s;
        self.host_latency.push(host.as_secs_f64());
        self.latencies.push(host.as_secs_f64());
    }

    pub fn host_latency_mean(&self) -> Duration {
        Duration::from_secs_f64(self.host_latency.mean().max(0.0))
    }

    pub fn host_latency_p95(&self) -> Duration {
        if self.latencies.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_secs_f64(percentile(&self.latencies, 95.0))
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} seizures={} misses={} sim_energy={:.1} uJ sim_active={:.1} ms host_mean={:?} host_p95={:?}",
            self.requests,
            self.seizures_detected,
            self.deadline_misses,
            self.sim_energy_j * 1e6,
            self.sim_active_s * 1e3,
            self.host_latency_mean(),
            self.host_latency_p95(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = Metrics::default();
        m.record(true, true, 500e-6, 0.05, Duration::from_millis(2));
        m.record(false, false, 400e-6, 0.20, Duration::from_millis(4));
        assert_eq!(m.requests, 2);
        assert_eq!(m.seizures_detected, 1);
        assert_eq!(m.deadline_misses, 1);
        assert!((m.sim_energy_j - 900e-6).abs() < 1e-12);
        assert!(m.host_latency_mean() >= Duration::from_millis(2));
        let s = m.summary();
        assert!(s.contains("requests=2"));
    }
}
