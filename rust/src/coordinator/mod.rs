//! The legacy inference coordinator.
//!
//! Originally a self-contained request loop (one worker thread, per-deadline
//! DP solves, unbounded schedule cache); now a thin compatibility wrapper
//! over the [`crate::serve`] subsystem: a single-worker
//! [`crate::serve::ServePool`] resolving every deadline against the
//! precomputed schedule atlas, with a bounded LRU on the request path.
//! [`Metrics`] remains the per-worker metrics type the pool aggregates.

pub mod metrics;
pub mod service;

pub use metrics::Metrics;
pub use service::{Coordinator, InferenceOutcome, Request};
