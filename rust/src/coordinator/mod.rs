//! The inference coordinator: a threaded request loop gluing the MEDEA
//! schedule, the platform simulator (time/energy accounting) and the PJRT
//! runtime (functional prediction).
//!
//! Rust owns the event loop and process lifetime; Python existed only at
//! `make artifacts` time. One worker thread owns the PJRT runtime; clients
//! submit EEG windows over a channel and receive predictions plus the
//! simulated on-device cost of the schedule that would have produced them.

pub mod metrics;
pub mod service;

pub use metrics::Metrics;
pub use service::{Coordinator, InferenceOutcome, Request};
