//! Power characterization stand-in (§3.1.3 `S_P` and §4.1.2 ASIC flow).
//!
//! The paper derives per-kernel power from post-synthesis simulation with
//! per-voltage standard-cell libraries (PrimePower). Here, the platform's
//! physical power description ([`crate::platform::pe::PePower`]) plays that
//! role: characterized power for a kernel type on a PE at a voltage level is
//!
//! `P(p_j, τ_i, v_l) = P_base(v_l, f_l) + P_pe(p_j, τ_i, v_l, f_l)`
//!
//! i.e. whole-SoC power while that kernel runs (bus/L2/DMA base + the active
//! PE), which is what a board-level measurement sees. As in the paper, power
//! is assumed independent of the kernel's operational size `s_i`.

pub mod model;

pub use model::{decompose, kernel_power, PowerBreakdown};
