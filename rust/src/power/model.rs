//! The `S_P` power model.

use crate::ir::KernelType;
use crate::platform::{PeId, Platform, VfPoint};
use crate::util::units::{Freq, Power};

/// Characterized whole-SoC active power while `ty` runs on `pe` at `vf`.
pub fn kernel_power(platform: &Platform, pe: PeId, ty: KernelType, vf: VfPoint) -> Power {
    let base = platform.active_base.p_total(ty, vf.v, vf.f);
    let pe_power = platform.pe(pe).power.p_total(ty, vf.v, vf.f);
    base + pe_power
}

/// Static/dynamic decomposition of a characterized power entry, mirroring
/// the paper's two-frequency measurement technique (§3.1.3): static power is
/// the `f → 0` limit at fixed voltage, dynamic is reported at `f_base`.
#[derive(Debug, Clone, Copy)]
pub struct PowerBreakdown {
    pub p_stat: Power,
    pub p_dyn_base: Power,
    pub f_base: Freq,
}

/// Decompose `S_P(pe, ty, v)` into static + dynamic-at-`f_base`.
pub fn decompose(
    platform: &Platform,
    pe: PeId,
    ty: KernelType,
    vf: VfPoint,
    f_base: Freq,
) -> PowerBreakdown {
    let p = platform.pe(pe);
    let p_stat = platform.active_base.p_stat(vf.v) + p.power.p_stat(vf.v);
    let p_dyn_base =
        platform.active_base.p_dyn(ty, vf.v, f_base) + p.power.p_dyn(ty, vf.v, f_base);
    PowerBreakdown {
        p_stat,
        p_dyn_base,
        f_base,
    }
}

impl PowerBreakdown {
    /// Reconstruct total power at operating frequency `f` (dynamic power is
    /// proportional to frequency at fixed voltage).
    pub fn at(&self, f: Freq) -> Power {
        self.p_stat + self.p_dyn_base * (f.raw() / self.f_base.raw())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::heeptimize::{heeptimize, CARUS, CGRA, CPU};

    #[test]
    fn decomposition_reconstructs_total() {
        let p = heeptimize();
        for pe in [CPU, CGRA, CARUS] {
            for &vf in p.vf.points() {
                let total = kernel_power(&p, pe, KernelType::MatMul, vf);
                let bd = decompose(&p, pe, KernelType::MatMul, vf, Freq::from_mhz(100.0));
                let rebuilt = bd.at(vf.f);
                assert!(
                    (total.raw() - rebuilt.raw()).abs() / total.raw() < 1e-12,
                    "pe={pe} vf={}",
                    vf.label()
                );
            }
        }
    }

    #[test]
    fn power_monotone_in_vf() {
        let p = heeptimize();
        for pe in [CPU, CGRA, CARUS] {
            let mut last = Power::ZERO;
            for &vf in p.vf.points() {
                let pw = kernel_power(&p, pe, KernelType::MatMul, vf);
                assert!(pw > last, "power must rise with V-F");
                last = pw;
            }
        }
    }

    #[test]
    fn active_power_scale_is_ulp() {
        // Whole-SoC active power at the extremes must stay in the paper's
        // envelope: ~1–2 mW at 0.5 V, ~15–25 mW at 0.9 V (Table 5 implies
        // ≈1.65 mW avg at 0.5 V and ≈19 mW at the 50 ms/0.9 V corner).
        let p = heeptimize();
        let lo = kernel_power(&p, CGRA, KernelType::MatMul, p.vf.min());
        let hi = kernel_power(&p, CARUS, KernelType::MatMul, p.vf.max());
        assert!(
            (0.8..2.5).contains(&lo.as_mw()),
            "low-corner power {lo} out of ULP envelope"
        );
        assert!(
            (10.0..40.0).contains(&hi.as_mw()),
            "high-corner power {hi} out of ULP envelope"
        );
    }

    #[test]
    fn sleep_far_below_active() {
        let p = heeptimize();
        let min_active = kernel_power(&p, CPU, KernelType::Add, p.vf.min());
        assert!(p.sleep_power.raw() < min_active.raw() / 5.0);
    }
}
