//! PJRT runtime: load and execute the AOT-compiled HLO artifacts.
//!
//! Python runs only at `make artifacts` time; this module is the entire
//! request-path compute stack: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Executables are compiled once and cached per artifact.

pub mod artifacts;
pub mod client;
pub mod infer;
#[cfg(not(medea_pjrt_sys))]
pub(crate) mod xla_stub;

pub use artifacts::{ArtifactManifest, ArtifactMeta};
pub use client::Runtime;
pub use infer::TsdInference;
