//! TSD inference over the PJRT artifacts.
//!
//! Two functional paths, cross-checked in tests:
//! * **full**: the `tsd_full` executable (in-graph FFT frontend).
//! * **staged**: the Rust FFT frontend ([`crate::eeg::frontend`]) feeding
//!   the `tsd_core` executable — the path the coordinator uses, since the
//!   platform schedule also treats the frontend as a separate (CPU) kernel.

use super::client::Runtime;
use crate::eeg::frontend::window_features;
use crate::eeg::synth::EegWindow;
use crate::util::error::Result;

/// Class labels of the TSD head.
pub const CLASSES: [&str; 2] = ["background", "seizure"];

/// Inference outcome.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub logits: Vec<f32>,
    pub class_idx: usize,
    pub seizure: bool,
}

fn to_prediction(logits: Vec<f32>) -> Prediction {
    // A NaN logit (runtime numerical blow-up) must not panic the pool
    // worker, and must not win the argmax either: non-finite logits are
    // skipped, so a finite class wins whenever one exists (all-NaN falls
    // back to class 0).
    let class_idx = logits
        .iter()
        .enumerate()
        .filter(|(_, v)| v.is_finite())
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0);
    Prediction {
        seizure: class_idx == 1,
        class_idx,
        logits,
    }
}

/// TSD inference façade over a [`Runtime`].
pub struct TsdInference {
    pub n_fft: usize,
    pub patch_dim: usize,
}

impl Default for TsdInference {
    fn default() -> Self {
        TsdInference {
            n_fft: 256,
            patch_dim: 80,
        }
    }
}

impl TsdInference {
    /// Full-model path: raw window → logits.
    pub fn infer_full(&self, rt: &mut Runtime, window: &EegWindow) -> Result<Prediction> {
        let flat = window.flat();
        let out = rt.run_f32("tsd_full", &[&flat])?;
        Ok(to_prediction(out.into_iter().next().unwrap()))
    }

    /// Staged path: Rust frontend → `tsd_core` executable.
    pub fn infer_staged(&self, rt: &mut Runtime, window: &EegWindow) -> Result<Prediction> {
        let feats = window_features(&window.data, self.n_fft, self.patch_dim);
        let flat: Vec<f32> = feats.into_iter().flatten().collect();
        let out = rt.run_f32("tsd_core", &[&flat])?;
        Ok(to_prediction(out.into_iter().next().unwrap()))
    }

    /// Batched staged path: run every window's Rust frontend, then execute
    /// `tsd_core` over the whole batch via [`Runtime::run_f32_batch`] (a
    /// cold compile is charged to the batch, not its first member).
    /// Returns one prediction per window, in order.
    pub fn infer_staged_batch(
        &self,
        rt: &mut Runtime,
        windows: &[&EegWindow],
    ) -> Result<Vec<Prediction>> {
        let flats: Vec<Vec<f32>> = windows
            .iter()
            .map(|w| {
                window_features(&w.data, self.n_fft, self.patch_dim)
                    .into_iter()
                    .flatten()
                    .collect()
            })
            .collect();
        let members: Vec<Vec<&[f32]>> = flats.iter().map(|f| vec![f.as_slice()]).collect();
        let outs = rt.run_f32_batch("tsd_core", &members)?;
        Ok(outs
            .into_iter()
            .map(|o| {
                to_prediction(o.into_iter().next().unwrap_or_default())
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eeg::synth::{EegGenerator, SynthConfig};
    use crate::runtime::artifacts::ArtifactManifest;

    fn runtime() -> Option<Runtime> {
        if !Runtime::available() {
            eprintln!("skipping: PJRT backend not built (stub; build with --cfg medea_pjrt)");
            return None;
        }
        let dir = ArtifactManifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::new(&dir).unwrap())
    }

    #[test]
    fn full_and_staged_paths_agree() {
        let Some(mut rt) = runtime() else { return };
        let infer = TsdInference::default();
        let mut gen = EegGenerator::new(SynthConfig::default(), 42);
        for _ in 0..3 {
            let w = gen.next_window();
            let full = infer.infer_full(&mut rt, &w).unwrap();
            let staged = infer.infer_staged(&mut rt, &w).unwrap();
            assert_eq!(full.logits.len(), 2);
            for (a, b) in full.logits.iter().zip(&staged.logits) {
                assert!(
                    (a - b).abs() < 2e-3,
                    "frontend paths diverge: {:?} vs {:?}",
                    full.logits,
                    staged.logits
                );
            }
        }
    }

    #[test]
    fn predictions_are_deterministic() {
        let Some(mut rt) = runtime() else { return };
        let infer = TsdInference::default();
        let mut gen = EegGenerator::new(SynthConfig::default(), 1);
        let w = gen.next_window();
        let a = infer.infer_full(&mut rt, &w).unwrap();
        let b = infer.infer_full(&mut rt, &w).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.class_idx, b.class_idx);
    }

    #[test]
    fn logits_are_finite() {
        let Some(mut rt) = runtime() else { return };
        let infer = TsdInference::default();
        let mut gen = EegGenerator::new(SynthConfig::default(), 9);
        for label in [false, true] {
            let w = gen.window_with_label(label);
            let p = infer.infer_full(&mut rt, &w).unwrap();
            assert!(p.logits.iter().all(|v| v.is_finite()), "{:?}", p.logits);
        }
    }
}
