//! Compile-time stand-in for the `xla` PJRT bindings.
//!
//! The build image does not always carry the vendored `xla` crate, so the
//! default build compiles against this API-compatible stub: every entry point
//! that would touch PJRT fails with a clear message, and [`PjRtClient::cpu`]
//! failing up front means callers ([`super::client::Runtime`]) degrade to
//! schedule-only serving before any stubbed method could be reached. Build
//! with `RUSTFLAGS="--cfg medea_pjrt"` (and add the real `xla` dependency)
//! to swap this out for actual execution.

use crate::util::error::{Error, Result};

const UNAVAILABLE: &str =
    "PJRT backend not built: rebuild with --cfg medea_pjrt and provide the `xla` crate";

fn unavailable<T>() -> Result<T> {
    Err(Error::msg(UNAVAILABLE))
}

/// Stub for `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Stub for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Stub for `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Stub for `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Stub for `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// Stub for `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("medea_pjrt"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
