//! The artifact manifest written by `python/compile/aot.py`.

use crate::util::json::{parse, Json};
use std::path::{Path, PathBuf};

/// Tensor signature (shape + dtype; only f32 artifacts are emitted today).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub seed: u64,
    pub artifacts: Vec<ArtifactMeta>,
}

impl ArtifactManifest {
    pub fn load(dir: &Path) -> Result<ArtifactManifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {path:?}: {e} (run `make artifacts`)"))?;
        let v = parse(&text).map_err(|e| e.to_string())?;
        Self::from_json(dir, &v)
    }

    fn from_json(dir: &Path, v: &Json) -> Result<ArtifactManifest, String> {
        let seed = v.req("seed")?.as_u64().ok_or("seed")?;
        let mut artifacts = Vec::new();
        for av in v.req("artifacts")?.as_arr().ok_or("artifacts")? {
            let sig = |key: &str| -> Result<Vec<TensorSig>, String> {
                av.req(key)?
                    .as_arr()
                    .ok_or(key)?
                    .iter()
                    .map(|t| {
                        let shape = t
                            .req("shape")?
                            .as_arr()
                            .ok_or("shape")?
                            .iter()
                            .map(|d| d.as_usize().ok_or("dim".to_string()))
                            .collect::<Result<Vec<_>, _>>()?;
                        Ok(TensorSig { shape })
                    })
                    .collect()
            };
            artifacts.push(ArtifactMeta {
                name: av.req("name")?.as_str().ok_or("name")?.to_string(),
                file: dir.join(av.req("file")?.as_str().ok_or("file")?),
                inputs: sig("inputs")?,
                outputs: sig("outputs")?,
            });
        }
        Ok(ArtifactManifest {
            dir: dir.to_path_buf(),
            seed,
            artifacts,
        })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Default artifacts directory: `$MEDEA_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("MEDEA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_available() -> bool {
        ArtifactManifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_manifest_when_built() {
        if !manifest_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = ArtifactManifest::load(&ArtifactManifest::default_dir()).unwrap();
        assert!(m.get("tsd_full").is_some());
        assert!(m.get("tsd_core").is_some());
        assert!(m.get("k_softmax").is_some());
        let full = m.get("tsd_full").unwrap();
        assert_eq!(full.inputs.len(), 1);
        assert_eq!(full.inputs[0].shape, vec![16, 1536]);
        assert_eq!(full.outputs[0].shape, vec![2]);
        assert!(full.file.exists());
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let err = ArtifactManifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.contains("make artifacts"));
    }
}
