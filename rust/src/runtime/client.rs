//! The PJRT client wrapper: compile-once executable cache + typed execute.

use super::artifacts::{ArtifactManifest, ArtifactMeta};
use crate::util::error::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

// Two cfg gates keep every build combination compilable offline:
// `--cfg medea_pjrt` opts into the functional path (type-checked against the
// in-crate stub, whose client constructor fails cleanly at runtime), while
// `--cfg medea_pjrt_sys` additionally resolves `xla::` to the real vendored
// bindings — which the build must then provide as an external crate.
#[cfg(not(medea_pjrt_sys))]
use super::xla_stub as xla;

/// A loaded PJRT runtime with an executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    cache: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the artifact manifest.
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let manifest =
            ArtifactManifest::load(artifact_dir).map_err(|e| anyhow!("manifest: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            cache: BTreeMap::new(),
        })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the named artifact.
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let meta = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?;
            let proto = xla::HloModuleProto::from_text_file(
                meta.file
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 path {:?}", meta.file))?,
            )
            .with_context(|| format!("parse HLO text {:?}", meta.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile artifact `{name}`"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute the named artifact on f32 inputs; shapes are validated
    /// against the manifest. Returns the flattened f32 outputs.
    pub fn run_f32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?
            .clone();
        validate_inputs(&meta, inputs)?;

        let mut literals = Vec::with_capacity(inputs.len());
        for (sig, data) in meta.inputs.iter().zip(inputs) {
            let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
            literals.push(
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .context("reshape input literal")?,
            );
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("execute `{name}`"))?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        // aot.py lowers with return_tuple=True: the result is a tuple.
        let elems = result.to_tuple().context("untuple result")?;
        if elems.len() != meta.outputs.len() {
            bail!(
                "artifact `{name}` returned {} outputs, manifest says {}",
                elems.len(),
                meta.outputs.len()
            );
        }
        let mut outs = Vec::with_capacity(elems.len());
        for (lit, sig) in elems.iter().zip(&meta.outputs) {
            let v = lit.to_vec::<f32>().context("output to_vec")?;
            if v.len() != sig.elements() {
                bail!(
                    "artifact `{name}` output has {} elements, expected {}",
                    v.len(),
                    sig.elements()
                );
            }
            outs.push(v);
        }
        Ok(outs)
    }

    /// Execute the named artifact over a *batch* of members, warming the
    /// compile cache once up front so a cold compilation is charged to the
    /// batch, not to its first member. `members[m][i]` is member `m`'s data
    /// for input `i`, with the same per-member shapes (and per-member
    /// validation) as [`Runtime::run_f32`]. Returns one output set per
    /// member.
    ///
    /// Today's AOT artifacts are exported per-window (no batch axis), so
    /// execution itself is still one `execute` per member; a true
    /// single-dispatch batch is [`Runtime::run_f32_stacked`], which needs a
    /// batch-shaped artifact (see the ROADMAP item on batch-shaped export).
    pub fn run_f32_batch(
        &mut self,
        name: &str,
        members: &[Vec<&[f32]>],
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        if members.is_empty() {
            return Ok(Vec::new());
        }
        self.executable(name)?;
        members.iter().map(|m| self.run_f32(name, m)).collect()
    }

    /// Execute a **batch-shaped** artifact once over `members` stacked along
    /// the leading axis — the true single-dispatch batch. The manifest's
    /// leading dimension must equal the batch size on every input and
    /// output (the executable was compiled for `[n, …]`, so anything else
    /// would be rejected by the backend), and each member supplies its
    /// per-member slice (`elements() / n` values per tensor). Today's AOT
    /// pipeline does not yet emit batch-shaped artifacts; see the ROADMAP.
    pub fn run_f32_stacked(
        &mut self,
        name: &str,
        members: &[Vec<&[f32]>],
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        let n = members.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?
            .clone();
        let shaped = meta
            .inputs
            .iter()
            .chain(meta.outputs.iter())
            .all(|sig| sig.shape.first() == Some(&n));
        if !shaped {
            bail!(
                "artifact `{name}` is not batch-shaped for n={n}: every input/output \
                 leading dimension must equal the batch size (got inputs {:?})",
                meta.inputs.iter().map(|s| s.shape.clone()).collect::<Vec<_>>()
            );
        }
        for (mi, m) in members.iter().enumerate() {
            if m.len() != meta.inputs.len() {
                bail!(
                    "artifact `{name}` takes {} inputs, member {mi} supplied {}",
                    meta.inputs.len(),
                    m.len()
                );
            }
            for (i, sig) in meta.inputs.iter().enumerate() {
                let per_member = sig.elements() / n;
                if m[i].len() != per_member {
                    bail!(
                        "artifact `{name}` input {i} needs {per_member} elements per \
                         member ({:?} / n={n}), member {mi} supplied {}",
                        sig.shape,
                        m[i].len()
                    );
                }
            }
        }

        let mut literals = Vec::with_capacity(meta.inputs.len());
        for (i, sig) in meta.inputs.iter().enumerate() {
            // Stack member `i`-th slices contiguously into the compiled
            // [n, ...] parameter shape.
            let mut stacked = Vec::with_capacity(sig.elements());
            for m in members {
                stacked.extend_from_slice(m[i]);
            }
            let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
            literals.push(
                xla::Literal::vec1(&stacked)
                    .reshape(&dims)
                    .context("reshape stacked input literal")?,
            );
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("stacked execute `{name}` (n={n})"))?[0][0]
            .to_literal_sync()
            .context("fetch stacked result")?;
        let elems = result.to_tuple().context("untuple stacked result")?;
        if elems.len() != meta.outputs.len() {
            bail!(
                "artifact `{name}` returned {} outputs, manifest says {}",
                elems.len(),
                meta.outputs.len()
            );
        }
        // Split each stacked output back into per-member chunks.
        let mut per_member: Vec<Vec<Vec<f32>>> = vec![Vec::with_capacity(elems.len()); n];
        for (lit, sig) in elems.iter().zip(&meta.outputs) {
            let v = lit.to_vec::<f32>().context("stacked output to_vec")?;
            if v.len() != sig.elements() {
                bail!(
                    "artifact `{name}` stacked output has {} elements, expected {}",
                    v.len(),
                    sig.elements()
                );
            }
            for (m, chunk) in v.chunks_exact(sig.elements() / n).enumerate() {
                per_member[m].push(chunk.to_vec());
            }
        }
        Ok(per_member)
    }

    /// Number of compiled executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.len()
    }

    /// Whether this build can actually execute PJRT artifacts: it needs
    /// both `--cfg medea_pjrt` (the functional path) and
    /// `--cfg medea_pjrt_sys` (the real vendored `xla` bindings replacing
    /// the in-tree stub). With either cfg missing, [`Runtime::new`] always
    /// errors and serving degrades to schedule-only responses — and
    /// artifact-gated tests skip instead of panicking on the stub.
    pub fn available() -> bool {
        cfg!(all(medea_pjrt, medea_pjrt_sys))
    }
}

fn validate_inputs(meta: &ArtifactMeta, inputs: &[&[f32]]) -> Result<()> {
    if inputs.len() != meta.inputs.len() {
        bail!(
            "artifact `{}` takes {} inputs, got {}",
            meta.name,
            meta.inputs.len(),
            inputs.len()
        );
    }
    for (i, (sig, data)) in meta.inputs.iter().zip(inputs).enumerate() {
        if data.len() != sig.elements() {
            bail!(
                "artifact `{}` input {i} needs {} elements ({:?}), got {}",
                meta.name,
                sig.elements(),
                sig.shape,
                data.len()
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::ArtifactManifest;

    fn runtime() -> Option<Runtime> {
        if !Runtime::available() {
            eprintln!("skipping: PJRT backend not built (stub; build with --cfg medea_pjrt)");
            return None;
        }
        let dir = ArtifactManifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::new(&dir).unwrap())
    }

    #[test]
    fn kernel_artifact_computes_correct_matmul() {
        let Some(mut rt) = runtime() else { return };
        // k_mm_class: (1,128) @ (128,2).
        let a: Vec<f32> = (0..128).map(|i| (i % 7) as f32 * 0.1).collect();
        let b: Vec<f32> = (0..256).map(|i| ((i % 5) as f32 - 2.0) * 0.05).collect();
        let out = rt.run_f32("k_mm_class", &[&a, &b]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), 2);
        // CPU reference.
        let mut want = [0f32; 2];
        for j in 0..2 {
            for k in 0..128 {
                want[j] += a[k] * b[k * 2 + j];
            }
        }
        for j in 0..2 {
            assert!(
                (out[0][j] - want[j]).abs() < 1e-4,
                "out {} vs want {}",
                out[0][j],
                want[j]
            );
        }
    }

    #[test]
    fn softmax_artifact_outputs_distribution() {
        let Some(mut rt) = runtime() else { return };
        let x: Vec<f32> = (0..97 * 97).map(|i| ((i % 13) as f32 - 6.0) * 0.3).collect();
        let out = rt.run_f32("k_softmax", &[&x]).unwrap();
        let rows = 97;
        for r in 0..rows {
            let row_sum: f32 = out[0][r * 97..(r + 1) * 97].iter().sum();
            assert!((row_sum - 1.0).abs() < 1e-4, "row {r} sums to {row_sum}");
            assert!(out[0][r * 97..(r + 1) * 97].iter().all(|&v| v > 0.0));
        }
    }

    #[test]
    fn executable_cache_reuses_compilations() {
        let Some(mut rt) = runtime() else { return };
        let x: Vec<f32> = vec![0.5; 97 * 128];
        rt.run_f32("k_norm", &[&x]).unwrap();
        rt.run_f32("k_norm", &[&x]).unwrap();
        assert_eq!(rt.cached_executables(), 1);
    }

    #[test]
    fn shape_validation_errors() {
        let Some(mut rt) = runtime() else { return };
        let too_short: Vec<f32> = vec![0.0; 10];
        assert!(rt.run_f32("k_norm", &[&too_short]).is_err());
        assert!(rt.run_f32("bogus_artifact", &[&too_short]).is_err());
        let x: Vec<f32> = vec![0.0; 97 * 128];
        assert!(rt.run_f32("k_add", &[&x]).is_err()); // needs 2 inputs
    }
}
