//! The kernel-level energy attribution ledger and atlas drift detector.
//!
//! MEDEA's savings claim is *kernel-level*: per-kernel DVFS + PE assignment.
//! The registry's `sim_energy_nj` total says how many joules a pool spent,
//! but not *where* — which PE, at which V-F point, serving which atlas knot.
//! The [`EnergyLedger`] closes that gap on the serving hot path: every
//! dispatch decomposes its resolved `Schedule.decisions` (through the same
//! [`fold_assignments`] primitive the Fig 6 histogram uses) into
//!
//! * per-(PE, V-F) energy and busy-time accumulators, and
//! * per-(platform, workload, knot) dispatch counters,
//!
//! all fixed-size atomic tables sized from the atlas at pool start — one
//! shard per worker, no locks, no per-dispatch allocation.
//!
//! On top of the knot tables sits the **atlas drift detector**: a per-knot
//! EWMA of `realized host dispatch time / modeled time` (the knot's
//! sim-validated `sim_time` for solo dispatches, the batch-makespan model
//! for groups). The atlas is a design-time artifact; if the backend slows
//! down — thermal throttling, a degraded accelerator, a stale calibration —
//! the realized/modeled ratio climbs and the `medea_atlas_drift_ratio`
//! gauge crosses the SLO engine's optional `atlas_drift` objective, which
//! in turn arms the flight recorder. Snapshots ride inside
//! [`crate::telemetry::RegistrySnapshot`], so postmortem bundles and bench
//! artifacts carry the ledger for free.

use crate::manager::schedule::{fold_assignments, Decision};
use crate::platform::Platform;
use crate::util::json::{Json, JsonObj};
use crate::util::units::Time;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// EWMA smoothing factor for the per-knot drift ratio: converges to within
/// ~10 % of a step change in 8 dispatches while absorbing one-off hiccups.
pub const DRIFT_ALPHA: f64 = 0.25;

/// Static description of one servable (platform, workload) entry, built at
/// pool start from the platform preset and its schedule atlas.
#[derive(Debug, Clone)]
pub struct LedgerEntrySpec {
    pub platform: String,
    pub workload: String,
    /// PE display names, indexed by `PeId`.
    pub pe_labels: Vec<String>,
    /// V-F point labels, indexed by `vf_idx`.
    pub vf_labels: Vec<String>,
    /// Atlas knot deadlines in ascending order (the knot key is the exact
    /// deadline bit pattern the pool stamps on dispatch groups).
    pub knot_deadlines: Vec<Time>,
}

impl LedgerEntrySpec {
    /// Derive labels from a platform preset; `knot_deadlines` come from the
    /// entry's schedule atlas (ascending by construction).
    pub fn new(
        platform: &Platform,
        workload: impl Into<String>,
        knot_deadlines: Vec<Time>,
    ) -> LedgerEntrySpec {
        LedgerEntrySpec {
            platform: platform.name.clone(),
            workload: workload.into(),
            pe_labels: platform.pes.iter().map(|p| p.name.clone()).collect(),
            vf_labels: (0..platform.vf.len()).map(|i| platform.vf.get(i).label()).collect(),
            knot_deadlines,
        }
    }
}

/// Resolved per-entry geometry: label strings plus offsets into the flat
/// per-shard tables.
#[derive(Debug)]
struct EntryMeta {
    platform: String,
    workload: String,
    /// `platform/workload`, the `entry` label value on every ledger series.
    label: String,
    pe_labels: Vec<String>,
    vf_labels: Vec<String>,
    knot_labels: Vec<String>,
    /// Ascending raw-bit patterns of the knot deadlines (positive f64 bits
    /// order like the values, so an exact-match binary search works).
    knot_bits: Vec<u64>,
    cell_base: usize,
    knot_base: usize,
}

impl EntryMeta {
    fn cells(&self) -> usize {
        self.pe_labels.len() * self.vf_labels.len()
    }
}

/// One worker's private accumulator tables. Only that worker writes them
/// (snapshot readers merge across shards), so every update is a plain
/// relaxed atomic op on a thread-local cacheline.
#[derive(Debug)]
struct LedgerShard {
    /// Row-major `[entry][pe][vf]` energy, nanojoules.
    pe_energy_nj: Box<[AtomicU64]>,
    /// Row-major `[entry][pe][vf]` modeled busy time, nanoseconds.
    pe_busy_ns: Box<[AtomicU64]>,
    /// `[entry][knot]` dispatch counts (groups, not members).
    knot_dispatches: Box<[AtomicU64]>,
    /// `[entry][knot]` EWMA of realized/modeled dispatch time, stored as
    /// f64 bits; 0 means "no sample yet" (a real ratio is always > 0).
    knot_drift_bits: Box<[AtomicU64]>,
}

fn atomic_table(n: usize) -> Box<[AtomicU64]> {
    (0..n).map(|_| AtomicU64::new(0)).collect()
}

/// The pool-wide attribution ledger: entry metadata plus one
/// [`LedgerShard`] per worker.
#[derive(Debug)]
pub struct EnergyLedger {
    entries: Vec<EntryMeta>,
    shards: Vec<LedgerShard>,
    /// Dispatches whose entry or knot was not in the tables (an entry
    /// hot-swapped in after pool start) — counted, never silently dropped.
    unattributed: AtomicU64,
}

impl EnergyLedger {
    /// Build the fixed tables for `workers` shards over `specs` entries.
    pub fn new(workers: usize, specs: &[LedgerEntrySpec]) -> Arc<EnergyLedger> {
        let mut entries = Vec::with_capacity(specs.len());
        let mut cell_base = 0usize;
        let mut knot_base = 0usize;
        for spec in specs {
            let mut knot_labels: Vec<String> = spec
                .knot_deadlines
                .iter()
                .map(|d| format!("{:.3}ms", d.as_ms()))
                .collect();
            // Distinct knots may round to one millisecond label (e.g. a
            // deadline-atlas and an energy-atlas knot nanoseconds apart in
            // a merged fleet table); suffix repeats so every knot keeps a
            // unique Prometheus label set.
            for i in 1..knot_labels.len() {
                if knot_labels[..i].contains(&knot_labels[i]) {
                    let unique = format!("{}#{i}", knot_labels[i]);
                    knot_labels[i] = unique;
                }
            }
            let meta = EntryMeta {
                label: format!("{}/{}", spec.platform, spec.workload),
                platform: spec.platform.clone(),
                workload: spec.workload.clone(),
                pe_labels: spec.pe_labels.clone(),
                vf_labels: spec.vf_labels.clone(),
                knot_labels,
                knot_bits: spec.knot_deadlines.iter().map(|d| d.raw().to_bits()).collect(),
                cell_base,
                knot_base,
            };
            cell_base += meta.cells();
            knot_base += meta.knot_bits.len();
            entries.push(meta);
        }
        let shards = (0..workers.max(1))
            .map(|_| LedgerShard {
                pe_energy_nj: atomic_table(cell_base),
                pe_busy_ns: atomic_table(cell_base),
                knot_dispatches: atomic_table(knot_base),
                knot_drift_bits: atomic_table(knot_base),
            })
            .collect();
        Arc::new(EnergyLedger {
            entries,
            shards,
            unattributed: AtomicU64::new(0),
        })
    }

    pub fn entry_count(&self) -> usize {
        self.entries.len()
    }

    /// Resolve an entry index by preset names. A linear scan over a
    /// fleet-sized list (a few dozen at most) of `&str` compares —
    /// allocation-free, so dispatch paths may call it per group.
    pub fn find_entry(&self, platform: &str, workload: &str) -> Option<usize> {
        self.entries
            .iter()
            .position(|e| e.platform == platform && e.workload == workload)
    }

    /// Count one dispatch whose entry or knot is not in the tables.
    pub fn record_unattributed(&self) {
        // ordering: relaxed monotone counter, same contract as the registry
        // shards — readers take a statistical snapshot, not a linearizable
        // one.
        self.unattributed.fetch_add(1, Ordering::Relaxed);
    }

    /// Attribute one dispatch (solo or batch) executed by `worker`.
    ///
    /// * `knot_deadline` — the resolved knot's deadline (exact bit match
    ///   against the tables built from the atlas).
    /// * `members` — windows served by the dispatch (≥ 1); per-kernel
    ///   energy/time scale by it, the knot dispatch counter does not.
    /// * `realized` — host wall time of the dispatch.
    /// * `expected` — the modeled time: the knot's `sim_time` for a solo
    ///   dispatch, the batch-makespan model for a group.
    ///
    /// Allocation-free: one [`fold_assignments`] walk plus a binary search.
    pub fn record_dispatch(
        &self,
        worker: usize,
        entry: usize,
        knot_deadline: Time,
        decisions: &[Decision],
        members: u64,
        realized: Duration,
        expected: Time,
    ) {
        let (Some(meta), Some(shard)) = (self.entries.get(entry), self.shards.get(worker)) else {
            self.record_unattributed();
            return;
        };
        let pes = meta.pe_labels.len();
        let vfs = meta.vf_labels.len();
        let m = members.max(1);
        fold_assignments(decisions, |pe, vf, _count, time, energy| {
            if pe.0 >= pes || vf >= vfs {
                return;
            }
            let cell = meta.cell_base + pe.0 * vfs + vf;
            let nj = (energy.raw().max(0.0) * 1e9).round() as u64;
            let ns = (time.raw().max(0.0) * 1e9).round() as u64;
            // ordering: relaxed monotone counters on this worker's private
            // shard; snapshot readers tolerate cross-cell skew by design.
            shard.pe_energy_nj[cell].fetch_add(nj.saturating_mul(m), Ordering::Relaxed);
            // ordering: relaxed monotone counter, see above.
            shard.pe_busy_ns[cell].fetch_add(ns.saturating_mul(m), Ordering::Relaxed);
        });
        let Ok(k) = meta.knot_bits.binary_search(&knot_deadline.raw().to_bits()) else {
            self.record_unattributed();
            return;
        };
        let kidx = meta.knot_base + k;
        // ordering: relaxed monotone counter, see above.
        shard.knot_dispatches[kidx].fetch_add(1, Ordering::Relaxed);
        if expected.raw() > 0.0 {
            let ratio = realized.as_secs_f64() / expected.raw();
            // ordering: this shard's drift slot has a single writer (its
            // worker), so the relaxed load/store pair is a private
            // read-modify-write; concurrent snapshot readers may observe a
            // stale EWMA, which the gauge semantics allow.
            let prev = f64::from_bits(shard.knot_drift_bits[kidx].load(Ordering::Relaxed));
            let next = if prev > 0.0 { prev + DRIFT_ALPHA * (ratio - prev) } else { ratio };
            // ordering: single-writer gauge publish, see above.
            shard.knot_drift_bits[kidx].store(next.to_bits(), Ordering::Relaxed);
        }
    }

    /// Merge every shard into a plain-data snapshot. Counter cells sum;
    /// drift gauges take the worst (max) worker EWMA — both commutative and
    /// associative, so the result is independent of shard order.
    pub fn snapshot(&self) -> LedgerSnapshot {
        let entries = self
            .entries
            .iter()
            .map(|meta| {
                let cells = meta.cells();
                let knots = meta.knot_bits.len();
                let mut e = LedgerEntrySnapshot {
                    platform: meta.platform.clone(),
                    workload: meta.workload.clone(),
                    label: meta.label.clone(),
                    pe_labels: meta.pe_labels.clone(),
                    vf_labels: meta.vf_labels.clone(),
                    knot_labels: meta.knot_labels.clone(),
                    pe_energy_nj: vec![0; cells],
                    pe_busy_ns: vec![0; cells],
                    knot_dispatches: vec![0; knots],
                    knot_drift: vec![0.0; knots],
                };
                for shard in &self.shards {
                    for c in 0..cells {
                        // ordering: relaxed statistical snapshot reads,
                        // same contract as WorkerShard::snapshot.
                        e.pe_energy_nj[c] +=
                            shard.pe_energy_nj[meta.cell_base + c].load(Ordering::Relaxed);
                        // ordering: relaxed snapshot read, see above.
                        e.pe_busy_ns[c] +=
                            shard.pe_busy_ns[meta.cell_base + c].load(Ordering::Relaxed);
                    }
                    for k in 0..knots {
                        // ordering: relaxed snapshot read, see above.
                        e.knot_dispatches[k] +=
                            shard.knot_dispatches[meta.knot_base + k].load(Ordering::Relaxed);
                        // ordering: relaxed snapshot read, see above.
                        let bits = shard.knot_drift_bits[meta.knot_base + k].load(Ordering::Relaxed);
                        let drift = f64::from_bits(bits);
                        if drift > e.knot_drift[k] {
                            e.knot_drift[k] = drift;
                        }
                    }
                }
                e
            })
            .collect();
        LedgerSnapshot {
            entries,
            // ordering: relaxed snapshot read, see above.
            unattributed: self.unattributed.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data copy of one entry's merged tables.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LedgerEntrySnapshot {
    pub platform: String,
    pub workload: String,
    /// `platform/workload` — the `entry` label value.
    pub label: String,
    pub pe_labels: Vec<String>,
    pub vf_labels: Vec<String>,
    pub knot_labels: Vec<String>,
    /// Row-major `[pe][vf]`, nanojoules.
    pub pe_energy_nj: Vec<u64>,
    /// Row-major `[pe][vf]`, nanoseconds of modeled busy time.
    pub pe_busy_ns: Vec<u64>,
    pub knot_dispatches: Vec<u64>,
    /// Per-knot worst-worker EWMA of realized/modeled time; 0 = no sample.
    pub knot_drift: Vec<f64>,
}

impl LedgerEntrySnapshot {
    fn vfs(&self) -> usize {
        self.vf_labels.len()
    }

    /// Total busy nanoseconds attributed to `pe` (summed over V-F points).
    pub fn pe_busy_total_ns(&self, pe: usize) -> u64 {
        let vfs = self.vfs();
        self.pe_busy_ns[pe * vfs..(pe + 1) * vfs].iter().sum()
    }

    /// Total nanojoules attributed to `pe` (summed over V-F points).
    pub fn pe_energy_total_nj(&self, pe: usize) -> u64 {
        let vfs = self.vfs();
        self.pe_energy_nj[pe * vfs..(pe + 1) * vfs].iter().sum()
    }

    /// Worst per-knot drift ratio in this entry (0 when nothing sampled).
    pub fn max_drift(&self) -> f64 {
        self.knot_drift.iter().fold(0.0, |a, &b| a.max(b))
    }
}

/// Plain-data copy of the whole ledger at one instant.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LedgerSnapshot {
    pub entries: Vec<LedgerEntrySnapshot>,
    pub unattributed: u64,
}

impl LedgerSnapshot {
    /// Worst drift ratio across every entry and knot — the scalar the SLO
    /// engine's `atlas_drift` objective judges.
    pub fn max_drift(&self) -> f64 {
        self.entries.iter().fold(0.0, |a, e| a.max(e.max_drift()))
    }

    /// Busiest PE by busy-time delta since `prev`: `(entry/pe label, share
    /// of the summed busy delta)`. The periodic reporter's "top PE" readout.
    pub fn top_pe_since(&self, prev: &LedgerSnapshot) -> Option<(String, f64)> {
        let mut total: u64 = 0;
        let mut best: Option<(String, u64)> = None;
        for e in &self.entries {
            let earlier = prev.entries.iter().find(|p| p.label == e.label);
            for (pe, pe_label) in e.pe_labels.iter().enumerate() {
                let now = e.pe_busy_total_ns(pe);
                let before = earlier
                    .filter(|p| p.pe_labels.len() == e.pe_labels.len())
                    .map(|p| p.pe_busy_total_ns(pe))
                    .unwrap_or(0);
                let delta = now.saturating_sub(before);
                total += delta;
                let leads = match &best {
                    Some((_, b)) => delta > *b,
                    None => delta > 0,
                };
                if leads {
                    best = Some((format!("{}:{}", e.label, pe_label), delta));
                }
            }
        }
        let (label, busiest) = best?;
        Some((label, busiest as f64 / total.max(1) as f64))
    }

    pub fn to_json(&self) -> Json {
        let strings = |v: &[String]| Json::Arr(v.iter().map(|s| Json::from(s.as_str())).collect());
        let counts = |v: &[u64]| Json::Arr(v.iter().map(|&n| Json::from(n)).collect());
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                let mut o = JsonObj::new();
                o.insert("platform", e.platform.as_str());
                o.insert("workload", e.workload.as_str());
                o.insert("pe", strings(&e.pe_labels));
                o.insert("vf", strings(&e.vf_labels));
                o.insert("knots", strings(&e.knot_labels));
                o.insert("pe_energy_nj", counts(&e.pe_energy_nj));
                o.insert("pe_busy_ns", counts(&e.pe_busy_ns));
                o.insert("knot_dispatches", counts(&e.knot_dispatches));
                o.insert(
                    "knot_drift",
                    Json::Arr(e.knot_drift.iter().map(|&d| Json::from(d)).collect()),
                );
                Json::Obj(o)
            })
            .collect();
        let mut o = JsonObj::new();
        o.insert("unattributed", self.unattributed);
        o.insert("entries", Json::Arr(entries));
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<LedgerSnapshot, String> {
        let strings = |v: &Json, key: &str| -> Result<Vec<String>, String> {
            v.req(key)?
                .as_arr()
                .ok_or(format!("{key} is not an array"))?
                .iter()
                .map(|s| s.as_str().map(String::from).ok_or(format!("{key} element")))
                .collect()
        };
        let counts = |v: &Json, key: &str| -> Result<Vec<u64>, String> {
            v.req(key)?
                .as_arr()
                .ok_or(format!("{key} is not an array"))?
                .iter()
                .map(|n| n.as_u64().ok_or(format!("{key} element")))
                .collect()
        };
        let mut entries = Vec::new();
        for ev in v.req("entries")?.as_arr().ok_or("entries is not an array")? {
            let platform = ev.req("platform")?.as_str().ok_or("platform")?.to_string();
            let workload = ev.req("workload")?.as_str().ok_or("workload")?.to_string();
            let knot_drift: Vec<f64> = ev
                .req("knot_drift")?
                .as_arr()
                .ok_or("knot_drift is not an array")?
                .iter()
                .map(|d| d.as_f64().ok_or("knot_drift element".to_string()))
                .collect::<Result<_, _>>()?;
            entries.push(LedgerEntrySnapshot {
                label: format!("{platform}/{workload}"),
                platform,
                workload,
                pe_labels: strings(ev, "pe")?,
                vf_labels: strings(ev, "vf")?,
                knot_labels: strings(ev, "knots")?,
                pe_energy_nj: counts(ev, "pe_energy_nj")?,
                pe_busy_ns: counts(ev, "pe_busy_ns")?,
                knot_dispatches: counts(ev, "knot_dispatches")?,
                knot_drift,
            });
        }
        Ok(LedgerSnapshot {
            entries,
            unattributed: v.get("unattributed").and_then(|n| n.as_u64()).unwrap_or(0),
        })
    }
}

// ---- Prometheus re-ingestion + the energy-report tables -------------------

/// Parse one exposition series line: `name{k="v",…} value`.
fn parse_series(line: &str) -> Option<(&str, Vec<(String, String)>, f64)> {
    let open = line.find('{')?;
    let close = line.rfind('}')?;
    let name = &line[..open];
    let value: f64 = line[close + 1..].trim().parse().ok()?;
    let mut labels = Vec::new();
    let body = &line[open + 1..close];
    let mut rest = body;
    while !rest.is_empty() {
        let eq = rest.find("=\"")?;
        let key = rest[..eq].trim_start_matches(',').to_string();
        let mut val = String::new();
        let mut chars = rest[eq + 2..].char_indices();
        let mut consumed = None;
        while let Some((i, c)) = chars.next() {
            match c {
                '\\' => match chars.next() {
                    Some((_, 'n')) => val.push('\n'),
                    Some((_, other)) => val.push(other),
                    None => return None,
                },
                '"' => {
                    consumed = Some(eq + 2 + i + 1);
                    break;
                }
                other => val.push(other),
            }
        }
        rest = &rest[consumed?..];
        labels.push((key, val));
    }
    Some((name, labels, value))
}

fn label<'a>(labels: &'a [(String, String)], key: &str) -> Option<&'a str> {
    labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

/// Index of `label` in `labels`, appending it when new.
fn intern(labels: &mut Vec<String>, label: &str) -> usize {
    match labels.iter().position(|l| l == label) {
        Some(i) => i,
        None => {
            labels.push(label.to_string());
            labels.len() - 1
        }
    }
}

/// Rebuild a [`LedgerSnapshot`] from Prometheus exposition text — the
/// inverse of the exposition's ledger families, used by
/// `medea energy-report <addr>` against a live scrape. Cell/knot label sets
/// are discovered in order of appearance, and (pe, vf) matrices are grown
/// as new label pairs show up, so the result is label-order independent.
pub fn ledger_from_prometheus(text: &str) -> Result<LedgerSnapshot, String> {
    let mut snap = LedgerSnapshot::default();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((name, labels, value)) = parse_series(line) else { continue };
        if name == "medea_unattributed_dispatches_total" {
            snap.unattributed = value.max(0.0) as u64;
            continue;
        }
        let is_cell =
            matches!(name, "medea_pe_energy_joules_total" | "medea_pe_busy_seconds_total");
        let is_knot = matches!(name, "medea_knot_dispatches_total" | "medea_atlas_drift_ratio");
        if !is_cell && !is_knot {
            continue;
        }
        let entry_label = label(&labels, "entry").ok_or_else(|| format!("{name}: no entry label"))?;
        let eidx = match snap.entries.iter().position(|e| e.label == entry_label) {
            Some(i) => i,
            None => {
                let (platform, workload) =
                    entry_label.split_once('/').unwrap_or((entry_label, ""));
                snap.entries.push(LedgerEntrySnapshot {
                    platform: platform.to_string(),
                    workload: workload.to_string(),
                    label: entry_label.to_string(),
                    ..LedgerEntrySnapshot::default()
                });
                snap.entries.len() - 1
            }
        };
        let e = &mut snap.entries[eidx];
        if is_cell {
            let pe = label(&labels, "pe").ok_or_else(|| format!("{name}: no pe label"))?;
            let vf = label(&labels, "vf").ok_or_else(|| format!("{name}: no vf label"))?;
            let (old_pes, old_vfs) = (e.pe_labels.len(), e.vf_labels.len());
            let p = intern(&mut e.pe_labels, pe);
            let v = intern(&mut e.vf_labels, vf);
            let (pes, vfs) = (e.pe_labels.len(), e.vf_labels.len());
            if (pes, vfs) != (old_pes, old_vfs) {
                // Re-layout the row-major matrices for the grown label sets.
                for table in [&mut e.pe_energy_nj, &mut e.pe_busy_ns] {
                    let mut grown = vec![0u64; pes * vfs];
                    for op in 0..old_pes {
                        for ov in 0..old_vfs {
                            grown[op * vfs + ov] = table[op * old_vfs + ov];
                        }
                    }
                    *table = grown;
                }
            }
            let cell = p * vfs + v;
            match name {
                "medea_pe_energy_joules_total" => {
                    e.pe_energy_nj[cell] = (value.max(0.0) * 1e9).round() as u64;
                }
                _ => e.pe_busy_ns[cell] = (value.max(0.0) * 1e9).round() as u64,
            }
        } else {
            let knot = label(&labels, "knot").ok_or_else(|| format!("{name}: no knot label"))?;
            let k = intern(&mut e.knot_labels, knot);
            if e.knot_dispatches.len() < e.knot_labels.len() {
                e.knot_dispatches.resize(e.knot_labels.len(), 0);
                e.knot_drift.resize(e.knot_labels.len(), 0.0);
            }
            match name {
                "medea_knot_dispatches_total" => e.knot_dispatches[k] = value.max(0.0) as u64,
                _ => e.knot_drift[k] = value.max(0.0),
            }
        }
    }
    if snap.entries.is_empty() {
        return Err("no ledger families (medea_pe_*/medea_knot_*/medea_atlas_*) in input".into());
    }
    Ok(snap)
}

/// Render the `medea energy-report` tables: per-PE utilization and energy
/// share, per-(PE, V-F) energy split, and the per-knot dispatch/drift view.
pub fn render_energy_report(snap: &LedgerSnapshot) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    for e in &snap.entries {
        let _ = writeln!(out, "entry {}", e.label);
        let busy_total: u64 = e.pe_busy_ns.iter().sum();
        let energy_total: u64 = e.pe_energy_nj.iter().sum();
        let _ = writeln!(
            out,
            "  {:<14} {:>12} {:>7} {:>14} {:>8}",
            "pe", "busy_s", "busy%", "energy_uj", "energy%"
        );
        for (p, pe) in e.pe_labels.iter().enumerate() {
            let busy = e.pe_busy_total_ns(p);
            let energy = e.pe_energy_total_nj(p);
            let _ = writeln!(
                out,
                "  {:<14} {:>12.4} {:>6.1}% {:>14.1} {:>7.1}%",
                pe,
                busy as f64 / 1e9,
                100.0 * busy as f64 / busy_total.max(1) as f64,
                energy as f64 / 1e3,
                100.0 * energy as f64 / energy_total.max(1) as f64,
            );
        }
        let vfs = e.vfs();
        let _ = writeln!(out, "  {:<14} {:<14} {:>14} {:>8}", "pe", "vf", "energy_uj", "share");
        for (p, pe) in e.pe_labels.iter().enumerate() {
            for (v, vf) in e.vf_labels.iter().enumerate() {
                let nj = e.pe_energy_nj[p * vfs + v];
                if nj == 0 {
                    continue;
                }
                let _ = writeln!(
                    out,
                    "  {:<14} {:<14} {:>14.1} {:>7.1}%",
                    pe,
                    vf,
                    nj as f64 / 1e3,
                    100.0 * nj as f64 / energy_total.max(1) as f64,
                );
            }
        }
        let _ = writeln!(out, "  {:<14} {:>12} {:>12}", "knot", "dispatches", "drift");
        for (k, knot) in e.knot_labels.iter().enumerate() {
            if e.knot_dispatches[k] == 0 {
                continue;
            }
            let _ = writeln!(
                out,
                "  {:<14} {:>12} {:>12.3}",
                knot, e.knot_dispatches[k], e.knot_drift[k]
            );
        }
    }
    if snap.unattributed > 0 {
        let _ = writeln!(out, "unattributed dispatches: {}", snap.unattributed);
    }
    let _ = writeln!(out, "worst atlas drift ratio: {:.3}", snap.max_drift());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::PeId;
    use crate::tiling::modes::TilingMode;
    use crate::util::units::Energy;

    fn spec() -> LedgerEntrySpec {
        LedgerEntrySpec {
            platform: "heeptimize".into(),
            workload: "tsd-core".into(),
            pe_labels: vec!["cpu".into(), "cgra".into()],
            vf_labels: vec!["0.80V@170MHz".into(), "0.90V@250MHz".into()],
            knot_deadlines: vec![Time::from_ms(50.0), Time::from_ms(200.0)],
        }
    }

    fn d(kernel: usize, pe: usize, vf: usize, us: f64, uj: f64) -> Decision {
        Decision {
            kernel,
            pe: PeId(pe),
            vf_idx: vf,
            mode: TilingMode::SingleBuffer,
            time: Time::from_us(us),
            energy: Energy::from_uj(uj),
        }
    }

    #[test]
    fn attributes_cells_knots_and_drift() {
        let ledger = EnergyLedger::new(2, &[spec()]);
        assert_eq!(ledger.entry_count(), 1);
        assert_eq!(ledger.find_entry("heeptimize", "tsd-core"), Some(0));
        assert_eq!(ledger.find_entry("heeptimize", "nope"), None);
        let decisions = [d(0, 0, 1, 100.0, 2.0), d(1, 1, 0, 300.0, 5.0), d(2, 0, 1, 100.0, 3.0)];
        // Two solo dispatches on worker 0, realized exactly 2x the model.
        for _ in 0..2 {
            ledger.record_dispatch(
                0,
                0,
                Time::from_ms(50.0),
                &decisions,
                1,
                Duration::from_millis(20),
                Time::from_ms(10.0),
            );
        }
        // One batch of 4 on worker 1 against the laxer knot, on-model.
        ledger.record_dispatch(
            1,
            0,
            Time::from_ms(200.0),
            &decisions,
            4,
            Duration::from_millis(10),
            Time::from_ms(10.0),
        );
        let snap = ledger.snapshot();
        let e = &snap.entries[0];
        // (cpu, vf1): (2 + 3) uJ x (2 solos + 4 members) = 30 uJ.
        assert_eq!(e.pe_energy_nj[1], 30_000_000);
        // (cgra, vf0): 5 uJ x 6 = 30 uJ; busy 300 us x 6 = 1.8 ms.
        assert_eq!(e.pe_energy_nj[2], 30_000_000);
        assert_eq!(e.pe_busy_ns[2], 1_800_000);
        assert_eq!(e.pe_busy_total_ns(0), 1_200_000);
        assert_eq!(e.knot_dispatches, vec![2, 1]);
        // Knot 0 saw ratio 2.0 twice (EWMA of a constant is the constant);
        // knot 1 sat on-model at 1.0.
        assert!((e.knot_drift[0] - 2.0).abs() < 1e-12);
        assert!((e.knot_drift[1] - 1.0).abs() < 1e-12);
        assert!((snap.max_drift() - 2.0).abs() < 1e-12);
        assert_eq!(snap.unattributed, 0);
    }

    #[test]
    fn unknown_entry_or_knot_counts_unattributed() {
        let ledger = EnergyLedger::new(1, &[spec()]);
        let decisions = [d(0, 0, 0, 10.0, 1.0)];
        ledger.record_dispatch(
            0,
            7, // no such entry
            Time::from_ms(50.0),
            &decisions,
            1,
            Duration::from_millis(1),
            Time::from_ms(1.0),
        );
        ledger.record_dispatch(
            0,
            0,
            Time::from_ms(51.0), // not a knot deadline
            &decisions,
            1,
            Duration::from_millis(1),
            Time::from_ms(1.0),
        );
        let snap = ledger.snapshot();
        assert_eq!(snap.unattributed, 2);
        // The off-knot dispatch still attributed its cells.
        assert_eq!(snap.entries[0].pe_energy_nj[0], 1_000);
        assert_eq!(snap.entries[0].knot_dispatches, vec![0, 0]);
    }

    #[test]
    fn drift_ewma_converges_toward_step_change() {
        let ledger = EnergyLedger::new(1, &[spec()]);
        let decisions = [d(0, 0, 0, 10.0, 1.0)];
        let record = |ms: u64| {
            ledger.record_dispatch(
                0,
                0,
                Time::from_ms(50.0),
                &decisions,
                1,
                Duration::from_millis(ms),
                Time::from_ms(10.0),
            )
        };
        record(10); // seeds at 1.0
        assert!((ledger.snapshot().entries[0].knot_drift[0] - 1.0).abs() < 1e-12);
        for _ in 0..16 {
            record(30); // step to 3x
        }
        let drift = ledger.snapshot().entries[0].knot_drift[0];
        assert!(drift > 2.9 && drift < 3.0 + 1e-12, "EWMA at {drift}, want ~3");
    }

    /// The satellite invariant: the merged snapshot must not depend on
    /// which worker recorded what, or in what interleaving — sums and max
    /// are commutative/associative across shards.
    #[test]
    fn snapshot_is_merge_order_invariant() {
        let calls: Vec<(usize, f64, u64, u64)> = vec![
            // (knot idx as deadline selector, deadline_ms, members, realized_ms)
            (0, 50.0, 1, 20),
            (1, 200.0, 3, 10),
            (0, 50.0, 2, 20),
            (1, 200.0, 1, 10),
            (0, 50.0, 1, 20),
            (1, 200.0, 2, 10),
        ];
        let decisions = [d(0, 0, 1, 100.0, 2.0), d(1, 1, 0, 300.0, 5.0)];
        // Assign call i to worker i % n, then replay in three different
        // global interleavings (forward, reverse, odd-then-even).
        let run = |order: &[usize]| {
            let ledger = EnergyLedger::new(3, &[spec()]);
            for &i in order {
                let (_, dl, members, ms) = calls[i];
                ledger.record_dispatch(
                    i % 3,
                    0,
                    Time::from_ms(dl),
                    &decisions,
                    members,
                    Duration::from_millis(ms),
                    Time::from_ms(10.0),
                );
            }
            ledger.snapshot()
        };
        let forward = run(&[0, 1, 2, 3, 4, 5]);
        let reverse = run(&[5, 4, 3, 2, 1, 0]);
        let shuffled = run(&[1, 3, 5, 0, 2, 4]);
        assert_eq!(forward, reverse);
        assert_eq!(forward, shuffled);
        assert_eq!(forward.entries[0].knot_dispatches, vec![3, 3]);
    }

    #[test]
    fn json_round_trip() {
        let ledger = EnergyLedger::new(2, &[spec()]);
        let decisions = [d(0, 0, 1, 100.0, 2.0), d(1, 1, 0, 300.0, 5.0)];
        ledger.record_dispatch(
            0,
            0,
            Time::from_ms(50.0),
            &decisions,
            2,
            Duration::from_millis(30),
            Time::from_ms(10.0),
        );
        ledger.record_unattributed();
        let snap = ledger.snapshot();
        let text = snap.to_json().to_pretty();
        let back = LedgerSnapshot::from_json(
            &crate::util::json::parse(&text).expect("ledger json parses"),
        )
        .expect("ledger json decodes");
        assert_eq!(back, snap);
    }

    #[test]
    fn prometheus_round_trip_and_report() {
        let mut text = String::new();
        for (name, series) in [
            ("medea_pe_energy_joules_total", "pe=\"cpu\",vf=\"0.80V@170MHz\"} 0.002"),
            ("medea_pe_energy_joules_total", "pe=\"cgra\",vf=\"0.90V@250MHz\"} 0.006"),
            ("medea_pe_busy_seconds_total", "pe=\"cpu\",vf=\"0.80V@170MHz\"} 0.5"),
            ("medea_pe_busy_seconds_total", "pe=\"cgra\",vf=\"0.90V@250MHz\"} 1.5"),
            ("medea_knot_dispatches_total", "knot=\"50.000ms\"} 7"),
            ("medea_atlas_drift_ratio", "knot=\"50.000ms\"} 2.5"),
        ] {
            text.push_str(name);
            text.push_str("{platform=\"heeptimize\",workload=\"tsd-core\",entry=\"heeptimize/tsd-core\",");
            text.push_str(series);
            text.push('\n');
        }
        let snap = ledger_from_prometheus(&text).expect("scrape parses");
        assert_eq!(snap.entries.len(), 1);
        let e = &snap.entries[0];
        assert_eq!(e.platform, "heeptimize");
        assert_eq!(e.pe_labels, vec!["cpu", "cgra"]);
        assert_eq!(e.pe_energy_total_nj(1), 6_000_000);
        assert_eq!(e.pe_busy_total_ns(0), 500_000_000);
        assert_eq!(e.knot_dispatches, vec![7]);
        assert!((snap.max_drift() - 2.5).abs() < 1e-12);
        let report = render_energy_report(&snap);
        assert!(report.contains("entry heeptimize/tsd-core"));
        assert!(report.contains("cgra"));
        assert!(report.contains("75.0%"), "cgra holds 3/4 of the energy:\n{report}");
        assert!(report.contains("worst atlas drift ratio: 2.500"));
        // Junk input fails loudly instead of returning an empty report.
        assert!(ledger_from_prometheus("medea_requests_total 4\n").is_err());
    }

    #[test]
    fn top_pe_tracks_the_busy_delta() {
        let ledger = EnergyLedger::new(1, &[spec()]);
        let cpu_heavy = [d(0, 0, 0, 900.0, 1.0)];
        let cgra_heavy = [d(0, 1, 1, 900.0, 1.0)];
        ledger.record_dispatch(
            0,
            0,
            Time::from_ms(50.0),
            &cpu_heavy,
            1,
            Duration::from_millis(1),
            Time::from_ms(1.0),
        );
        let prev = ledger.snapshot();
        for _ in 0..3 {
            ledger.record_dispatch(
                0,
                0,
                Time::from_ms(50.0),
                &cgra_heavy,
                1,
                Duration::from_millis(1),
                Time::from_ms(1.0),
            );
        }
        let now = ledger.snapshot();
        let (label, share) = now.top_pe_since(&prev).expect("busy delta exists");
        assert_eq!(label, "heeptimize/tsd-core:cgra");
        assert!((share - 1.0).abs() < 1e-12, "all new busy time is cgra's: {share}");
        // Against an empty baseline the totals themselves decide.
        let (label, _) = now.top_pe_since(&LedgerSnapshot::default()).expect("totals");
        assert_eq!(label, "heeptimize/tsd-core:cgra");
        // No delta at all -> None.
        assert!(prev.top_pe_since(&prev).is_none());
    }
}
