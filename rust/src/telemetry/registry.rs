//! The lock-free live metrics registry both pools publish into.
//!
//! One [`TelemetryRegistry`] per pool; one [`WorkerShard`] per worker so the
//! hot path touches only thread-local cachelines (atomic counters plus
//! [`AtomicHist`] buckets — never a lock). Admission-side shed counters live
//! on the registry itself, since shed requests never reach a worker.
//!
//! Readers — the Prometheus endpoint, the periodic reporter, the shutdown
//! aggregate — call [`TelemetryRegistry::snapshot`] and work on plain data.
//! [`WorkerSnapshot::to_metrics`] rebuilds a per-worker
//! [`crate::coordinator::Metrics`] from the same histograms, which is what
//! makes live and shutdown percentiles identical by construction.

use crate::coordinator::Metrics;
use crate::serve::queue::Rejection;
use crate::telemetry::hist::{AtomicHist, HistData};
use crate::telemetry::ledger::{EnergyLedger, LedgerSnapshot};
use crate::util::json::{Json, JsonObj};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// Linear batch-size slots: sizes `1..=BATCH_SLOTS` (larger clamps to last).
pub const BATCH_SLOTS: usize = 64;

/// Convert a [`Duration`] to whole nanoseconds (saturating).
pub(crate) fn dur_ns(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

/// Convert joules to whole nanojoules (saturating; NaN records as 0).
fn joules_nj(j: f64) -> u64 {
    (j.max(0.0) * 1e9).round() as u64
}

/// Convert seconds to whole nanoseconds (saturating; NaN records as 0).
fn secs_ns(s: f64) -> u64 {
    (s.max(0.0) * 1e9).round() as u64
}

/// Per-worker recording surface. All methods take `&self` and are wait-free.
#[derive(Debug)]
pub struct WorkerShard {
    requests: AtomicU64,
    seizures: AtomicU64,
    deadline_misses: AtomicU64,
    steals: AtomicU64,
    stolen_requests: AtomicU64,
    sim_energy_nj: AtomicU64,
    sim_active_ns: AtomicU64,
    batch_hist: [AtomicU64; BATCH_SLOTS],
    /// End-to-end host latency (submit → reply ready), ns.
    host: AtomicHist,
    /// Queue wait (submit → dequeued by a worker), ns.
    queue_wait: AtomicHist,
    /// Head-of-group laxity at dispatch (remaining slack), ns.
    laxity: AtomicHist,
    /// Dispatch execution time (dequeue → group fully retired), ns.
    dispatch: AtomicHist,
    /// Per-request simulated energy, nJ.
    energy: AtomicHist,
    /// Steal-wake latency (victim posts a wake → thief observes it), ns.
    wake: AtomicHist,
    /// Parks that ended without a wake token (heartbeat / stray notify).
    spurious_wakeups: AtomicU64,
    /// Effective batch fill window chosen for the latest dispatch, ns
    /// (a gauge: last-write-wins, not a monotone counter).
    batch_window_ns: AtomicU64,
    /// Admission queue depth of this worker's shard (a gauge mirroring the
    /// lock-free depth counter the stealing heuristics already keep).
    queue_depth: AtomicU64,
}

impl Default for WorkerShard {
    fn default() -> Self {
        WorkerShard {
            requests: AtomicU64::new(0),
            seizures: AtomicU64::new(0),
            deadline_misses: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            stolen_requests: AtomicU64::new(0),
            sim_energy_nj: AtomicU64::new(0),
            sim_active_ns: AtomicU64::new(0),
            batch_hist: std::array::from_fn(|_| AtomicU64::new(0)),
            host: AtomicHist::new(),
            queue_wait: AtomicHist::new(),
            laxity: AtomicHist::new(),
            dispatch: AtomicHist::new(),
            energy: AtomicHist::new(),
            wake: AtomicHist::new(),
            spurious_wakeups: AtomicU64::new(0),
            batch_window_ns: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
        }
    }
}

impl WorkerShard {
    /// Record one served request (mirrors [`Metrics::record`]).
    pub fn record(
        &self,
        seizure: bool,
        deadline_met: bool,
        energy_j: f64,
        active_s: f64,
        host: Duration,
    ) {
        // ordering: every counter in this impl is an independent relaxed
        // monotone count. Snapshot readers tolerate cross-counter skew by
        // design (deltas saturate, hit rates are ratios of large counts),
        // so no release/acquire pairing is needed anywhere in this shard.
        self.requests.fetch_add(1, Ordering::Relaxed);
        if seizure {
            // ordering: relaxed counter, see `record`.
            self.seizures.fetch_add(1, Ordering::Relaxed);
        }
        if !deadline_met {
            // ordering: relaxed counter, see `record`.
            self.deadline_misses.fetch_add(1, Ordering::Relaxed);
        }
        let nj = joules_nj(energy_j);
        // ordering: relaxed counters, see `record`.
        self.sim_energy_nj.fetch_add(nj, Ordering::Relaxed);
        self.sim_active_ns.fetch_add(secs_ns(active_s), Ordering::Relaxed);
        self.energy.record(nj);
        self.host.record(dur_ns(host));
    }

    /// Record one dispatch of `size` coalesced requests (1 = solo).
    pub fn record_batch(&self, size: usize) {
        let slot = size.clamp(1, BATCH_SLOTS) - 1;
        // ordering: relaxed counter, see `record`.
        self.batch_hist[slot].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one steal event of `size` coalesced requests.
    pub fn record_steal(&self, size: usize) {
        // ordering: relaxed counters, see `record`.
        self.steals.fetch_add(1, Ordering::Relaxed);
        self.stolen_requests.fetch_add(size.max(1) as u64, Ordering::Relaxed);
    }

    /// Record how long a request sat queued before a worker picked it up.
    pub fn record_queue_wait(&self, wait: Duration) {
        self.queue_wait.record(dur_ns(wait));
    }

    /// Record the dispatch group head's remaining laxity.
    pub fn record_head_laxity(&self, laxity: Duration) {
        self.laxity.record(dur_ns(laxity));
    }

    /// Record how long one dispatch (solo or batch) took end to end.
    pub fn record_dispatch_time(&self, took: Duration) {
        self.dispatch.record(dur_ns(took));
    }

    /// Record one steal-wake delivery latency (victim posted the wake →
    /// this thief consumed it on waking).
    pub fn record_wakeup(&self, latency: Duration) {
        self.wake.record(dur_ns(latency));
    }

    /// Record one park that ended without a wake token (fallback heartbeat
    /// expiry or a stray notify) — the event-driven path's waste metric.
    pub fn record_spurious_wakeup(&self) {
        // ordering: relaxed counter, see `record`.
        self.spurious_wakeups.fetch_add(1, Ordering::Relaxed);
    }

    /// Publish this worker's current admission queue depth (called where
    /// the shard's lock-free depth mirror is already maintained).
    pub fn set_queue_depth(&self, depth: usize) {
        // ordering: last-write-wins gauge with no payload protocol; readers
        // take whatever the most recent admission/dispatch published.
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
    }

    /// Publish the effective batch fill window chosen for the latest
    /// dispatch (static `--batch-window-us` or the autotuner's pick).
    pub fn set_batch_window(&self, window: Duration) {
        // ordering: last-write-wins gauge with no payload protocol; readers
        // take whatever the most recent dispatch published.
        self.batch_window_ns.store(dur_ns(window), Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> WorkerSnapshot {
        // ordering: relaxed reads of relaxed counters, see `record` — the
        // snapshot is a statistically consistent view, not a linearizable
        // one; each counter is individually monotone, which is all the
        // delta arithmetic downstream (SLO windows, rates) relies on.
        let load = |c: &AtomicU64| c.load(Ordering::Relaxed);
        let mut batch_hist: Vec<u64> = self.batch_hist.iter().map(load).collect();
        while batch_hist.last() == Some(&0) {
            batch_hist.pop();
        }
        WorkerSnapshot {
            // ordering: relaxed snapshot reads, see above.
            requests: self.requests.load(Ordering::Relaxed),
            seizures: self.seizures.load(Ordering::Relaxed),
            deadline_misses: self.deadline_misses.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
            stolen_requests: self.stolen_requests.load(Ordering::Relaxed),
            sim_energy_nj: self.sim_energy_nj.load(Ordering::Relaxed),
            sim_active_ns: self.sim_active_ns.load(Ordering::Relaxed),
            batch_hist,
            host: self.host.snapshot(),
            queue_wait: self.queue_wait.snapshot(),
            laxity: self.laxity.snapshot(),
            dispatch: self.dispatch.snapshot(),
            energy: self.energy.snapshot(),
            wake: self.wake.snapshot(),
            // ordering: relaxed snapshot reads, see above.
            spurious_wakeups: self.spurious_wakeups.load(Ordering::Relaxed),
            batch_window_ns: self.batch_window_ns.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data copy of one worker's shard.
#[derive(Debug, Clone, Default)]
pub struct WorkerSnapshot {
    pub requests: u64,
    pub seizures: u64,
    pub deadline_misses: u64,
    pub steals: u64,
    pub stolen_requests: u64,
    pub sim_energy_nj: u64,
    pub sim_active_ns: u64,
    /// Trailing-zero-trimmed linear slots: `[i]` counts dispatches of `i+1`.
    pub batch_hist: Vec<u64>,
    pub host: HistData,
    pub queue_wait: HistData,
    pub laxity: HistData,
    pub dispatch: HistData,
    pub energy: HistData,
    pub wake: HistData,
    pub spurious_wakeups: u64,
    /// Gauge, not a counter: the latest published effective fill window.
    pub batch_window_ns: u64,
    /// Gauge: this worker's admission queue depth when snapped.
    pub queue_depth: u64,
}

impl WorkerSnapshot {
    pub fn merge(&mut self, other: &WorkerSnapshot) {
        self.requests += other.requests;
        self.seizures += other.seizures;
        self.deadline_misses += other.deadline_misses;
        self.steals += other.steals;
        self.stolen_requests += other.stolen_requests;
        self.sim_energy_nj += other.sim_energy_nj;
        self.sim_active_ns += other.sim_active_ns;
        if self.batch_hist.len() < other.batch_hist.len() {
            self.batch_hist.resize(other.batch_hist.len(), 0);
        }
        for (slot, &n) in self.batch_hist.iter_mut().zip(&other.batch_hist) {
            *slot += n;
        }
        self.host.merge(&other.host);
        self.queue_wait.merge(&other.queue_wait);
        self.laxity.merge(&other.laxity);
        self.dispatch.merge(&other.dispatch);
        self.energy.merge(&other.energy);
        self.wake.merge(&other.wake);
        self.spurious_wakeups += other.spurious_wakeups;
        // Merging gauges: keep the widest window any worker is holding open.
        self.batch_window_ns = self.batch_window_ns.max(other.batch_window_ns);
        // Depth gauges sum: the merged value is the pool's total backlog.
        self.queue_depth += other.queue_depth;
    }

    /// Total dispatches (solo + batched).
    pub fn dispatches(&self) -> u64 {
        self.batch_hist.iter().sum()
    }

    /// Rebuild a [`Metrics`] from this snapshot — the bridge that lets
    /// `ServeMetrics` read the live registry instead of a shutdown-only
    /// merge path.
    pub fn to_metrics(&self) -> Metrics {
        Metrics {
            requests: self.requests,
            seizures_detected: self.seizures,
            deadline_misses: self.deadline_misses,
            sim_energy_j: self.sim_energy_nj as f64 / 1e9,
            sim_active_s: self.sim_active_ns as f64 / 1e9,
            batch_hist: self.batch_hist.clone(),
            steals: self.steals,
            stolen_requests: self.stolen_requests,
            host: self.host.clone(),
        }
    }
}

/// One pool's registry: per-worker shards plus admission-side counters.
#[derive(Debug)]
pub struct TelemetryRegistry {
    platform: String,
    workload: String,
    started: Instant,
    req_seq: AtomicU64,
    shed_below_floor: AtomicU64,
    shed_queue_full: AtomicU64,
    shed_unknown_entry: AtomicU64,
    shed_shutting_down: AtomicU64,
    workers: Vec<Arc<WorkerShard>>,
    /// The pool's energy attribution ledger, installed once at pool start
    /// (after the atlas is built, which sizes the ledger's tables).
    ledger: OnceLock<Arc<EnergyLedger>>,
}

impl TelemetryRegistry {
    pub fn new(
        platform: impl Into<String>,
        workload: impl Into<String>,
        workers: usize,
    ) -> TelemetryRegistry {
        TelemetryRegistry {
            platform: platform.into(),
            workload: workload.into(),
            started: Instant::now(),
            req_seq: AtomicU64::new(0),
            shed_below_floor: AtomicU64::new(0),
            shed_queue_full: AtomicU64::new(0),
            shed_unknown_entry: AtomicU64::new(0),
            shed_shutting_down: AtomicU64::new(0),
            workers: (0..workers).map(|_| Arc::new(WorkerShard::default())).collect(),
            ledger: OnceLock::new(),
        }
    }

    /// Install the pool's energy attribution ledger. Pools call this once
    /// at startup, after the atlas has sized the ledger's tables; a second
    /// install is ignored (the first tables keep accumulating).
    pub fn install_ledger(&self, ledger: Arc<EnergyLedger>) {
        let _ = self.ledger.set(ledger);
    }

    /// The installed ledger, if the pool attached one.
    pub fn ledger(&self) -> Option<&Arc<EnergyLedger>> {
        self.ledger.get()
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    pub fn workload(&self) -> &str {
        &self.workload
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// The shard worker `i` records into (shared, cheap to clone).
    pub fn worker(&self, i: usize) -> Arc<WorkerShard> {
        self.workers[i].clone()
    }

    /// Allocate the next request id (1-based, threaded through traces).
    pub fn next_request_id(&self) -> u64 {
        // ordering: fetch_add is atomic regardless of ordering, so every
        // caller still gets a unique id; ids carry no payload protocol.
        self.req_seq.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Count one admission-side shed, keyed by the typed rejection. Both
    /// floor variants fold into the `below_floor` counter, matching the
    /// `ServeMetrics` shed taxonomy.
    pub fn record_shed(&self, reason: &Rejection) {
        let counter = match reason {
            Rejection::BelowFloor { .. } | Rejection::BelowEnergyFloor { .. } => {
                &self.shed_below_floor
            }
            Rejection::QueueFull { .. } => &self.shed_queue_full,
            Rejection::UnknownEntry { .. } => &self.shed_unknown_entry,
            Rejection::ShuttingDown => &self.shed_shutting_down,
        };
        // ordering: relaxed monotone counter, same contract as WorkerShard.
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    pub fn snapshot(&self) -> RegistrySnapshot {
        RegistrySnapshot {
            platform: self.platform.clone(),
            workload: self.workload.clone(),
            uptime: self.started.elapsed(),
            // ordering: relaxed statistical snapshot reads, same contract
            // as `WorkerShard::snapshot`.
            shed_below_floor: self.shed_below_floor.load(Ordering::Relaxed),
            shed_queue_full: self.shed_queue_full.load(Ordering::Relaxed),
            shed_unknown_entry: self.shed_unknown_entry.load(Ordering::Relaxed),
            shed_shutting_down: self.shed_shutting_down.load(Ordering::Relaxed),
            workers: self.workers.iter().map(|w| w.snapshot()).collect(),
            ledger: self.ledger.get().map(|l| l.snapshot()),
        }
    }
}

/// Plain-data copy of a whole registry at one instant.
#[derive(Debug, Clone, Default)]
pub struct RegistrySnapshot {
    pub platform: String,
    pub workload: String,
    pub uptime: Duration,
    pub shed_below_floor: u64,
    pub shed_queue_full: u64,
    pub shed_unknown_entry: u64,
    pub shed_shutting_down: u64,
    pub workers: Vec<WorkerSnapshot>,
    /// The energy attribution ledger, when the pool installed one.
    pub ledger: Option<LedgerSnapshot>,
}

impl RegistrySnapshot {
    /// All worker shards merged into one.
    pub fn totals(&self) -> WorkerSnapshot {
        let mut t = WorkerSnapshot::default();
        for w in &self.workers {
            t.merge(w);
        }
        t
    }

    pub fn total_shed(&self) -> u64 {
        self.shed_below_floor
            + self.shed_queue_full
            + self.shed_unknown_entry
            + self.shed_shutting_down
    }

    /// Worst atlas drift ratio across every entry and knot (0 when no
    /// ledger is installed or nothing has been sampled yet) — the scalar
    /// the SLO engine's `atlas_drift` objective judges.
    pub fn drift_ratio(&self) -> f64 {
        match &self.ledger {
            Some(l) => l.max_drift(),
            None => 0.0,
        }
    }

    /// Compact JSON summary (attached to bench artifacts).
    pub fn to_json(&self) -> Json {
        let t = self.totals();
        let mut shed = JsonObj::new();
        shed.insert("below_floor", self.shed_below_floor);
        shed.insert("queue_full", self.shed_queue_full);
        shed.insert("unknown_entry", self.shed_unknown_entry);
        shed.insert("shutting_down", self.shed_shutting_down);
        let mut o = JsonObj::new();
        o.insert("platform", self.platform.as_str());
        o.insert("workload", self.workload.as_str());
        o.insert("uptime_s", self.uptime.as_secs_f64());
        o.insert("requests", t.requests);
        o.insert("deadline_misses", t.deadline_misses);
        o.insert("shed", shed);
        o.insert("steals", t.steals);
        o.insert("stolen_requests", t.stolen_requests);
        o.insert("dispatches", t.dispatches());
        o.insert(
            "batch_hist",
            Json::Arr(t.batch_hist.iter().map(|&n| Json::from(n)).collect()),
        );
        o.insert("sim_energy_uj", t.sim_energy_nj as f64 / 1e3);
        o.insert("energy_per_request_uj", t.energy.mean() / 1e3);
        o.insert("host_p50_us", t.host.percentile(50.0) as f64 / 1e3);
        o.insert("host_p99_us", t.host.percentile(99.0) as f64 / 1e3);
        o.insert("queue_wait_p99_us", t.queue_wait.percentile(99.0) as f64 / 1e3);
        o.insert("dispatch_p99_us", t.dispatch.percentile(99.0) as f64 / 1e3);
        o.insert("wakeup_p99_us", t.wake.percentile(99.0) as f64 / 1e3);
        o.insert("spurious_wakeups", t.spurious_wakeups);
        o.insert("batch_window_us", t.batch_window_ns as f64 / 1e3);
        o.insert("queue_depth", t.queue_depth);
        o.insert("atlas_drift_ratio", self.drift_ratio());
        if let Some(ledger) = &self.ledger {
            o.insert("ledger", ledger.to_json());
        }
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{Energy, Time};

    #[test]
    fn shard_snapshot_round_trips_into_metrics() {
        let shard = WorkerShard::default();
        shard.record(true, true, 500e-6, 0.05, Duration::from_millis(2));
        shard.record(false, false, 400e-6, 0.20, Duration::from_millis(4));
        shard.record_batch(2);
        shard.record_steal(2);
        shard.record_queue_wait(Duration::from_micros(30));
        shard.record_head_laxity(Duration::from_millis(90));
        shard.record_dispatch_time(Duration::from_millis(3));
        shard.record_wakeup(Duration::from_micros(12));
        shard.record_spurious_wakeup();
        shard.set_batch_window(Duration::from_micros(250));
        let snap = shard.snapshot();
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.wake.count(), 1);
        assert_eq!(snap.spurious_wakeups, 1);
        assert_eq!(snap.batch_window_ns, 250_000);
        assert_eq!(snap.batch_hist, vec![0, 1]);
        assert_eq!(snap.dispatches(), 1);
        let m = snap.to_metrics();
        assert_eq!(m.requests, 2);
        assert_eq!(m.seizures_detected, 1);
        assert_eq!(m.deadline_misses, 1);
        assert!((m.sim_energy_j - 900e-6).abs() < 1e-9);
        assert_eq!(m.steals, 1);
        assert_eq!(m.stolen_requests, 2);
        assert_eq!(m.host_latency_percentile(0.0), Duration::from_millis(2));
        assert_eq!(m.host_latency_percentile(100.0), Duration::from_millis(4));
    }

    #[test]
    fn registry_sheds_and_totals() {
        let reg = TelemetryRegistry::new("heeptimize", "tsd-core", 2);
        assert_eq!(reg.worker_count(), 2);
        assert_eq!(reg.next_request_id(), 1);
        assert_eq!(reg.next_request_id(), 2);
        reg.record_shed(&Rejection::BelowFloor {
            requested: Time::from_ms(1.0),
            floor: Time::from_ms(2.0),
        });
        reg.record_shed(&Rejection::BelowEnergyFloor {
            requested: Energy::from_uj(1.0),
            floor: Energy::from_uj(2.0),
        });
        reg.record_shed(&Rejection::QueueFull { capacity: 4 });
        reg.record_shed(&Rejection::UnknownEntry {
            platform: "x".into(),
            workload: "y".into(),
        });
        reg.record_shed(&Rejection::ShuttingDown);
        reg.worker(0).record(false, true, 1e-6, 0.01, Duration::from_millis(1));
        reg.worker(1).record(false, true, 1e-6, 0.01, Duration::from_millis(3));
        reg.worker(0).set_batch_window(Duration::from_micros(100));
        reg.worker(1).set_batch_window(Duration::from_micros(400));
        let snap = reg.snapshot();
        assert_eq!(snap.shed_below_floor, 2);
        assert_eq!(snap.shed_queue_full, 1);
        assert_eq!(snap.shed_unknown_entry, 1);
        assert_eq!(snap.shed_shutting_down, 1);
        assert_eq!(snap.total_shed(), 5);
        let t = snap.totals();
        assert_eq!(t.requests, 2);
        assert_eq!(t.host.count(), 2);
        assert_eq!(t.host.percentile(100.0), 3_000_000);
        // The fill-window gauge merges as a max across workers.
        assert_eq!(t.batch_window_ns, 400_000);
        let j = snap.to_json();
        assert_eq!(j.get("requests").and_then(|v| v.as_u64()), Some(2));
        let shed = j.get("shed").expect("shed key");
        assert_eq!(shed.get("below_floor").and_then(|v| v.as_u64()), Some(2));
    }

    #[test]
    fn queue_depth_gauge_sums_and_ledger_installs_once() {
        use crate::telemetry::ledger::{EnergyLedger, LedgerEntrySpec};
        let reg = TelemetryRegistry::new("heeptimize", "tsd-core", 2);
        reg.worker(0).set_queue_depth(3);
        reg.worker(1).set_queue_depth(5);
        reg.worker(1).set_queue_depth(4); // last write wins per worker
        assert!(reg.ledger().is_none());
        assert_eq!(reg.snapshot().drift_ratio(), 0.0);
        let spec = LedgerEntrySpec {
            platform: "heeptimize".into(),
            workload: "tsd-core".into(),
            pe_labels: vec!["cpu".into()],
            vf_labels: vec!["0.80V@170MHz".into()],
            knot_deadlines: vec![Time::from_ms(50.0)],
        };
        reg.install_ledger(EnergyLedger::new(2, std::slice::from_ref(&spec)));
        // A second install is ignored: the first tables keep accumulating.
        reg.install_ledger(EnergyLedger::new(2, &[spec.clone(), spec]));
        let installed = reg.ledger().expect("ledger installed");
        assert_eq!(installed.entry_count(), 1);
        let snap = reg.snapshot();
        assert_eq!(snap.totals().queue_depth, 7);
        let ledger = snap.ledger.as_ref().expect("snapshot carries the ledger");
        assert_eq!(ledger.entries.len(), 1);
        let j = snap.to_json();
        assert_eq!(j.get("queue_depth").and_then(|v| v.as_u64()), Some(7));
        assert!(j.get("ledger").is_some(), "ledger rides in to_json");
        assert_eq!(j.get("atlas_drift_ratio").and_then(|v| v.as_f64()), Some(0.0));
    }

    #[test]
    fn oversized_batches_clamp_to_last_slot() {
        let shard = WorkerShard::default();
        shard.record_batch(BATCH_SLOTS + 10);
        shard.record_batch(0); // treated as solo
        let snap = shard.snapshot();
        assert_eq!(snap.batch_hist.len(), BATCH_SLOTS);
        assert_eq!(snap.batch_hist[BATCH_SLOTS - 1], 1);
        assert_eq!(snap.batch_hist[0], 1);
    }

    /// The SLO engine's input arithmetic: successive snapshots taken while
    /// workers record concurrently must yield monotone, underflow-safe
    /// window deltas — every counter non-decreasing across snapshots, and
    /// saturating subtraction of any earlier snapshot from any later one
    /// never wrapping.
    #[test]
    fn snapshot_deltas_stay_monotone_under_concurrent_recording() {
        let reg = std::sync::Arc::new(TelemetryRegistry::new("heeptimize", "tsd-core", 4));
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let workers: Vec<_> = (0..4)
            .map(|w| {
                let reg = reg.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut n = 0u64;
                    // ordering: plain shutdown flag; no data is published
                    // through it, so relaxed polling is enough.
                    while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                        reg.worker(w).record(
                            n % 7 == 0,
                            n % 5 != 0,
                            1e-6,
                            1e-5,
                            Duration::from_micros(50 + n % 300),
                        );
                        reg.worker(w).record_dispatch_time(Duration::from_micros(10 + n % 90));
                        if n % 11 == 0 {
                            reg.record_shed(&Rejection::QueueFull { capacity: 4 });
                        }
                        n += 1;
                    }
                })
            })
            .collect();

        // Under Miri every snapshot/sleep round-trip is orders of magnitude
        // slower, so take far fewer snapshots there (requires
        // `-Zmiri-disable-isolation` for `thread::sleep` / `Instant`).
        const SNAPS: usize = if cfg!(miri) { 6 } else { 32 };
        let mut snaps = Vec::with_capacity(SNAPS);
        for _ in 0..SNAPS {
            snaps.push(reg.snapshot());
            std::thread::sleep(Duration::from_millis(1));
        }
        // ordering: relaxed shutdown flag, see the recorder loop above.
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        for h in workers {
            h.join().expect("recorder thread panicked");
        }

        for pair in snaps.windows(2) {
            let (a, b) = (&pair[0], &pair[1]);
            assert!(b.uptime >= a.uptime, "uptime went backwards");
            let (ta, tb) = (a.totals(), b.totals());
            assert!(tb.requests >= ta.requests, "requests regressed");
            assert!(tb.deadline_misses >= ta.deadline_misses, "misses regressed");
            assert!(tb.sim_energy_nj >= ta.sim_energy_nj, "energy regressed");
            assert!(b.total_shed() >= a.total_shed(), "shed regressed");
            assert!(tb.dispatch.count() >= ta.dispatch.count(), "dispatch count regressed");
            // The forward delta is exactly what plain subtraction gives;
            // the reversed (mis-ordered) delta must clamp to zero, not wrap.
            assert_eq!(tb.requests.saturating_sub(ta.requests), tb.requests - ta.requests);
            assert_eq!(ta.requests.saturating_sub(tb.requests).min(1), 0);
            let d = tb.dispatch.delta(&ta.dispatch);
            assert_eq!(d.count(), tb.dispatch.count() - ta.dispatch.count());
            assert_eq!(ta.dispatch.delta(&tb.dispatch).count(), 0, "reversed delta must clamp");
        }
        // And per worker too: a torn per-shard view would show up here.
        for pair in snaps.windows(2) {
            for (wa, wb) in pair[0].workers.iter().zip(&pair[1].workers) {
                assert!(wb.requests >= wa.requests);
                assert!(wb.deadline_misses >= wa.deadline_misses);
                assert!(wb.dispatch.count() >= wa.dispatch.count());
            }
        }
    }
}
