//! The anomaly-triggered flight recorder: bounded, rate-limited post-mortem
//! bundles.
//!
//! Counters tell you *that* the pool degraded; this module captures *what it
//! looked like* at that moment. When the SLO engine sees a `Critical`
//! transition or a burn-rate spike (see [`crate::telemetry::slo`]), it hands
//! the recorder the evaluation that fired, and the recorder atomically
//! writes one JSON bundle — the full registry snapshot, the drained
//! trace-ring tail, and the firing SLO status — into a bounded directory.
//!
//! Two guards keep a sustained storm from producing thousands of files:
//!
//! * **rate limit** — at most one bundle per `min_interval` (a storm that
//!   lasts minutes produces a handful of bundles, each a fresh snapshot);
//! * **bounded directory** — after every write the oldest bundles beyond
//!   `max_bundles` are pruned, so the post-mortem dir never grows without
//!   bound.
//!
//! Bundles are written tmp-then-rename so a reader (or a crash mid-write)
//! never sees a torn file.

use crate::telemetry::registry::RegistrySnapshot;
use crate::telemetry::trace::TraceEvent;
use crate::util::error::{anyhow, Result};
use crate::util::json::{Json, JsonObj};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// Flight-recorder knobs (`serve --postmortem-*`).
#[derive(Debug, Clone)]
pub struct FlightConfig {
    /// Directory bundles are written into (created if missing).
    pub dir: PathBuf,
    /// Oldest bundles beyond this count are pruned after each write.
    pub max_bundles: usize,
    /// Minimum spacing between bundles; triggers inside the window are
    /// counted ([`FlightRecorder::suppressed`]) but write nothing.
    pub min_interval: Duration,
    /// At most this many trace events (the newest) go into one bundle.
    pub max_trace_events: usize,
}

impl Default for FlightConfig {
    fn default() -> Self {
        FlightConfig {
            dir: PathBuf::from("postmortems"),
            max_bundles: 8,
            min_interval: Duration::from_secs(30),
            max_trace_events: 4096,
        }
    }
}

struct FlightState {
    last_write: Option<Instant>,
    seq: u64,
}

/// Always-on post-mortem bundle writer. All methods take `&self`; the write
/// path serializes under one mutex (it runs off the serving hot path).
pub struct FlightRecorder {
    cfg: FlightConfig,
    state: Mutex<FlightState>,
    written: AtomicU64,
    suppressed: AtomicU64,
}

impl FlightRecorder {
    /// Create the bundle directory and the recorder.
    pub fn new(cfg: FlightConfig) -> Result<FlightRecorder> {
        std::fs::create_dir_all(&cfg.dir)
            .map_err(|e| anyhow!("postmortem dir `{}`: {e}", cfg.dir.display()))?;
        Ok(FlightRecorder {
            cfg,
            state: Mutex::new(FlightState { last_write: None, seq: 0 }),
            written: AtomicU64::new(0),
            suppressed: AtomicU64::new(0),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.cfg.dir
    }

    /// Bundles written so far.
    pub fn bundles_written(&self) -> u64 {
        // ordering: relaxed monotone diagnostic counter, no payload.
        self.written.load(Ordering::Relaxed)
    }

    /// Triggers swallowed by the rate limiter so far.
    pub fn suppressed(&self) -> u64 {
        // ordering: relaxed monotone diagnostic counter, no payload.
        self.suppressed.load(Ordering::Relaxed)
    }

    /// Write one post-mortem bundle, unless the rate limiter is in its
    /// holdoff window. Returns the bundle path when one was written. Write
    /// errors are logged and swallowed — the recorder must never take the
    /// pool down with it.
    pub fn record(
        &self,
        trigger: &str,
        slo: Json,
        snap: &RegistrySnapshot,
        trace: &[TraceEvent],
    ) -> Option<PathBuf> {
        let seq = {
            // lint: allow(no-unwrap): poisoned state lock means a panic
            // mid-bundle; propagating the panic is the correct response.
            let mut st = self.state.lock().expect("flight state lock poisoned");
            if let Some(last) = st.last_write {
                if last.elapsed() < self.cfg.min_interval {
                    // ordering: relaxed counter, see `suppressed`.
                    self.suppressed.fetch_add(1, Ordering::Relaxed);
                    return None;
                }
            }
            st.last_write = Some(Instant::now());
            st.seq += 1;
            st.seq
        };
        let bundle = self.bundle_json(trigger, slo, snap, trace);
        let wall_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        let name = format!("postmortem-{wall_ms}-{seq:04}.json");
        let path = self.cfg.dir.join(&name);
        let tmp = self.cfg.dir.join(format!(".tmp-{name}"));
        let write = std::fs::write(&tmp, bundle.to_pretty())
            .and_then(|()| std::fs::rename(&tmp, &path));
        if let Err(e) = write {
            crate::log_warn!("flight recorder: writing {}: {e}", path.display());
            let _ = std::fs::remove_file(&tmp);
            return None;
        }
        // ordering: relaxed counter, see `bundles_written`.
        self.written.fetch_add(1, Ordering::Relaxed);
        crate::log_info!("flight recorder: {trigger} -> {}", path.display());
        self.prune();
        Some(path)
    }

    fn bundle_json(
        &self,
        trigger: &str,
        slo: Json,
        snap: &RegistrySnapshot,
        trace: &[TraceEvent],
    ) -> Json {
        let skipped = trace.len().saturating_sub(self.cfg.max_trace_events);
        let events: Vec<Json> = trace[skipped..]
            .iter()
            .map(|e| {
                let mut o = JsonObj::new();
                o.insert("seq", e.seq);
                o.insert("name", e.kind.name());
                o.insert("worker", u64::from(e.worker));
                o.insert("ts_ns", e.ts_ns);
                o.insert("req", e.req);
                o.insert("arg", e.arg);
                Json::Obj(o)
            })
            .collect();
        let wall_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        let mut o = JsonObj::new();
        o.insert("schema", "medea.postmortem.v1");
        o.insert("trigger", trigger);
        o.insert("wall_unix_ms", wall_ms);
        o.insert("uptime_s", snap.uptime.as_secs_f64());
        o.insert("slo", slo);
        // `RegistrySnapshot::to_json` embeds the energy ledger (per-PE
        // energy/busy tables and per-knot drift EWMAs) when one is
        // installed, so a drift-triggered bundle carries the attribution
        // evidence with it.
        o.insert("registry", snap.to_json());
        o.insert("trace_events_skipped", skipped);
        o.insert("trace", Json::Arr(events));
        Json::Obj(o)
    }

    /// Drop the oldest bundles beyond `max_bundles` (name order is write
    /// order: names embed wall-clock millis then a sequence number).
    fn prune(&self) {
        let Ok(entries) = std::fs::read_dir(&self.cfg.dir) else { return };
        let mut bundles: Vec<PathBuf> = entries
            .flatten()
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("postmortem-") && n.ends_with(".json"))
            })
            .collect();
        if bundles.len() <= self.cfg.max_bundles.max(1) {
            return;
        }
        bundles.sort();
        let excess = bundles.len() - self.cfg.max_bundles.max(1);
        for stale in &bundles[..excess] {
            let _ = std::fs::remove_file(stale);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::registry::TelemetryRegistry;
    use crate::telemetry::trace::{TraceEventKind, TraceRing};
    use crate::util::json::parse;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("medea-flight-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample_snapshot() -> RegistrySnapshot {
        let reg = TelemetryRegistry::new("heeptimize", "tsd-core", 1);
        reg.worker(0).record(false, false, 100e-6, 0.01, Duration::from_millis(3));
        reg.snapshot()
    }

    #[test]
    fn bundle_round_trips_and_rate_limits() {
        let dir = temp_dir("roundtrip");
        let rec = FlightRecorder::new(FlightConfig {
            dir: dir.clone(),
            min_interval: Duration::from_secs(3600),
            ..FlightConfig::default()
        })
        .expect("recorder");
        let ring = TraceRing::new(64);
        ring.record(TraceEventKind::Enqueue, 0, 1, 42);
        ring.record(TraceEventKind::Retire, 0, 1, 0);
        let snap = sample_snapshot();
        let path = rec
            .record(
                "deadline critical (burn 9.00x/3.00x)",
                Json::from("evaluation"),
                &snap,
                &ring.events(),
            )
            .expect("first bundle written");
        assert!(path.exists());
        assert_eq!(rec.bundles_written(), 1);

        // Inside the holdoff window: suppressed, not written.
        assert!(rec.record("again", Json::from("x"), &snap, &[]).is_none());
        assert_eq!(rec.bundles_written(), 1);
        assert_eq!(rec.suppressed(), 1);

        let doc = parse(&std::fs::read_to_string(&path).expect("read bundle")).expect("json");
        assert_eq!(doc.get("schema").and_then(|v| v.as_str()), Some("medea.postmortem.v1"));
        assert_eq!(
            doc.get("trigger").and_then(|v| v.as_str()),
            Some("deadline critical (burn 9.00x/3.00x)")
        );
        assert_eq!(doc.get("slo").and_then(|v| v.as_str()), Some("evaluation"));
        let registry = doc.get("registry").expect("registry snapshot embedded");
        assert_eq!(registry.get("requests").and_then(|v| v.as_u64()), Some(1));
        let trace = doc.get("trace").and_then(|v| v.as_arr()).expect("trace array");
        assert_eq!(trace.len(), 2);
        assert_eq!(trace[0].get("name").and_then(|v| v.as_str()), Some("enqueue"));
        assert_eq!(trace[0].get("arg").and_then(|v| v.as_u64()), Some(42));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bundle_registry_carries_the_ledger() {
        use crate::telemetry::ledger::{EnergyLedger, LedgerEntrySpec};
        use crate::util::units::Time;
        let dir = temp_dir("ledger");
        let rec = FlightRecorder::new(FlightConfig {
            dir: dir.clone(),
            min_interval: Duration::ZERO,
            ..FlightConfig::default()
        })
        .expect("recorder");
        let reg = TelemetryRegistry::new("heeptimize", "tsd-core", 1);
        reg.install_ledger(EnergyLedger::new(1, &[LedgerEntrySpec {
            platform: "heeptimize".into(),
            workload: "tsd-core".into(),
            pe_labels: vec!["cpu".into()],
            vf_labels: vec!["0.90V@250MHz".into()],
            knot_deadlines: vec![Time::from_ms(50.0)],
        }]));
        let path = rec
            .record("atlas_drift critical", Json::from("x"), &reg.snapshot(), &[])
            .expect("bundle");
        let doc = parse(&std::fs::read_to_string(&path).expect("read")).expect("json");
        let ledger = doc
            .get("registry")
            .and_then(|r| r.get("ledger"))
            .expect("postmortem bundle must embed the ledger snapshot");
        let entries = ledger.get("entries").and_then(|v| v.as_arr()).expect("entries");
        assert_eq!(entries.len(), 1);
        assert_eq!(
            entries[0].get("platform").and_then(|v| v.as_str()),
            Some("heeptimize")
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn directory_stays_bounded() {
        let dir = temp_dir("bounded");
        let rec = FlightRecorder::new(FlightConfig {
            dir: dir.clone(),
            max_bundles: 3,
            min_interval: Duration::ZERO,
            ..FlightConfig::default()
        })
        .expect("recorder");
        let snap = sample_snapshot();
        for i in 0..7 {
            assert!(
                rec.record(&format!("storm {i}"), Json::from(i as u64), &snap, &[]).is_some(),
                "bundle {i} suppressed unexpectedly"
            );
        }
        assert_eq!(rec.bundles_written(), 7);
        let left = std::fs::read_dir(&dir)
            .expect("read dir")
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().starts_with("postmortem-"))
            .count();
        assert_eq!(left, 3, "prune must keep only max_bundles files");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trace_tail_is_capped() {
        let dir = temp_dir("cap");
        let rec = FlightRecorder::new(FlightConfig {
            dir: dir.clone(),
            max_trace_events: 4,
            min_interval: Duration::ZERO,
            ..FlightConfig::default()
        })
        .expect("recorder");
        let ring = TraceRing::new(64);
        for i in 0..10u64 {
            ring.record(TraceEventKind::Dispatch, 0, i, 0);
        }
        let path = rec
            .record("cap", Json::from("x"), &sample_snapshot(), &ring.events())
            .expect("bundle");
        let doc = parse(&std::fs::read_to_string(&path).expect("read")).expect("json");
        let trace = doc.get("trace").and_then(|v| v.as_arr()).expect("trace");
        assert_eq!(trace.len(), 4);
        // The *newest* events survive the cap.
        assert_eq!(trace[3].get("req").and_then(|v| v.as_u64()), Some(9));
        assert_eq!(doc.get("trace_events_skipped").and_then(|v| v.as_u64()), Some(6));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
