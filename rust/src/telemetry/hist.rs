//! Fixed-bucket log-linear histograms for hot-path telemetry.
//!
//! Values `0..32` get exact unit buckets; above that, every power-of-two
//! octave splits into 16 linear sub-buckets, so the relative quantization
//! error stays under ~6% all the way to `2^43 − 1` (about 2.4 hours when the
//! unit is nanoseconds) with a fixed 640-slot table and no allocation on the
//! record path.
//!
//! Two forms share the bucket layout: [`AtomicHist`] is the wait-free
//! per-worker recording surface (plain `fetch_add`/`fetch_min`/`fetch_max`,
//! never a lock), and [`HistData`] is its mergeable snapshot — also the
//! store behind [`crate::coordinator::Metrics`] percentiles, so live scrapes
//! and the shutdown aggregate run the same arithmetic over the same buckets.

use std::sync::atomic::{AtomicU64, Ordering};

/// Linear sub-buckets per octave; values below `2 * SUB` are exact.
const SUB: u64 = 16;

/// Total bucket count; the last bucket absorbs everything ≥ `2^43`.
pub const NBUCKETS: usize = 640;

/// Map a value to its bucket index (monotone non-decreasing in `v`).
pub fn bucket_index(v: u64) -> usize {
    if v < 2 * SUB {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros();
    let shift = msb - 4;
    let sub = (v >> shift) - SUB;
    ((u64::from(shift) + 1) * SUB + sub).min(NBUCKETS as u64 - 1) as usize
}

/// Inclusive upper bound of bucket `idx` (the value a percentile reports).
pub fn bucket_upper(idx: usize) -> u64 {
    if idx < (2 * SUB) as usize {
        idx as u64
    } else {
        let shift = (idx as u64 / SUB - 1) as u32;
        let sub = idx as u64 % SUB;
        ((SUB + sub + 1) << shift) - 1
    }
}

/// A plain (single-threaded) histogram: the snapshot/merge/query form.
///
/// `counts` stays empty until the first sample so unused histograms inside a
/// [`crate::coordinator::Metrics`] value cost nothing.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HistData {
    counts: Vec<u64>,
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl HistData {
    pub fn record(&mut self, v: u64) {
        if self.counts.is_empty() {
            self.counts = vec![0; NBUCKETS];
        }
        self.counts[bucket_index(v)] += 1;
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
    }

    /// Assemble from already-accumulated parts (atomic snapshot path).
    /// Callers guarantee `min <= max` whenever `count > 0`.
    pub(crate) fn from_parts(counts: Vec<u64>, count: u64, sum: u64, min: u64, max: u64) -> Self {
        HistData { counts, count, sum, min, max }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact sum of all recorded values (saturating).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Raw per-bucket counts (empty slice until the first sample); index
    /// with [`bucket_upper`] for bounds.
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn merge(&mut self, other: &HistData) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = other.clone();
            return;
        }
        for (slot, &n) in self.counts.iter_mut().zip(&other.counts) {
            *slot += n;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Per-bucket saturating difference against an `earlier` snapshot of the
    /// same stream — the windowed-histogram primitive behind the SLO
    /// engine's burn-rate math. Counter skew from relaxed-ordering atomic
    /// snapshots cannot underflow: every field saturates at zero. `min`/`max`
    /// are rebuilt from the surviving buckets (bucket bounds, not exact
    /// sample values), which keeps percentile clamping within the bucket
    /// quantization error.
    pub fn delta(&self, earlier: &HistData) -> HistData {
        let count = self.count.saturating_sub(earlier.count);
        if count == 0 {
            return HistData::default();
        }
        let mut counts = vec![0u64; NBUCKETS];
        for (i, slot) in counts.iter_mut().enumerate() {
            let later = self.counts.get(i).copied().unwrap_or(0);
            let before = earlier.counts.get(i).copied().unwrap_or(0);
            *slot = later.saturating_sub(before);
        }
        let first = counts.iter().position(|&c| c > 0);
        let last = counts.iter().rposition(|&c| c > 0);
        let (min, max) = match (first, last) {
            // Bucket lower bound for min, upper bound for max: the true
            // window extrema lie inside these buckets.
            (Some(f), Some(l)) => {
                let lower = if f == 0 { 0 } else { bucket_upper(f - 1) + 1 };
                (lower, bucket_upper(l))
            }
            // Skewed snapshot pair: count moved but no bucket did.
            _ => (0, 0),
        };
        HistData {
            counts,
            count,
            sum: self.sum.saturating_sub(earlier.sum),
            min,
            max,
        }
    }

    /// Percentile (`q` in `[0, 100]`): the upper bound of the bucket holding
    /// the rank-`ceil(q/100 · count)` sample, clamped into `[min, max]` so
    /// p0/p100 and single-sample distributions are exact. Zero when empty.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        if q <= 0.0 {
            return self.min;
        }
        if q >= 100.0 {
            return self.max;
        }
        let target = ((q / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return bucket_upper(i).clamp(self.min, self.max);
            }
        }
        self.max
    }
}

/// The wait-free recording surface: one per worker per tracked distribution.
///
/// Every operation is a relaxed atomic RMW on a fixed-size table — the hot
/// path never locks, allocates, or contends beyond cacheline traffic.
#[derive(Debug)]
pub struct AtomicHist {
    counts: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for AtomicHist {
    fn default() -> Self {
        Self::new()
    }
}

impl AtomicHist {
    pub fn new() -> AtomicHist {
        AtomicHist {
            counts: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    pub fn record(&self, v: u64) {
        // ordering: each field is an independent monotone accumulator and
        // readers (`snapshot`) are explicitly tolerant of straddled,
        // non-linearizable views, so relaxed RMWs are sufficient — there
        // is no cross-field invariant a stronger ordering would protect.
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Copy into a queryable [`HistData`]. Concurrent records may straddle
    /// the field reads, but every field is monotone, so the result is a
    /// valid histogram of a prefix-plus-some of the stream (normalized so
    /// `min ≤ max` even mid-first-record).
    pub fn snapshot(&self) -> HistData {
        // ordering: relaxed statistical reads, mirroring `record` — see
        // the doc comment above for why a straddled view is acceptable.
        let count = self.count.load(Ordering::Relaxed);
        if count == 0 {
            return HistData::default();
        }
        // ordering: relaxed snapshot reads, see above.
        let counts: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let sum = self.sum.load(Ordering::Relaxed);
        let max = self.max.load(Ordering::Relaxed);
        let min = self.min.load(Ordering::Relaxed).min(max);
        HistData::from_parts(counts, count, sum, min, max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_mapping_is_monotone_and_consistent() {
        let mut prev = 0usize;
        for v in (0..4096u64).chain((12..44).map(|p| (1u64 << p) - 1)) {
            let idx = bucket_index(v);
            assert!(idx >= prev, "index regressed at v={v}");
            assert!(v <= bucket_upper(idx), "v={v} above its bucket upper");
            prev = idx;
        }
        // Exact region: identity below 32.
        for v in 0..32u64 {
            assert_eq!(bucket_index(v), v as usize);
            assert_eq!(bucket_upper(v as usize), v);
        }
        // Top bucket absorbs the extreme.
        assert_eq!(bucket_index(u64::MAX), NBUCKETS - 1);
        assert_eq!(bucket_upper(NBUCKETS - 1), (1u64 << 43) - 1);
    }

    #[test]
    fn relative_error_is_bounded() {
        for v in [100u64, 999, 5_000, 123_456, 9_999_999, 4_000_000_000] {
            let upper = bucket_upper(bucket_index(v));
            assert!(upper >= v);
            let err = (upper - v) as f64 / v as f64;
            assert!(err < 1.0 / 16.0, "error {err} too large at v={v}");
        }
    }

    #[test]
    fn percentiles_clamp_to_observed_range() {
        let mut h = HistData::default();
        h.record(7_000_000);
        // Single sample: every percentile is that sample, exactly.
        assert_eq!(h.percentile(0.0), 7_000_000);
        assert_eq!(h.percentile(50.0), 7_000_000);
        assert_eq!(h.percentile(99.0), 7_000_000);
        assert_eq!(h.percentile(100.0), 7_000_000);
        h.record(2_000_000);
        h.record(4_000_000);
        assert_eq!(h.percentile(0.0), 2_000_000);
        assert_eq!(h.percentile(100.0), 7_000_000);
        let p50 = h.percentile(50.0);
        assert!((4_000_000..=4_300_000).contains(&p50), "p50={p50}");
        // Monotone in q.
        let mut last = 0;
        for q in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            let p = h.percentile(q);
            assert!(p >= last);
            last = p;
        }
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = HistData::default();
        assert!(h.is_empty());
        assert_eq!(h.percentile(50.0), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn merge_equals_combined_stream() {
        let mut a = HistData::default();
        let mut b = HistData::default();
        let mut whole = HistData::default();
        for i in 0..1000u64 {
            let v = 100 + i * 17;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            whole.record(v);
        }
        a.merge(&b);
        assert_eq!(a, whole);
        // Merge into empty clones the source.
        let mut fresh = HistData::default();
        fresh.merge(&whole);
        assert_eq!(fresh, whole);
    }

    /// Deterministic xorshift64* stream for the property tests (no rand
    /// dependency).
    fn xorshift(state: &mut u64) -> u64 {
        let mut x = *state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        *state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Property: for every in-range value stream and every quantile, the
    /// reported percentile `r` and the true ceil-rank sample `t` satisfy
    /// `t <= r <= bucket_upper(bucket_index(t))`, so the relative error is
    /// below 1/16 (exact below 32). Overflow values (>= 2^43) land in the
    /// absorbing top bucket, where only clamping and monotonicity hold.
    #[test]
    fn quantile_relative_error_bound_over_random_streams() {
        const OVERFLOW: u64 = 1 << 43;
        let quantiles = [1.0, 5.0, 25.0, 50.0, 75.0, 90.0, 99.0, 99.9];
        // Miri executes each recorded sample ~1000x slower; one seed and
        // shorter streams still exercise every bucket region.
        let seeds: &[u64] = if cfg!(miri) {
            &[3]
        } else {
            &[3, 77, 4242, 987_654_321]
        };
        let shapes: &[(usize, u64)] = if cfg!(miri) {
            &[(33, 31), (200, u64::MAX)]
        } else {
            &[(33, 31), (500, 100_000), (2000, u64::MAX)]
        };
        for &seed in seeds {
            let mut rng = seed;
            for &(len, spread) in shapes {
                let mut h = AtomicHist::new();
                let mut sorted: Vec<u64> = (0..len)
                    .map(|_| {
                        let raw = xorshift(&mut rng);
                        // Mix exact-region, mid-range, and overflow values.
                        let v = raw % spread.max(1);
                        h.record(v);
                        v
                    })
                    .collect();
                sorted.sort_unstable();
                let snap = h.snapshot();
                assert_eq!(snap.count(), len as u64);
                let mut prev = 0u64;
                for q in quantiles {
                    let r = snap.percentile(q);
                    assert!(r >= prev, "percentile not monotone in q at q={q}");
                    prev = r;
                    assert!(r >= snap.min() && r <= snap.max(), "q={q} outside range");
                    let rank = ((q / 100.0) * len as f64).ceil().max(1.0) as usize;
                    let t = sorted[rank - 1];
                    if t >= OVERFLOW {
                        // Absorbing bucket: no error bound, clamp only.
                        continue;
                    }
                    assert!(r >= t, "seed {seed} q={q}: reported {r} < true {t}");
                    let upper = bucket_upper(bucket_index(t));
                    assert!(
                        r <= upper.max(snap.min()),
                        "seed {seed} q={q}: reported {r} above bucket bound {upper}"
                    );
                    if t > 0 {
                        let err = (r.saturating_sub(t)) as f64 / t as f64;
                        assert!(
                            err < 1.0 / 16.0,
                            "seed {seed} q={q}: relative error {err} at t={t}"
                        );
                    } else {
                        assert_eq!(r, 0, "exact region must be exact at t=0");
                    }
                }
            }
        }
    }

    #[test]
    fn overflow_values_clamp_and_stay_monotone() {
        let mut h = HistData::default();
        h.record(5);
        h.record((1 << 43) + 12345);
        h.record(u64::MAX);
        assert_eq!(h.min(), 5);
        assert_eq!(h.max(), u64::MAX);
        let mut prev = 0u64;
        for q in [0.0, 25.0, 50.0, 75.0, 99.0, 100.0] {
            let p = h.percentile(q);
            assert!(p >= prev, "not monotone at q={q}");
            assert!(p >= h.min() && p <= h.max(), "q={q} escaped [min, max]");
            prev = p;
        }
        assert_eq!(h.percentile(100.0), u64::MAX);
    }

    #[test]
    fn delta_recovers_the_suffix_stream() {
        // Exact-region suffix: bucket width 1, so delta min/max/counts are
        // exactly the suffix histogram's.
        let mut earlier = HistData::default();
        for v in [3u64, 9, 14, 30] {
            earlier.record(v);
        }
        let mut later = earlier.clone();
        let mut suffix = HistData::default();
        for v in [6u64, 6, 21, 31, 2] {
            later.record(v);
            suffix.record(v);
        }
        let d = later.delta(&earlier);
        assert_eq!(d.count(), suffix.count());
        assert_eq!(d.sum(), suffix.sum());
        assert_eq!(d.min(), suffix.min());
        assert_eq!(d.max(), suffix.max());
        assert_eq!(d.bucket_counts(), suffix.bucket_counts());
        // Wide-range suffix: counts still exact, extrema within one bucket.
        let mut later2 = later.clone();
        later2.record(1_000_000);
        later2.record(40);
        let d2 = later2.delta(&later);
        assert_eq!(d2.count(), 2);
        assert!(d2.min() <= 40 && d2.max() >= 1_000_000);
        assert!(d2.max() <= bucket_upper(bucket_index(1_000_000)));
    }

    #[test]
    fn delta_is_underflow_safe() {
        let mut earlier = HistData::default();
        let mut later = HistData::default();
        for v in [10u64, 20, 500] {
            earlier.record(v);
            later.record(v);
        }
        later.record(7);
        // Same stream: zero delta.
        assert_eq!(later.delta(&later), HistData::default());
        // Reversed arguments (skewed snapshot pair) saturate, never panic.
        let reversed = earlier.delta(&later);
        assert_eq!(reversed, HistData::default());
        // Empty sides.
        assert_eq!(HistData::default().delta(&earlier), HistData::default());
        let from_empty = later.delta(&HistData::default());
        assert_eq!(from_empty.count(), later.count());
        assert_eq!(from_empty.bucket_counts(), later.bucket_counts());
    }

    #[test]
    fn atomic_snapshot_matches_plain() {
        let ah = AtomicHist::new();
        let mut plain = HistData::default();
        for v in [0u64, 1, 31, 32, 1_000, 65_536, 10_000_000] {
            ah.record(v);
            plain.record(v);
        }
        assert_eq!(ah.snapshot(), plain);
        // Empty atomic snapshots normalize to the default.
        assert_eq!(AtomicHist::new().snapshot(), HistData::default());
    }
}
